"""Modulation playground: sweep schemes/SNRs and inspect per-bit protection.

    PYTHONPATH=src python examples/modulation_playground.py
"""

import jax
import jax.numpy as jnp

from repro.core import modulation as M

key = jax.random.PRNGKey(0)

print("=== BER vs SNR (Rayleigh uplink) ===")
print(f"{'snr_db':>7} " + " ".join(f"{n:>9}" for n in M.MOD_SCHEMES))
for snr in (0, 5, 10, 15, 20, 25, 30):
    row = [float(M.measure_ber(key, s, snr, n_symbols=1 << 14))
           for s in M.MOD_SCHEMES.values()]
    print(f"{snr:7.0f} " + " ".join(f"{b:9.4f}" for b in row))

print("\n=== per-bit error rate within a Gray 256-QAM symbol ===")
scheme = M.MOD_SCHEMES["256qam"]
k = scheme.bits_per_symbol
sym = jax.random.randint(key, (1 << 16,), 0, scheme.points).astype(jnp.uint32)
tx = M.modulate(sym, scheme)
k1, k2 = jax.random.split(key)
noise = 0.08 * (jax.random.normal(k1, sym.shape) + 1j * jax.random.normal(k2, sym.shape))
rx = M.demod_hard(tx + noise.astype(jnp.complex64), scheme)
diff = sym ^ rx
for j in range(k):
    r = float(jnp.mean((diff >> (k - 1 - j)) & 1))
    bar = "#" * int(r * 2500)
    print(f"bit {j} ({'most significant' if j == 0 else 'least significant' if j == k - 1 else '...':>17}): {r:.4f} {bar}")
print("\nMSB-first float packing rides this gradient of protection: the "
      "float sign/exponent land on the best-protected constellation bits.")
