"""End-to-end FL driver: the paper's experiment (Sec. V) at laptop scale.

    PYTHONPATH=src python examples/fl_mnist_e2e.py [--clients 40] [--rounds 120]

Trains the paper's 2conv+2fc CNN with FedSGD over a simulated wireless
uplink under four transports (perfect / naive / approx / ecrt) and prints
accuracy-vs-airtime trajectories (Fig. 3's comparison).
"""

import argparse
import dataclasses

from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import latency as LAT
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.loop import run_fl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--snr-db", type=float, default=10.0)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--modulation", default="qpsk")
    args = ap.parse_args()

    (img, lab), (ti, tl) = synth_mnist.train_test(300, 60)
    parts = partition.non_iid_partition(img, lab, n_clients=args.clients)
    cx, cy = partition.stack_clients(parts, per_client=96)
    cfg = dataclasses.replace(cnn_config(), lr=args.lr)
    print(f"{args.clients} clients, non-iid 2 digits each, SNR={args.snr_db} dB")

    for mode in ("perfect", "naive", "approx", "ecrt"):
        e_tx = 1.0
        if mode == "ecrt":
            e_tx = LAT.calibrate_ecrt(args.snr_db, args.modulation,
                                      n_codewords=48, max_tx=6)
        tcfg = T.TransportConfig(
            mode=mode, modulation=args.modulation,
            channel=CH.ChannelConfig(snr_db=args.snr_db),
            simulate_fec=False, ecrt_expected_tx=float(e_tx))
        res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=args.rounds,
                     batch_per_round=32, eval_every=max(2, args.rounds // 10))
        traj = " ".join(f"{a:.2f}@{t:.1f}s" for a, t in
                        zip(res.accuracy, res.airtime_s))
        print(f"\n{mode:8s} final={res.final_accuracy:.3f} "
              f"airtime={res.airtime_s[-1]:.1f}s wall={res.wall_s:.0f}s")
        print(f"  acc@air: {traj}")


if __name__ == "__main__":
    main()
