"""Train a ~100M-param transformer with the approximate-uplink all-reduce.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_llm_approx.py --steps 200

Each of the 4 data shards plays a client cohort: its gradients pass through
an independently-faded QPSK channel (bit-30 clamp, no FEC) before the psum.
This is the production-mesh pattern from launch/steps.py at host scale.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data.tokens import TokenStream
from repro.launch import steps as S
from repro.models import registry as R
from repro.optim.sgd import sgd as make_sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--snr-db", type=float, default=15.0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param qwen2-family config
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
        d_ff=2048, vocab_size=32000)
    n_dev = len(jax.devices())
    dshape = (n_dev // 2, 2) if n_dev >= 4 else (n_dev, 1)
    mesh = jax.make_mesh(dshape, ("data", "model"))

    tcfg = T.TransportConfig(mode="approx",
                             channel=CH.ChannelConfig(snr_db=args.snr_db))
    opt = make_sgd(3e-2)
    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    n = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"model {n/1e6:.0f}M params, mesh {dict(mesh.shape)}, "
          f"uplink approx@{args.snr_db}dB")

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch)
    opt_state = opt.init(params)
    with jax.set_mesh(mesh):
        step = jax.jit(S.make_train_step_approx(cfg, opt, tcfg, mesh))
        for i in range(args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
            key, sk = jax.random.split(key)
            params, opt_state, loss, stats = step(params, opt_state, batch, sk)
            if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(loss):.4f} "
                      f"uplink_ber {float(stats.ber):.4f} ({time.time()-t0:.2f}s)")


if __name__ == "__main__":
    main()
