"""Batched serving example: greedy decode with full and ring KV caches.

    PYTHONPATH=src python examples/serve_batched.py --arch falcon-mamba-7b

Runs reduced variants of three families (attention, SSM, hybrid) through
the serve_step path used by the decode_32k / long_500k dry-run shapes.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as S
from repro.models import registry as R


def decode(arch: str, batch: int, gen: int, ring: bool):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    cache_len = cfg.decode_window if ring else gen + 8
    cache = R.init_cache(cfg, batch, cache_len)
    step = jax.jit(S.make_serve_step(cfg, ring=ring))
    tok = jax.random.randint(key, (batch, 1), 0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    for pos in range(gen):
        tok, cache = step(params, cache, tok, jnp.int32(pos))
    dt = time.time() - t0
    print(f"{arch:24s} ring={ring!s:5s} {batch * gen:5d} tokens "
          f"in {dt:5.2f}s ({batch * gen / dt:7.1f} tok/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [
        "qwen2-1.5b", "falcon-mamba-7b", "recurrentgemma-2b"]
    for arch in archs:
        cfg = get_config(arch)
        decode(arch, args.batch, args.gen, ring=False)
        if cfg.family in ("dense", "moe", "vlm"):
            decode(arch, args.batch, args.gen, ring=True)


if __name__ == "__main__":
    main()
