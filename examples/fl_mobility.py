"""FL under mobility: the link-adaptation subsystem end to end.

    PYTHONPATH=src python examples/fl_mobility.py [--scenario vehicular]
        [--clients 24] [--rounds 60] [--compare]

Runs FedSGD where each client's link quality evolves round to round
(``repro.link.dynamics``), the PS estimates SNR from pilots, and a
threshold+hysteresis policy picks each client's transport per round —
ECRT when the channel is bad, the paper's MSB-protected Gray-QAM uncoded
scheme (up to 256-QAM) when it is "satisfactory". Prints the per-round
mode mix / SNR telemetry and, with ``--compare``, the fixed-mode baselines
under the same channel trajectories.

``--downlink OFFSET_DB`` adds the noisy broadcast leg: the global model
reaches each client through its own downlink channel at the uplink SNR +
OFFSET_DB, with per-client downlink modes picked from the same policy table
(``DownlinkConfig(adaptive=True)``); the telemetry grows downlink airtime
and residual-BER columns.

``--compress RATIO`` turns on sparse top-k + error-feedback uplinks at the
given kept fraction (``repro.compress``): each round every client transmits
only the largest coordinates of its accumulated gradient, values through
the approx pipeline and indices on protected Gray-MSB bits. The telemetry
grows compression-ratio / EF-residual-norm / bits-on-air columns (a
scenario whose policy sets ``compress_ratios`` — e.g. ``iot-lowrate`` —
compresses deeper in the low-SNR modes).

``--buffered K`` switches to the asynchronous FedBuff-style engine
(``repro.fl.async_engine``): clients run on their own event clocks (compute
time + airtime; scenarios like ``metro-rush`` add churn and idle gaps) and
the server aggregates every K arrivals with polynomially staleness-damped
weights. The telemetry's ``round`` column then counts dispatched waves.

``--ledger PATH`` attaches the JSONL run ledger (``repro.obs``): a config/
provenance manifest followed by every round record and eval point, flushed
as written — summarize or diff ledgers with ``python -m tools.report``.
With ``--buffered``, ``--trace PATH`` additionally exports a Chrome/
Perfetto trace of the event clock (dispatch waves, per-client compute and
uplink spans, buffer fill, aggregations) and ``--timers`` prints per-phase
wall-clock timers with the first (compile) call split from steady state.

``--metrics`` attaches the constant-memory per-client distribution
sketches (``repro.obs.RoundSketcher``): every round's SNR / BER / airtime
/ mode-dwell population folds into mergeable bucket histograms on device,
and a run-level quantile table (p50/p90/p99/mean per metric) prints after
the run. With ``--ledger`` the per-round sketch groups also land in the
ledger (schema v2) for ``tools/report.py`` / ``tools/metrics_export.py``.
None of the observability sinks changes the run's numbers.
"""

import argparse
import dataclasses

from repro.compress import CompressionConfig
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.async_engine import run_fl_buffered
from repro.fl.loop import run_fl
from repro.link import policy as policy_lib
from repro.link import scenario as scenario_lib
from repro.obs import PhaseTimers, RoundSketcher, TraceRecorder


def _run(cfg, tcfg, data, scen, rounds, compression=None, buffer_k=None,
         **obs_kw):
    cx, cy, ti, tl = data
    kw = dict(n_rounds=rounds, batch_per_round=32,
              eval_every=max(2, rounds // 10), scenario=scen,
              compression=compression, **obs_kw)
    if buffer_k is not None:
        return run_fl_buffered(cfg, tcfg, cx, cy, ti, tl,
                               buffer_k=buffer_k, staleness="polynomial",
                               **kw)
    kw.pop("trace", None)  # event traces exist only on the event clock
    return run_fl(cfg, tcfg, cx, cy, ti, tl, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="vehicular",
                    choices=scenario_lib.list_scenarios())
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--compare", action="store_true",
                    help="also run fixed-approx and fixed-ECRT baselines")
    ap.add_argument("--downlink", type=float, default=None, metavar="OFFSET_DB",
                    help="add a noisy adaptive broadcast downlink at uplink "
                         "SNR + OFFSET_DB (per-client mode via the policy "
                         "table)")
    ap.add_argument("--compress", type=float, default=None, metavar="RATIO",
                    help="sparse top-k + error-feedback uplinks keeping this "
                         "fraction of coordinates (e.g. 0.02 = 50x fewer "
                         "slots); indices ride protected Gray-MSB bits")
    ap.add_argument("--buffered", type=int, default=None, metavar="K",
                    help="asynchronous FedBuff-style engine: aggregate "
                         "every K arrivals with staleness-damped weights "
                         "instead of closing a synchronous round barrier")
    ap.add_argument("--ledger", default=None, metavar="PATH",
                    help="write a JSONL run ledger (manifest + per-round "
                         "records + eval curve); inspect it with "
                         "`python -m tools.report PATH`")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --buffered: export a Chrome/Perfetto event "
                         "trace of the run (load at ui.perfetto.dev)")
    ap.add_argument("--timers", action="store_true",
                    help="collect per-phase wall-clock timers (first/"
                         "compile call split from steady state) and print "
                         "the table")
    ap.add_argument("--metrics", action="store_true",
                    help="attach per-client distribution sketches and "
                         "print the run-level quantile table (p50/p90/p99 "
                         "per metric); with --ledger the per-round groups "
                         "also land in the ledger")
    args = ap.parse_args()
    if args.trace is not None and args.buffered is None:
        ap.error("--trace requires --buffered (spans live on the async "
                 "engine's event clock)")

    (img, lab), (ti, tl) = synth_mnist.train_test(300, 60)
    parts = partition.non_iid_partition(img, lab, n_clients=args.clients)
    cx, cy = partition.stack_clients(parts, per_client=96)
    data = (cx, cy, ti, tl)
    cfg = dataclasses.replace(cnn_config(), lr=args.lr)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))

    scen = scenario_lib.get_scenario(args.scenario)
    if args.downlink is not None:
        scen = dataclasses.replace(scen, downlink=scenario_lib.DownlinkConfig(
            mode="approx", snr_offset_db=args.downlink, adaptive=True))
    compression = (CompressionConfig(method="topk", ratio=args.compress)
                   if args.compress is not None else scen.compression)
    print(f"scenario '{scen.name}': {scen.description}")
    mode_names = ["/".join(m) for m in scen.policy.modes]
    print(f"{args.clients} clients, modes: {mode_names}, "
          f"thresholds {scen.policy.thresholds_db} dB "
          f"(hysteresis {scen.policy.hysteresis_db} dB)")
    if scen.downlink is not None:
        print(f"downlink: {scen.downlink.mode} at uplink SNR "
              f"{scen.downlink.snr_offset_db:+.1f} dB "
              f"(adaptive={scen.downlink.adaptive})")
    if compression is not None:
        ratios = (scen.policy.compress_ratios
                  if scen.policy.compress_ratios is not None
                  else f"flat {compression.ratio}")
        print(f"compression: {compression.method}+EF, ratios {ratios}, "
              f"header {compression.header}")
    print()

    if args.buffered is not None:
        print(f"buffered async engine: aggregate every K={args.buffered} "
              "arrivals, polynomial staleness weights\n")
    obs_kw = {}
    if args.ledger is not None:
        obs_kw["ledger"] = args.ledger
    if args.trace is not None:
        obs_kw["trace"] = TraceRecorder(args.trace)
    timers = PhaseTimers() if args.timers else None
    if timers is not None:
        obs_kw["phase_timers"] = timers
    sketcher = RoundSketcher(args.clients) if args.metrics else None
    if sketcher is not None:
        obs_kw["sketches"] = sketcher
    res = _run(cfg, tcfg, data, scen, args.rounds, compression,
               buffer_k=args.buffered, **obs_kw)
    dl_cols = "  dl airtime   dl BER" if scen.downlink is not None else ""
    cp_cols = ("    kept  res.norm  bits-on-air" if compression is not None
               else "")
    print(f"{'round':>5} {'mean SNR':>9} {'est SNR':>8} {'active':>6} "
          f"{'airtime':>9}{dl_cols}{cp_cols}  mode mix {mode_names}")
    step = max(1, len(res.link) // 12)
    for t in res.link[::step]:
        dl = (f" {t['downlink_airtime_s'] * 1e3:9.2f}ms {t['downlink_ber']:.1e}"
              if "downlink_airtime_s" in t else "")
        cp = (f"  {t['comp_ratio']:6.3f} {t['comp_residual_norm']:9.3f} "
              f"{t['comp_bits_on_air']:12.3g}"
              if "comp_ratio" in t else "")
        print(f"{t['round']:5d} {t['mean_snr_db']:8.1f}dB "
              f"{t['mean_est_db']:7.1f}dB {t['n_active']:6d} "
              f"{t['airtime_s'] * 1e3:8.2f}ms{dl}{cp}  {t['mode_counts']}")
    clock = (f" event_clock={res.event_s[-1]:.2f}s" if res.event_s else "")
    print(f"\nadaptive: final_acc={res.final_accuracy:.3f} "
          f"airtime={res.airtime_s[-1]:.2f}s{clock} wall={res.wall_s:.0f}s")
    if timers is not None:
        print("\n" + timers.report())
    if sketcher is not None:
        print("\nper-client sketches (run-level):")
        for name, sk in sorted(sketcher.run.items()):
            if sk.total == 0:
                continue
            print(f"  {name:<14} n={sk.total:<6d} "
                  f"p50={sk.quantile(0.5):<10.4g} "
                  f"p90={sk.quantile(0.9):<10.4g} "
                  f"p99={sk.quantile(0.99):<10.4g} mean={sk.mean():.4g}")
    if args.ledger is not None:
        print(f"\nledger: {args.ledger} "
              f"(summarize: python -m tools.report {args.ledger})")
    if args.trace is not None:
        print(f"trace: {args.trace} (load at https://ui.perfetto.dev)")

    if args.compare:
        for arm, pol in (("fixed approx/qpsk",
                          policy_lib.fixed_policy("approx", "qpsk")),
                         ("fixed ecrt/qpsk",
                          policy_lib.fixed_policy("ecrt", "qpsk"))):
            r = _run(cfg, tcfg, data,
                     dataclasses.replace(scen, policy=pol), args.rounds,
                     compression, buffer_k=args.buffered)
            print(f"{arm}: final_acc={r.final_accuracy:.3f} "
                  f"airtime={r.airtime_s[-1]:.2f}s")


if __name__ == "__main__":
    main()
