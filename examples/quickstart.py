"""Quickstart: send a gradient through the approximate wireless uplink.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core mechanics in ~40 lines: a bounded gradient survives
a 10 dB Rayleigh channel with no FEC (bit-30 clamp keeps every received
value finite and < 2), while naive transmission produces NaN/garbage.
"""

import jax
import jax.numpy as jnp

from repro.core import ChannelConfig, TransportConfig, transmit_flat
from repro.core.latency import PhyTimings, round_airtime

key = jax.random.PRNGKey(0)
grad = jax.random.normal(key, (100_000,)) * 0.05  # typical gradient scale

for mode in ("perfect", "naive", "approx", "ecrt"):
    cfg = TransportConfig(
        mode=mode,
        modulation="qpsk",
        channel=ChannelConfig(snr_db=10.0, fading="rayleigh"),
        simulate_fec=False,          # ecrt: use the calibrated airtime model
        ecrt_expected_tx=1.1,
    )
    out, stats = jax.jit(lambda g, k: transmit_flat(g, k, cfg))(grad, key)
    err = jnp.abs(out - grad)
    air = float(round_airtime(stats, PhyTimings(), mode)) * 1e3
    print(f"{mode:8s} ber={float(stats.ber):.4f} "
          f"mean|err|={float(jnp.nanmean(err)):.2e} "
          f"max|out|={float(jnp.abs(out).max()):9.3g} "
          f"finite={bool(jnp.isfinite(out).all())!s:5s} airtime={air:7.2f} ms")

print("\nThe paper's receiver prior: any gradient decodes to a finite value "
      "in (-2, 2); errors stay small enough for FedSGD to converge, and the "
      "uplink needs no FEC airtime (compare the ecrt row).")
