"""bench-diff: regression sentry over ``BENCH_*.json`` artifacts.

Turns the benchmark artifacts from write-only outputs into an enforced
perf/accuracy trajectory: diff a freshly-produced artifact against a
committed baseline under ``benchmarks/baselines/`` (or any two artifacts,
or a pair of run ledgers) using **per-key tolerance specs**, and exit
non-zero on drift so CI fails the PR that caused it.

Usage::

    # current vs an explicit baseline
    python -m tools.bench_diff BENCH_kernel_throughput.json \
        benchmarks/baselines/BENCH_kernel_throughput.json

    # each artifact vs its committed baseline of the same name
    python -m tools.bench_diff --against-baselines \
        BENCH_kernel_throughput.json BENCH_async_fl.json

    # two run ledgers (compares manifest fingerprint + summary fields)
    python -m tools.bench_diff run_a.jsonl run_b.jsonl

Tolerance specs live in ``benchmarks/baselines/tolerances.json``: one
entry per artifact basename mapping dotted key paths to a rule —
``{"equals": v}`` (exact expected value), ``{"rel": r}`` /
``{"abs": a}`` (relative/absolute drift vs the baseline value),
``{"min": m}`` / ``{"max": m}`` (absolute floor/ceiling on the current
value). Keys absent from the spec are informational only (wall-clock
timings vary across machines and must not gate), but a spec'd key
missing from the current artifact is always drift. Exit codes: 0 = no
drift, 1 = drift, 2 = unusable inputs.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = REPO_ROOT / "benchmarks" / "baselines"
DEFAULT_SPEC = BASELINE_DIR / "tolerances.json"

# Ledger pairs are compared on these summary fields with a shared default
# rule (overridable by a "_ledger" spec entry).
LEDGER_SUMMARY_RULES = {
    "summary.final_accuracy": {"abs": 0.1},
    "summary.airtime_s": {"rel": 0.05},
    "manifest.fingerprint": {"equals_baseline": True},
}


def flatten(obj, prefix: str = "") -> dict:
    """Flatten nested dicts/lists into ``{dotted.path: scalar}``."""
    out: dict = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}.{i}" if prefix else str(i)))
    else:
        out[prefix] = obj
    return out


def _load_artifact(path: pathlib.Path) -> dict:
    """Load one artifact: a BENCH json object, or a JSONL run ledger
    reduced to its ``manifest.*`` / ``summary.*`` views."""
    if path.suffix == ".jsonl":
        manifest, summary = {}, {}
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn final line: the tolerated crash case
                if obj.get("kind") == "manifest":
                    manifest = obj
                elif obj.get("kind") == "summary":
                    summary = obj
        return {"manifest": manifest, "summary": summary}
    with open(path) as f:
        obj = json.load(f)
    if not isinstance(obj, dict):
        raise ValueError(f"{path}: top level is not an object")
    return obj


def check_key(key: str, rule: dict, cur, base) -> str | None:
    """Apply one tolerance rule; returns a drift message or ``None``."""
    if cur is None:
        return f"{key}: missing from current artifact (baseline: {base!r})"
    if rule.get("equals_baseline"):
        if cur != base:
            return f"{key}: {cur!r} != baseline {base!r}"
        return None
    if "equals" in rule:
        if cur != rule["equals"]:
            return f"{key}: {cur!r} != expected {rule['equals']!r}"
        return None
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        return f"{key}: non-numeric value {cur!r} under a numeric rule"
    if "min" in rule and cur < rule["min"]:
        return f"{key}: {cur:.6g} < floor {rule['min']:.6g}"
    if "max" in rule and cur > rule["max"]:
        return f"{key}: {cur:.6g} > ceiling {rule['max']:.6g}"
    if "rel" in rule or "abs" in rule:
        if base is None or not isinstance(base, (int, float)) \
                or isinstance(base, bool):
            return (f"{key}: baseline has no numeric value "
                    f"({base!r}) for a rel/abs rule")
        delta = abs(cur - base)
        bound = rule.get("abs", 0.0) + rule.get("rel", 0.0) * abs(base)
        if delta > bound:
            return (f"{key}: {cur:.6g} drifted from baseline {base:.6g} "
                    f"(|delta| {delta:.3g} > allowed {bound:.3g})")
    return None


def diff(current: pathlib.Path, baseline: pathlib.Path,
         spec: dict) -> tuple[list[str], int]:
    """Diff one artifact pair; returns ``(drift messages, keys checked)``.

    The spec entry is selected by the baseline's basename (falling back to
    the current's); ledger pairs use the built-in summary rules merged
    under any ``"_ledger"`` entry.
    """
    cur = flatten(_load_artifact(current))
    base = flatten(_load_artifact(baseline))
    if current.suffix == ".jsonl":
        rules = dict(LEDGER_SUMMARY_RULES)
        rules.update(spec.get("_ledger", {}))
    else:
        rules = spec.get(baseline.name) or spec.get(current.name)
        if rules is None:
            raise ValueError(
                f"no tolerance spec for {baseline.name!r} "
                f"(add it to {DEFAULT_SPEC.name})")
    problems = []
    for key, rule in sorted(rules.items()):
        msg = check_key(key, rule, cur.get(key), base.get(key))
        if msg is not None:
            problems.append(f"{current}: {msg}")
    return problems, len(rules)


def main(argv=None) -> int:
    """CLI entry point; returns the exit code (0 ok / 1 drift / 2 usage)."""
    ap = argparse.ArgumentParser(
        description="Diff BENCH_*.json artifacts (or run-ledger pairs) "
                    "against tolerance specs; non-zero exit on drift")
    ap.add_argument("paths", nargs="+",
                    help="CURRENT BASELINE — or, with --against-baselines, "
                         "one or more artifacts to check against "
                         "benchmarks/baselines/<name>")
    ap.add_argument("--against-baselines", action="store_true",
                    help="compare each artifact against the committed "
                         "baseline of the same basename")
    ap.add_argument("--spec", default=str(DEFAULT_SPEC),
                    help="tolerance spec json (default: "
                         "benchmarks/baselines/tolerances.json)")
    args = ap.parse_args(argv)
    try:
        with open(args.spec) as f:
            spec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: unreadable spec {args.spec}: {e}",
              file=sys.stderr)
        return 2
    if args.against_baselines:
        pairs = [(pathlib.Path(p), BASELINE_DIR / pathlib.Path(p).name)
                 for p in args.paths]
    else:
        if len(args.paths) != 2:
            print("bench_diff: need exactly CURRENT and BASELINE "
                  "(or use --against-baselines)", file=sys.stderr)
            return 2
        pairs = [(pathlib.Path(args.paths[0]), pathlib.Path(args.paths[1]))]
    drifted = False
    for current, baseline in pairs:
        try:
            problems, checked = diff(current, baseline, spec)
        except (OSError, ValueError) as e:
            print(f"bench_diff: {e}", file=sys.stderr)
            return 2
        if problems:
            drifted = True
            for p in problems:
                print(f"DRIFT {p}")
        else:
            print(f"OK {current} vs {baseline} ({checked} keys checked)")
    return 1 if drifted else 0


if __name__ == "__main__":
    raise SystemExit(main())
