"""Render and diff FL run ledgers (``repro.obs`` JSONL files).

One ledger -> a run summary::

    PYTHONPATH=src python -m tools.report out.jsonl

prints the manifest (engine, algorithm, scenario, fingerprint, provenance),
the accuracy-vs-airtime eval curve, the aggregate link-mode histogram, the
per-leg BER aggregates, the run-level sketch quantile table with ASCII
histograms (when the run attached ``sketches=``), and the phase-timer
table when the run collected one.

Two ledgers -> a diff::

    PYTHONPATH=src python -m tools.report a.jsonl b.jsonl

lines the two runs up on the config fingerprint (a mismatch is reported,
not fatal — diffing across configs is the point of the tool), then compares
final accuracy, total airtime, accuracy at the smaller run's airtime
budget, mode histograms, and mean BER per leg.
"""

from __future__ import annotations

import argparse

from repro.obs import ledger as obs_ledger
from repro.obs import sketch as sketch_lib


def _fmt(v, digits: int = 4) -> str:
    """Compact scalar formatting for table cells."""
    if isinstance(v, float):
        return f"{v:.{digits}g}"
    return str(v)


def _table(rows: list, headers: list) -> str:
    """Fixed-width text table (no external deps)."""
    cells = [[_fmt(c) for c in row] for row in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in cells:
        out.append("  ".join(c.rjust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def mode_histogram(data: obs_ledger.LedgerData) -> list | None:
    """Summed per-mode client counts across the run (``None`` when the run
    had no scenario link telemetry)."""
    counts = None
    for rec in data.rounds:
        if rec.mode_counts is None:
            continue
        if counts is None:
            counts = [0] * len(rec.mode_counts)
        for i, c in enumerate(rec.mode_counts):
            counts[i] += c
    return counts


def ber_per_leg(data: obs_ledger.LedgerData) -> dict:
    """Mean per-leg BER over the rounds that recorded it (uplink BER comes
    from the observability ``uplink_*`` fields, downlink from the link
    telemetry)."""
    out = {}
    up = [r.uplink_ber for r in data.rounds if r.uplink_ber is not None]
    down = [r.downlink_ber for r in data.rounds
            if r.downlink_ber is not None]
    if up:
        out["uplink"] = sum(up) / len(up)
    if down:
        out["downlink"] = sum(down) / len(down)
    return out


def collect_sketches(data: obs_ledger.LedgerData) -> dict:
    """Run-level :class:`~repro.obs.sketch.Sketch` objects for a ledger.

    Prefers the summary line's ``sketches`` group; a crashed run (no
    summary) falls back to merging the per-round groups — the merge is
    element-wise count addition, so both paths agree exactly.
    """
    group = (data.summary or {}).get("sketches")
    if group:
        return {m: sketch_lib.Sketch.from_dict(d)
                for m, d in group.items()}
    out: dict = {}
    for rec in data.rounds:
        if not rec.sketches:
            continue
        for m, d in rec.sketches.items():
            if m == "exemplars":
                continue
            sk = sketch_lib.Sketch.from_dict(d)
            out[m] = out[m].merge(sk) if m in out else sk
    return out


def _ascii_hist(sk: sketch_lib.Sketch, width: int = 48) -> str:
    """One-line ASCII density strip over the sketch's in-range buckets.

    Buckets rebin into ``width`` columns; glyph height scales with the
    column's share of the peak column (any non-empty column renders at
    least the lowest glyph).
    """
    n = sk.layout.n
    counts = [int(c) for c in sk.counts[:n]]
    cols = []
    for i in range(width):
        lo = i * n // width
        hi = max(lo + 1, (i + 1) * n // width)
        cols.append(sum(counts[lo:hi]))
    peak = max(cols)
    if peak == 0:
        return " " * width
    glyphs = " .:-=+*#%@"
    out = []
    for c in cols:
        level = 0 if c == 0 else max(1, c * (len(glyphs) - 1) // peak)
        out.append(glyphs[level])
    return "".join(out)


def print_sketches(data: obs_ledger.LedgerData) -> None:
    """Quantile table + per-metric ASCII histograms (no-op when the run
    collected no sketches)."""
    sketches = collect_sketches(data)
    if not sketches:
        return
    rows = [[m, sk.total, sk.quantile(0.5), sk.quantile(0.9),
             sk.quantile(0.99), sk.mean()]
            for m, sk in sorted(sketches.items()) if sk.total > 0]
    print("\nper-client sketches (run-level):")
    print(_table(rows, ["metric", "n", "p50", "p90", "p99", "mean"]))
    for m, sk in sorted(sketches.items()):
        if sk.total == 0:
            continue
        lay = sk.layout
        lo = f"{lay.lo:.3g}"
        hi = f"{lay.hi:.3g}"
        print(f"  {m:<14} {lo:>8} |{_ascii_hist(sk)}| {hi}")


def accuracy_at_airtime(data: obs_ledger.LedgerData,
                        budget_s: float) -> float | None:
    """Best accuracy reached within ``budget_s`` cumulative airtime."""
    best = None
    for ev in data.evals:
        if ev["airtime_s"] <= budget_s:
            best = ev["accuracy"] if best is None else max(best,
                                                           ev["accuracy"])
    return best


def summarize(path: str) -> None:
    """Print the single-ledger run summary."""
    data = obs_ledger.read_ledger(path)
    man = data.manifest
    prov = man.get("provenance", {})
    print(f"== run ledger: {path}")
    for key in ("engine", "algorithm", "scenario", "dispatch",
                "transport_mode", "n_rounds", "num_clients", "seed",
                "buffer_k", "staleness", "fingerprint"):
        if key in man:
            print(f"  {key:<16} {man[key]}")
    print(f"  {'provenance':<16} jax {prov.get('jax')}  "
          f"backend {prov.get('backend')}  git {prov.get('git_sha')}  "
          f"{prov.get('timestamp')}")
    print(f"  {'records':<16} {len(data.rounds)} rounds, "
          f"{len(data.events)} events, {len(data.evals)} evals")

    if data.evals:
        headers = ["round", "accuracy", "airtime_s"]
        rows = [[ev["round"], ev["accuracy"], ev["airtime_s"]]
                for ev in data.evals]
        if any("event_s" in ev for ev in data.evals):
            headers.append("event_s")
            for row, ev in zip(rows, data.evals):
                row.append(ev.get("event_s", ""))
        print()
        print(_table(rows, headers))

    hist = mode_histogram(data)
    if hist is not None:
        names = man.get("mode_names") or [f"mode{i}"
                                          for i in range(len(hist))]
        pairs = ", ".join(f"{n}: {c}" for n, c in zip(names, hist))
        print(f"\nmode histogram (client-rounds): {pairs}")
    ber = ber_per_leg(data)
    for leg, val in ber.items():
        print(f"mean {leg} BER: {val:.3e}")
    print_sketches(data)

    if data.summary:
        s = data.summary
        print(f"\nfinal accuracy {s.get('final_accuracy'):.4f}  "
              f"wall {s.get('wall_s', 0.0):.1f}s  "
              f"airtime {s.get('airtime_s', 0.0):.2f}s")
        phases = s.get("phases")
        if phases:
            rows = [[name, p["calls"], p["first_s"], p["steady_median_s"],
                     p["total_s"]] for name, p in phases.items()]
            print()
            print(_table(rows, ["phase", "calls", "first_s",
                                "steady_med_s", "total_s"]))
    else:
        print("\n(no summary line — the run did not finish)")


def diff(path_a: str, path_b: str) -> None:
    """Print the two-ledger comparison."""
    a = obs_ledger.read_ledger(path_a)
    b = obs_ledger.read_ledger(path_b)
    fa, fb = a.manifest.get("fingerprint"), b.manifest.get("fingerprint")
    print(f"== diff: {path_a} vs {path_b}")
    print(f"  fingerprints {'match' if fa == fb else 'DIFFER'}: "
          f"{fa} vs {fb}")
    rows = []
    for key in ("engine", "algorithm", "scenario", "n_rounds",
                "num_clients", "seed", "buffer_k", "staleness"):
        va, vb = a.manifest.get(key), b.manifest.get(key)
        if va is not None or vb is not None:
            rows.append([key, va, vb, "" if va == vb else "<>"])
    print(_table(rows, ["config", "a", "b", ""]))

    rows = []
    sa = a.summary or {}
    sb = b.summary or {}
    for label, va, vb in [
        ("final_accuracy", sa.get("final_accuracy"),
         sb.get("final_accuracy")),
        ("airtime_s", sa.get("airtime_s"), sb.get("airtime_s")),
        ("wall_s", sa.get("wall_s"), sb.get("wall_s")),
    ]:
        if va is not None and vb is not None:
            rows.append([label, va, vb, vb - va])
    if rows:
        print()
        print(_table(rows, ["metric", "a", "b", "b-a"]))

    # Accuracy at the smaller airtime budget: the honest
    # accuracy-vs-airtime comparison when total airtimes differ.
    if a.evals and b.evals:
        budget = min(a.evals[-1]["airtime_s"], b.evals[-1]["airtime_s"])
        aa = accuracy_at_airtime(a, budget)
        ab = accuracy_at_airtime(b, budget)
        if aa is not None and ab is not None:
            print(f"\naccuracy @ {budget:.2f}s airtime: "
                  f"a={aa:.4f}  b={ab:.4f}  (b-a: {ab - aa:+.4f})")

    for label, data in (("a", a), ("b", b)):
        hist = mode_histogram(data)
        if hist is not None:
            print(f"mode histogram [{label}]: {hist}")
    for label, data in (("a", a), ("b", b)):
        ber = ber_per_leg(data)
        if ber:
            pairs = "  ".join(f"{leg}={val:.3e}"
                              for leg, val in ber.items())
            print(f"BER per leg [{label}]: {pairs}")


def main() -> None:
    """CLI entry: one ledger summarizes, two ledgers diff."""
    ap = argparse.ArgumentParser(
        description="summarize one FL run ledger, or diff two")
    ap.add_argument("ledger", nargs="+",
                    help="1 (summary) or 2 (diff) JSONL ledger paths")
    args = ap.parse_args()
    if len(args.ledger) == 1:
        summarize(args.ledger[0])
    elif len(args.ledger) == 2:
        diff(args.ledger[0], args.ledger[1])
    else:
        ap.error("expected 1 or 2 ledger paths")


if __name__ == "__main__":
    main()
