"""Schema gate for the ``BENCH_*.json`` benchmark artifacts.

Every suite that writes a JSON report goes through
``benchmarks.common.write_bench_json``, which stamps the shared ``meta``
provenance block. This validator pins the contract from the consumer side:
each known artifact must carry **exactly** its expected top-level keys (a
missing key means the suite silently dropped a result; an extra key means
the schema drifted without this file being updated), and ``meta`` must
carry the full provenance key set.

Usage (CI runs it after the bench jobs)::

    python -m tools.bench_schema [FILE ...]

With no arguments, validates every known ``BENCH_*.json`` present in the
working directory (absent files are skipped — suites are independent).
Exit 1 on any problem.
"""

from __future__ import annotations

import json
import pathlib
import sys

# Keep in sync with repro.obs.ledger.PROVENANCE_KEYS (imported when the
# package is on the path; this literal keeps the tool standalone).
try:
    from repro.obs.ledger import PROVENANCE_KEYS as META_KEYS
except ImportError:  # pragma: no cover - PYTHONPATH=src not set
    META_KEYS = ("schema", "jax", "numpy", "python", "platform", "backend",
                 "git_sha", "timestamp")

# filename -> accepted top-level key sets (link_adaptation has two shapes:
# the full FL run, and the dispatch-only standalone invocation).
EXPECTED: dict[str, tuple[frozenset, ...]] = {
    "BENCH_async_fl.json": (frozenset({
        "clients", "scenario", "buffer_k", "arms", "tdma_barrier_s",
        "buffered_matches_sync_in_0p6x_time", "ledger", "meta"}),),
    "BENCH_compression.json": (frozenset({
        "clients", "rounds", "sparse_rounds", "scenarios",
        "topk_matches_dense_at_fifth_airtime", "meta"}),),
    "BENCH_fl_round.json": (frozenset({
        "snr_db", "clients", "rounds", "arms",
        "downlink_worse_than_uplink", "meta"}),),
    "BENCH_link_adaptation.json": (
        frozenset({"dispatch", "arms", "select_single_trace", "meta"}),
        frozenset({"dispatch", "meta"}),
    ),
    "BENCH_obs.json": (frozenset({
        "clients", "rounds", "scenario", "ledger", "trace",
        "ledger_rounds", "ledger_events", "track_types", "phases",
        "sinks_are_neutral", "meta"}),),
}


def validate_file(path: pathlib.Path) -> list[str]:
    """Problems with one artifact (empty list = valid)."""
    shapes = EXPECTED.get(path.name)
    if shapes is None:
        return [f"{path}: unknown benchmark artifact "
                f"(add it to tools/bench_schema.py EXPECTED)"]
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable: {e}"]
    if not isinstance(obj, dict):
        return [f"{path}: top level is {type(obj).__name__}, expected object"]
    keys = frozenset(obj)
    if keys not in shapes:
        best = min(shapes, key=lambda s: len(s ^ keys))
        problems = []
        for k in sorted(best - keys):
            problems.append(f"{path}: missing top-level key {k!r}")
        for k in sorted(keys - best):
            problems.append(f"{path}: unexpected top-level key {k!r}")
        return problems
    meta = obj.get("meta")
    if not isinstance(meta, dict):
        return [f"{path}: 'meta' is not an object"]
    return [f"{path}: meta missing key {k!r}" for k in META_KEYS
            if k not in meta]


def main(argv: list[str] | None = None) -> int:
    """CLI entry; returns the process exit code."""
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        paths = [pathlib.Path(a) for a in argv]
        missing = [p for p in paths if not p.exists()]
        if missing:
            for p in missing:
                print(f"{p}: no such file")
            return 1
    else:
        paths = [p for name in EXPECTED
                 if (p := pathlib.Path(name)).exists()]
        if not paths:
            print("bench-schema: no BENCH_*.json artifacts found")
            return 1
    problems = []
    for p in paths:
        problems.extend(validate_file(p))
    for msg in problems:
        print(msg)
    if problems:
        print(f"bench-schema: {len(problems)} problem(s)")
        return 1
    print(f"bench-schema: OK ({len(paths)} artifact(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
