"""Schema gate for the ``BENCH_*.json`` benchmark artifacts.

Thin CLI wrapper over the ``bench-schema`` repro-lint rule
(``tools.lint.rules.benchschema``), kept so the historical entry point —
``python -m tools.bench_schema [FILE ...]`` — and its exact output and
exit-code contract stay valid for CI. The ``EXPECTED`` shape table and
``validate_file`` now live with the rule; this module re-exports both for
backward compatibility.

With no arguments, validates every known ``BENCH_*.json`` present in the
working directory (absent files are skipped — suites are independent).
Exit 1 on any problem. Run ``python -m tools.lint`` for the full rule
suite.
"""

from __future__ import annotations

import pathlib
import sys

from tools.lint.rules.benchschema import (  # noqa: F401  (re-exports)
    EXPECTED,
    META_KEYS,
    validate_file,
)


def main(argv: list[str] | None = None) -> int:
    """CLI entry; returns the process exit code."""
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        paths = [pathlib.Path(a) for a in argv]
        missing = [p for p in paths if not p.exists()]
        if missing:
            for p in missing:
                print(f"{p}: no such file")
            return 1
    else:
        paths = [p for name in EXPECTED
                 if (p := pathlib.Path(name)).exists()]
        if not paths:
            print("bench-schema: no BENCH_*.json artifacts found")
            return 1
    problems = []
    for p in paths:
        problems.extend(validate_file(p))
    for msg in problems:
        print(msg)
    if problems:
        print(f"bench-schema: {len(problems)} problem(s)")
        return 1
    print(f"bench-schema: OK ({len(paths)} artifact(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
