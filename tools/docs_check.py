"""Documentation gate for the library packages and the tools
(``make docs-check``).

Thin CLI wrapper over the ``docstrings`` repro-lint rule
(``tools.lint.rules.docstrings``), kept so the historical entry point —
``python tools/docs_check.py`` — and its exact output/exit-code contract
stay valid for CI and the Makefile. The walk, the gating semantics, and
the message formats are unchanged: fails (exit 1) when a public module
under one of the gated packages lacks a module docstring, or a public
(non-underscore) top-level function, class, or public method of a public
class lacks its own. Run ``python -m tools.lint`` for the full rule
suite.
"""

from __future__ import annotations

import ast
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:  # script-style invocation: python tools/...
    sys.path.insert(0, str(_ROOT))

from tools.lint.rules.docstrings import docstring_problems  # noqa: E402

_SRC = _ROOT / "src" / "repro"
PACKAGES = [_SRC / "core", _SRC / "link", _SRC / "fl", _SRC / "compress",
            _SRC / "obs", _ROOT / "tools", _ROOT / "tools" / "lint",
            _ROOT / "tools" / "lint" / "rules"]


def check_module(path: pathlib.Path) -> list[str]:
    """Docstring problems of one module (empty list = clean)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    # the module-docstring problem historically prints without a line number
    return [f"{path}: {msg}" if msg == "missing module docstring"
            else f"{path}:{line}: {msg}"
            for line, msg in docstring_problems(tree)]


def main() -> int:
    """Walk the gated packages; exit 1 when any docstring is missing."""
    problems, n_modules = [], 0
    for pkg in PACKAGES:
        for path in sorted(pkg.glob("*.py")):
            if path.name.startswith("_") and path.name != "__init__.py":
                continue
            n_modules += 1
            problems.extend(check_module(path))
    for p in problems:
        print(p)
    if problems:
        print(f"docs-check: {len(problems)} problem(s)")
        return 1
    print(f"docs-check: OK ({n_modules} modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
