"""Documentation gate for the library packages and the tools
(``make docs-check``).

Fails (exit 1) when a public module under ``src/repro/core/``,
``src/repro/link/``, ``src/repro/fl/``, ``src/repro/compress/``,
``src/repro/obs/``, or ``tools/`` lacks a module docstring, or a public
(non-underscore) top-level function or class in one of those modules lacks
its own docstring. Public *methods* of public classes are also checked
(dunder methods other than ``__init__`` are exempt; ``__init__`` may
document itself in the class docstring instead, the repo's prevailing
style). Kept dependency-free: pure ``ast``.
"""

from __future__ import annotations

import ast
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SRC = _ROOT / "src" / "repro"
PACKAGES = [_SRC / "core", _SRC / "link", _SRC / "fl", _SRC / "compress",
            _SRC / "obs", _ROOT / "tools"]


def check_module(path: pathlib.Path) -> list[str]:
    """Docstring problems of one module (empty list = clean)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    problems = []
    if ast.get_docstring(tree) is None:
        problems.append(f"{path}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                problems.append(
                    f"{path}:{node.lineno}: public function "
                    f"`{node.name}` missing docstring")
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            if ast.get_docstring(node) is None:
                problems.append(
                    f"{path}:{node.lineno}: public class "
                    f"`{node.name}` missing docstring")
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                if sub.name.startswith("_"):  # incl. __init__: the class
                    continue                  # docstring documents it
                if ast.get_docstring(sub) is None:
                    problems.append(
                        f"{path}:{sub.lineno}: public method "
                        f"`{node.name}.{sub.name}` missing docstring")
    return problems


def main() -> int:
    """Walk the gated packages; exit 1 when any docstring is missing."""
    problems, n_modules = [], 0
    for pkg in PACKAGES:
        for path in sorted(pkg.glob("*.py")):
            if path.name.startswith("_") and path.name != "__init__.py":
                continue
            n_modules += 1
            problems.extend(check_module(path))
    for p in problems:
        print(p)
    if problems:
        print(f"docs-check: {len(problems)} problem(s)")
        return 1
    print(f"docs-check: OK ({n_modules} modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
