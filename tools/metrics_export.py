"""OpenMetrics exporter CLI: run ledger -> Prometheus text exposition.

Usage::

    PYTHONPATH=src python -m tools.metrics_export RUN_LEDGER.jsonl
    PYTHONPATH=src python -m tools.metrics_export RUN_LEDGER.jsonl -o out.prom

Rebuilds a :class:`repro.obs.metrics.MetricsRegistry` from the ledger
(round/event counters, final-accuracy/airtime gauges, and one merged
histogram per sketched metric — the per-round sketch groups merge by
element-wise count addition, so the export is identical no matter how the
rounds were batched) and writes the OpenMetrics text to stdout or a file.
The output is scrape-ready: ``# HELP``/``# TYPE`` metadata, cumulative
``_bucket{le=...}`` series, and a final ``# EOF``.
"""

from __future__ import annotations

import argparse
import sys


def export(path, out=None) -> str:
    """Render ``path``'s ledger as OpenMetrics text (also returns it)."""
    from repro.obs.metrics import registry_from_ledger

    text = registry_from_ledger(path).render()
    if out is None:
        sys.stdout.write(text)
    else:
        with open(out, "w") as f:
            f.write(text)
    return text


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(
        description="Export a run ledger as OpenMetrics text")
    ap.add_argument("ledger", help="path to a RUN_LEDGER.jsonl")
    ap.add_argument("-o", "--out", default=None,
                    help="output file (default: stdout)")
    args = ap.parse_args(argv)
    try:
        export(args.ledger, args.out)
    except (OSError, ValueError) as e:
        print(f"metrics_export: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
