"""docstrings: documentation coverage for the library packages and tools.

The migrated ``tools/docs_check.py`` gate, now a repro-lint rule: every
public module under the gated packages (``src/repro/core``,
``src/repro/link``, ``src/repro/fl``, ``src/repro/compress``,
``src/repro/obs``, ``tools``, ``tools/lint`` and its rules) must carry a
module docstring, and every public (non-underscore) top-level function,
class, and public method of a public class must carry its own. Dunder
methods other than ``__init__`` are exempt; ``__init__`` may document
itself in the class docstring instead (the repo's prevailing style).

``tools/docs_check.py`` remains as a thin CLI wrapper over this rule so
``make docs-check`` and the CI job keep working unchanged.
"""

from __future__ import annotations

import ast

from tools.lint.core import Finding, Module, Rule

GATED_DIRS = (
    "src/repro/core",
    "src/repro/link",
    "src/repro/fl",
    "src/repro/compress",
    "src/repro/obs",
    "tools",
    "tools/lint",
    "tools/lint/rules",
)


def docstring_problems(tree: ast.Module) -> list[tuple[int, str]]:
    """``(line, message)`` docstring problems of one parsed module."""
    problems: list[tuple[int, str]] = []
    if ast.get_docstring(tree) is None:
        problems.append((1, "missing module docstring"))
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                problems.append(
                    (node.lineno,
                     f"public function `{node.name}` missing docstring"))
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            if ast.get_docstring(node) is None:
                problems.append(
                    (node.lineno,
                     f"public class `{node.name}` missing docstring"))
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                if sub.name.startswith("_"):  # incl. __init__: the class
                    continue                  # docstring documents it
                if ast.get_docstring(sub) is None:
                    problems.append(
                        (sub.lineno,
                         f"public method `{node.name}.{sub.name}` "
                         "missing docstring"))
    return problems


class DocstringRule(Rule):
    """Docstring coverage over the gated packages."""

    name = "docstrings"
    description = ("public modules/functions/classes/methods under the "
                   "library packages and tools/ must carry docstrings")

    def __init__(self, gated_dirs: tuple[str, ...] = GATED_DIRS) -> None:
        """The gated directory list is injectable for tests."""
        self.gated_dirs = gated_dirs

    def _gated(self, relpath: str) -> bool:
        """Is the module directly inside one of the gated directories?

        Matches the historical ``docs_check`` semantics: non-recursive
        per-package globs, private modules (except ``__init__.py``)
        skipped.
        """
        parent, _, name = relpath.rpartition("/")
        if name.startswith("_") and name != "__init__.py":
            return False
        return parent in self.gated_dirs

    def check_module(self, module: Module) -> list[Finding]:
        """Report docstring problems for gated modules."""
        if not self._gated(module.relpath):
            return []
        return [self.finding(module, line, msg)
                for line, msg in docstring_problems(module.tree)]
