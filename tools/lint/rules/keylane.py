"""keylane: every ``fold_in`` must ride a registered key lane.

The repo's PRNG discipline derives every auxiliary draw (downlink, header,
selection, event layer) from reserved ``fold_in`` lanes declared in
``src/repro/core/keylanes.py``. This rule statically cross-checks call
sites against that table:

* the second argument of ``jax.random.fold_in`` must resolve to a
  registered lane symbol (``*_KEY_LANE``), optionally plus a constant
  offset and/or one client-index expression;
* bare integer literals and unregistered constants are findings — a raw
  ``fold_in(key, 12345)`` silently claims an unreserved lane;
* a constant offset must stay inside the lane's declared span;
* client-indexed sites (``LANE + i``, or a bare index under a generic
  schedule) must sit inside a scope whose chain contains a span guard —
  a ``keylanes.check_cohort(...)`` / ``keylanes.check_range(...)`` call,
  mirroring the broadcast leg's historical ``num_clients`` validation;
* the registry itself is re-checked for overlapping ``[base, base+span)``
  ranges per key space (defense in depth on top of the import-time
  rejection in ``reserve()``).

The registry is parsed, not imported — base/span expressions are folded by
a tiny constant evaluator, so the rule works on any checkout without
``PYTHONPATH`` set up.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib

from tools.lint.core import Finding, Module, REPO_ROOT, Rule

REGISTRY_PATH = REPO_ROOT / "src" / "repro" / "core" / "keylanes.py"

_GUARD_NAMES = {"check_cohort", "check_range"}

_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.LShift: lambda a, b: a << b,
    ast.RShift: lambda a, b: a >> b,
    ast.BitOr: lambda a, b: a | b,
    ast.BitAnd: lambda a, b: a & b,
    ast.FloorDiv: lambda a, b: a // b,
}


def const_int(node: ast.AST):
    """Fold an int-literal expression to its value (None if not constant)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        op = _BINOPS.get(type(node.op))
        if op is None:
            return None
        a, b = const_int(node.left), const_int(node.right)
        if a is None or b is None:
            return None
        return op(a, b)
    return None


@dataclasses.dataclass(frozen=True)
class LaneDecl:
    """One parsed ``reserve()`` declaration from the registry module."""

    symbol: str
    name: str
    base: int
    span: int
    space: str
    owner: str
    line: int

    @property
    def end(self) -> int:
        """One past the last reserved index."""
        return self.base + self.span


def parse_registry(path: pathlib.Path = REGISTRY_PATH,
                   ) -> tuple[dict[str, LaneDecl], list[str]]:
    """Parse lane declarations from ``keylanes.py``.

    Returns ``(lanes_by_symbol, problems)`` — problems are malformed
    declarations (non-constant base/span) the rule reports against the
    registry file itself.
    """
    lanes: dict[str, LaneDecl] = {}
    problems: list[str] = []
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        call = node.value
        if not (isinstance(target, ast.Name) and isinstance(call, ast.Call)):
            continue
        fn = call.func
        fn_name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else "")
        if fn_name != "reserve":
            continue
        kw = {k.arg: k.value for k in call.keywords}
        name = (call.args[0].value if call.args
                and isinstance(call.args[0], ast.Constant) else target.id)
        base = const_int(kw.get("base", ast.Constant(value=None)))
        span = const_int(kw.get("span", ast.Constant(value=None)))
        space = (kw["space"].value if "space" in kw
                 and isinstance(kw["space"], ast.Constant) else "round")
        owner = (kw["owner"].value if "owner" in kw
                 and isinstance(kw["owner"], ast.Constant) else "")
        if base is None or span is None:
            problems.append(
                f"line {node.lineno}: lane {target.id} has non-constant "
                f"base/span — the static checker cannot verify it")
            continue
        lanes[target.id] = LaneDecl(target.id, name, base, span, space,
                                    owner, node.lineno)
    return lanes, problems


def _terminal_name(node: ast.AST) -> str | None:
    """The rightmost identifier of a Name/Attribute chain."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _add_terms(node: ast.AST) -> list[ast.AST]:
    """Flatten a (possibly nested) ``+`` expression into its terms."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return _add_terms(node.left) + _add_terms(node.right)
    return [node]


class KeyLaneRule(Rule):
    """Cross-check ``jax.random.fold_in`` call sites against the registry."""

    name = "keylane"
    description = ("fold_in second arguments must resolve to a registered "
                   "key lane (src/repro/core/keylanes.py), with span-bound "
                   "guards on client-indexed sites")

    def __init__(self, registry_path: pathlib.Path = REGISTRY_PATH) -> None:
        """Parse the registry once; its own problems surface per run."""
        self.registry_path = registry_path
        self.lanes, self.registry_problems = parse_registry(registry_path)
        self._reported_registry = False

    # ----------------------------------------------------------- registry

    def _registry_findings(self) -> list[Finding]:
        """Registry-file findings: parse problems + overlapping ranges."""
        if self._reported_registry:
            return []
        self._reported_registry = True
        rel = self.registry_path
        try:
            rel = self.registry_path.relative_to(REPO_ROOT)
        except ValueError:
            pass
        out = [self.finding(str(rel), 1, p) for p in self.registry_problems]
        decls = sorted(self.lanes.values(), key=lambda d: (d.space, d.base))
        for a, b in zip(decls, decls[1:]):
            if a.space == b.space and b.base < a.end:
                out.append(self.finding(
                    str(rel), b.line,
                    f"lane {b.symbol} [{b.base}, {b.end}) overlaps "
                    f"{a.symbol} [{a.base}, {a.end}) in the "
                    f"{a.space!r} key space"))
        return out

    # ------------------------------------------------------------- checks

    def check_module(self, module: Module) -> list[Finding]:
        """Classify every ``fold_in`` second argument in the module."""
        findings = self._registry_findings()
        # scope chain: stack of enclosing function/lambda nodes
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(module.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if _terminal_name(node.func) != "fold_in":
                continue
            if len(node.args) < 2:
                continue
            findings.extend(self._check_arg(module, node, node.args[1],
                                            parents))
        return findings

    def _check_arg(self, module: Module, call: ast.Call, arg: ast.AST,
                   parents: dict[ast.AST, ast.AST]) -> list[Finding]:
        """Findings for one fold_in second argument."""
        terms = _add_terms(arg)
        symbols = [t for t in terms
                   if _terminal_name(t) in self.lanes]
        consts = [const_int(t) for t in terms]
        others = [t for t, c in zip(terms, consts)
                  if c is None and t not in symbols]
        const_sum = sum(c for c in consts if c is not None)

        if len(symbols) > 1:
            return [self.finding(
                module, call.lineno,
                "fold_in combines two registered lane symbols "
                f"({', '.join(_terminal_name(s) for s in symbols)}) — "
                "reserve a dedicated lane instead")]
        if not symbols:
            if not others:
                # pure integer expression: an unregistered lane
                return [self.finding(
                    module, call.lineno,
                    f"fold_in lane is a bare integer ({const_sum}) — "
                    "reserve it in repro.core.keylanes and use the symbol")]
            # bare index expression (generic schedules like client_keys):
            # legal only under a span guard in the enclosing scopes
            if self._guarded(call, parents):
                return []
            return [self.finding(
                module, call.lineno,
                "fold_in index is not derived from a registered lane "
                "symbol and no keylanes.check_cohort/check_range guard is "
                "in scope — unbounded indices can walk into another lane")]

        lane = self.lanes[_terminal_name(symbols[0])]
        out: list[Finding] = []
        if others:
            # client-indexed use: LANE (+ const) + i — guard required
            if not self._guarded(call, parents):
                out.append(self.finding(
                    module, call.lineno,
                    f"client-indexed use of lane {lane.symbol} has no "
                    "keylanes.check_cohort/check_range guard in scope — "
                    f"a cohort larger than {lane.span} would cross lanes"))
            if not 0 <= const_sum < lane.span:
                out.append(self.finding(
                    module, call.lineno,
                    f"constant offset {const_sum} walks out of lane "
                    f"{lane.symbol} (span {lane.span}) — reserve a "
                    "dedicated sub-lane"))
        elif not 0 <= const_sum < lane.span:
            out.append(self.finding(
                module, call.lineno,
                f"constant offset {const_sum} walks out of lane "
                f"{lane.symbol} (span {lane.span})"))
        return out

    def _guarded(self, call: ast.Call,
                 parents: dict[ast.AST, ast.AST]) -> bool:
        """Is a span-guard call present in any enclosing scope?"""
        scope: ast.AST | None = call
        while scope is not None:
            if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                for sub in ast.walk(scope):
                    if (isinstance(sub, ast.Call)
                            and _terminal_name(sub.func) in _GUARD_NAMES):
                        return True
            scope = parents.get(scope)
        return False
