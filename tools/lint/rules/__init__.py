"""Rule registry for repro-lint.

``ALL_RULES`` instantiates every rule in priority order; the CLI's
``--rules`` flag and ``--help`` epilog are driven from it, so adding a
module here is all a new rule needs.
"""

from tools.lint.rules.keylane import KeyLaneRule
from tools.lint.rules.determinism import DeterminismRule
from tools.lint.rules.jitpurity import JitPurityRule
from tools.lint.rules.dtype import DtypeDisciplineRule
from tools.lint.rules.docstrings import DocstringRule
from tools.lint.rules.benchschema import BenchSchemaRule


def all_rules():
    """Fresh instances of every registered rule, in priority order."""
    return [KeyLaneRule(), DeterminismRule(), JitPurityRule(),
            DtypeDisciplineRule(), DocstringRule(), BenchSchemaRule()]


__all__ = ["all_rules", "KeyLaneRule", "DeterminismRule", "JitPurityRule",
           "DtypeDisciplineRule", "DocstringRule", "BenchSchemaRule"]
