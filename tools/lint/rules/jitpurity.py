"""jit-purity: no host effects inside ``jax.jit``-compiled functions.

A function under ``jax.jit`` traces once and replays as XLA — host effects
inside it either fail at trace time on real inputs (``float()`` on a
tracer), silently run once instead of every call (``print``), or corrupt
closure state across retraces. This rule finds functions that are jitted —
via ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators, or wrapped as
``jax.jit(fn)`` / ``jax.jit(lambda ...)`` / ``jax.jit(self.method)`` — and
flags inside them:

* ``print(...)`` calls;
* ``.item()`` calls (device->host sync);
* ``float(x)`` / ``int(x)`` where ``x`` is a traced parameter of the
  jitted function or contains a nested call (e.g. ``float(jnp.mean(g))``);
* closure mutation: ``nonlocal`` / ``global`` declarations, and mutating
  method calls (``.append`` / ``.extend`` / ``.add`` / ``.update`` /
  ``.pop``) on names captured from an enclosing scope.
"""

from __future__ import annotations

import ast

from tools.lint.core import Finding, Module, Rule

_MUTATORS = {"append", "extend", "add", "update", "pop", "insert",
             "setdefault"}


def _terminal(node: ast.AST) -> str | None:
    """Rightmost identifier of a Name/Attribute chain."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """Does this expression denote ``jax.jit`` (or ``partial(jax.jit)``)?"""
    if _terminal(node) == "jit":
        return True
    if isinstance(node, ast.Call) and _terminal(node.func) == "partial":
        return any(_is_jit_expr(a) for a in node.args)
    return False


class JitPurityRule(Rule):
    """Flag host effects inside jit-compiled functions."""

    name = "jit-purity"
    description = ("no print/.item()/float()/int()-on-tracers/closure "
                   "mutation inside functions compiled with jax.jit")

    def check_module(self, module: Module) -> list[Finding]:
        """Collect the module's jitted functions, then scan their bodies."""
        jitted = self._jitted_functions(module.tree)
        findings: list[Finding] = []
        for fn in jitted:
            findings.extend(self._scan(module, fn))
        return findings

    # ----------------------------------------------------- jit detection

    def _jitted_functions(self, tree: ast.Module) -> list[ast.AST]:
        """Functions/lambdas compiled by jit, by decorator or by wrapping."""
        out: list[ast.AST] = []
        # name -> def node, and (class, name) -> method node for resolution
        defs: dict[str, ast.AST] = {}
        methods: dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)
            if isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        methods.setdefault(sub.name, sub)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jit_expr(d) for d in node.decorator_list):
                    out.append(node)
            elif isinstance(node, ast.Call) and _is_jit_expr(node.func) \
                    and node.args:
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    out.append(target)
                elif isinstance(target, ast.Name) \
                        and target.id in defs:
                    out.append(defs[target.id])
                elif isinstance(target, ast.Attribute) \
                        and target.attr in methods:
                    out.append(methods[target.attr])
        return out

    # ------------------------------------------------------------- scan

    def _scan(self, module: Module, fn: ast.AST) -> list[Finding]:
        """Findings inside one jitted function body."""
        findings: list[Finding] = []
        params = self._param_names(fn)
        local_names = self._assigned_names(fn) | params
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                findings.extend(self._check_node(module, node, params,
                                                 local_names))
        return findings

    def _param_names(self, fn: ast.AST) -> set[str]:
        """Parameter names of a function/lambda (traced inputs)."""
        a = fn.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)

    def _assigned_names(self, fn: ast.AST) -> set[str]:
        """Names bound inside the function (not closure captures)."""
        out: set[str] = set()
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name) \
                        and isinstance(node.ctx, ast.Store):
                    out.add(node.id)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    out.add(node.name)
        return out

    def _check_node(self, module: Module, node: ast.AST, params: set[str],
                    local_names: set[str]) -> list[Finding]:
        """Findings for one AST node inside a jitted body."""
        if isinstance(node, (ast.Nonlocal, ast.Global)):
            kind = "nonlocal" if isinstance(node, ast.Nonlocal) else "global"
            return [self.finding(
                module, node.lineno,
                f"`{kind}` mutation inside a jitted function — state "
                "written at trace time replays stale")]
        if not isinstance(node, ast.Call):
            return []
        fname = _terminal(node.func)
        if isinstance(node.func, ast.Name) and fname == "print":
            return [self.finding(
                module, node.lineno,
                "print() inside a jitted function runs at trace time "
                "only — use jax.debug.print or hoist it out")]
        if isinstance(node.func, ast.Attribute) and fname == "item" \
                and not node.args:
            return [self.finding(
                module, node.lineno,
                ".item() inside a jitted function forces a device->host "
                "sync — return the array instead")]
        if isinstance(node.func, ast.Name) and fname in ("float", "int") \
                and len(node.args) == 1:
            arg = node.args[0]
            is_param = isinstance(arg, ast.Name) and arg.id in params
            has_call = any(isinstance(n, ast.Call) for n in ast.walk(arg))
            if is_param or has_call:
                return [self.finding(
                    module, node.lineno,
                    f"{fname}() on a traced value inside a jitted "
                    "function fails at trace time — keep it an array")]
        if isinstance(node.func, ast.Attribute) and fname in _MUTATORS:
            base = node.func.value
            if isinstance(base, ast.Name) and base.id not in local_names:
                return [self.finding(
                    module, node.lineno,
                    f"`.{fname}()` on closure variable `{base.id}` inside "
                    "a jitted function mutates host state at trace time "
                    "only")]
        return []
