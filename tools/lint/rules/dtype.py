"""dtype-discipline: the wire format is 32-bit; float64 never rides it.

The approximate wire carries IEEE-754 float32 words (bitcast to uint32 and
modulated); a float64 sneaking into a wire-format module either doubles
airtime silently or, more likely, changes the bit pattern the goldens pin.
The sanctioned dtype set is *declared* — ``WIRE_DTYPES`` in
``src/repro/core/float_codec.py`` — and this rule parses it from there, so
the source of truth lives with the codec, not the linter. In the wire
modules the rule flags:

* dtype references outside the declared set (``np.float64``,
  ``jnp.float64``, ``"float64"``, dtype strings like ``"f8"``, and the
  Python ``float``/``int`` builtins used as a ``dtype=`` argument — host
  numpy resolves them to 64-bit);
* host-numpy array creation (``np.array`` / ``np.asarray`` / ``np.zeros``
  / ``np.ones`` / ``np.empty`` / ``np.full``) *without* an explicit dtype
  argument — numpy's implied default is float64.

Host-side stats reductions that legitimately accumulate in float64 carry
inline ``# lint: ignore[dtype-discipline]`` suppressions.
"""

from __future__ import annotations

import ast
import pathlib

from tools.lint.core import Finding, Module, REPO_ROOT, Rule

DECL_PATH = REPO_ROOT / "src" / "repro" / "core" / "float_codec.py"

WIRE_MODULES = (
    "src/repro/core/float_codec.py",
    "src/repro/core/modulation.py",
    "src/repro/core/channel.py",
    "src/repro/core/ecrt.py",
    "src/repro/core/transport.py",
    "src/repro/compress/framing.py",
    "src/repro/compress/sparsify.py",
    "src/repro/kernels/approx_channel.py",
    "src/repro/kernels/ref.py",
    "src/repro/kernels/ops.py",
)

_CREATORS = {"array", "asarray", "zeros", "ones", "empty", "full"}
_BANNED_STRINGS = {"float64", "f8", "double", "complex128", "c16"}


def parse_wire_dtypes(path: pathlib.Path = DECL_PATH) -> frozenset[str]:
    """The declared wire dtype set, parsed from the codec module's AST."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "WIRE_DTYPES" \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            return frozenset(
                e.value for e in node.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str))
    return frozenset()


def _terminal(node: ast.AST) -> str | None:
    """Rightmost identifier of a Name/Attribute chain."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class DtypeDisciplineRule(Rule):
    """Enforce the declared wire dtype set in wire-format modules."""

    name = "dtype-discipline"
    description = ("no float64 (explicit or numpy-implied) in wire-format "
                   "modules; allowed dtypes are declared as "
                   "float_codec.WIRE_DTYPES")

    def __init__(self, wire_modules: tuple[str, ...] = WIRE_MODULES,
                 decl_path: pathlib.Path = DECL_PATH) -> None:
        """Module list and declaration path are injectable for tests."""
        self.wire_modules = wire_modules
        self.wire_dtypes = parse_wire_dtypes(decl_path)

    def check_module(self, module: Module) -> list[Finding]:
        """Scan one module (no-op outside the wire-module list)."""
        if module.relpath not in self.wire_modules:
            return []
        findings: list[Finding] = []
        for node in ast.walk(module.tree):
            findings.extend(self._check_node(module, node))
        return findings

    def _check_node(self, module: Module, node: ast.AST) -> list[Finding]:
        """Findings for one AST node in a wire module."""
        # np.float64 / jnp.float64 / jnp.complex128 attribute references
        if isinstance(node, ast.Attribute):
            base = _terminal(node.value)
            if base in ("np", "numpy", "jnp") \
                    and node.attr in _BANNED_STRINGS:
                return [self.finding(
                    module, node.lineno,
                    f"{base}.{node.attr} in a wire-format module — the "
                    "wire dtype set is float_codec.WIRE_DTYPES")]
            # int dtypes are host index math; the 64-bit hazard the
            # goldens care about is float/complex payload precision
            if base in ("np", "numpy", "jnp") \
                    and node.attr.startswith(("float", "complex")) \
                    and node.attr not in self.wire_dtypes \
                    and node.attr != "float":
                return [self.finding(
                    module, node.lineno,
                    f"dtype {base}.{node.attr} is not in the declared "
                    "wire dtype set (float_codec.WIRE_DTYPES)")]
        # dtype= keyword carrying a banned string or the float builtin
        if isinstance(node, ast.keyword) and node.arg == "dtype":
            v = node.value
            if isinstance(v, ast.Constant) and v.value in _BANNED_STRINGS:
                return [self.finding(
                    module, node.lineno,
                    f'dtype="{v.value}" in a wire-format module')]
            if isinstance(v, ast.Name) and v.id == "float":
                return [self.finding(
                    module, node.lineno,
                    "dtype=float resolves to float64 on host numpy — "
                    "declare an explicit wire dtype")]
        # host-numpy creation without an explicit dtype (implied float64)
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            base = _terminal(node.func.value)
            if base in ("np", "numpy") and node.func.attr in _CREATORS:
                has_dtype = (len(node.args) >= 2 or any(
                    k.arg == "dtype" for k in node.keywords))
                if node.func.attr == "full":
                    has_dtype = (len(node.args) >= 3 or any(
                        k.arg == "dtype" for k in node.keywords))
                if not has_dtype:
                    return [self.finding(
                        module, node.lineno,
                        f"np.{node.func.attr}(...) without an explicit "
                        "dtype in a wire-format module — numpy implies "
                        "float64; declare one of float_codec.WIRE_DTYPES")]
        return []
