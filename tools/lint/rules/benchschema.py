"""bench-schema: exact-key validation of the ``BENCH_*.json`` artifacts.

The migrated ``tools/bench_schema.py`` gate, now a repro-lint rule: every
suite that writes a JSON report goes through
``benchmarks.common.write_bench_json``, which stamps the shared ``meta``
provenance block. Each known artifact must carry **exactly** its expected
top-level keys (a missing key means the suite silently dropped a result;
an extra key means the schema drifted without this file being updated),
and ``meta`` must carry the full provenance key set.

As a lint rule it validates any ``BENCH_*.json`` the file walker hands it
(artifacts live in the repo root, so a plain ``python -m tools.lint src
tools benchmarks`` run sees none — CI invokes the wrapper CLI
``python -m tools.bench_schema`` on the artifacts it just produced, which
delegates here).
"""

from __future__ import annotations

import json
import pathlib

from tools.lint.core import Finding, Rule

# Keep in sync with repro.obs.ledger.PROVENANCE_KEYS (imported when the
# package is on the path; this literal keeps the tool standalone).
try:
    from repro.obs.ledger import PROVENANCE_KEYS as META_KEYS
except ImportError:  # pragma: no cover - PYTHONPATH=src not set
    META_KEYS = ("schema", "jax", "numpy", "python", "platform", "backend",
                 "git_sha", "timestamp")

# filename -> accepted top-level key sets (link_adaptation has two shapes:
# the full FL run, and the dispatch-only standalone invocation).
EXPECTED: dict[str, tuple[frozenset, ...]] = {
    "BENCH_async_fl.json": (frozenset({
        "clients", "scenario", "buffer_k", "arms", "tdma_barrier_s",
        "buffered_matches_sync_in_0p6x_time", "ledger", "meta"}),),
    "BENCH_compression.json": (frozenset({
        "clients", "rounds", "sparse_rounds", "scenarios",
        "topk_matches_dense_at_fifth_airtime", "meta"}),),
    "BENCH_fl_round.json": (frozenset({
        "snr_db", "clients", "rounds", "arms",
        "downlink_worse_than_uplink", "meta"}),),
    "BENCH_kernel_throughput.json": (frozenset({
        "clients", "n_floats", "arms", "roofline", "gates", "meta"}),),
    "BENCH_link_adaptation.json": (
        frozenset({"dispatch", "arms", "select_single_trace", "meta"}),
        frozenset({"dispatch", "meta"}),
    ),
    "BENCH_obs.json": (frozenset({
        "clients", "rounds", "scenario", "ledger", "trace",
        "ledger_rounds", "ledger_events", "sketch_rounds", "track_types",
        "phases", "sinks_are_neutral", "overhead", "sketch_scale",
        "meta"}),),
}


def validate_file(path: pathlib.Path) -> list[str]:
    """Problems with one artifact (empty list = valid)."""
    shapes = EXPECTED.get(path.name)
    if shapes is None:
        return [f"{path}: unknown benchmark artifact "
                f"(add it to tools/lint/rules/benchschema.py EXPECTED)"]
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable: {e}"]
    if not isinstance(obj, dict):
        return [f"{path}: top level is {type(obj).__name__}, expected object"]
    keys = frozenset(obj)
    if keys not in shapes:
        best = min(shapes, key=lambda s: len(s ^ keys))
        problems = []
        for k in sorted(best - keys):
            problems.append(f"{path}: missing top-level key {k!r}")
        for k in sorted(keys - best):
            problems.append(f"{path}: unexpected top-level key {k!r}")
        return problems
    meta = obj.get("meta")
    if not isinstance(meta, dict):
        return [f"{path}: 'meta' is not an object"]
    return [f"{path}: meta missing key {k!r}" for k in META_KEYS
            if k not in meta]


class BenchSchemaRule(Rule):
    """Validate BENCH_*.json artifacts encountered by the walker."""

    name = "bench-schema"
    description = ("BENCH_*.json artifacts must carry exactly their "
                   "declared top-level keys and the full meta provenance "
                   "block")

    def check_paths(self, files: list[pathlib.Path]) -> list[Finding]:
        """Validate every ``BENCH_*.json`` in the walked file set."""
        findings: list[Finding] = []
        for f in files:
            if not (f.name.startswith("BENCH_")
                    and f.name.endswith(".json")):
                continue
            for msg in validate_file(f):
                # strip the "path: " prefix validate_file embeds
                findings.append(self.finding(
                    f, 1, msg.split(": ", 1)[1] if ": " in msg else msg))
        return findings
