"""determinism: seeded-only randomness and no wall-clock in numeric paths.

Every number the library emits must be a pure function of explicit seeds —
that is what makes the golden bit-identity suites meaningful. Under
``src/repro/`` this rule flags:

* ``time.time`` / ``perf_counter`` / ``monotonic`` / ``process_time`` (and
  their ``_ns`` variants) — wall-clock reads;
* ``datetime.now`` / ``utcnow`` / ``today`` — ditto;
* any use of the stdlib ``random`` module (unseeded global PRNG);
* legacy ``np.random.*`` calls (``seed``, ``rand``, ``randn``, …) — global
  mutable state — and ``np.random.default_rng()`` *without* a seed.

``np.random.default_rng(seed)`` with an explicit seed and all of
``jax.random`` are the sanctioned sources. Whitelisted subtrees:
``src/repro/obs/`` (provenance stamping and phase timers *are* wall-clock
consumers) and ``src/repro/launch/`` (host-side launch drivers that time
compilation and serving). Engine wall-clock telemetry (``FLResult.wall_s``)
carries inline ``# lint: ignore[determinism]`` suppressions instead, so
every exemption is visible at the call site.
"""

from __future__ import annotations

import ast

from tools.lint.core import Finding, Module, Rule

SCOPE_PREFIX = "src/repro/"
WHITELIST_PREFIXES = ("src/repro/obs/", "src/repro/launch/")

_TIME_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns", "process_time",
             "process_time_ns"}
_DATETIME_FNS = {"now", "utcnow", "today"}
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "BitGenerator"}


def _attr_chain(node: ast.AST) -> list[str]:
    """``a.b.c`` -> ``["a", "b", "c"]`` (empty if not a pure chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


class DeterminismRule(Rule):
    """Flag wall-clock and unseeded randomness in ``src/repro/``."""

    name = "determinism"
    description = ("no time.time/datetime.now/stdlib random/unseeded "
                   "np.random under src/repro/ (obs/ and launch/ are "
                   "whitelisted host layers)")

    def __init__(self, scope_prefix: str = SCOPE_PREFIX,
                 whitelist: tuple[str, ...] = WHITELIST_PREFIXES) -> None:
        """Scope and whitelist are injectable for the fixture tests."""
        self.scope_prefix = scope_prefix
        self.whitelist = whitelist

    def check_module(self, module: Module) -> list[Finding]:
        """Scan one module (no-op outside the scoped subtree)."""
        rel = module.relpath
        if not rel.startswith(self.scope_prefix):
            return []
        if any(rel.startswith(w) for w in self.whitelist):
            return []
        # names bound to the stdlib random module / its functions
        random_names: set[str] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        random_names.add(alias.asname or "random")
            elif isinstance(node, ast.ImportFrom) and node.module == "random":
                for alias in node.names:
                    random_names.add(alias.asname or alias.name)
        findings: list[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            findings.extend(self._check_call(module, node, chain,
                                             random_names))
        return findings

    def _check_call(self, module: Module, node: ast.Call,
                    chain: list[str],
                    random_names: set[str]) -> list[Finding]:
        """Findings for one attribute-chain call."""
        head, tail = chain[0], chain[-1]
        loc = ".".join(chain)
        if head == "time" and len(chain) == 2 and tail in _TIME_FNS:
            return [self.finding(
                module, node.lineno,
                f"wall-clock read `{loc}()` in a numeric path — results "
                "must be a pure function of the seed (use the obs layer "
                "for telemetry)")]
        if tail in _DATETIME_FNS and any(
                p in ("datetime", "date") for p in chain[:-1]):
            return [self.finding(
                module, node.lineno,
                f"wall-clock read `{loc}()` in a numeric path — stamp "
                "provenance in repro.obs instead")]
        if head in random_names or (len(chain) == 1
                                    and tail in random_names):
            return [self.finding(
                module, node.lineno,
                f"stdlib random call `{loc}()` — use jax.random with an "
                "explicit key")]
        if len(chain) >= 3 and chain[0] in ("np", "numpy") \
                and chain[1] == "random":
            if tail not in _NP_RANDOM_OK:
                return [self.finding(
                    module, node.lineno,
                    f"legacy global-state RNG `{loc}()` — use "
                    "np.random.default_rng(seed)")]
            if tail == "default_rng" and not node.args \
                    and not node.keywords:
                return [self.finding(
                    module, node.lineno,
                    "`np.random.default_rng()` without a seed — pass an "
                    "explicit seed")]
        return []
