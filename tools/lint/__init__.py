"""repro-lint: AST invariant checkers for the repo's reproducibility rules.

The bit-identity guarantees this repo ships (batched ≡ per-client, bucketed
≡ select, async ≡ sync, sinks-on ≡ sinks-off) rest on conventions no test
can see from the outside: reserved ``fold_in`` key lanes, seeded-only
randomness, jit-pure round steps, and an explicit wire dtype set. This
package machine-checks them::

    python -m tools.lint src tools benchmarks

Architecture: :mod:`tools.lint.core` holds the shared file walker,
``Finding``/``Module`` types, ``# lint: ignore[rule]`` suppression parsing,
and the text/JSON reporters; each module under :mod:`tools.lint.rules`
contributes one :class:`~tools.lint.core.Rule`. The legacy standalone
gates (``tools/docs_check.py``, ``tools/bench_schema.py``) are now thin
wrappers over their migrated rules, keeping their CLIs valid for CI.
"""

from tools.lint.core import Finding, Module, Rule, gather_files, run_rules

__all__ = ["Finding", "Module", "Rule", "gather_files", "run_rules"]
