"""CLI for repro-lint: ``python -m tools.lint [PATH ...]``.

Walks the given paths (default: ``src tools benchmarks`` relative to the
repo root), runs every registered rule, and prints findings as text or
JSON. Exit 0 when clean, 1 on any finding, 2 on usage errors. The rule
list (with one-line descriptions) is printed by ``--help`` and
``--list-rules``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from tools.lint import core
from tools.lint.rules import all_rules

DEFAULT_PATHS = ["src", "tools", "benchmarks"]


def build_parser(rules) -> argparse.ArgumentParser:
    """The argument parser, with the rule list in the ``--help`` epilog."""
    rule_lines = "\n".join(f"  {r.name:<18} {r.description}" for r in rules)
    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: AST invariant checkers for this repo's "
                    "reproducibility rules (key lanes, determinism, jit "
                    "purity, wire dtypes, docstrings, bench schemas).",
        epilog=f"rules:\n{rule_lines}\n\nsuppress one finding with a "
               "trailing `# lint: ignore[rule]` comment (or on the line "
               "above).",
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument(
        "paths", nargs="*", default=None,
        help="files or directories to check (default: src tools "
             "benchmarks, relative to the repo root)")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (json is one object with every finding)")
    parser.add_argument(
        "--rules", default=None, metavar="R1,R2",
        help="comma-separated subset of rules to run")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit")
    return parser


def main(argv: list[str] | None = None) -> int:
    """CLI entry; returns the process exit code."""
    rules = all_rules()
    parser = build_parser(rules)
    args = parser.parse_args(argv)
    if args.list_rules:
        for r in rules:
            print(f"{r.name:<18} {r.description}")
        return 0
    if args.rules is not None:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        known = {r.name for r in rules}
        unknown = wanted - known
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                  f"valid: {', '.join(sorted(known))}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in wanted]
    raw_paths = args.paths or [str(core.REPO_ROOT / p)
                               for p in DEFAULT_PATHS]
    paths = [pathlib.Path(p) for p in raw_paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        for p in missing:
            print(f"{p}: no such file or directory", file=sys.stderr)
        return 2
    files = core.gather_files(paths)
    findings, n_suppressed = core.run_rules(rules, files)
    report = (core.report_json if args.format == "json"
              else core.report_text)
    print(report(findings, len(files), n_suppressed))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
