"""Shared machinery for the repro-lint rules.

A :class:`Module` is one parsed Python file (source, AST, repo-relative
path, and its suppression map); a :class:`Rule` examines modules (or, for
artifact-level rules, raw paths) and emits :class:`Finding` records with
``path:line`` locations. :func:`run_rules` walks the requested paths,
applies every rule, and filters findings through ``# lint: ignore[rule]``
suppressions:

* a trailing comment suppresses the named rule(s) on its own line;
* a comment-only line suppresses them on the next line;
* ``# lint: ignore[rule1,rule2]`` names several rules at once.

Rules never crash the run on unparsable input — a syntax error becomes a
``parse-error`` finding so CI surfaces it like any other problem.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent.parent

_IGNORE_RE = re.compile(r"#.*?\blint:\s*ignore\[([A-Za-z0-9_,\- ]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at ``path:line``."""

    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        """Render as ``path:line: [rule] message`` (the text reporter)."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        """Plain-dict form for the JSON reporter."""
        return dataclasses.asdict(self)


class Module:
    """One parsed Python module, as rules see it.

    ``relpath`` is the repo-relative POSIX path — rules scope themselves on
    it (e.g. the determinism rule only applies under ``src/repro/``), which
    also lets tests feed synthetic modules with any claimed location.
    """

    def __init__(self, relpath: str, source: str,
                 tree: ast.Module | None = None) -> None:
        """Parse ``source`` (unless a pre-parsed ``tree`` is supplied)."""
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.parse_error: SyntaxError | None = None
        if tree is not None:
            self.tree = tree
        else:
            try:
                self.tree = ast.parse(source, filename=relpath)
            except SyntaxError as e:  # surfaced as a parse-error finding
                self.tree = ast.Module(body=[], type_ignores=[])
                self.parse_error = e
        self.suppressed = self._suppressions()

    def _suppressions(self) -> dict[int, set[str]]:
        """``{lineno: {rule, ...}}`` from ``# lint: ignore[...]`` comments."""
        out: dict[int, set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _IGNORE_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            comment_only = line.lstrip().startswith("#")
            target = i + 1 if comment_only else i
            out.setdefault(target, set()).update(rules)
        return out

    def is_suppressed(self, finding: Finding) -> bool:
        """Whether an ignore comment covers this finding's rule and line."""
        return finding.rule in self.suppressed.get(finding.line, set())


class Rule:
    """Base class: one named invariant checker.

    Subclasses set ``name``/``description`` and override
    :meth:`check_module` (per parsed Python file) and/or
    :meth:`check_paths` (once per run, for artifact-level rules such as the
    benchmark-schema gate).
    """

    name = ""
    description = ""

    def check_module(self, module: Module) -> list[Finding]:
        """Findings for one parsed module (default: none)."""
        return []

    def check_paths(self, files: list[pathlib.Path]) -> list[Finding]:
        """Run-level findings over the walked file list (default: none)."""
        return []

    def finding(self, module_or_path, line: int, message: str) -> Finding:
        """Build a :class:`Finding` tagged with this rule's name."""
        path = (module_or_path.relpath if isinstance(module_or_path, Module)
                else str(module_or_path))
        return Finding(self.name, path, line, message)


def relpath_of(path: pathlib.Path) -> str:
    """Repo-relative POSIX path (absolute fallback outside the repo)."""
    p = path.resolve()
    try:
        return p.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return p.as_posix()


def gather_files(paths: list[pathlib.Path]) -> list[pathlib.Path]:
    """Expand CLI paths to the checkable file set (sorted, deduplicated).

    Directories are walked recursively for ``*.py`` plus ``BENCH_*.json``
    artifacts; ``__pycache__`` and hidden directories are skipped. Explicit
    file arguments are taken as-is, whatever their suffix.
    """
    out: set[pathlib.Path] = set()
    for p in paths:
        if p.is_dir():
            for f in p.rglob("*"):
                if not f.is_file():
                    continue
                parts = f.relative_to(p).parts
                if any(s == "__pycache__" or s.startswith(".")
                       for s in parts):
                    continue
                if f.suffix == ".py" or f.name.startswith("BENCH_"):
                    out.add(f)
        else:
            out.add(p)
    return sorted(out)


def load_module(path: pathlib.Path) -> Module:
    """Read + parse one file into a :class:`Module`."""
    return Module(relpath_of(path), path.read_text())


def run_rules(rules: list[Rule], files: list[pathlib.Path],
              ) -> tuple[list[Finding], int]:
    """Apply ``rules`` to ``files``; returns ``(findings, n_suppressed)``.

    Python files go through every rule's :meth:`Rule.check_module` (after a
    shared parse); the full file list goes through each rule's
    :meth:`Rule.check_paths` once. Suppressed findings are dropped and
    counted.
    """
    findings: list[Finding] = []
    n_suppressed = 0
    py_files = [f for f in files if f.suffix == ".py"]
    for f in py_files:
        module = load_module(f)
        if module.parse_error is not None:
            e = module.parse_error
            findings.append(Finding("parse-error", module.relpath,
                                    e.lineno or 1, f"syntax error: {e.msg}"))
            continue
        for rule in rules:
            for fd in rule.check_module(module):
                if module.is_suppressed(fd):
                    n_suppressed += 1
                else:
                    findings.append(fd)
    for rule in rules:
        findings.extend(rule.check_paths(files))
    findings.sort(key=lambda fd: (fd.path, fd.line, fd.rule))
    return findings, n_suppressed


def report_text(findings: list[Finding], n_files: int,
                n_suppressed: int) -> str:
    """The human-readable report (one line per finding + a summary)."""
    lines = [fd.format() for fd in findings]
    if findings:
        lines.append(f"repro-lint: {len(findings)} finding(s) in "
                     f"{n_files} file(s) ({n_suppressed} suppressed)")
    else:
        lines.append(f"repro-lint: OK ({n_files} file(s), "
                     f"{n_suppressed} suppressed)")
    return "\n".join(lines)


def report_json(findings: list[Finding], n_files: int,
                n_suppressed: int) -> str:
    """The machine-readable report (one JSON object, for CI tooling)."""
    return json.dumps({
        "findings": [fd.to_json() for fd in findings],
        "files": n_files,
        "suppressed": n_suppressed,
        "ok": not findings,
    }, indent=2)
