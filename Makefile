# Developer entry points. Everything runs from the repo root with no install
# step; src/ goes on PYTHONPATH.

PY := python
export PYTHONPATH := src

# Host env for wall-clock benchmarks (SNIPPETS idiom): preload tcmalloc
# when the host has it (this container does not — $(wildcard) keeps the
# preload empty rather than crashing the loader), silence TF/XLA host
# chatter, and pin a single host platform device so timings are not
# skewed by surprise intra-op sharding. benchmarks/common.py stamps the
# values actually in effect into every BENCH_*.json meta.host_flags.
TCMALLOC := $(firstword $(wildcard /usr/lib/x86_64-linux-gnu/libtcmalloc.so* \
        /usr/lib/libtcmalloc.so*))
BENCH_ENV := $(if $(TCMALLOC),LD_PRELOAD=$(TCMALLOC)) \
        TF_CPP_MIN_LOG_LEVEL=4 \
        XLA_FLAGS="--xla_force_host_platform_device_count=1"

.PHONY: test bench-smoke bench-link bench-fl bench-compress bench-async \
        bench-obs bench-kernel bench-diff docs-check lint

# Tier-1 verify (same command the CI driver runs).
test:
	$(PY) -m pytest -x -q

# Quick pass over the benchmark suites that exercise the hot paths
# (single-client kernel, batched multi-client engine) — minutes, not hours.
bench-smoke:
	$(PY) -m benchmarks.run --only kernel,scaling

# Link-adaptation smoke: adaptive policy vs fixed transports at reduced
# scale (quick profile: one scenario, 24 clients), the 64-client mixed-mode
# single-trace check, and the bucketed-vs-select dispatch arm (asserts
# bit-equivalence, records timings). Writes BENCH_link_adaptation.json
# (uploaded as a CI artifact).
bench-link:
	$(PY) -m benchmarks.run --only link

# Uplink-vs-downlink error-budget study (Qu et al. asymmetry): four FL arms
# with one noisy leg at a time at matched SNR; asserts the noisy downlink
# degrades accuracy more than the equally-noisy uplink and writes
# BENCH_fl_round.json (uploaded as a CI artifact).
bench-fl:
	$(PY) -m benchmarks.run --only fl_round

# Compression Pareto study: dense-approx vs top-k+EF sparse arms vs ECRT on
# vehicular and iot-flaky; asserts a top-k arm reaches dense accuracy at
# <= 1/5 the cumulative airtime and writes BENCH_compression.json (uploaded
# as a CI artifact).
bench-compress:
	$(PY) -m benchmarks.run --only compression

# Buffered-async (FedBuff) vs synchronous FL under heavy straggling on
# metro-rush; asserts the buffered arm reaches sync final accuracy in
# <= 0.6x the event-clock time and writes BENCH_async_fl.json (uploaded
# as a CI artifact).
bench-async:
	$(PY) -m benchmarks.run --only async_fl

# Observability smoke: a 5-round buffered metro-rush run with the JSONL
# ledger, the Perfetto trace recorder, and the phase timers attached;
# asserts the ledger schema-validates and reproduces FLResult.link
# bit-identically, the trace carries >= 4 track types, and a sink-free
# twin run is numerically identical. Then schema-validates the artifact.
bench-obs:
	$(PY) -m benchmarks.run --only obs
	$(PY) -m tools.bench_schema BENCH_obs.json

# Fused-kernel throughput study: layered jnp round vs batch kernel vs the
# in-kernel-aggregation fused round, the analytic HBM roofline from the
# real transport config (gate: fused moves >= 5x less traffic than the
# layered round), a fused-vs-layered bit-identity self-check, and the
# bucketed-vs-select dispatch arm on a single-mode cohort. Runs under the
# tuned host env above; writes BENCH_kernel_throughput.json (uploaded as
# a CI artifact) and schema-validates it.
bench-kernel:
	$(BENCH_ENV) $(PY) -m benchmarks.run --only kernel
	$(PY) -m tools.bench_schema BENCH_kernel_throughput.json

# Bench-regression sentry: diff freshly-produced BENCH artifacts against
# the committed baselines under benchmarks/baselines/ using the per-key
# tolerance specs in benchmarks/baselines/tolerances.json; exits non-zero
# on drift. Run after bench-kernel + bench-async (the gated artifacts).
bench-diff:
	$(PY) -m tools.bench_diff --against-baselines \
		BENCH_kernel_throughput.json BENCH_async_fl.json

# Fails if a public module (or public function/class) under
# src/repro/{core,link,fl,compress,obs} or tools/ lacks a docstring.
# (Thin wrapper over the `docstrings` rule of tools/lint.)
docs-check:
	$(PY) tools/docs_check.py

# repro-lint: the AST invariant checker suite (keylane, determinism,
# jit-purity, dtype-discipline, docstrings, bench-schema). Pure AST — no
# jax import, fast enough for a pre-commit hook.
lint:
	$(PY) -m tools.lint src tools benchmarks
