"""FedAvg over the approximate wireless uplink (beyond-paper extension).

The paper evaluates FedSGD (one gradient per round). FedAvg transmits the
*weight delta* after E local steps instead; deltas are larger than single
gradients but still bounded in practice (|Δw| <= eta * sum|g| over the local
steps), so the same exponent-clamp receiver prior applies — optionally with
an adaptive per-round scale factor (see ``scale_mode``):

  ``none``     transmit raw deltas (paper-style prior |Δ| < 2)
  ``max_abs``  scale by 1/max|Δ| before transmission and undo at the PS;
               the scalar travels on the (error-free) control channel.
               This concentrates values near the top of the representable
               range where relative QAM error is smallest — a beyond-paper
               trick enabled by the same boundedness insight.

Since the round-engine refactor this module is a thin façade over
:mod:`repro.fl.engine` (:class:`~repro.fl.engine.FedAvg` plugged into the
shared :class:`~repro.fl.engine.RoundEngine`): scenarios, both adaptive
dispatches, ECRT pricing, the noisy downlink leg, airtime and telemetry all
come from the same engine FedSGD uses. ``run_fedavg`` keeps its historical
signature and is bit-identical to the pre-engine loop for every
pre-existing configuration (``tests/test_engine_golden.py``).
"""

from __future__ import annotations

import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import engine as engine_lib
from repro.fl.engine import FLResult


def run_fedavg(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,
    client_y: np.ndarray,
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 30,
    local_steps: int = 4,
    batch_per_step: int = 32,
    scale_mode: str = "none",  # "none" | "max_abs"
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
    adaptive_dispatch: str = "bucketed",
    downlink=None,
    compression=None,
    fused_aggregate: bool = False,
    ledger=None,
    phase_timers=None,
    sketches=None,
) -> FLResult:
    """FedAvg over the simulated uplink: ``local_steps`` SGD steps per
    client per round, weight deltas on the wire.

    Mirrors :func:`repro.fl.loop.run_fl`'s arguments (including the
    ``ledger``/``phase_timers``/``sketches`` observability sinks); the FedAvg-specific
    ones are ``local_steps`` / ``batch_per_step`` (the local schedule) and
    ``scale_mode`` (the adaptive per-client delta scaling above). See the
    module and :mod:`repro.fl.engine` docstrings for scenarios, dispatches,
    and the downlink leg. ``fused_aggregate=True`` (the fused round hot
    path) requires ``scale_mode='none'`` — the ``max_abs`` descale runs
    between demap and aggregate and cannot fold into the kernel.
    """
    algo = engine_lib.FedAvg(cfg, local_steps=local_steps,
                             batch_per_step=batch_per_step,
                             scale_mode=scale_mode)
    return engine_lib.RoundEngine(
        algo, transport_cfg, client_x, client_y, test_x, test_y,
        n_rounds=n_rounds, seed=seed, eval_every=eval_every, timings=timings,
        scenario=scenario, adaptive_dispatch=adaptive_dispatch,
        downlink=downlink, compression=compression,
        fused_aggregate=fused_aggregate, ledger=ledger,
        phase_timers=phase_timers, sketches=sketches,
    ).run()
