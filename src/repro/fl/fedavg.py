"""FedAvg over the approximate wireless uplink (beyond-paper extension).

The paper evaluates FedSGD (one gradient per round). FedAvg transmits the
*weight delta* after E local epochs instead; deltas are larger than single
gradients but still bounded in practice (|Δw| <= eta * sum|g| over the local
steps), so the same exponent-clamp receiver prior applies — optionally with
an adaptive per-round scale factor (see ``scale_mode``):

  ``none``     transmit raw deltas (paper-style prior |Δ| < 2)
  ``max_abs``  scale by 1/max|Δ| before transmission and undo at the PS;
               the scalar travels on the (error-free) control channel.
               This concentrates values near the top of the representable
               range where relative QAM error is smallest — a beyond-paper
               trick enabled by the same boundedness insight.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.fl.loop import FLResult
from repro.optim.sgd import sgd as make_sgd


def run_fedavg(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,
    client_y: np.ndarray,
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 30,
    local_steps: int = 4,
    batch_per_step: int = 32,
    scale_mode: str = "none",  # "none" | "max_abs"
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    grad_fn = jax.grad(cnn.loss_fn)

    if transport_cfg.mode == "ecrt" and transport_cfg.simulate_fec:
        # mean SNR for heterogeneous cohorts (see loop.py)
        snr_cal = float(np.mean(np.asarray(transport_cfg.channel.snr_db)))
        e_tx = latency_lib.calibrate_ecrt(
            snr_cal, transport_cfg.modulation, n_codewords=64, max_tx=6)
        transport_cfg = dataclasses.replace(
            transport_cfg, simulate_fec=False, ecrt_expected_tx=float(e_tx))

    @jax.jit
    def round_step(params, xb, yb, key):
        # xb: (M, local_steps, batch, 28, 28)
        def client_update(x, y):
            def body(p, inp):
                xi, yi = inp
                g = grad_fn(p, xi, yi)
                p = jax.tree_util.tree_map(lambda a, b: a - cfg.lr * b, p, g)
                return p, None

            local, _ = jax.lax.scan(body, params, (x, y))
            return jax.tree_util.tree_map(lambda a, b: a - b, local, params)

        deltas = jax.vmap(client_update)(xb, yb)  # leaves (M, ...)

        if scale_mode == "max_abs":
            # Per-client adaptive scale: one scalar per client travels on the
            # (error-free) control channel; the whole cohort then rides the
            # batched uplink in a single fused computation.
            flat = jnp.concatenate(
                [l.reshape(M, -1) for l in jax.tree_util.tree_leaves(deltas)],
                axis=1)
            scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-8) / 0.9

            def expand(s, like):
                return s.reshape((M,) + (1,) * (like.ndim - 1))

            scaled = jax.tree_util.tree_map(
                lambda l: l / expand(scale, l), deltas)
            out, stats = transport_lib.transmit_pytree_batch(
                scaled, key, transport_cfg)
            deltas_hat = jax.tree_util.tree_map(
                lambda l: l * expand(scale, l), out)
        else:
            deltas_hat, stats = transport_lib.transmit_pytree_batch(
                deltas, key, transport_cfg)

        agg = jax.tree_util.tree_map(lambda d: jnp.mean(d, axis=0), deltas_hat)
        new_params = jax.tree_util.tree_map(lambda p, d: p + d, params, agg)
        return new_params, stats

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, local_steps, batch_per_step))
        xb = jnp.asarray(np.take_along_axis(
            client_x, take.reshape(M, -1)[:, :, None, None], axis=1
        ).reshape(M, local_steps, batch_per_step, 28, 28))
        yb = jnp.asarray(np.take_along_axis(
            client_y, take.reshape(M, -1), axis=1
        ).reshape(M, local_steps, batch_per_step))
        params, stats = round_step(params, xb, yb, rk)
        air = latency_lib.round_airtime(stats, timings, transport_cfg.mode)
        cum_air += float(jnp.sum(air))
        if r % eval_every == 0 or r == n_rounds - 1:
            res.rounds.append(r)
            res.accuracy.append(float(eval_acc(params)))
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res
