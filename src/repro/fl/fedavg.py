"""FedAvg over the approximate wireless uplink (beyond-paper extension).

The paper evaluates FedSGD (one gradient per round). FedAvg transmits the
*weight delta* after E local epochs instead; deltas are larger than single
gradients but still bounded in practice (|Δw| <= eta * sum|g| over the local
steps), so the same exponent-clamp receiver prior applies — optionally with
an adaptive per-round scale factor (see ``scale_mode``):

  ``none``     transmit raw deltas (paper-style prior |Δ| < 2)
  ``max_abs``  scale by 1/max|Δ| before transmission and undo at the PS;
               the scalar travels on the (error-free) control channel.
               This concentrates values near the top of the representable
               range where relative QAM error is smallest — a beyond-paper
               trick enabled by the same boundedness insight.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.fl.loop import (
    FLResult,
    dropout_weighted_mean,
    record_link_round,
    resolve_ecrt_analytic,
    resolve_scenario,
    select_mode_cfgs,
)
from repro.optim.sgd import sgd as make_sgd


def run_fedavg(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,
    client_y: np.ndarray,
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 30,
    local_steps: int = 4,
    batch_per_step: int = 32,
    scale_mode: str = "none",  # "none" | "max_abs"
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
    adaptive_dispatch: str = "bucketed",
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    grad_fn = jax.grad(cnn.loss_fn)
    driver = resolve_scenario(scenario, transport_cfg)
    if adaptive_dispatch not in ("bucketed", "select"):
        raise ValueError(
            f"adaptive_dispatch must be bucketed|select, got {adaptive_dispatch!r}")

    ecrt_air_scale = None
    if driver is None:
        # Per-client analytic E[tx] for heterogeneous cohorts (see loop.py).
        transport_cfg, ecrt_air_scale = resolve_ecrt_analytic(transport_cfg, M)

    def client_deltas(params, xb, yb):
        # xb: (M, local_steps, batch, 28, 28) -> weight deltas, leaves (M, ...)
        def client_update(x, y):
            def body(p, inp):
                xi, yi = inp
                g = grad_fn(p, xi, yi)
                p = jax.tree_util.tree_map(lambda a, b: a - cfg.lr * b, p, g)
                return p, None

            local, _ = jax.lax.scan(body, params, (x, y))
            return jax.tree_util.tree_map(lambda a, b: a - b, local, params)

        return jax.vmap(client_update)(xb, yb)

    def expand(s, like):
        return s.reshape((M,) + (1,) * (like.ndim - 1))

    # jitted so the host-driven bucketed round doesn't run the scale math
    # op-by-op; inside round_step_link's trace they simply inline.
    @jax.jit
    def compute_scale(deltas):
        flat = jnp.concatenate(
            [l.reshape(M, -1) for l in jax.tree_util.tree_leaves(deltas)],
            axis=1)
        return jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-8) / 0.9

    @jax.jit
    def div_scale(deltas, scale):
        return jax.tree_util.tree_map(lambda l: l / expand(scale, l), deltas)

    @jax.jit
    def mul_scale(deltas, scale):
        return jax.tree_util.tree_map(lambda l: l * expand(scale, l), deltas)

    def scaled_uplink(deltas, transmit):
        # Per-client adaptive scale (scale_mode == "max_abs"): one scalar per
        # client travels on the (error-free) control channel; the cohort then
        # rides the batched uplink in a single fused computation.
        if scale_mode != "max_abs":
            return transmit(deltas)
        scale = compute_scale(deltas)
        out, stats = transmit(div_scale(deltas, scale))
        return mul_scale(out, scale), stats

    @jax.jit
    def round_step(params, xb, yb, key):
        deltas = client_deltas(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch(t, key, transport_cfg))
        agg = jax.tree_util.tree_map(lambda d: jnp.mean(d, axis=0), deltas_hat)
        new_params = jax.tree_util.tree_map(lambda p, d: p + d, params, agg)
        return new_params, stats

    @jax.jit
    def round_step_link(params, xb, yb, key, lstate, prev_mode, prev_est):
        # Select dispatch, scenario-driven round: link pipeline + vmapped-
        # switch uplink + dropout-weighted FedAvg aggregate (see loop.run_fl).
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link)
        deltas = client_deltas(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch_adaptive(
                t, k_tx, select_mode_cfgs(driver), rnd.mode,
                snr_db=rnd.snr_db, dispatch="select"))
        agg = dropout_weighted_mean(deltas_hat, rnd.active)
        new_params = jax.tree_util.tree_map(lambda p, d: p + d, params, agg)
        return new_params, stats, lstate, rnd

    @jax.jit
    def link_round(lstate, prev_mode, prev_est, key):
        return driver.round(lstate, prev_mode, prev_est, key)

    @jax.jit
    def deltas_fn(params, xb, yb):
        return client_deltas(params, xb, yb)

    @jax.jit
    def apply_deltas(params, deltas_hat, active):
        agg = dropout_weighted_mean(deltas_hat, active)
        return jax.tree_util.tree_map(lambda p, d: p + d, params, agg)

    def round_step_link_bucketed(params, xb, yb, key, lstate, prev_mode,
                                 prev_est):
        # Bucketed dispatch: the mode vector syncs to the host after the
        # jitted link step, the uplink runs each mode once on its own client
        # bucket, and the (jitted) aggregate applies the deltas (see
        # loop.run_fl for the trade-off).
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
        mode_np = np.asarray(rnd.mode)
        deltas = deltas_fn(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch_adaptive(
                t, k_tx, driver.mode_cfgs, mode_np, snr_db=rnd.snr_db,
                dispatch="bucketed"))
        params = apply_deltas(params, deltas_hat, rnd.active)
        return params, stats, lstate, rnd

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    if driver is not None:
        key, lk = jax.random.split(key)
        lstate, prev_mode, prev_est = driver.init(lk, M)

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, local_steps, batch_per_step))
        xb = jnp.asarray(np.take_along_axis(
            client_x, take.reshape(M, -1)[:, :, None, None], axis=1
        ).reshape(M, local_steps, batch_per_step, 28, 28))
        yb = jnp.asarray(np.take_along_axis(
            client_y, take.reshape(M, -1), axis=1
        ).reshape(M, local_steps, batch_per_step))
        if driver is None:
            params, stats = round_step(params, xb, yb, rk)
            air = latency_lib.round_airtime(stats, timings, transport_cfg.mode)
            if ecrt_air_scale is not None:
                air = air * ecrt_air_scale
        else:
            step = (round_step_link_bucketed
                    if adaptive_dispatch == "bucketed" else round_step_link)
            params, stats, lstate, rnd = step(
                params, xb, yb, rk, lstate, prev_mode, prev_est)
            prev_mode, prev_est = rnd.mode, rnd.est_db
            air = record_link_round(res, r, driver, stats, rnd, timings)
        cum_air += float(jnp.sum(air))
        if r % eval_every == 0 or r == n_rounds - 1:
            res.rounds.append(r)
            res.accuracy.append(float(eval_acc(params)))
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res
