"""The paper's FL model (Sec. V): 2x conv(k5) + 2x maxpool(2) + 2x FC.

ReLU hidden activations, log-softmax output, cross-entropy loss, eta=0.01.
28x28 -> conv(1->10,k5) -> pool2 -> conv(10->20,k5) -> pool2 -> flatten(320)
-> fc(50) -> fc(10).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_params(key, cfg):
    """He-initialized parameter pytree for the paper's CNN (see module doc)."""
    k = jax.random.split(key, 4)
    c1, c2 = cfg.conv_channels
    K = cfg.kernel
    flat = c2 * 4 * 4  # 28 -> 24 -> 12 -> 8 -> 4
    he = lambda kk, shape, fan: jax.random.normal(kk, shape, jnp.float32) * jnp.sqrt(2.0 / fan)
    return {
        "conv1_w": he(k[0], (c1, 1, K, K), K * K),
        "conv1_b": jnp.zeros((c1,), jnp.float32),
        "conv2_w": he(k[1], (c2, c1, K, K), c1 * K * K),
        "conv2_b": jnp.zeros((c2,), jnp.float32),
        "fc1_w": he(k[2], (flat, cfg.fc_hidden), flat),
        "fc1_b": jnp.zeros((cfg.fc_hidden,), jnp.float32),
        "fc2_w": he(k[3], (cfg.fc_hidden, cfg.n_classes), cfg.fc_hidden),
        "fc2_b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }


def _conv(x, w, b):
    # x: (B, C, H, W); w: (O, C, K, K)
    y = jax.lax.conv_general_dilated(x, w, (1, 1), "VALID",
                                     dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def _pool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def logits_fn(params, images):
    """images: (B, 28, 28) -> logits (B, 10)."""
    x = images[:, None]  # (B,1,28,28)
    x = jax.nn.relu(_conv(x, params["conv1_w"], params["conv1_b"]))
    x = _pool2(x)
    x = jax.nn.relu(_conv(x, params["conv2_w"], params["conv2_b"]))
    x = _pool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"] + params["fc1_b"])
    return x @ params["fc2_w"] + params["fc2_b"]


def loss_fn(params, images, labels):
    """Mean cross-entropy of ``(B, 28, 28)`` images vs integer labels."""
    logits = logits_fn(params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(params, images, labels):
    """Top-1 accuracy of the model on ``(B, 28, 28)`` images."""
    return jnp.mean(jnp.argmax(logits_fn(params, images), -1) == labels)
