from repro.fl import cnn, partition
from repro.fl.loop import run_fl, FLResult
