"""Federated-learning loops over the simulated wireless links.

``engine`` is the unified round driver (Algorithm strategies x scenario
dispatches x uplink/downlink legs); ``async_engine`` replaces its barrier
with a FedBuff-style buffered event loop; ``loop``/``fedavg`` are the thin
algorithm entry points; ``cnn``/``partition`` are the paper's model and
non-iid data split.
"""

from repro.fl import cnn, partition
from repro.fl.async_engine import (AsyncRoundEngine, run_fedavg_buffered,
                                   run_fl_buffered, staleness_weight)
from repro.fl.engine import FedAvg, FedSGD, FLResult, RoundEngine
from repro.fl.fedavg import run_fedavg
from repro.fl.loop import run_fl
