"""Non-iid data partition: 2 digits per client (paper Sec. V)."""

from __future__ import annotations

import numpy as np


def non_iid_partition(images, labels, n_clients: int = 100,
                      digits_per_client: int = 2, seed: int = 0):
    """Each client gets ``digits_per_client`` digit classes, shards split
    evenly among the clients assigned to each digit. Returns a list of
    (images, labels) per client."""
    rng = np.random.default_rng(seed)
    # assign digits to clients round-robin over a shuffled multiset
    assignments = []
    pool = []
    for _ in range(n_clients * digits_per_client // 10 + 1):
        pool.extend(rng.permutation(10).tolist())
    for c in range(n_clients):
        assignments.append(pool[c * digits_per_client : (c + 1) * digits_per_client])

    by_digit = {d: np.where(labels == d)[0] for d in range(10)}
    cursor = {d: 0 for d in range(10)}
    counts = {d: sum(a.count(d) for a in [list(x) for x in assignments]) for d in range(10)}
    out = []
    for c in range(n_clients):
        idx = []
        for d in assignments[c]:
            share = len(by_digit[d]) // max(counts[d], 1)
            lo = cursor[d]
            idx.extend(by_digit[d][lo : lo + share].tolist())
            cursor[d] += share
        idx = np.array(idx, np.int64)
        rng.shuffle(idx)
        out.append((images[idx], labels[idx]))
    return out


def stack_clients(parts, per_client: int, seed: int = 0):
    """Stack each client's first ``per_client`` samples -> (M, n, 28, 28)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for img, lab in parts:
        n = len(lab)
        take = rng.choice(n, per_client, replace=n < per_client)
        xs.append(img[take])
        ys.append(lab[take])
    return np.stack(xs), np.stack(ys)
