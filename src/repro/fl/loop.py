"""The paper's FL simulation: FedSGD over a noisy wireless uplink.

One round (paper Sec. II):
  1. every client computes a single-step gradient on its local shard (4)
  2. the stacked (M, D) gradient matrix goes through the *batched* uplink
     engine (``transport.transmit_batch``) — M independent fading channels,
     optionally heterogeneous per-client SNR, one fused computation
  3. the PS aggregates (5) and updates the global model (6)
  4. airtime for the round = slowest client's uplink (TDMA: sum is also
     reported; Fig. 3 uses the per-round wall time accumulation)

One XLA program per round regardless of M; per-client TxStats feed the
latency model directly.

Scenario-driven rounds (``scenario=``): instead of one static transport
mode and SNR, each round runs the link-adaptation pipeline inside the same
jitted step — ``repro.link`` dynamics evolve per-client SNR, the estimator
produces noisy CSI, the policy picks each client's mode, the mixed-mode
batched uplink delivers (``transmit_pytree_batch_adaptive``), and dropped
clients are excluded from the weighted aggregate. Per-round link telemetry
lands in ``FLResult.link``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.optim.sgd import sgd as make_sgd


@dataclasses.dataclass
class FLResult:
    rounds: list
    accuracy: list
    airtime_s: list  # cumulative uplink airtime (TDMA sum over clients)
    wall_s: float
    final_accuracy: float
    # Per-round link telemetry (scenario-driven runs only; [] otherwise).
    # Each entry: {round, mean_snr_db, mean_est_db, mode_counts, n_active,
    # n_stragglers, airtime_s} — mode_counts indexes the driver's mode table.
    link: list = dataclasses.field(default_factory=list)


def resolve_scenario(scenario, transport_cfg):
    """``scenario=`` argument -> a bound ``ScenarioDriver`` (or ``None``).

    Accepts a registered scenario name, a ``Scenario``, or an already-built
    ``ScenarioDriver``; shared by ``run_fl`` and ``fedavg.run_fedavg``.
    """
    if scenario is None:
        return None
    from repro.link import scenario as scenario_lib

    if isinstance(scenario, scenario_lib.ScenarioDriver):
        return scenario
    if isinstance(scenario, str):
        scenario = scenario_lib.get_scenario(scenario)
    return scenario_lib.ScenarioDriver(scenario, transport_cfg)


def dropout_weighted_mean(tree, active):
    """Mean of ``(M, ...)`` leaves over active clients only.

    ``active`` is the 0/1 ``(M,)`` availability vector; an all-dropped round
    yields zeros (the global model simply does not move). Jit-safe — the
    shared aggregation rule of both scenario-driven FL loops.
    """
    denom = jnp.maximum(jnp.sum(active), 1.0)
    return jax.tree_util.tree_map(
        lambda g: jnp.tensordot(active, g, axes=(0, 0)) / denom, tree)


def record_link_round(res: "FLResult", r: int, driver, stats, rnd,
                      timings) -> jax.Array:
    """Per-round scenario bookkeeping shared by the FL loops: price the
    round's per-client airtime and append the telemetry record. Returns the
    ``(M,)`` airtime vector."""
    air = driver.airtime(stats, rnd, timings)
    res.link.append(link_telemetry(r, rnd, air, len(driver.mode_cfgs)))
    return air


def link_telemetry(r: int, rnd, per_client_air, n_modes: int) -> dict:
    """One ``FLResult.link`` record from a round's ``LinkRound`` + airtime."""
    mode = np.asarray(rnd.mode)
    return {
        "round": r,
        "mean_snr_db": float(np.mean(np.asarray(rnd.snr_db))),
        "mean_est_db": float(np.mean(np.asarray(rnd.est_db))),
        "mode_counts": np.bincount(mode, minlength=n_modes).tolist(),
        "n_active": int(np.asarray(rnd.active).sum()),
        "n_stragglers": int(np.asarray(rnd.straggler).sum()),
        "airtime_s": float(np.asarray(per_client_air).sum()),
    }


def run_fl(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,  # (M, n, 28, 28)
    client_y: np.ndarray,  # (M, n)
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 40,
    batch_per_round: int = 32,
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    opt = make_sgd(cfg.lr)
    opt_state = opt.init(params)
    driver = resolve_scenario(scenario, transport_cfg)

    # ECRT inside a vmapped per-round loop uses the calibrated analytic model
    # (the real decoder is exercised in tests/benchmarks; see DESIGN.md).
    # Heterogeneous cohorts calibrate at the mean SNR (E[tx] is a round-level
    # airtime constant here, not a per-client quantity).
    if (driver is None and transport_cfg.mode == "ecrt"
            and transport_cfg.simulate_fec):
        snr_cal = float(np.mean(np.asarray(transport_cfg.channel.snr_db)))
        e_tx = latency_lib.calibrate_ecrt(
            snr_cal, transport_cfg.modulation, n_codewords=96, max_tx=6)
        transport_cfg = dataclasses.replace(
            transport_cfg, simulate_fec=False, ecrt_expected_tx=float(e_tx))

    grad_fn = jax.grad(cnn.loss_fn)

    @jax.jit
    def round_step(params, opt_state, xb, yb, key):
        def client_grad(x, y):
            return grad_fn(params, x, y)

        grads = jax.vmap(client_grad)(xb, yb)  # pytree leaves (M, ...)
        # Batched uplink: M independent channels, fold_in key schedule,
        # per-client TxStats — one fused computation instead of M pipelines.
        grads_hat, stats = transport_lib.transmit_pytree_batch(
            grads, key, transport_cfg)
        agg = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads_hat)
        new_params, new_state = opt.update(agg, opt_state, params)
        return new_params, new_state, stats

    @jax.jit
    def round_step_link(params, opt_state, xb, yb, key, lstate, prev_mode,
                        prev_est):
        # One fused program: dynamics -> noisy CSI -> mode policy ->
        # mixed-mode batched uplink -> dropout-weighted aggregation.
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link)

        def client_grad(x, y):
            return grad_fn(params, x, y)

        grads = jax.vmap(client_grad)(xb, yb)
        grads_hat, stats = transport_lib.transmit_pytree_batch_adaptive(
            grads, k_tx, driver.mode_cfgs, rnd.mode, snr_db=rnd.snr_db)
        agg = dropout_weighted_mean(grads_hat, rnd.active)
        new_params, new_state = opt.update(agg, opt_state, params)
        return new_params, new_state, stats, lstate, rnd

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    if driver is not None:
        key, lk = jax.random.split(key)
        lstate, prev_mode, prev_est = driver.init(lk, M)

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, batch_per_round))
        xb = jnp.asarray(np.take_along_axis(client_x, take[:, :, None, None], axis=1))
        yb = jnp.asarray(np.take_along_axis(client_y, take, axis=1))
        if driver is None:
            params, opt_state, stats = round_step(params, opt_state, xb, yb, rk)
            # TDMA uplink: total airtime is the sum over clients ((M,) stats)
            per_client_air = latency_lib.round_airtime(
                stats, timings, transport_cfg.mode)
        else:
            params, opt_state, stats, lstate, rnd = round_step_link(
                params, opt_state, xb, yb, rk, lstate, prev_mode, prev_est)
            prev_mode, prev_est = rnd.mode, rnd.est_db
            per_client_air = record_link_round(
                res, r, driver, stats, rnd, timings)
        cum_air += float(jnp.sum(per_client_air))
        if r % eval_every == 0 or r == n_rounds - 1:
            acc = float(eval_acc(params))
            res.rounds.append(r)
            res.accuracy.append(acc)
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res
