"""The paper's FL simulation: FedSGD over a noisy wireless uplink.

One round (paper Sec. II):
  1. every client computes a single-step gradient on its local shard (4)
  2. the stacked (M, D) gradient matrix goes through the *batched* uplink
     engine (``transport.transmit_batch``) — M independent fading channels,
     optionally heterogeneous per-client SNR, one fused computation
  3. the PS aggregates (5) and updates the global model (6)
  4. airtime for the round = slowest client's uplink (TDMA: sum is also
     reported; Fig. 3 uses the per-round wall time accumulation)

Since the round-engine refactor this module is a thin façade: the round
mechanics — driver resolution, adaptive dispatch (``bucketed``/``select``),
ECRT pricing, the optional noisy downlink broadcast leg, airtime/telemetry,
eval cadence — live in :mod:`repro.fl.engine` (:class:`~repro.fl.engine.RoundEngine`
plus the :class:`~repro.fl.engine.FedSGD` strategy), shared with FedAvg and
any future algorithm. ``run_fl`` keeps its historical signature and is
bit-identical to the pre-engine loop for every pre-existing configuration
(``tests/test_engine_golden.py``).

Scenario-driven rounds (``scenario=``), adaptive dispatch
(``adaptive_dispatch=``), and the downlink leg (``downlink=``) are
documented on the engine module.
"""

from __future__ import annotations

import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import engine as engine_lib

# Re-exported for backward compatibility: these helpers lived here before
# the engine refactor and are imported by tests/benchmarks.
from repro.fl.engine import (  # noqa: F401
    FLResult,
    dropout_weighted_mean,
    link_telemetry,
    record_link_round,
    resolve_ecrt_analytic,
    resolve_scenario,
    select_mode_cfgs,
)


def run_fl(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,  # (M, n, 28, 28)
    client_y: np.ndarray,  # (M, n)
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 40,
    batch_per_round: int = 32,
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
    adaptive_dispatch: str = "bucketed",
    downlink=None,
    compression=None,
    fused_aggregate: bool = False,
    ledger=None,
    phase_timers=None,
    sketches=None,
) -> FLResult:
    """FedSGD over the simulated wireless uplink (paper Sec. II eq. (4)-(6)).

    Args:
      cfg: CNN model/optimizer config (``configs.mnist_cnn``).
      transport_cfg: uplink transport; real-FEC ECRT is swapped for the
        calibrated analytic model (see ``engine.resolve_ecrt_analytic``).
      client_x / client_y: stacked per-client shards, leaves ``(M, n, ...)``.
      test_x / test_y: held-out eval set (accuracy every ``eval_every``).
      n_rounds / batch_per_round / seed: round count, per-round minibatch
        size, and the seed driving params/keys/batch sampling.
      timings: PHY timing model for airtime pricing.
      scenario: ``None`` for the paper's static single-mode uplink, else a
        scenario name / ``Scenario`` / ``ScenarioDriver`` — per-round link
        adaptation with telemetry in ``FLResult.link``.
      adaptive_dispatch: ``"bucketed"`` (default) or ``"select"`` — see
        :mod:`repro.fl.engine`.
      downlink: optional ``DownlinkConfig`` enabling the noisy broadcast
        leg (defaults to the scenario's ``downlink`` field; ``None`` = the
        historical error-free downlink, bit-identical to pre-engine runs).
      compression: optional ``repro.compress.CompressionConfig`` enabling
        sparse (top-k/rand-k/threshold + error-feedback) uplinks over the
        sparse wire format (defaults to the scenario's ``compression``
        field; ``None`` = dense uplinks, bit-identical to the
        pre-compression engine).
      fused_aggregate: fold the PS aggregation into the uplink transport
        (in-kernel accumulator on ``use_kernel`` configs) — the fused round
        hot path, bit-identical to the layered
        ``fedsgd_aggregate``-over-``transmit_batch`` composition; see
        :mod:`repro.fl.engine`.
      ledger: optional JSONL run-ledger sink — a path or a
        ``repro.obs.RunLedger``. Writes a run manifest, per-round records,
        eval points, and a summary; changes no numeric result.
      phase_timers: optional ``repro.obs.PhaseTimers`` collecting per-phase
        wall-clock scopes (first/compile call split from steady state).
      sketches: ``True`` / layout dict / ``repro.obs.RoundSketcher`` —
        attach constant-memory per-client distribution sketches to every
        round record (scenario runs only; changes no numeric result).

    Returns:
      :class:`~repro.fl.engine.FLResult`.
    """
    algo = engine_lib.FedSGD(cfg, batch_per_round=batch_per_round)
    return engine_lib.RoundEngine(
        algo, transport_cfg, client_x, client_y, test_x, test_y,
        n_rounds=n_rounds, seed=seed, eval_every=eval_every, timings=timings,
        scenario=scenario, adaptive_dispatch=adaptive_dispatch,
        downlink=downlink, compression=compression,
        fused_aggregate=fused_aggregate, ledger=ledger,
        phase_timers=phase_timers, sketches=sketches,
    ).run()
