"""The paper's FL simulation: FedSGD over a noisy wireless uplink.

One round (paper Sec. II):
  1. every client computes a single-step gradient on its local shard (4)
  2. the stacked (M, D) gradient matrix goes through the *batched* uplink
     engine (``transport.transmit_batch``) — M independent fading channels,
     optionally heterogeneous per-client SNR, one fused computation
  3. the PS aggregates (5) and updates the global model (6)
  4. airtime for the round = slowest client's uplink (TDMA: sum is also
     reported; Fig. 3 uses the per-round wall time accumulation)

One XLA program per round regardless of M; per-client TxStats feed the
latency model directly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.optim.sgd import sgd as make_sgd


@dataclasses.dataclass
class FLResult:
    rounds: list
    accuracy: list
    airtime_s: list  # cumulative uplink airtime (TDMA sum over clients)
    wall_s: float
    final_accuracy: float


def run_fl(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,  # (M, n, 28, 28)
    client_y: np.ndarray,  # (M, n)
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 40,
    batch_per_round: int = 32,
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    opt = make_sgd(cfg.lr)
    opt_state = opt.init(params)

    # ECRT inside a vmapped per-round loop uses the calibrated analytic model
    # (the real decoder is exercised in tests/benchmarks; see DESIGN.md).
    # Heterogeneous cohorts calibrate at the mean SNR (E[tx] is a round-level
    # airtime constant here, not a per-client quantity).
    if transport_cfg.mode == "ecrt" and transport_cfg.simulate_fec:
        snr_cal = float(np.mean(np.asarray(transport_cfg.channel.snr_db)))
        e_tx = latency_lib.calibrate_ecrt(
            snr_cal, transport_cfg.modulation, n_codewords=96, max_tx=6)
        transport_cfg = dataclasses.replace(
            transport_cfg, simulate_fec=False, ecrt_expected_tx=float(e_tx))

    grad_fn = jax.grad(cnn.loss_fn)

    @jax.jit
    def round_step(params, opt_state, xb, yb, key):
        def client_grad(x, y):
            return grad_fn(params, x, y)

        grads = jax.vmap(client_grad)(xb, yb)  # pytree leaves (M, ...)
        # Batched uplink: M independent channels, fold_in key schedule,
        # per-client TxStats — one fused computation instead of M pipelines.
        grads_hat, stats = transport_lib.transmit_pytree_batch(
            grads, key, transport_cfg)
        agg = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads_hat)
        new_params, new_state = opt.update(agg, opt_state, params)
        return new_params, new_state, stats

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, batch_per_round))
        xb = jnp.asarray(np.take_along_axis(client_x, take[:, :, None, None], axis=1))
        yb = jnp.asarray(np.take_along_axis(client_y, take, axis=1))
        params, opt_state, stats = round_step(params, opt_state, xb, yb, rk)
        # TDMA uplink: total airtime is the sum over clients ((M,) stats)
        per_client_air = latency_lib.round_airtime(stats, timings, transport_cfg.mode)
        cum_air += float(jnp.sum(per_client_air))
        if r % eval_every == 0 or r == n_rounds - 1:
            acc = float(eval_acc(params))
            res.rounds.append(r)
            res.accuracy.append(acc)
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res
