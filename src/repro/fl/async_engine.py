"""Event-driven buffered FL engine: FedBuff-style asynchronous rounds.

The synchronous :class:`~repro.fl.engine.RoundEngine` closes a barrier every
round: the cohort's uplink is materialized at once and the slowest client
stalls everyone — exactly the regime the paper's approximate-communication
scheme is meant to escape. This module replaces the barrier with an **event
clock** (Nguyen et al.'s FedBuff, arXiv:2106.06639, composed with this
repo's noisy two-leg transport): clients are dispatched in *waves*, each
client's update lands at

    t_arrival = t_dispatch + downlink_wait + compute_time + uplink_airtime

(``core.latency.arrival_times``; compute times from
``link.dynamics.ComputeTimeConfig``, airtime from the same per-client
pricing the synchronous engine uses), and the server aggregates whenever
``buffer_k`` updates have landed — weighting each buffered update by a
pluggable **staleness function** of how many aggregations it missed while
in flight (constant / polynomial / inverse).

Determinism and the key-lane convention
---------------------------------------
The wave key schedule *is* the synchronous round schedule: one
``key, rk = split(key)`` per dispatched wave, with every extra draw riding
reserved ``fold_in`` lanes of ``rk`` (``dynamics.COMPUTE_KEY_LANE`` for
compute times, ``dynamics.EVENT_KEY_LANE`` for churn/idle draws) — lanes
consume no splits and each client folds its own index, so arrival draws are
bit-stable across dispatches and independent of cohort batching. Every wave
computes the **full-cohort** uplink with non-members masked out: per-client
fold_in keys make the member rows bit-identical to a subset computation,
shapes stay static (one compiled program per wave variant), and discarded
non-member draws perturb nothing.

The load-bearing invariant (``tests/test_async_golden.py``): with
simultaneous arrivals (degenerate compute model), ``buffer_k =`` cohort
size, and constant staleness weights, every wave is one full synchronous
round — the buffered engine is **bit-identical** to ``RoundEngine`` on
every scenario x algorithm x dispatch combination, including compressed
and noisy-downlink arms. Two arithmetic details make that exact:

* a buffer holding one complete uniform-weight driver-less wave aggregates
  with ``jnp.mean`` (the weighted mean reduces to the plain mean in real
  arithmetic, but not bit-wise — ``tensordot(ones, g)/M != mean(g, 0)`` on
  XLA CPU, so the degenerate path must use the synchronous engine's op);
* scenario buffers use ``tensordot(wvec, hat) / where(total > 0, total, 1)``
  — bit-equal to ``engine.dropout_weighted_mean``'s ``maximum(total, 1)``
  form whenever the weights are 0/1.

State across participation gaps
-------------------------------
EF/compression residuals update through a ``where(member, new, old)`` mask:
a client that skips R waves (dropped, in flight, or churned out) re-enters
with its full accumulated residual bit-exact. Link-policy hysteresis and
CSI memory survive the same way: ``ScenarioDriver.round(observed=member)``
holds absent clients' modes, and the previous-estimate carry only refreshes
member rows.
"""

from __future__ import annotations

import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import framing as framing_lib
from repro.compress import sparsify as sparsify_lib
from repro.core import aggregation as aggregation_lib
from repro.core import keylanes
from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import engine as engine_lib
from repro.link import dynamics as dynamics_lib
from repro.obs import records as obs_records_lib
from repro.obs import trace as obs_trace_lib

__all__ = [
    "STALENESS_KINDS",
    "staleness_weight",
    "weighted_buffer_mean",
    "AsyncRoundEngine",
    "run_fl_buffered",
    "run_fedavg_buffered",
]

STALENESS_KINDS = ("constant", "polynomial", "inverse")


def staleness_weight(staleness, kind: str = "constant",
                     alpha: float = 0.5) -> jax.Array:
    """Aggregation weight of an update that missed ``staleness`` rounds.

    ``constant`` is exactly 1.0 regardless of staleness (FedBuff's
    unweighted buffer, and the synchronous-equivalence setting);
    ``polynomial`` is ``(1 + s)^-alpha`` (Xie et al.'s FedAsync damping);
    ``inverse`` is ``1 / (1 + s)``. All are non-negative, equal to 1 at
    ``s = 0``, and non-increasing in ``s``; normalization happens in the
    aggregation (:func:`weighted_buffer_mean` divides by the total weight).
    """
    s = jnp.asarray(staleness, jnp.float32)
    if kind == "constant":
        return jnp.ones_like(s)
    if kind == "polynomial":
        return (1.0 + s) ** (-alpha)
    if kind == "inverse":
        return 1.0 / (1.0 + s)
    raise ValueError(
        f"unknown staleness kind {kind!r}; pick one of {STALENESS_KINDS}")


def weighted_buffer_mean(entries):
    """Staleness-weighted mean of buffered wave payloads.

    ``entries`` is an iterable of ``(wave_id, hat, wvec)``: ``hat`` a
    payload pytree with ``(M, ...)`` leaves, ``wvec`` the ``(M,)``
    per-client weight (0 for clients of the wave not in the buffer).
    Entries are canonicalized by wave id before any float op, so the
    result is **invariant to arrival order** — the property the buffered
    engine's aggregation schedule relies on (and
    ``tests/test_async_properties.py`` pins). An all-zero total weight
    yields zeros (the model does not move), mirroring
    ``engine.dropout_weighted_mean``.
    """
    entries = sorted(entries, key=lambda e: e[0])
    if not entries:
        raise ValueError("weighted_buffer_mean needs at least one entry")
    part = None
    total = jnp.float32(0.0)
    for _, hat, wvec in entries:
        w = jnp.asarray(wvec, jnp.float32)
        p = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(w, g, axes=(0, 0)), hat)
        part = p if part is None else jax.tree_util.tree_map(
            jnp.add, part, p)
        total = total + jnp.sum(w)
    denom = jnp.where(total > 0, total, 1.0)
    return jax.tree_util.tree_map(lambda g: g / denom, part)


class AsyncRoundEngine(engine_lib.RoundEngine):
    """Buffered asynchronous round driver over the synchronous engine.

    Inherits all of :class:`~repro.fl.engine.RoundEngine`'s construction —
    scenario/downlink/compression resolution, analytic-ECRT pricing, the
    key schedule — and replaces the barrier loop with the event loop
    described in the module docstring. ``n_rounds`` counts *aggregations*
    (model versions), so results line up with the synchronous engine's
    round axis; ``FLResult.event_s`` carries the event-clock timestamp of
    each eval point.

    Scheduling model: new waves are dispatched at aggregation boundaries
    (and on buffer drains), sending every client that is joined, idle, and
    past its post-upload gap — a batched approximation of per-client
    restarts that keeps one compiled program per wave variant. Dropped
    clients (scenario ``dropout_prob``) produce no arrival and become
    ready again after their compute time; churned-out clients
    (``ArrivalConfig.p_leave``) keep any in-flight upload but are not
    re-dispatched until they rejoin.
    """

    def __init__(self, algorithm, transport_cfg, client_x, client_y,
                 test_x, test_y, *, n_rounds: int, buffer_k: int | None = None,
                 staleness: str = "constant", staleness_alpha: float = 0.5,
                 compute: dynamics_lib.ComputeTimeConfig | None = None,
                 arrival: dynamics_lib.ArrivalConfig | None = None,
                 seed: int = 0, eval_every: int = 2,
                 timings: latency_lib.PhyTimings | None = None,
                 scenario=None, adaptive_dispatch: str = "bucketed",
                 downlink=None, compression=None,
                 fused_aggregate: bool = False, ledger=None, trace=None,
                 phase_timers=None, sketches=None):
        super().__init__(
            algorithm, transport_cfg, client_x, client_y, test_x, test_y,
            n_rounds=n_rounds, seed=seed, eval_every=eval_every,
            timings=timings, scenario=scenario,
            adaptive_dispatch=adaptive_dispatch, downlink=downlink,
            compression=compression, fused_aggregate=fused_aggregate,
            ledger=ledger, phase_timers=phase_timers, sketches=sketches)
        # Perfetto trace sink (repro.obs.trace): a path or a TraceRecorder.
        # Like the ledger, a pure observer of host values the event loop
        # already computed.
        self.trace = obs_trace_lib.as_trace(trace)
        M = self.num_clients
        self.buffer_k = M if buffer_k is None else int(buffer_k)
        if not 1 <= self.buffer_k <= M:
            raise ValueError(
                f"buffer_k must be in [1, {M}], got {self.buffer_k}")
        if self.fused_aggregate and self.buffer_k != M:
            # With one full wave per aggregation, every buffered update has
            # staleness 0 and the aggregation weights are known at dispatch
            # — the precondition for folding the weighted sum into the wave's
            # transport pass. A partial buffer mixes waves of different
            # staleness, whose weights only exist at aggregation time.
            raise ValueError(
                "fused_aggregate=True needs buffer_k == num_clients "
                f"({M}): partial buffers weight updates by staleness at "
                "aggregation time, after the fused transport pass")
        if staleness not in STALENESS_KINDS:
            raise ValueError(
                f"staleness must be one of {STALENESS_KINDS}, got "
                f"{staleness!r}")
        self.staleness = staleness
        self.staleness_alpha = float(staleness_alpha)
        scen = None if self.driver is None else self.driver.scenario
        self.compute_cfg = (compute
                            or (scen.compute if scen is not None else None)
                            or dynamics_lib.ComputeTimeConfig())
        self.arrival_cfg = (arrival if arrival is not None
                            else (scen.arrival if scen is not None else None))
        # Frozen per-client speed factors ride a reserved lane of the
        # post-init base key — fold_in consumes no splits, so the wave key
        # schedule below still matches the synchronous round schedule.
        self._speed = dynamics_lib.client_speed_factors(
            jax.random.fold_in(self._key, keylanes.COMPUTE_KEY_LANE),
            M, self.compute_cfg)
        self._build_wave_fns()

    # ------------------------------------------------------- observability

    def _manifest(self) -> dict:
        """The synchronous manifest plus the buffering axis; the config
        fingerprint re-derives over the buffer/staleness/event-layer
        configs so async runs never collide with their sync twins."""
        from repro.obs import ledger as obs_ledger_lib

        man = super()._manifest()
        man["engine"] = "async"
        man["buffer_k"] = self.buffer_k
        man["staleness"] = self.staleness
        man["staleness_alpha"] = self.staleness_alpha
        man["fingerprint"] = obs_ledger_lib.config_fingerprint(
            man["fingerprint"], self.buffer_k, self.staleness,
            self.staleness_alpha, self.compute_cfg, self.arrival_cfg)
        return man

    def _emit_event(self, ev: obs_records_lib.EventRecord) -> None:
        """Fan one event-clock record out to the attached sinks (callers
        gate on ``_obs_events`` so uninstrumented runs build no records)."""
        if self.ledger is not None:
            self.ledger.write_event(ev)
        if self.trace is not None:
            self.trace.add(ev)

    @property
    def _obs_events(self) -> bool:
        """Whether any sink wants the event stream."""
        return (self.trace is not None
                or (self.ledger is not None and self.ledger.events))

    # ----------------------------------------------------------- wave fns

    def _build_wave_fns(self):
        """Jitted wave-step variants: the synchronous round steps with the
        aggregate/apply tail split off (buffered aggregation happens at its
        own event times) and a ``member`` mask threaded through the EF and
        link-memory updates. Masked-out rows are computed (static shapes)
        and discarded — per-client fold_in keys keep member rows
        bit-identical to the synchronous full-cohort rounds."""
        algo, tcfg, driver = self.algo, self.transport_cfg, self.driver
        dl, M = self.downlink, self.num_clients
        comp, D, kbase = self.compression, self._comp_dim, self._comp_k

        def _sel_keys(key):
            if comp.method != "randk":
                return None
            return sparsify_lib.selection_keys(key, M)

        # Aggregation/apply tails. The degenerate driver-less buffer (one
        # complete uniform-weight wave) must use jnp.mean — see the module
        # docstring; the weighted tail's where-form denominator is
        # bit-equal to dropout_weighted_mean's maximum-form for 0/1
        # weights.
        @jax.jit
        def agg_apply_mean(params, aux, hat):
            agg = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), hat)
            return algo.apply(params, aux, agg)

        @jax.jit
        def agg_apply_one(params, aux, hat, wvec):
            total = jnp.sum(wvec)
            denom = jnp.where(total > 0, total, 1.0)
            agg = jax.tree_util.tree_map(
                lambda g: jnp.tensordot(wvec, g, axes=(0, 0)) / denom, hat)
            return algo.apply(params, aux, agg)

        @jax.jit
        def apply_only(params, aux, agg):
            return algo.apply(params, aux, agg)

        self._agg_apply_mean = agg_apply_mean
        self._agg_apply_one = agg_apply_one
        self._apply_only = apply_only

        if driver is None:

            @jax.jit
            def wave_plain(params, xb, yb, key):
                dstats = None
                if dl is None:
                    payload = algo.payload(params, xb, yb)
                else:
                    recv, dstats = transport_lib.transmit_pytree_broadcast(
                        params, key, self.dl_cfg, M)
                    payload = algo.payload_from(recv, xb, yb)
                hat, stats = algo.wrap_uplink(
                    payload,
                    lambda t: transport_lib.transmit_pytree_batch(
                        t, key, tcfg))
                return hat, stats, dstats

            self._wave_plain = wave_plain

            if self.fused_aggregate:

                @jax.jit
                def wave_plain_fused(params, xb, yb, key, member):
                    # Fused wave: uplink + weighted aggregation in one
                    # transport pass. buffer_k == M guarantees this wave is
                    # the whole next aggregation (staleness 0), so the
                    # weights — the normalized member mask — are known now.
                    dstats = None
                    if dl is None:
                        payload = algo.payload(params, xb, yb)
                    else:
                        recv, dstats = transport_lib.transmit_pytree_broadcast(
                            params, key, self.dl_cfg, M)
                        payload = algo.payload_from(recv, xb, yb)
                    w = aggregation_lib.normalize_weights(member)
                    agg, stats = transport_lib.transmit_pytree_batch_aggregate(
                        payload, key, tcfg, w, donate=True)
                    return agg, stats, dstats

                self._wave_plain_fused = wave_plain_fused

            if comp is not None:

                @jax.jit
                def wave_plain_comp(params, xb, yb, key, residual, member):
                    dstats = None
                    if dl is None:
                        payload = algo.payload(params, xb, yb)
                    else:
                        recv, dstats = \
                            transport_lib.transmit_pytree_broadcast(
                                params, key, self.dl_cfg, M)
                        payload = algo.payload_from(recv, xb, yb)
                    flat, spec = transport_lib._flatten_client_tree(payload)
                    vals, idx, new_res = sparsify_lib.ef_select_batch(
                        residual, flat, kbase, comp, _sel_keys(key),
                        active=member)
                    hat_flat, stats = algo.wrap_uplink(
                        vals,
                        lambda v: framing_lib.transmit_sparse_batch(
                            v, idx, D, key, tcfg, comp))
                    hat = transport_lib._unflatten_client_tree(hat_flat, spec)
                    # Non-members never transmitted: keep their residual
                    # bit-exact (their payload rows were mask fodder).
                    new_res = jnp.where(member[:, None] > 0, new_res,
                                        residual)
                    return hat, stats, dstats, new_res

                self._wave_plain_comp = wave_plain_comp
            return

        @jax.jit
        def wave_link(params, xb, yb, key, lstate, prev_mode, prev_est,
                      member):
            # Select dispatch: the synchronous fused round minus its
            # aggregate/apply tail; hysteresis and CSI memory only refresh
            # member rows.
            k_link, k_tx = jax.random.split(key)
            lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link,
                                       observed=member)
            dstats = None
            if dl is None:
                payload = algo.payload(params, xb, yb)
            else:
                recv, dstats = self._broadcast_scenario(params, k_tx, rnd)
                payload = algo.payload_from(recv, xb, yb)
            hat, stats = algo.wrap_uplink(
                payload,
                lambda t: transport_lib.transmit_pytree_batch_adaptive(
                    t, k_tx, engine_lib.select_mode_cfgs(driver), rnd.mode,
                    snr_db=rnd.snr_db, dispatch="select"))
            new_est = jnp.where(member > 0, rnd.est_db, prev_est)
            return hat, stats, lstate, rnd, dstats, new_est

        self._wave_link = wave_link

        @jax.jit
        def link_round_obs(lstate, prev_mode, prev_est, key, member):
            lstate, rnd = driver.round(lstate, prev_mode, prev_est, key,
                                       observed=member)
            new_est = jnp.where(member > 0, rnd.est_db, prev_est)
            return lstate, rnd, new_est

        payload_shared = jax.jit(lambda params, xb, yb: algo.payload(
            params, xb, yb))
        payload_per_client = jax.jit(lambda recv, xb, yb: algo.payload_from(
            recv, xb, yb))

        def wave_link_bucketed(params, xb, yb, key, lstate, prev_mode,
                               prev_est, member):
            # Bucketed dispatch: the mode vector syncs to the host so each
            # transport leg runs per-mode buckets, as in the synchronous
            # engine.
            k_link, k_tx = jax.random.split(key)
            lstate, rnd, new_est = link_round_obs(lstate, prev_mode,
                                                  prev_est, k_link, member)
            mode_np = np.asarray(rnd.mode)
            dstats = None
            if dl is None:
                payload = payload_shared(params, xb, yb)
            else:
                dl_mode = None
                if dl.adaptive:
                    dl_mode = np.asarray(self._downlink_modes(
                        np.asarray(rnd.est_db)))
                recv, dstats = self._broadcast_scenario(
                    params, k_tx, rnd, dl_mode=dl_mode, dispatch="bucketed")
                payload = payload_per_client(recv, xb, yb)
            hat, stats = algo.wrap_uplink(
                payload,
                lambda t: transport_lib.transmit_pytree_batch_adaptive(
                    t, k_tx, driver.mode_cfgs, mode_np, snr_db=rnd.snr_db,
                    dispatch="bucketed"))
            return hat, stats, lstate, rnd, dstats, new_est

        self._wave_link_bucketed = wave_link_bucketed

        if self.fused_aggregate:
            fused_weights = jax.jit(
                lambda member, active: aggregation_lib.normalize_weights(
                    member * active))

            def wave_link_bucketed_fused(params, xb, yb, key, lstate,
                                         prev_mode, prev_est, member):
                # Fused bucketed wave: dropped and non-member clients still
                # transmit (mask fodder, exactly as the layered wave) but
                # fold into the accumulator with weight 0; only members that
                # will actually arrive carry weight, and with buffer_k == M
                # those are the whole next aggregation (staleness 0).
                k_link, k_tx = jax.random.split(key)
                lstate, rnd, new_est = link_round_obs(lstate, prev_mode,
                                                      prev_est, k_link,
                                                      member)
                mode_np = np.asarray(rnd.mode)
                dstats = None
                if dl is None:
                    payload = payload_shared(params, xb, yb)
                else:
                    dl_mode = None
                    if dl.adaptive:
                        dl_mode = np.asarray(self._downlink_modes(
                            np.asarray(rnd.est_db)))
                    recv, dstats = self._broadcast_scenario(
                        params, k_tx, rnd, dl_mode=dl_mode,
                        dispatch="bucketed")
                    payload = payload_per_client(recv, xb, yb)
                agg, stats = \
                    transport_lib.transmit_pytree_batch_adaptive_aggregate(
                        payload, k_tx, driver.mode_cfgs, mode_np,
                        fused_weights(member, rnd.active),
                        snr_db=rnd.snr_db, donate=True)
                return agg, stats, lstate, rnd, dstats, new_est

            self._wave_link_bucketed_fused = wave_link_bucketed_fused

        if comp is None:
            return

        @jax.jit
        def wave_link_comp(params, xb, yb, key, lstate, prev_mode, prev_est,
                           residual, member):
            k_link, k_tx = jax.random.split(key)
            lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link,
                                       observed=member)
            dstats = None
            if dl is None:
                payload = algo.payload(params, xb, yb)
            else:
                recv, dstats = self._broadcast_scenario(params, k_tx, rnd)
                payload = algo.payload_from(recv, xb, yb)
            flat, spec = transport_lib._flatten_client_tree(payload)
            eff = member * rnd.active
            vals, idx, new_res = sparsify_lib.ef_select_batch(
                residual, flat, kbase, comp, _sel_keys(k_tx), active=eff)
            hat_flat, stats = algo.wrap_uplink(
                vals,
                lambda v: framing_lib.transmit_sparse_batch_adaptive(
                    v, idx, D, k_tx, engine_lib.select_mode_cfgs(driver),
                    rnd.mode, comp, snr_db=rnd.snr_db, dispatch="select"))
            hat = transport_lib._unflatten_client_tree(hat_flat, spec)
            new_res = jnp.where(member[:, None] > 0, new_res, residual)
            new_est = jnp.where(member > 0, rnd.est_db, prev_est)
            return hat, stats, lstate, rnd, dstats, new_res, new_est

        self._wave_link_comp = wave_link_comp

        if comp.error_feedback:
            accumulate = jax.jit(lambda r, f: r + f)
            residual_update = jax.jit(
                lambda acc, sent, act: acc - sent * act[:, None])
        else:
            accumulate = jax.jit(lambda r, f: f)
            residual_update = jax.jit(
                lambda acc, sent, act: jnp.zeros_like(acc))
        keep_absent = jax.jit(
            lambda member, new, old: jnp.where(member[:, None] > 0, new, old))

        def wave_link_bucketed_comp(params, xb, yb, key, lstate, prev_mode,
                                    prev_est, residual, member):
            k_link, k_tx = jax.random.split(key)
            lstate, rnd, new_est = link_round_obs(lstate, prev_mode,
                                                  prev_est, k_link, member)
            mode_np = np.asarray(rnd.mode)
            dstats = None
            if dl is None:
                payload = payload_shared(params, xb, yb)
            else:
                dl_mode = None
                if dl.adaptive:
                    dl_mode = np.asarray(self._downlink_modes(
                        np.asarray(rnd.est_db)))
                recv, dstats = self._broadcast_scenario(
                    params, k_tx, rnd, dl_mode=dl_mode, dispatch="bucketed")
                payload = payload_per_client(recv, xb, yb)
            flat, spec = transport_lib._flatten_client_tree(payload)
            acc = accumulate(residual, flat)
            dense_hat, stats, sent = self._sparse_bucketed_uplink(
                acc, k_tx, mode_np, rnd.snr_db)
            eff = member * rnd.active
            new_res = residual_update(acc, sent, eff)
            new_res = keep_absent(member, new_res, residual)
            hat = transport_lib._unflatten_client_tree(dense_hat, spec)
            return hat, stats, lstate, rnd, dstats, new_res, new_est

        self._wave_link_bucketed_comp = wave_link_bucketed_comp

    # --------------------------------------------------------------- run

    def run(self) -> engine_lib.FLResult:
        """Drive ``n_rounds`` buffered aggregations; returns ``FLResult``
        with ``event_s`` timestamps alongside the usual curves."""
        algo, driver, timings = self.algo, self.driver, self.timings
        comp, tm = self.compression, self.phase_timers
        obs_events = self._obs_events
        M, K = self.num_clients, self.buffer_k
        params, aux, key = self.params, self.aux, self._key
        rng = np.random.default_rng(self.seed)
        res = engine_lib.FLResult([], [], [], 0.0, 0.0)
        t0 = time.time()  # lint: ignore[determinism] wall-clock telemetry
        if self.ledger is not None:
            self.ledger.write_manifest(self._manifest())

        cum_air = 0.0
        t_now = 0.0
        version = 0
        next_wave = 0
        buffered = 0
        ready_t = np.zeros(M, np.float64)
        in_flight = np.zeros(M, bool)
        joined = np.ones(M, np.float32)
        heap = []  # (t_arrival, wave_id, client) — deterministic tie order
        waves = {}  # wave_id -> {hat, version, arrived, pending, gaps}

        def dispatch():
            """Send one wave of every joined, idle, ready client. Returns
            True iff a wave went out. Consumes exactly one key split per
            attempt that reaches the churn/wave draw — never on a plain
            nobody-is-ready miss (the degenerate schedule stays one split
            per synchronous round)."""
            nonlocal key, next_wave, cum_air, params, aux
            idle = (joined > 0) & ~in_flight & (ready_t <= t_now)
            if self.arrival_cfg is None and not idle.any():
                return False
            key, rk = jax.random.split(key)
            if self.arrival_cfg is not None:
                prev_joined = joined.copy()
                joined[:] = np.asarray(dynamics_lib.churn_step(
                    rk, jnp.asarray(joined), self.arrival_cfg))
                if obs_events:
                    for i in np.nonzero(prev_joined != joined)[0]:
                        self._emit_event(obs_records_lib.EventRecord(
                            t=t_now,
                            kind="join" if joined[i] > 0 else "leave",
                            client=int(i)))
                idle = (joined > 0) & ~in_flight & (ready_t <= t_now)
                if not idle.any():
                    return False
            member_np = idle.astype(np.float32)
            member = jnp.asarray(member_np)
            with tm.scope("sample"):
                xb, yb = algo.sample(rng, self.client_x, self.client_y)
            rnd = None
            agg = hat = None
            if driver is None:
                with tm.scope("wave"):
                    if self.fused_aggregate:
                        agg, stats, dstats = self._wave_plain_fused(
                            params, xb, yb, rk, member)
                    elif comp is None:
                        hat, stats, dstats = self._wave_plain(
                            params, xb, yb, rk)
                    else:
                        hat, stats, dstats, self._ef_residual = \
                            self._wave_plain_comp(params, xb, yb, rk,
                                                  self._ef_residual, member)
                rec = obs_records_lib.RoundRecord(round=next_wave)
                with tm.scope("telemetry"):
                    per_air = latency_lib.round_airtime(
                        stats, timings, self.transport_cfg.mode)
                    if self.ecrt_air_scale is not None:
                        per_air = per_air * self.ecrt_air_scale
                    per_air = per_air * member
                active = member
            else:
                with tm.scope("wave"):
                    if self.fused_aggregate:
                        (agg, stats, self.lstate, rnd, dstats,
                         self.prev_est) = self._wave_link_bucketed_fused(
                            params, xb, yb, rk, self.lstate, self.prev_mode,
                            self.prev_est, member)
                    elif comp is None:
                        step = (self._wave_link_bucketed
                                if self.dispatch == "bucketed"
                                else self._wave_link)
                        (hat, stats, self.lstate, rnd, dstats,
                         self.prev_est) = step(
                            params, xb, yb, rk, self.lstate, self.prev_mode,
                            self.prev_est, member)
                    else:
                        step = (self._wave_link_bucketed_comp
                                if self.dispatch == "bucketed"
                                else self._wave_link_comp)
                        (hat, stats, self.lstate, rnd, dstats,
                         self._ef_residual, self.prev_est) = step(
                            params, xb, yb, rk, self.lstate, self.prev_mode,
                            self.prev_est, self._ef_residual, member)
                self.prev_mode = rnd.mode
                with tm.scope("telemetry"):
                    per_air = driver.airtime(stats, rnd, timings) * member
                    rec = obs_records_lib.scenario_round_record(
                        next_wave, rnd, per_air, len(driver.mode_cfgs))
                active = member * rnd.active
            cum_air += float(jnp.sum(per_air))
            if comp is not None:
                self._compression_record(rec, stats, rnd)
            dl_wait = 0.0
            if dstats is not None:
                dl_wait = self._downlink_air_record(rec, dstats)
                cum_air += dl_wait
            comp_s = np.asarray(dynamics_lib.compute_times(
                rk, self.compute_cfg, M, self._speed), np.float64)
            air_np = np.asarray(per_air, np.float64)
            arr = latency_lib.arrival_times(t_now, comp_s, air_np, dl_wait)
            gaps = np.zeros(M, np.float64)
            if self.arrival_cfg is not None:
                gaps = np.asarray(dynamics_lib.idle_gaps(
                    rk, M, self.arrival_cfg), np.float64)
            active_b = np.asarray(active) > 0
            pending = 0
            for i in np.nonzero(member_np > 0)[0]:
                i = int(i)
                if active_b[i]:
                    heapq.heappush(heap, (float(arr[i]), next_wave, i))
                    in_flight[i] = True
                    pending += 1
                else:
                    # Dropped: no uplink happened (air = 0), the client is
                    # back after its broadcast wait + compute time.
                    ready_t[i] = float(arr[i])
            if obs_events:
                members = np.nonzero(member_np > 0)[0]
                arrived = [float(arr[i]) for i in members if active_b[i]]
                self._emit_event(obs_records_lib.EventRecord(
                    t=t_now, kind="wave", wave=next_wave,
                    dur=(max(arrived) - t_now) if arrived else 0.0,
                    value=float(len(members))))
                for i in members:
                    i = int(i)
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_now + dl_wait, kind="compute", wave=next_wave,
                        client=i, dur=float(comp_s[i])))
                    if active_b[i]:
                        self._emit_event(obs_records_lib.EventRecord(
                            t=t_now + dl_wait + float(comp_s[i]),
                            kind="uplink", wave=next_wave, client=i,
                            dur=float(air_np[i])))
            if self.sketcher is not None:
                with tm.scope("telemetry"):
                    rec.sketches = self.sketcher.round_group(
                        rk, snr_db=rnd.snr_db, est_db=rnd.est_db,
                        ber=stats.client_metrics()["ber"],
                        airtime_s=per_air, mode=rnd.mode,
                        active=rnd.active, member=member,
                        downlink_ber=(None if dstats is None
                                      else dstats.ber))
            rec.t_event = t_now
            self._finish_record(res, rec, stats)
            waves[next_wave] = {
                "hat": hat, "agg": agg, "version": version,
                "arrived": np.zeros(M, np.float32),
                "pending": pending, "gaps": gaps,
            }
            next_wave += 1
            return True

        def aggregate():
            """Fold the buffer into the model: one aggregation = one model
            version. Entries iterate in wave-id order (arrival-order
            invariant); the degenerate driver-less buffer takes the
            synchronous engine's ``jnp.mean`` path. Fused runs hold exactly
            one wave (buffer_k == M) whose transport pass already produced
            the aggregate — only the apply tail runs here."""
            nonlocal params, aux, version, buffered
            if self.fused_aggregate:
                w = max(waves)
                info = waves[w]
                if obs_events:
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_now, kind="aggregate", version=version,
                        value=float(info["arrived"].sum())))
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_now, kind="buffer", value=0.0))
                if self.sketcher is not None:
                    # Fused buffers hold exactly one zero-staleness wave.
                    self.sketcher.observe_staleness(
                        np.zeros(int(info["arrived"].sum()), np.float32))
                params, aux = self._apply_only(params, aux, info["agg"])
                del waves[w]
                buffered = 0
            else:
                entries = []
                for w in sorted(waves):
                    info = waves[w]
                    mask = info["arrived"]
                    if not mask.any():
                        continue
                    om = float(staleness_weight(
                        version - info["version"], self.staleness,
                        self.staleness_alpha))
                    entries.append((w, info["hat"],
                                    jnp.asarray(mask * np.float32(om)),
                                    mask, om))
                if self.sketcher is not None and entries:
                    # One staleness observation per folded client update.
                    self.sketcher.observe_staleness(np.concatenate([
                        np.full(int(mask.sum()),
                                version - waves[w]["version"], np.float32)
                        for w, _, _, mask, _ in entries]))
                if obs_events:
                    folded = sum(
                        int(mask.sum()) for _, _, _, mask, _ in entries)
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_now, kind="aggregate", version=version,
                        value=float(folded)))
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_now, kind="buffer", value=0.0))
                uniform_full = (
                    len(entries) == 1 and entries[0][4] > 0
                    and bool(entries[0][3].all()))
                if not entries:
                    # Every member of the flushed wave dropped out before
                    # the uplink: the synchronous engine still applies the
                    # (zero) aggregate and counts the round, so mirror its
                    # arithmetic — zero weights through the weighted tail.
                    w = max(waves)
                    params, aux = self._agg_apply_one(
                        params, aux, waves[w]["hat"],
                        jnp.zeros(M, jnp.float32))
                elif driver is None and uniform_full:
                    params, aux = self._agg_apply_mean(params, aux,
                                                       entries[0][1])
                elif len(entries) == 1:
                    params, aux = self._agg_apply_one(params, aux,
                                                      entries[0][1],
                                                      entries[0][2])
                else:
                    agg = weighted_buffer_mean(
                        [(w, hat, wvec) for w, hat, wvec, _, _ in entries])
                    params, aux = self._apply_only(params, aux, agg)
                for w, *_ in entries:
                    waves[w]["arrived"][:] = 0.0
                for w in [w for w, info in waves.items()
                          if info["pending"] == 0
                          and not info["arrived"].any()]:
                    del waves[w]
                buffered = 0
            r = version
            version += 1
            if r % self.eval_every == 0 or r == self.n_rounds - 1:
                with tm.scope("eval"):
                    acc = float(self._eval_acc(params))
                res.rounds.append(r)
                res.accuracy.append(acc)
                res.airtime_s.append(cum_air)
                res.event_s.append(t_now)
                if self.ledger is not None:
                    self.ledger.write_eval(r, acc, cum_air, event_s=t_now)

        dispatch()
        stalls = 0
        while version < self.n_rounds:
            if buffered >= K or (not heap and waves):
                # Trigger: K updates landed — or the pipeline drained with
                # outstanding waves (a partial buffer, e.g. the wave minus
                # dropouts — or a fully-dropped wave, which still costs a
                # zero-update round), which must aggregate *before* any
                # re-dispatch so the degenerate schedule matches the
                # synchronous rounds.
                aggregate()
                if version < self.n_rounds:
                    dispatch()
                continue
            if heap:
                t_arr, w, i = heapq.heappop(heap)
                t_now = t_arr
                info = waves[w]
                info["arrived"][i] = 1.0
                info["pending"] -= 1
                in_flight[i] = False
                ready_t[i] = t_arr + info["gaps"][i]
                buffered += 1
                if obs_events:
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_arr, kind="arrival", wave=w, client=int(i)))
                    self._emit_event(obs_records_lib.EventRecord(
                        t=t_arr, kind="buffer", value=float(buffered)))
                continue
            # Empty buffer, nothing in flight: dispatch, or advance the
            # clock to the next ready client, or churn until someone
            # rejoins.
            if dispatch():
                stalls = 0
                continue
            cand = ready_t[(joined > 0) & ~in_flight]
            if cand.size and cand.min() > t_now:
                t_now = float(cand.min())
                continue
            stalls += 1
            if (self.arrival_cfg is None
                    or self.arrival_cfg.p_rejoin <= 0 or stalls > 100_000):
                raise RuntimeError(
                    "buffered run stalled: no client can ever arrive "
                    f"(version {version}/{self.n_rounds})")

        self.params, self.aux, self._key = params, aux, key
        res.wall_s = time.time() - t0  # lint: ignore[determinism]
        res.final_accuracy = res.accuracy[-1]
        self._finish_run(res)
        if self.trace is not None and self.trace.path is not None:
            self.trace.export()
        return res


def run_fl_buffered(cfg, transport_cfg, client_x, client_y, test_x, test_y,
                    n_rounds: int = 40, batch_per_round: int = 32,
                    seed: int = 0, eval_every: int = 2, timings=None,
                    scenario=None, adaptive_dispatch: str = "bucketed",
                    downlink=None, compression=None,
                    fused_aggregate: bool = False,
                    buffer_k: int | None = None,
                    staleness: str = "constant",
                    staleness_alpha: float = 0.5,
                    compute=None, arrival=None, ledger=None, trace=None,
                    phase_timers=None, sketches=None) -> engine_lib.FLResult:
    """Buffered (FedBuff-style) FedSGD over the simulated wireless uplink.

    The asynchronous counterpart of :func:`repro.fl.loop.run_fl` — same
    arguments plus the buffer size ``buffer_k`` (``None`` = cohort size),
    the ``staleness`` weighting (``constant``/``polynomial``/``inverse``
    with exponent ``staleness_alpha``), and optional
    ``compute``/``arrival`` event-layer overrides (defaulting to the
    scenario's fields). With ``buffer_k = None``, a degenerate compute
    model, and constant weights the result is bit-identical to ``run_fl``.
    ``ledger``/``trace``/``phase_timers`` attach observability sinks
    (:mod:`repro.obs`) without changing any numeric result.
    """
    algo = engine_lib.FedSGD(cfg, batch_per_round=batch_per_round)
    return AsyncRoundEngine(
        algo, transport_cfg, client_x, client_y, test_x, test_y,
        n_rounds=n_rounds, buffer_k=buffer_k, staleness=staleness,
        staleness_alpha=staleness_alpha, compute=compute, arrival=arrival,
        seed=seed, eval_every=eval_every, timings=timings, scenario=scenario,
        adaptive_dispatch=adaptive_dispatch, downlink=downlink,
        compression=compression, fused_aggregate=fused_aggregate,
        ledger=ledger, trace=trace, phase_timers=phase_timers,
        sketches=sketches,
    ).run()


def run_fedavg_buffered(cfg, transport_cfg, client_x, client_y, test_x,
                        test_y, n_rounds: int = 40, local_steps: int = 4,
                        batch_per_step: int = 32, scale_mode: str = "none",
                        seed: int = 0, eval_every: int = 2, timings=None,
                        scenario=None, adaptive_dispatch: str = "bucketed",
                        downlink=None, compression=None,
                        fused_aggregate: bool = False,
                        buffer_k: int | None = None,
                        staleness: str = "constant",
                        staleness_alpha: float = 0.5,
                        compute=None, arrival=None, ledger=None, trace=None,
                        phase_timers=None,
                        sketches=None) -> engine_lib.FLResult:
    """Buffered (FedBuff-style) FedAvg — the asynchronous counterpart of
    :func:`repro.fl.fedavg.run_fedavg`; see :func:`run_fl_buffered` for the
    buffering and observability arguments."""
    algo = engine_lib.FedAvg(cfg, local_steps=local_steps,
                             batch_per_step=batch_per_step,
                             scale_mode=scale_mode)
    return AsyncRoundEngine(
        algo, transport_cfg, client_x, client_y, test_x, test_y,
        n_rounds=n_rounds, buffer_k=buffer_k, staleness=staleness,
        staleness_alpha=staleness_alpha, compute=compute, arrival=arrival,
        seed=seed, eval_every=eval_every, timings=timings, scenario=scenario,
        adaptive_dispatch=adaptive_dispatch, downlink=downlink,
        compression=compression, fused_aggregate=fused_aggregate,
        ledger=ledger, trace=trace, phase_timers=phase_timers,
        sketches=sketches,
    ).run()
