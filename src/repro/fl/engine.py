"""Unified FL round engine: one driver for every algorithm x dispatch x leg.

Before this module, ``fl/loop.py`` (FedSGD) and ``fl/fedavg.py`` (FedAvg)
each hand-wrote four round-step variants (driver-less, scenario+select,
scenario+bucketed, plus the jitted helper pieces) and duplicated the
driver/ECRT/airtime/eval plumbing — eight round functions to maintain, and
every new transport leg or algorithm would have doubled that again. The
engine splits the round into two orthogonal pieces:

* an :class:`Algorithm` strategy — *what* the clients compute and how the PS
  applies the aggregate. :class:`FedSGD` uploads one-step gradients and
  applies them through the SGD optimizer (paper eq. (4)-(6));
  :class:`FedAvg` uploads local-step weight deltas with optional per-client
  ``max_abs`` scaling and adds the mean delta to the global model.
* one :class:`RoundEngine` — *how* a round runs: scenario-driver resolution,
  adaptive-dispatch selection (bucketed/select), analytic-ECRT pricing,
  the optional noisy **downlink broadcast leg**, airtime accumulation, link
  telemetry, and the eval cadence. Every algorithm gets every axis for free.

``run_fl`` / ``run_fedavg`` keep their exact historical signatures as thin
wrappers and are **bit-identical** to the pre-engine loops for any
pre-existing configuration (``tests/test_engine_golden.py`` pins this
against a frozen snapshot): the fold_in key schedule, the jit boundaries,
and the op order of every round variant are preserved.

Downlink leg (beyond-paper; Qu et al., arXiv:2310.16652)
--------------------------------------------------------
``downlink=DownlinkConfig(...)`` (or a scenario whose ``downlink`` is set)
inserts a broadcast step at the top of each round: the global model rides
``transport.transmit_broadcast`` through every client's *downlink* channel
(error-free, or uncoded at an SNR offset from the uplink; per-client mode
via the scenario's policy table when ``adaptive=True``), and each client
computes its payload from its own corrupted copy. The broadcast reuses the
round's uplink base key on the downlink key lane
(``transport.DOWNLINK_KEY_LANE``), so uplink draws are unchanged — with
``downlink=None`` every result is bit-identical to the downlink-free loops.

Compressed uplinks (beyond-paper; Ma et al. 2404.11035, Amiri & Gündüz
1907.09769)
-----------------------------------------------------------------------
``compression=CompressionConfig(...)`` (or a scenario whose ``compression``
is set) replaces each round's dense uplink with the sparse wire
(:mod:`repro.compress`): every client accumulates an error-feedback
residual, selects ``k`` coordinates of ``residual + payload`` (top-k /
rand-k / threshold), and transmits the values through the configured
transport plus a protected index header. The EF residual is carried across
rounds per client inside the engine — dropped clients keep their whole
accumulation (they never transmitted) — and the selection/transport keys
derive from the same per-client fold_in keys as the dense engine, so every
dispatch (driver-less, select, bucketed) sees the same selection. Under a
scenario, ``PolicyConfig.compress_ratios`` makes the slot budget
CSI-adaptive per mode (bucketed dispatch only — ragged per-mode budgets
cannot live in one fused trace). ``compression=None`` leaves every code
path and every random draw bit-identical to the dense engine.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import framing as framing_lib
from repro.compress import sparsify as sparsify_lib
from repro.core import aggregation as aggregation_lib
from repro.core import keylanes
from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.obs import ledger as obs_ledger_lib
from repro.obs import metrics as obs_metrics_lib
from repro.obs import records as obs_records_lib
from repro.obs import timers as obs_timers_lib
from repro.optim.sgd import sgd as make_sgd

__all__ = [
    "FLResult",
    "FedSGD",
    "FedAvg",
    "RoundEngine",
    "resolve_scenario",
    "resolve_downlink",
    "resolve_compression",
    "dropout_weighted_mean",
    "record_link_round",
    "link_telemetry",
    "select_mode_cfgs",
    "resolve_ecrt_analytic",
]


@dataclasses.dataclass
class FLResult:
    """Outcome of one FL run (shared by every algorithm/loop)."""

    rounds: list
    accuracy: list
    airtime_s: list  # cumulative airtime: TDMA uplink sum (+ downlink leg)
    wall_s: float
    final_accuracy: float
    # Per-round link telemetry, as dicts — the historical view, preserved
    # bit-identically (same keys, insertion order, values) now that the
    # engines build typed records first. Scenario-driven runs append {round,
    # mean_snr_db, mean_est_db, mode_counts, n_active, n_stragglers,
    # airtime_s} (mode_counts indexes the driver's mode table); runs with a
    # downlink leg add {downlink_airtime_s, downlink_ber[, and for adaptive
    # downlinks downlink_mode_counts]}; compressed runs add
    # {comp_ratio (mean kept fraction), comp_bits_on_air (active clients'
    # on-air bits this round), comp_residual_norm (mean per-client L2 of
    # the EF residual)} — driver-less downlink/compressed runs append
    # records with just their own fields. [] otherwise.
    link: list = dataclasses.field(default_factory=list)
    # Typed per-round telemetry: one ``repro.obs.records.RoundRecord`` per
    # round (or per dispatched wave of the buffered engine), *including*
    # rounds with no link fields. ``link`` above is the dict view of the
    # records that have any (``rec.to_link_dict()``); the records carry
    # observability-only extras (uplink BER aggregates, event-clock times)
    # when a ledger is attached.
    records: list = dataclasses.field(default_factory=list)
    # Event-clock timestamps (seconds) of each eval point, parallel to
    # ``rounds``/``accuracy``. Only the buffered asynchronous engine
    # (``fl.async_engine``) fills this — the synchronous engine has no
    # event clock and leaves it empty, keeping its results bit-comparable
    # to pre-async runs.
    event_s: list = dataclasses.field(default_factory=list)


def resolve_scenario(scenario, transport_cfg):
    """``scenario=`` argument -> a bound ``ScenarioDriver`` (or ``None``).

    Accepts a registered scenario name, a ``Scenario``, or an already-built
    ``ScenarioDriver``; the single resolution rule under ``run_fl`` and
    ``run_fedavg``.
    """
    if scenario is None:
        return None
    from repro.link import scenario as scenario_lib

    if isinstance(scenario, scenario_lib.ScenarioDriver):
        return scenario
    if isinstance(scenario, str):
        scenario = scenario_lib.get_scenario(scenario)
    return scenario_lib.ScenarioDriver(scenario, transport_cfg)


def resolve_downlink(downlink, driver):
    """``downlink=`` argument -> the round's ``DownlinkConfig`` (or ``None``).

    An explicit argument wins; otherwise a scenario-driven run inherits the
    scenario's ``downlink`` field. ``None`` means the historical error-free
    downlink (no broadcast leg at all).
    """
    if downlink is not None:
        return downlink
    if driver is not None:
        return driver.scenario.downlink
    return None


def resolve_compression(compression, driver):
    """``compression=`` argument -> the run's ``CompressionConfig`` (or ``None``).

    An explicit argument wins; otherwise a scenario-driven run inherits the
    scenario's ``compression`` field. ``None`` means dense uplinks —
    bit-identical to the pre-compression engine.
    """
    if compression is not None:
        return compression
    if driver is not None:
        return driver.scenario.compression
    return None


def dropout_weighted_mean(tree, active):
    """Mean of ``(M, ...)`` leaves over active clients only.

    ``active`` is the 0/1 ``(M,)`` availability vector; an all-dropped round
    yields zeros (the global model simply does not move). Jit-safe — the
    shared aggregation rule of every scenario-driven round.
    """
    denom = jnp.maximum(jnp.sum(active), 1.0)
    return jax.tree_util.tree_map(
        lambda g: jnp.tensordot(active, g, axes=(0, 0)) / denom, tree)


def record_link_round(res: "FLResult", r: int, driver, stats, rnd,
                      timings) -> jax.Array:
    """Per-round scenario bookkeeping shared by the FL loops: price the
    round's per-client airtime and append the telemetry record. Returns the
    ``(M,)`` airtime vector."""
    air = driver.airtime(stats, rnd, timings)
    res.link.append(link_telemetry(r, rnd, air, len(driver.mode_cfgs)))
    return air


def link_telemetry(r: int, rnd, per_client_air, n_modes: int) -> dict:
    """One ``FLResult.link`` record from a round's ``LinkRound`` + airtime."""
    mode = np.asarray(rnd.mode)
    return {
        "round": r,
        "mean_snr_db": float(np.mean(np.asarray(rnd.snr_db))),
        "mean_est_db": float(np.mean(np.asarray(rnd.est_db))),
        "mode_counts": np.bincount(mode, minlength=n_modes).tolist(),
        "n_active": int(np.asarray(rnd.active).sum()),
        "n_stragglers": int(np.asarray(rnd.straggler).sum()),
        "airtime_s": float(np.asarray(per_client_air).sum()),
    }


def select_mode_cfgs(driver):
    """The driver's mode table, legal for the select dispatch.

    Delegates to ``transport.clear_kernel_rows`` (the one clearing rule):
    the fused select round cannot lower the Pallas grid. A select round is
    therefore *not* bit-comparable to a bucketed round of a kernel-enabled
    table — the jnp rows draw their own, equally valid, channel
    realization; within the select dispatch everything stays deterministic
    as usual.
    """
    return transport_lib.clear_kernel_rows(driver.mode_cfgs)


def resolve_ecrt_analytic(transport_cfg, num_clients: int):
    """Swap real-FEC ECRT for the calibrated analytic model in an FL loop.

    The real decoder inside a vmapped per-round loop would only re-measure a
    constant; calibrate instead — with the shared pricing sample budget
    (``latency.DEFAULT_CALIB_CODEWORDS``), so every entry point resolves
    the same channel to the same E[tx]. Heterogeneous cohorts get E[tx]
    interpolated per client over an SNR grid (``ecrt_expected_tx_profile``),
    with the cohort mean driving the transport constant and the per-client
    ratio returned as a ``(num_clients,)`` airtime scale (the analytic model
    is linear in E[tx]). Returns ``(transport_cfg, air_scale_or_None)``.
    """
    if not (transport_cfg.mode == "ecrt" and transport_cfg.simulate_fec):
        return transport_cfg, None
    snr_vec = np.asarray(transport_cfg.channel.snr_db, np.float32).reshape(-1)
    e_tx = latency_lib.ecrt_expected_tx_profile(
        snr_vec, transport_cfg.modulation,
        n_codewords=latency_lib.DEFAULT_CALIB_CODEWORDS,
        max_tx=latency_lib.DEFAULT_CALIB_MAX_TX)
    e_mean = float(e_tx.mean())
    transport_cfg = dataclasses.replace(
        transport_cfg, simulate_fec=False, ecrt_expected_tx=e_mean)
    air_scale = None
    if e_tx.size == num_clients and e_tx.size > 1:
        air_scale = jnp.asarray(e_tx / e_mean)
    return transport_cfg, air_scale


# --------------------------------------------------------------- algorithms


class FedSGD:
    """The paper's algorithm: one gradient per client per round (eq. (4)-(6)).

    Payload = the stacked per-client single-step gradients; the PS applies
    the (dropout-weighted) mean through the SGD optimizer.
    """

    name = "fedsgd"

    def __init__(self, cfg, batch_per_round: int = 32):
        self.cfg = cfg
        self.batch_per_round = batch_per_round
        self.opt = make_sgd(cfg.lr)
        self.grad_fn = jax.grad(cnn.loss_fn)

    def init_params(self, key):
        """Global model at round 0."""
        return cnn.init_params(key, self.cfg)

    def init_opt(self, params):
        """Optimizer state threaded through the rounds."""
        return self.opt.init(params)

    def sample(self, rng, client_x, client_y):
        """One round's per-client minibatches: ``(M, B, ...)`` images/labels."""
        M = client_x.shape[0]
        take = rng.integers(0, client_x.shape[1], (M, self.batch_per_round))
        xb = jnp.asarray(
            np.take_along_axis(client_x, take[:, :, None, None], axis=1))
        yb = jnp.asarray(np.take_along_axis(client_y, take, axis=1))
        return xb, yb

    def payload(self, params, xb, yb):
        """Per-client gradients of the shared global model (error-free
        downlink): leaves ``(M, ...)``."""
        def client_grad(x, y):
            return self.grad_fn(params, x, y)

        return jax.vmap(client_grad)(xb, yb)

    def payload_from(self, recv_params, xb, yb):
        """Per-client gradients at each client's *received* model copy (the
        noisy-downlink variant of :meth:`payload`)."""
        return jax.vmap(self.grad_fn)(recv_params, xb, yb)

    def wrap_uplink(self, payload, transmit):
        """FedSGD uploads raw gradients — no transport-side scaling."""
        return transmit(payload)

    def apply(self, params, opt_state, agg):
        """PS update (eq. (6)): one optimizer step on the aggregate."""
        return self.opt.update(agg, opt_state, params)


class FedAvg:
    """FedAvg over the approximate uplink (beyond-paper extension).

    Payload = the weight delta after ``local_steps`` local SGD steps;
    deltas stay bounded (|Δw| <= eta * sum|g|), so the same exponent-clamp
    receiver prior applies. ``scale_mode``:

      ``none``     transmit raw deltas (paper-style prior |Δ| < 2)
      ``max_abs``  scale by 1/max|Δ| before transmission and undo at the PS;
                   the scalar travels on the (error-free) control channel.
                   This concentrates values near the top of the representable
                   range where relative QAM error is smallest.
    """

    name = "fedavg"

    def __init__(self, cfg, local_steps: int = 4, batch_per_step: int = 32,
                 scale_mode: str = "none"):
        self.cfg = cfg
        self.local_steps = local_steps
        self.batch_per_step = batch_per_step
        self.scale_mode = scale_mode
        self.grad_fn = jax.grad(cnn.loss_fn)
        # jitted so the host-driven bucketed round doesn't run the scale math
        # op-by-op; inside a fused round's trace they simply inline.
        self._compute_scale = jax.jit(self._scale_of)
        self._div_scale = jax.jit(self._div)
        self._mul_scale = jax.jit(self._mul)

    def init_params(self, key):
        """Global model at round 0."""
        return cnn.init_params(key, self.cfg)

    def init_opt(self, params):
        """FedAvg applies deltas directly — no optimizer state."""
        return None

    def sample(self, rng, client_x, client_y):
        """One round's batches: ``(M, local_steps, B, ...)`` images/labels."""
        M = client_x.shape[0]
        L, B = self.local_steps, self.batch_per_step
        sample_shape = client_x.shape[2:]
        take = rng.integers(0, client_x.shape[1], (M, L, B))
        xb = jnp.asarray(np.take_along_axis(
            client_x, take.reshape(M, -1)[:, :, None, None], axis=1
        ).reshape((M, L, B) + sample_shape))
        yb = jnp.asarray(np.take_along_axis(
            client_y, take.reshape(M, -1), axis=1
        ).reshape(M, L, B))
        return xb, yb

    def _local_delta(self, start, x, y):
        """One client's weight delta after ``local_steps`` SGD steps from
        ``start`` (its received copy of the global model)."""
        def body(p, inp):
            xi, yi = inp
            g = self.grad_fn(p, xi, yi)
            p = jax.tree_util.tree_map(lambda a, b: a - self.cfg.lr * b, p, g)
            return p, None

        local, _ = jax.lax.scan(body, start, (x, y))
        return jax.tree_util.tree_map(lambda a, b: a - b, local, start)

    def payload(self, params, xb, yb):
        """Per-client local-step deltas from the shared global model."""
        return jax.vmap(lambda x, y: self._local_delta(params, x, y))(xb, yb)

    def payload_from(self, recv_params, xb, yb):
        """Per-client deltas, each relative to that client's *received*
        model copy — the PS still adds the mean delta to the true model."""
        return jax.vmap(self._local_delta)(recv_params, xb, yb)

    @staticmethod
    def _expand(s, like):
        return s.reshape((s.shape[0],) + (1,) * (like.ndim - 1))

    def _scale_of(self, deltas):
        leaves = jax.tree_util.tree_leaves(deltas)
        M = leaves[0].shape[0]
        flat = jnp.concatenate([l.reshape(M, -1) for l in leaves], axis=1)
        return jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-8) / 0.9

    def _div(self, deltas, scale):
        return jax.tree_util.tree_map(
            lambda l: l / self._expand(scale, l), deltas)

    def _mul(self, deltas, scale):
        return jax.tree_util.tree_map(
            lambda l: l * self._expand(scale, l), deltas)

    def wrap_uplink(self, deltas, transmit):
        """Per-client adaptive scale (``scale_mode == "max_abs"``): one
        scalar per client travels on the (error-free) control channel; the
        cohort then rides the batched uplink unchanged."""
        if self.scale_mode != "max_abs":
            return transmit(deltas)
        scale = self._compute_scale(deltas)
        out, stats = transmit(self._div_scale(deltas, scale))
        return self._mul_scale(out, scale), stats

    def apply(self, params, aux, agg):
        """PS update: add the aggregated delta to the global model."""
        return jax.tree_util.tree_map(lambda p, d: p + d, params, agg), aux


# -------------------------------------------------------------- round engine


class RoundEngine:
    """One composable FL round driver for any :class:`Algorithm`.

    Owns everything the old per-algorithm loops duplicated: scenario-driver
    resolution, dispatch selection, analytic-ECRT pricing, the downlink
    broadcast leg, per-round airtime accumulation, link telemetry, and the
    eval cadence. Three round variants cover every configuration:

    * **driver-less** — one fused jitted round: [broadcast ->] payload ->
      single-mode batched uplink -> mean -> apply.
    * **scenario + select** — one fused jitted round: link pipeline ->
      [broadcast ->] payload -> vmapped-switch uplink -> dropout-weighted
      aggregate -> apply.
    * **scenario + bucketed** — jitted link/payload/apply steps around
      host-driven mode-bucketed transports (each mode runs once on its own
      client bucket; Pallas kernel rows allowed) — the mode vector syncs to
      the host once per round.

    The key schedule is the pre-engine one, exactly: ``key -> params`` split,
    an optional driver-init split, one split per round, and inside a
    scenario round ``k_link, k_tx = split(round_key)``. The downlink leg
    rides the *same* round/uplink key on the downlink fold_in lane, so
    enabling it consumes no extra splits and ``downlink=None`` runs are
    bit-identical to the pre-engine loops.
    """

    def __init__(self, algorithm, transport_cfg, client_x, client_y,
                 test_x, test_y, *, n_rounds: int, seed: int = 0,
                 eval_every: int = 2,
                 timings: latency_lib.PhyTimings | None = None,
                 scenario=None, adaptive_dispatch: str = "bucketed",
                 downlink=None, compression=None, fused_aggregate: bool = False,
                 ledger=None, phase_timers=None, sketches=None):
        self.algo = algorithm
        self.client_x, self.client_y = client_x, client_y
        self.test_x, self.test_y = test_x, test_y
        self.n_rounds = n_rounds
        self.seed = seed
        self.eval_every = eval_every
        self.timings = timings or latency_lib.PhyTimings()
        self.num_clients = client_x.shape[0]
        # Observability sinks (repro.obs). Pure observers: they only read
        # values the round already produced, so attaching them changes no
        # numeric result. ``ledger`` accepts a path or a RunLedger;
        # ``phase_timers`` accepts a PhaseTimers (None = shared no-op).
        self.ledger = obs_ledger_lib.as_ledger(ledger)
        self.phase_timers = obs_timers_lib.resolve_timers(phase_timers)

        key = jax.random.PRNGKey(seed)
        key, pk = jax.random.split(key)
        self.params = algorithm.init_params(pk)
        self.aux = algorithm.init_opt(self.params)
        self.driver = resolve_scenario(scenario, transport_cfg)
        if adaptive_dispatch not in ("bucketed", "select"):
            raise ValueError(
                f"adaptive_dispatch must be bucketed|select, got "
                f"{adaptive_dispatch!r}")
        self.dispatch = adaptive_dispatch
        # Per-client distribution sketches (repro.obs.metrics): like the
        # ledger, a pure observer — the sketcher only reads arrays the
        # round step already produced plus a reserved fold_in lane of the
        # round key, so sketches-on runs stay bit-identical to
        # sketches-off runs on weights and accuracy.
        self.sketcher = obs_metrics_lib.resolve_sketches(
            sketches, self.num_clients)
        if self.sketcher is not None and self.driver is None:
            raise ValueError(
                "sketches= needs a scenario — the per-client SNR/mode "
                "distributions being sketched come from the link driver")

        # Kept pre-resolution: the downlink leg re-derives its own transport
        # from this (its ECRT pricing anchors at the *shifted* SNR, not the
        # uplink's — see _downlink_transport_cfg).
        self._raw_transport_cfg = transport_cfg
        self.ecrt_air_scale = None
        if self.driver is None:
            transport_cfg, self.ecrt_air_scale = resolve_ecrt_analytic(
                transport_cfg, self.num_clients)
        self.transport_cfg = transport_cfg
        self.downlink = resolve_downlink(downlink, self.driver)
        if (self.downlink is not None and self.downlink.adaptive
                and self.driver is None):
            raise ValueError(
                "DownlinkConfig(adaptive=True) needs a scenario — the "
                "per-client downlink mode comes from the scenario's policy "
                "table; driver-less runs use a single broadcast mode")
        self.dl_air_scale = None
        self.dl_cfg = (None if self.downlink is None
                       else self._downlink_transport_cfg())

        self.compression = resolve_compression(compression, self.driver)
        self._ef_residual = None
        self._comp_ks = None
        self._comp_dim = self._comp_k = 0
        if self.compression is not None:
            comp = self.compression
            self._comp_dim = int(sum(
                l.size for l in jax.tree_util.tree_leaves(self.params)))
            self._comp_k = sparsify_lib.resolve_k(comp, self._comp_dim)
            if self.driver is not None:
                from repro.link import policy as policy_lib

                pol = self.driver.scenario.policy
                if comp.k is not None:
                    # An explicit absolute budget wins everywhere
                    # (resolve_k's rule): the policy's ratio column applies
                    # only to ratio-derived budgets, so bucketed and select
                    # dispatches agree on the slots per client.
                    self._comp_ks = (self._comp_k,) * len(pol.modes)
                else:
                    if (pol.compress_ratios is not None
                            and self.dispatch != "bucketed"):
                        raise ValueError(
                            "PolicyConfig.compress_ratios (per-mode slot "
                            "budgets) needs adaptive_dispatch='bucketed' — "
                            "a fused select round cannot trace ragged "
                            "per-mode selections")
                    self._comp_ks = policy_lib.compress_k_table(
                        pol, self._comp_dim, comp.ratio)
            # The EF residual is carried even with error_feedback=False (as
            # zeros) so the jitted round signatures stay uniform.
            self._ef_residual = jnp.zeros(
                (self.num_clients, self._comp_dim), jnp.float32)

        # Fused-aggregate fast path: the uplink's weighted sum folds into
        # the transport (in-kernel accumulator on use_kernel rows, scan
        # fallback elsewhere) — per-client demapped payloads never land in
        # HBM. The fused round is pinned bit-identical to the layered
        # fedsgd_aggregate-over-transmit_batch composition, so anything
        # that must touch per-client rows *between* demap and aggregate is
        # incompatible and rejected here rather than silently layered.
        self.fused_aggregate = bool(fused_aggregate)
        if self.fused_aggregate:
            if self.compression is not None:
                raise ValueError(
                    "fused_aggregate=True is incompatible with a compressed "
                    "uplink: the sparse path must scatter per-client "
                    "coordinates before aggregating")
            if getattr(algorithm, "scale_mode", "none") == "max_abs":
                raise ValueError(
                    "fused_aggregate=True is incompatible with "
                    "scale_mode='max_abs': the per-client descale runs "
                    "between demap and aggregate")
            if self.driver is not None and self.dispatch != "bucketed":
                raise ValueError(
                    "fused_aggregate=True needs adaptive_dispatch="
                    "'bucketed' for scenario runs — the select lowering "
                    "has no kernel rows to fuse into")

        self._build_round_fns()
        if self.driver is not None:
            key, lk = jax.random.split(key)
            self.lstate, self.prev_mode, self.prev_est = self.driver.init(
                lk, self.num_clients)
        self._key = key

    # ----------------------------------------------------------- downlink

    def _downlink_transport_cfg(self):
        """The broadcast ``TransportConfig``: the *raw* uplink config with
        the downlink's mode/modulation and (driver-less) shifted channel SNR.

        Derived from the pre-resolution uplink config, then put through its
        own analytic-ECRT resolution, because an ECRT downlink must not (a)
        trace the real LDPC decoder inside the jitted round, nor (b) reuse
        an E[tx] calibrated at the uplink's unshifted SNR — the analytic
        model is SNR-blind, so the constant must be calibrated where the
        *downlink* operates. Driver-less: the shift is baked into the
        channel (shape preserved — per-client SNR vectors shift elementwise)
        and ``resolve_ecrt_analytic`` runs on the shifted config, yielding a
        per-client downlink airtime scale for heterogeneous cohorts.
        Scenario rounds override SNR per round (``rnd.snr_db + Δ``), so the
        config keeps the base channel and an ECRT downlink calibrates at the
        scenario's fleet operating point + Δ.
        """
        dl = self.downlink
        cfg = dataclasses.replace(
            self._raw_transport_cfg, mode=dl.mode,
            modulation=dl.modulation or self._raw_transport_cfg.modulation)
        if self.driver is not None:
            if cfg.mode == "ecrt" and cfg.simulate_fec:
                anchor = float(self.driver.scenario.dynamics.mean_snr_db
                               + dl.snr_offset_db)
                e_tx = latency_lib.calibrate_ecrt(
                    anchor, cfg.modulation,
                    n_codewords=latency_lib.DEFAULT_CALIB_CODEWORDS,
                    max_tx=latency_lib.DEFAULT_CALIB_MAX_TX)
                cfg = dataclasses.replace(
                    cfg, simulate_fec=False, ecrt_expected_tx=float(e_tx))
            return cfg
        ch = cfg.channel
        snr = np.asarray(ch.snr_db, np.float32) + np.float32(dl.snr_offset_db)
        snr_val = (float(snr) if snr.ndim == 0
                   else tuple(float(v) for v in snr.reshape(-1)))
        cfg = dataclasses.replace(
            cfg, channel=dataclasses.replace(ch, snr_db=snr_val))
        cfg, self.dl_air_scale = resolve_ecrt_analytic(cfg, self.num_clients)
        return cfg

    def _downlink_modes(self, est_db):
        """Adaptive downlink: per-client mode from the scenario's policy
        table at the shifted CSI (jit-safe; bucketed rounds pass host CSI)."""
        from repro.link import policy as policy_lib

        return policy_lib.downlink_mode(
            est_db, self.driver.scenario.policy, self.downlink.snr_offset_db)

    def _broadcast_scenario(self, params, k_tx, rnd, dl_mode=None,
                            dispatch="select"):
        """One scenario round's broadcast leg: global model -> per-client
        received copies at the shifted per-round SNR."""
        dl_snr = rnd.snr_db + self.downlink.snr_offset_db
        if self.downlink.adaptive:
            cfgs = (self.driver.mode_cfgs if dispatch == "bucketed"
                    else select_mode_cfgs(self.driver))
            mode = dl_mode if dl_mode is not None else self._downlink_modes(
                rnd.est_db)
            return transport_lib.transmit_pytree_broadcast_adaptive(
                params, k_tx, cfgs, mode, snr_db=dl_snr, dispatch=dispatch)
        return transport_lib.transmit_pytree_broadcast(
            params, k_tx, self.dl_cfg, self.num_clients, snr_db=dl_snr)

    def _downlink_air_record(self, rec, dstats):
        """Price the round's broadcast and set its fields on ``rec`` (the
        round's :class:`~repro.obs.records.RoundRecord`).

        Returns the seconds the PS spent broadcasting (each distinct mode is
        transmitted once — see ``latency.broadcast_airtime``).
        """
        dl = self.downlink
        if self.driver is not None and dl.adaptive:
            air = latency_lib.round_airtime_adaptive(
                dstats, self.timings, self.driver.mode_cfgs)
            total = latency_lib.broadcast_airtime(air, dstats.mode_idx)
        else:
            air = latency_lib.round_airtime(dstats, self.timings, dl.mode)
            if self.dl_air_scale is not None:
                # Heterogeneous analytic-ECRT downlink: per-client E[tx]
                # rescale, as on the uplink.
                air = air * self.dl_air_scale
            total = latency_lib.broadcast_airtime(air)
        rec.downlink_airtime_s = total
        rec.downlink_ber = float(np.mean(np.asarray(dstats.ber)))
        if dstats.mode_idx is not None:
            rec.downlink_mode_counts = np.bincount(
                np.asarray(dstats.mode_idx),
                minlength=len(self.driver.mode_cfgs)).tolist()
        return total

    # -------------------------------------------------------- round builds

    def _build_round_fns(self):
        algo, tcfg, driver = self.algo, self.transport_cfg, self.driver
        dl, M = self.downlink, self.num_clients
        comp, D, kbase = self.compression, self._comp_dim, self._comp_k

        @jax.jit
        def round_step(params, aux, xb, yb, key):
            # Driver-less round, one fused program. The downlink broadcast
            # (when configured) and the uplink share `key` on disjoint
            # fold_in lanes.
            dstats = None
            if dl is None:
                payload = algo.payload(params, xb, yb)
            else:
                recv, dstats = transport_lib.transmit_pytree_broadcast(
                    params, key, self.dl_cfg, M)
                payload = algo.payload_from(recv, xb, yb)
            hat, stats = algo.wrap_uplink(
                payload,
                lambda t: transport_lib.transmit_pytree_batch(t, key, tcfg))
            agg = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), hat)
            params, aux = algo.apply(params, aux, agg)
            return params, aux, stats, dstats

        self._round_step = round_step

        if self.fused_aggregate:
            # Uniform cohort weights, normalized once at build time (every
            # round reuses the same device constant, so all rounds share one
            # weight realization with the layered fedsgd_aggregate_batch
            # twin). Donation of the payload buffer happens inside the jit
            # boundary here (a single fused program — XLA already reuses
            # the buffer; the flag matters at the bucketed host-level
            # launches).
            uniform_w = aggregation_lib.normalize_weights(
                jnp.ones((M,), jnp.float32))

            @jax.jit
            def round_step_fused(params, aux, xb, yb, key):
                # Driver-less fused round: modulate -> channel -> demap ->
                # accumulate in one transport pass; no per-client hat tree.
                dstats = None
                if dl is None:
                    payload = algo.payload(params, xb, yb)
                else:
                    recv, dstats = transport_lib.transmit_pytree_broadcast(
                        params, key, self.dl_cfg, M)
                    payload = algo.payload_from(recv, xb, yb)
                agg, stats = transport_lib.transmit_pytree_batch_aggregate(
                    payload, key, tcfg, uniform_w, donate=True)
                params, aux = algo.apply(params, aux, agg)
                return params, aux, stats, dstats

            self._round_step = round_step_fused

        def _sel_keys(key):
            # rand-k selection keys ride the per-client transport key on the
            # reserved lane; deterministic methods need none.
            if comp.method != "randk":
                return None
            return sparsify_lib.selection_keys(key, M)

        if comp is not None:

            @jax.jit
            def round_step_comp(params, aux, xb, yb, key, residual):
                # Driver-less *compressed* round, one fused program: EF
                # accumulate -> select -> sparse uplink -> scatter -> mean.
                dstats = None
                if dl is None:
                    payload = algo.payload(params, xb, yb)
                else:
                    recv, dstats = transport_lib.transmit_pytree_broadcast(
                        params, key, self.dl_cfg, M)
                    payload = algo.payload_from(recv, xb, yb)
                flat, spec = transport_lib._flatten_client_tree(payload)
                vals, idx, residual = sparsify_lib.ef_select_batch(
                    residual, flat, kbase, comp, _sel_keys(key))
                hat_flat, stats = algo.wrap_uplink(
                    vals,
                    lambda v: framing_lib.transmit_sparse_batch(
                        v, idx, D, key, tcfg, comp))
                hat = transport_lib._unflatten_client_tree(hat_flat, spec)
                agg = jax.tree_util.tree_map(
                    lambda g: jnp.mean(g, axis=0), hat)
                params, aux = algo.apply(params, aux, agg)
                return params, aux, stats, dstats, residual

            self._round_step_comp = round_step_comp

        @jax.jit
        def eval_acc(params):
            return cnn.accuracy(params, jnp.asarray(self.test_x),
                                jnp.asarray(self.test_y))

        self._eval_acc = eval_acc

        if driver is None:
            return

        @jax.jit
        def round_step_link(params, aux, xb, yb, key, lstate, prev_mode,
                            prev_est):
            # Select dispatch: one fused program — dynamics -> noisy CSI ->
            # mode policy -> [broadcast ->] payload -> vmapped-switch uplink
            # -> dropout-weighted aggregation -> apply.
            k_link, k_tx = jax.random.split(key)
            lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link)
            dstats = None
            if dl is None:
                payload = algo.payload(params, xb, yb)
            else:
                recv, dstats = self._broadcast_scenario(params, k_tx, rnd)
                payload = algo.payload_from(recv, xb, yb)
            hat, stats = algo.wrap_uplink(
                payload,
                lambda t: transport_lib.transmit_pytree_batch_adaptive(
                    t, k_tx, select_mode_cfgs(driver), rnd.mode,
                    snr_db=rnd.snr_db, dispatch="select"))
            agg = dropout_weighted_mean(hat, rnd.active)
            params, aux = algo.apply(params, aux, agg)
            return params, aux, stats, lstate, rnd, dstats

        self._round_step_link = round_step_link

        if comp is not None:

            @jax.jit
            def round_step_link_comp(params, aux, xb, yb, key, lstate,
                                     prev_mode, prev_est, residual):
                # Select dispatch, compressed: one fused program — link
                # pipeline -> [broadcast ->] payload -> EF select -> sparse
                # vmapped-switch uplink -> dropout-weighted aggregate.
                # Uniform slot budget (per-mode budgets are bucketed-only).
                k_link, k_tx = jax.random.split(key)
                lstate, rnd = driver.round(lstate, prev_mode, prev_est,
                                           k_link)
                dstats = None
                if dl is None:
                    payload = algo.payload(params, xb, yb)
                else:
                    recv, dstats = self._broadcast_scenario(params, k_tx, rnd)
                    payload = algo.payload_from(recv, xb, yb)
                flat, spec = transport_lib._flatten_client_tree(payload)
                vals, idx, residual = sparsify_lib.ef_select_batch(
                    residual, flat, kbase, comp, _sel_keys(k_tx),
                    active=rnd.active)
                hat_flat, stats = algo.wrap_uplink(
                    vals,
                    lambda v: framing_lib.transmit_sparse_batch_adaptive(
                        v, idx, D, k_tx, select_mode_cfgs(driver), rnd.mode,
                        comp, snr_db=rnd.snr_db, dispatch="select"))
                hat = transport_lib._unflatten_client_tree(hat_flat, spec)
                agg = dropout_weighted_mean(hat, rnd.active)
                params, aux = algo.apply(params, aux, agg)
                return params, aux, stats, lstate, rnd, dstats, residual

            self._round_step_link_comp = round_step_link_comp

        @jax.jit
        def link_round(lstate, prev_mode, prev_est, key):
            return driver.round(lstate, prev_mode, prev_est, key)

        @jax.jit
        def payload_shared(params, xb, yb):
            return algo.payload(params, xb, yb)

        @jax.jit
        def payload_per_client(recv, xb, yb):
            return algo.payload_from(recv, xb, yb)

        @jax.jit
        def apply_update(params, aux, hat, active):
            agg = dropout_weighted_mean(hat, active)
            return algo.apply(params, aux, agg)

        def round_step_link_bucketed(params, aux, xb, yb, key, lstate,
                                     prev_mode, prev_est):
            # Bucketed dispatch: the link step runs first and the mode
            # vector syncs to the host, so each transport leg can sort
            # clients into per-mode buckets and run each mode once (O(M)
            # work, kernel rows allowed) around the jitted compute steps.
            k_link, k_tx = jax.random.split(key)
            lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
            mode_np = np.asarray(rnd.mode)
            dstats = None
            if dl is None:
                payload = payload_shared(params, xb, yb)
            else:
                dl_mode = None
                if dl.adaptive:
                    dl_mode = np.asarray(self._downlink_modes(
                        np.asarray(rnd.est_db)))
                recv, dstats = self._broadcast_scenario(
                    params, k_tx, rnd, dl_mode=dl_mode, dispatch="bucketed")
                payload = payload_per_client(recv, xb, yb)
            hat, stats = algo.wrap_uplink(
                payload,
                lambda t: transport_lib.transmit_pytree_batch_adaptive(
                    t, k_tx, driver.mode_cfgs, mode_np, snr_db=rnd.snr_db,
                    dispatch="bucketed"))
            params, aux = apply_update(params, aux, hat, rnd.active)
            return params, aux, stats, lstate, rnd, dstats

        self._round_step_link_bucketed = round_step_link_bucketed

        if self.fused_aggregate:
            # Dropout-as-weights: dropped clients still transmit in their
            # bucket (exactly as the layered bucketed round) but fold into
            # the accumulator with weight 0; the normalization is global
            # (before the bucket split), matching fedsgd_aggregate_batch
            # over the cohort's active mask.
            fused_weights = jax.jit(
                lambda active: aggregation_lib.normalize_weights(active))
            apply_agg = jax.jit(
                lambda params, aux, agg: algo.apply(params, aux, agg))

            def round_step_link_bucketed_fused(params, aux, xb, yb, key,
                                               lstate, prev_mode, prev_est):
                # Bucketed fused round: link step syncs the mode vector to
                # the host, each mode bucket runs uplink+aggregate in one
                # pass (kernel accumulator on use_kernel rows), partials add
                # in mode order, and only the apply tail is jitted.
                k_link, k_tx = jax.random.split(key)
                lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
                mode_np = np.asarray(rnd.mode)
                dstats = None
                if dl is None:
                    payload = payload_shared(params, xb, yb)
                else:
                    dl_mode = None
                    if dl.adaptive:
                        dl_mode = np.asarray(self._downlink_modes(
                            np.asarray(rnd.est_db)))
                    recv, dstats = self._broadcast_scenario(
                        params, k_tx, rnd, dl_mode=dl_mode,
                        dispatch="bucketed")
                    payload = payload_per_client(recv, xb, yb)
                agg, stats = \
                    transport_lib.transmit_pytree_batch_adaptive_aggregate(
                        payload, k_tx, driver.mode_cfgs, mode_np,
                        fused_weights(rnd.active), snr_db=rnd.snr_db,
                        donate=True)
                params, aux = apply_agg(params, aux, agg)
                return params, aux, stats, lstate, rnd, dstats

            self._round_step_link_bucketed = round_step_link_bucketed_fused

        if comp is None:
            return

        if comp.error_feedback:
            accumulate = jax.jit(lambda r, f: r + f)
            residual_update = jax.jit(
                lambda acc, sent, act: acc - sent * act[:, None])
        else:
            accumulate = jax.jit(lambda r, f: f)
            residual_update = jax.jit(
                lambda acc, sent, act: jnp.zeros_like(acc))

        def round_step_link_bucketed_comp(params, aux, xb, yb, key, lstate,
                                          prev_mode, prev_est, residual):
            # Bucketed dispatch, compressed: the mode vector syncs to the
            # host so each mode bucket selects with its *own* slot budget
            # (the CSI-adaptive compress_ratios column) and runs its sparse
            # batch once, around the jitted compute steps.
            k_link, k_tx = jax.random.split(key)
            lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
            mode_np = np.asarray(rnd.mode)
            dstats = None
            if dl is None:
                payload = payload_shared(params, xb, yb)
            else:
                dl_mode = None
                if dl.adaptive:
                    dl_mode = np.asarray(self._downlink_modes(
                        np.asarray(rnd.est_db)))
                recv, dstats = self._broadcast_scenario(
                    params, k_tx, rnd, dl_mode=dl_mode, dispatch="bucketed")
                payload = payload_per_client(recv, xb, yb)
            flat, spec = transport_lib._flatten_client_tree(payload)
            acc = accumulate(residual, flat)
            dense_hat, stats, sent = self._sparse_bucketed_uplink(
                acc, k_tx, mode_np, rnd.snr_db)
            residual = residual_update(acc, sent, rnd.active)
            hat = transport_lib._unflatten_client_tree(dense_hat, spec)
            params, aux = apply_update(params, aux, hat, rnd.active)
            return params, aux, stats, lstate, rnd, dstats, residual

        self._round_step_link_bucketed_comp = round_step_link_bucketed_comp

    def _sparse_bucketed_uplink(self, acc, key, mode_np, snr_db):
        """Per-mode-budget sparse uplink over host-side mode buckets.

        The compressed counterpart of the bucketed dispatch: clients are
        stable-argsorted by mode; each mode's bucket selects ``k_m``
        coordinates of its accumulated payload (``k_m`` from the policy's
        ``compress_ratios`` column), rides the algorithm's uplink wrapper
        (per-client ``max_abs`` scaling composes per bucket), and transmits
        through its own mode config; results scatter back to client order.
        Keys ride the *client index*, so each row is bit-identical to a
        per-client ``transmit_sparse`` call. Returns ``(dense_hat (M, D),
        stats, sent (M, D))`` — ``sent`` is the transmitter-side scatter
        of the selected values, the quantity error feedback subtracts.
        """
        comp, algo, driver = self.compression, self.algo, self.driver
        cfgs, ks = driver.mode_cfgs, self._comp_ks
        M, D = acc.shape
        if M == 0:
            empty = jnp.zeros((0,), jnp.float32)
            stats = transport_lib.TxStats(
                empty, empty, empty, empty,
                mode_idx=jnp.zeros((0,), jnp.int32), bits_on_air=empty)
            return acc, stats, acc
        snr_vec = transport_lib._resolve_batch_snr(cfgs[0], M, snr_db)
        keys = transport_lib.client_keys(key, M)
        order = np.argsort(mode_np, kind="stable")
        counts = np.bincount(mode_np, minlength=len(cfgs))
        starts = np.concatenate([[0], np.cumsum(counts)])
        parts_x, parts_sent, parts_st = [], [], []
        for m, cfg in enumerate(cfgs):
            count = int(counts[m])
            if count == 0:
                continue
            rows = jnp.asarray(order[starts[m]: starts[m] + count])
            xb = jnp.take(acc, rows, axis=0)
            kb = jnp.take(keys, rows, axis=0)
            sb = None if snr_vec is None else jnp.take(snr_vec, rows)
            sel = None
            if comp.method == "randk":
                sel = jax.vmap(lambda kk: jax.random.fold_in(
                    kk, keylanes.SELECT_KEY_LANE))(kb)
            vals, sidx = sparsify_lib.select_batch(xb, ks[m], comp, sel)
            parts_sent.append(sparsify_lib.scatter_dense_batch(vals, sidx, D))
            fn = framing_lib._sparse_fn(cfg, comp, D, sb is not None)
            hat_m, st_m = algo.wrap_uplink(
                vals,
                lambda v, sidx=sidx, kb=kb, sb=sb, fn=fn: (
                    fn(v, sidx, kb) if sb is None else fn(v, sidx, kb, sb)))
            parts_x.append(hat_m)
            parts_st.append(st_m)
        dense_hat, stats, inv = transport_lib._scatter_bucket_parts(
            parts_x, parts_st, order, M)
        sent = jnp.take(jnp.concatenate(parts_sent, axis=0), inv, axis=0)
        stats.mode_idx = jnp.asarray(mode_np, jnp.int32)
        return dense_hat, stats, sent

    def _compression_record(self, rec, stats, rnd):
        """Set one round's compression telemetry on ``rec`` (the round's
        :class:`~repro.obs.records.RoundRecord`).

        Records the mean kept fraction (per-mode budgets resolve through
        the round's mode vector), the active cohort's total bits on air,
        and the mean per-client L2 norm of the EF residual.
        """
        if rnd is not None and self._comp_ks is not None:
            k_vec = np.asarray(self._comp_ks)[np.asarray(rnd.mode)]
        else:
            k_vec = np.full(self.num_clients, self._comp_k)
        active = (np.asarray(rnd.active) if rnd is not None
                  else np.ones(self.num_clients, np.float32))
        boa = np.asarray(stats.bits_on_air, np.float32)
        rec.comp_ratio = float(k_vec.mean() / max(self._comp_dim, 1))
        rec.comp_bits_on_air = float((boa * active).sum())
        # Reduce on device: pulling only the scalar avoids a per-round
        # (num_clients, dim) device-to-host transfer for telemetry.
        rec.comp_residual_norm = float(jnp.sqrt(jnp.mean(jnp.sum(
            self._ef_residual ** 2, axis=1))))

    # ------------------------------------------------------- observability

    def _manifest(self) -> dict:
        """The run-manifest line of an attached ledger: the config
        fingerprint, the run's shape, config summaries, and the provenance
        block (see :mod:`repro.obs.ledger`)."""
        scen = None if self.driver is None else self.driver.scenario
        man = {
            "fingerprint": obs_ledger_lib.config_fingerprint(
                type(self.algo).__name__, self._raw_transport_cfg, scen,
                self.downlink, self.compression, self.dispatch,
                self.n_rounds, self.num_clients, self.seed),
            "engine": "sync",
            "algorithm": self.algo.name,
            "n_rounds": self.n_rounds,
            "num_clients": self.num_clients,
            "seed": self.seed,
            "eval_every": self.eval_every,
            "dispatch": self.dispatch,
            "transport_mode": self.transport_cfg.mode,
        }
        if scen is not None:
            from repro.link import policy as policy_lib

            man["scenario"] = scen.name
            man["mode_names"] = policy_lib.mode_names(scen.policy)
        if self.downlink is not None:
            man["downlink"] = dataclasses.asdict(self.downlink)
        if self.compression is not None:
            man["compression"] = dataclasses.asdict(self.compression)
        if self.fused_aggregate:
            # Re-derive (rather than add an unconditional fingerprint arg)
            # so every pre-existing layered run keeps its fingerprint.
            man["fused_aggregate"] = True
            man["fingerprint"] = obs_ledger_lib.config_fingerprint(
                man["fingerprint"], "fused_aggregate")
        man["provenance"] = obs_ledger_lib.provenance()
        return man

    def _finish_record(self, res, rec, stats):
        """Tail bookkeeping of one round's :class:`RoundRecord`: fill the
        observability-only ``uplink_*`` aggregates (ledger runs only — they
        force a device->host sync the dict view never paid), append the
        record, mirror its link-dict view, and write the ledger line."""
        if self.ledger is not None and stats is not None:
            for name, value in stats.round_summary().items():
                setattr(rec, name, value)
        res.records.append(rec)
        if rec.has_link_fields():
            res.link.append(rec.to_link_dict())
        if self.ledger is not None:
            self.ledger.write_round(rec)

    def _finish_run(self, res) -> None:
        """Close out the attached sinks at the end of :meth:`run`: the
        ledger's summary line (with the phase-timer summary when one was
        attached) and the ledger file itself."""
        if self.ledger is None:
            return
        summary = {
            "final_accuracy": res.final_accuracy,
            "wall_s": res.wall_s,
            "airtime_s": res.airtime_s[-1] if res.airtime_s else 0.0,
            "n_evals": len(res.accuracy),
        }
        if res.event_s:
            summary["event_s"] = res.event_s[-1]
        phases = self.phase_timers.summary()
        if phases:
            summary["phases"] = phases
        if self.sketcher is not None:
            summary["sketches"] = self.sketcher.summary()
        self.ledger.write_summary(summary)
        self.ledger.close()

    # --------------------------------------------------------------- run

    def run(self) -> FLResult:
        """Drive ``n_rounds`` rounds and return the :class:`FLResult`."""
        algo, driver, timings = self.algo, self.driver, self.timings
        comp, tm = self.compression, self.phase_timers
        params, aux, key = self.params, self.aux, self._key
        rng = np.random.default_rng(self.seed)
        res = FLResult([], [], [], 0.0, 0.0)
        t0 = time.time()  # lint: ignore[determinism] wall-clock telemetry
        if self.ledger is not None:
            self.ledger.write_manifest(self._manifest())
        cum_air = 0.0
        for r in range(self.n_rounds):
            key, rk = jax.random.split(key)
            with tm.scope("sample"):
                xb, yb = algo.sample(rng, self.client_x, self.client_y)
            rnd = None
            if driver is None:
                with tm.scope("round"):
                    if comp is None:
                        params, aux, stats, dstats = self._round_step(
                            params, aux, xb, yb, rk)
                    else:
                        (params, aux, stats, dstats,
                         self._ef_residual) = self._round_step_comp(
                            params, aux, xb, yb, rk, self._ef_residual)
                rec = obs_records_lib.RoundRecord(round=r)
                with tm.scope("telemetry"):
                    # TDMA uplink: total airtime is the sum over clients.
                    per_client_air = latency_lib.round_airtime(
                        stats, timings, self.transport_cfg.mode)
                    if self.ecrt_air_scale is not None:
                        # Heterogeneous analytic ECRT: rescale each client's
                        # airtime from the cohort-mean E[tx] to its own value.
                        per_client_air = per_client_air * self.ecrt_air_scale
            else:
                with tm.scope("round"):
                    if comp is None:
                        step = (self._round_step_link_bucketed
                                if self.dispatch == "bucketed"
                                else self._round_step_link)
                        params, aux, stats, self.lstate, rnd, dstats = step(
                            params, aux, xb, yb, rk, self.lstate,
                            self.prev_mode, self.prev_est)
                    else:
                        step = (self._round_step_link_bucketed_comp
                                if self.dispatch == "bucketed"
                                else self._round_step_link_comp)
                        (params, aux, stats, self.lstate, rnd, dstats,
                         self._ef_residual) = step(
                            params, aux, xb, yb, rk, self.lstate,
                            self.prev_mode, self.prev_est, self._ef_residual)
                self.prev_mode, self.prev_est = rnd.mode, rnd.est_db
                with tm.scope("telemetry"):
                    per_client_air = driver.airtime(stats, rnd, timings)
                    rec = obs_records_lib.scenario_round_record(
                        r, rnd, per_client_air, len(driver.mode_cfgs))
            cum_air += float(jnp.sum(per_client_air))
            if comp is not None:
                self._compression_record(rec, stats, rnd)
            if dstats is not None:
                cum_air += self._downlink_air_record(rec, dstats)
            if self.sketcher is not None:
                with tm.scope("telemetry"):
                    rec.sketches = self.sketcher.round_group(
                        rk, snr_db=rnd.snr_db, est_db=rnd.est_db,
                        ber=stats.client_metrics()["ber"],
                        airtime_s=per_client_air, mode=rnd.mode,
                        active=rnd.active,
                        downlink_ber=(None if dstats is None
                                      else dstats.ber))
            self._finish_record(res, rec, stats)
            if r % self.eval_every == 0 or r == self.n_rounds - 1:
                with tm.scope("eval"):
                    acc = float(self._eval_acc(params))
                res.rounds.append(r)
                res.accuracy.append(acc)
                res.airtime_s.append(cum_air)
                if self.ledger is not None:
                    self.ledger.write_eval(r, acc, cum_air)
        self.params, self.aux, self._key = params, aux, key
        res.wall_s = time.time() - t0  # lint: ignore[determinism]
        res.final_accuracy = res.accuracy[-1]
        self._finish_run(res)
        return res
