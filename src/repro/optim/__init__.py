"""Optimizers (pytree-native, optax-style (init, update) pairs)."""

from repro.optim.sgd import sgd, momentum_sgd
from repro.optim.adam import adam
from repro.optim.schedules import constant, cosine, warmup_cosine
