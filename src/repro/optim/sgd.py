"""SGD — the paper's optimizer (FedSGD, eq. (6): w <- w - eta g)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def sgd(lr) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = lr_fn(state["step"])
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum_sgd(lr, beta: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        eta = lr_fn(state["step"])
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state["mu"], grads)
        new = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - eta * m).astype(p.dtype), params, mu)
        return new, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update)
