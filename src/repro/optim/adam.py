"""Adam (used by non-FL baselines; FedSGD itself is stateless SGD)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params):
        t = state["step"] + 1
        eta = lr_fn(state["step"])
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m_, v_: (
                p.astype(jnp.float32) - eta * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            ).astype(p.dtype),
            params, m, v)
        return new, {"step": t, "m": m, "v": v}

    return Optimizer(init, update)
