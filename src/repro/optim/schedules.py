"""Learning-rate schedules (step -> lr, jnp-friendly)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * cos)

    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    base = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return w * base(jnp.maximum(step - warmup, 0))

    return fn
