"""Sparse wire format: protected index header + approximate value payload.

A sparse uplink carries two legs per client, both on the same radio:

* **value payload** — the ``(k,)`` selected values ride the *existing*
  transport pipeline unchanged (MSB-first packing + Gray-QAM + exponent
  clamp for ``approx``, LDPC for ``ecrt``, ...) under the client's
  transport key. A flipped value bit costs one coordinate a bounded error —
  the paper's whole premise.
* **index header** — the ``(k,)`` coordinate indices are *structural*: a
  flipped index bit scatters a value to the wrong coordinate, so the header
  gets more protection than the values. Three schemes
  (``CompressionConfig.header``):

  - ``"gray"`` — each header bit rides one of the two most-protected
    Gray-constellation positions (``b0``/``b1`` — the I and Q Gray MSBs,
    which share the lowest bit-error probability of the scheme; see
    ``modulation.py``). Two header bits per symbol whatever the
    modulation order; the remaining positions transmit zero. No coding
    overhead, lowest uncoded BER the constellation offers.
  - ``"ecrt"`` — indices pack into 32-bit words, bitcast to float32, and
    ride the rate-1/2 LDPC transport (analytic model by default: bits
    exact, airtime priced at the calibrated E[transmissions]).
  - ``"perfect"`` — an error-free control channel; still priced on the
    air at full constellation packing.

The receiver unpacks the header, drops indices that land out of range
(corrupted headers cannot write outside the payload), and scatters the
received values back to a dense vector.

Key schedule: the value leg uses the client's transport key directly; the
header leg uses ``fold_in(client_key, HEADER_KEY_LANE)``; rand-k selection
(upstream) uses ``fold_in(client_key, SELECT_KEY_LANE)``. All three are
derived from the same per-client fold_in key, so
:func:`transmit_sparse_batch` is bit-identical to a per-client loop of
:func:`transmit_sparse` — the engine-wide batching contract.
"""

from __future__ import annotations

import functools

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import sparsify as sparsify_lib
from repro.core import float_codec as fc
from repro.core import keylanes
from repro.core import modulation as mod_lib
from repro.core import transport as transport_lib

__all__ = [
    "HEADER_KEY_LANE",
    "index_bits",
    "pack_index_bits",
    "unpack_index_bits",
    "transmit_header",
    "scatter_received",
    "transmit_sparse",
    "transmit_sparse_batch",
    "sparse_batch_with_keys",
    "transmit_sparse_batch_adaptive",
]

# fold_in lane (applied to a *client* key) where the index header draws its
# channel realization; far above chunk indices and distinct from
# sparsify.SELECT_KEY_LANE, so the per-client derivations never collide.
# Declared centrally in repro.core.keylanes (overlap-checked at import);
# re-exported here with the historical value (1 << 21).
HEADER_KEY_LANE = keylanes.HEADER_KEY_LANE


def _default_compression(compression):
    return (sparsify_lib.CompressionConfig() if compression is None
            else compression)


def index_bits(dim: int) -> int:
    """Bits needed to address a coordinate of a ``dim``-vector (>= 1)."""
    return max(1, int(dim - 1).bit_length())


def pack_index_bits(indices: jax.Array, dim: int) -> jax.Array:
    """Pack ``(k,)`` indices into uint32 words, MSB-first.

    Each index contributes ``index_bits(dim)`` bits; the flat bit stream is
    zero-padded to a word boundary. Inverse: :func:`unpack_index_bits`.
    """
    b = index_bits(dim)
    shifts = jnp.uint32(b - 1 - jnp.arange(b))
    bits = ((indices.astype(jnp.uint32)[:, None] >> shifts)
            & jnp.uint32(1)).reshape(-1)
    pad = (-bits.shape[0]) % 32
    w = jnp.pad(bits, (0, pad)).reshape(-1, 32)
    wshift = jnp.uint32(31 - jnp.arange(32))
    return jnp.sum(w.astype(jnp.uint32) << wshift, axis=-1, dtype=jnp.uint32)


def unpack_index_bits(words: jax.Array, k: int, dim: int) -> jax.Array:
    """Inverse of :func:`pack_index_bits`: uint32 words -> ``(k,)`` int32."""
    b = index_bits(dim)
    wshift = jnp.uint32(31 - jnp.arange(32))
    bits = ((words[:, None] >> wshift) & jnp.uint32(1)).reshape(-1)[: k * b]
    shifts = jnp.uint32(b - 1 - jnp.arange(b))
    return jnp.sum(
        bits.reshape(k, b).astype(jnp.uint32) << shifts, axis=-1,
        dtype=jnp.uint32).astype(jnp.int32)


def _index_bit_vector(indices: jax.Array, dim: int) -> jax.Array:
    """Flat ``(k * index_bits,)`` 0/1 header bit stream, MSB-first."""
    b = index_bits(dim)
    shifts = jnp.uint32(b - 1 - jnp.arange(b))
    return ((indices.astype(jnp.uint32)[:, None] >> shifts)
            & jnp.uint32(1)).reshape(-1)


def _header_gray(indices, dim, key, cfg, snr_db):
    """Gray-MSB header leg: 2 header bits per symbol at the best positions.

    Header bit pairs land on ``b0``/``b1`` of each symbol index — the I and
    Q Gray MSBs, the two equally-most-protected positions of a square Gray
    QAM — and every less-protected position transmits zero. Returns
    ``(idx_rx, symbols, extra_tx, bit_errors, n_bits, bits_on_air)``.
    """
    k = indices.shape[0]
    b = index_bits(dim)
    km = cfg.scheme.bits_per_symbol
    bits = _index_bit_vector(indices, dim)
    n_hdr = bits.shape[0]
    pad = (-n_hdr) % 2
    bp = jnp.pad(bits, (0, pad)).reshape(-1, 2)
    sym = ((bp[:, 0] << jnp.uint32(km - 1))
           | (bp[:, 1] << jnp.uint32(km - 2))).astype(jnp.uint32)
    y, _ = transport_lib._through_channel(sym, key, cfg, snr_db)
    rx = mod_lib.demod_hard(y, cfg.scheme)
    b0 = (rx >> jnp.uint32(km - 1)) & jnp.uint32(1)
    b1 = (rx >> jnp.uint32(km - 2)) & jnp.uint32(1)
    bits_rx = jnp.stack([b0, b1], axis=-1).reshape(-1)[:n_hdr]
    errs = jnp.sum((bits_rx != bits).astype(jnp.float32))
    shifts = jnp.uint32(b - 1 - jnp.arange(b))
    idx_rx = jnp.sum(
        bits_rx.reshape(k, b).astype(jnp.uint32) << shifts, axis=-1,
        dtype=jnp.uint32).astype(jnp.int32)
    n_sym = sym.shape[0]
    return idx_rx, n_sym, 0.0, errs, n_hdr, n_sym * km


def _header_ecrt(indices, dim, key, cfg, compression, snr_db):
    """ECRT header leg: packed index words through the LDPC transport."""
    k = indices.shape[0]
    words = pack_index_bits(indices, dim)
    hcfg = dataclasses.replace(
        cfg, mode="ecrt", use_kernel=False, chunk_elems=0,
        simulate_fec=compression.header_simulate_fec,
        ecrt_expected_tx=compression.header_ecrt_expected_tx)
    x = fc.bits_to_f32(words)
    x_hat, st = transport_lib.transmit_flat(x, key, hcfg, snr_db=snr_db)
    idx_rx = unpack_index_bits(fc.f32_to_bits(x_hat), k, dim)
    return (idx_rx, st.data_symbols, st.transmissions - 1.0, st.bit_errors,
            st.n_bits, st.bits_on_air)


def _header_perfect(indices, dim, cfg):
    """Error-free control-channel header, still priced on the air."""
    k = indices.shape[0]
    b = index_bits(dim)
    km = cfg.scheme.bits_per_symbol
    n_sym = -(-k * b // km)  # full constellation packing
    return (indices.astype(jnp.int32), float(n_sym), 0.0, 0.0,
            float(k * b), float(n_sym * km))


def transmit_header(indices: jax.Array, dim: int, key: jax.Array, cfg,
                    compression=None, *, snr_db=None):
    """Carry one client's index header over its protected leg.

    ``cfg`` is the client's (value-leg) :class:`TransportConfig` — the
    header shares its constellation and channel. Returns ``(idx_rx,
    header_parts)`` where ``header_parts = (symbols, extra_transmissions,
    bit_errors, n_bits, bits_on_air)`` feeds the combined
    :class:`~repro.core.transport.TxStats`.
    """
    compression = _default_compression(compression)
    if compression.header == "gray":
        out = _header_gray(indices, dim, key, cfg, snr_db)
    elif compression.header == "ecrt":
        out = _header_ecrt(indices, dim, key, cfg, compression, snr_db)
    else:
        out = _header_perfect(indices, dim, cfg)
    return out[0], tuple(jnp.asarray(v, jnp.float32) for v in out[1:])


def scatter_received(values: jax.Array, idx_rx: jax.Array, dim: int
                     ) -> jax.Array:
    """Receiver-side scatter with a corrupted-header guard.

    Received indices that land out of range (possible only when the header
    leg flipped bits) are dropped; in-range duplicates accumulate — the
    damage a corrupted header can do is bounded to the slots it occupied.
    """
    valid = idx_rx < dim
    vals = jnp.where(valid, values, 0.0)
    idx = jnp.where(valid, idx_rx, 0)
    return jnp.zeros((dim,), vals.dtype).at[idx].add(vals, mode="drop")


def transmit_sparse(values: jax.Array, indices: jax.Array, dim: int,
                    key: jax.Array, cfg, compression=None, *, snr_db=None):
    """One client's sparse uplink: values + protected index header.

    Args:
      values: ``(k,)`` selected values (cast to float32).
      indices: ``(k,)`` coordinate indices in ``[0, dim)``.
      dim: dense payload dimension the receiver scatters back to.
      key: the client's transport key — the value leg consumes it directly
        (same schedule as a dense uplink); the header leg uses
        ``fold_in(key, HEADER_KEY_LANE)``.
      cfg: value-leg :class:`~repro.core.transport.TransportConfig`; the
        header shares its constellation/channel.
      compression: :class:`~repro.compress.sparsify.CompressionConfig`
        choosing the header protection (default if ``None``).
      snr_db: optional scalar SNR override, threaded to both legs.

    Returns:
      ``(x_hat, stats)``: the dense ``(dim,)`` reconstruction and a single
      :class:`~repro.core.transport.TxStats` whose ``data_symbols`` /
      ``bit_errors`` / ``n_bits`` / ``bits_on_air`` sum the two legs (so
      ``latency.round_airtime`` prices the sparse frame end to end) and
      whose ``transmissions`` counts one PHY frame plus any header
      retransmissions.
    """
    compression = _default_compression(compression)
    values = jnp.asarray(values, jnp.float32)
    k_hdr = jax.random.fold_in(key, HEADER_KEY_LANE)
    v_hat, vs = transport_lib.transmit_flat(values, key, cfg, snr_db=snr_db)
    idx_rx, (h_sym, h_xtx, h_err, h_bits, h_boa) = transmit_header(
        indices, dim, k_hdr, cfg, compression, snr_db=snr_db)
    dense = scatter_received(v_hat, idx_rx, dim)
    stats = transport_lib.TxStats(
        vs.data_symbols + h_sym, vs.transmissions + h_xtx,
        vs.bit_errors + h_err, vs.n_bits + h_bits,
        bits_on_air=vs.bits_on_air + h_boa)
    return dense, stats


def sparse_batch_with_keys(values: jax.Array, indices: jax.Array, dim: int,
                           keys: jax.Array, cfg, snr_vec, compression=None):
    """Sparse batch over explicit per-client keys (the bucketed hook).

    The sparse analogue of ``transport._batch_with_keys``: one ``vmap`` of
    :func:`transmit_sparse`, so batch semantics equal loop semantics by
    construction. ``snr_vec`` is ``None`` (homogeneous) or
    ``(num_clients,)``.
    """
    compression = _default_compression(compression)
    if snr_vec is None:
        return jax.vmap(
            lambda v, i, kc: transmit_sparse(v, i, dim, kc, cfg, compression)
        )(values, indices, keys)
    return jax.vmap(
        lambda v, i, kc, s: transmit_sparse(v, i, dim, kc, cfg, compression,
                                            snr_db=s)
    )(values, indices, keys, snr_vec)


@functools.lru_cache(maxsize=256)
def _cached_sparse_fn(cfg, compression, dim: int, with_snr: bool):
    """One jitted sparse batch per (config, compression, dim, snr-arity)."""
    if with_snr:
        return jax.jit(lambda v, i, kk, s: sparse_batch_with_keys(
            v, i, dim, kk, cfg, s, compression))
    return jax.jit(lambda v, i, kk: sparse_batch_with_keys(
        v, i, dim, kk, cfg, None, compression))


def _sparse_fn(cfg, compression, dim, with_snr):
    try:
        return _cached_sparse_fn(cfg, compression, dim, with_snr)
    except TypeError:
        # Unhashable config (array-valued channel snr_db): unjitted fallback.
        if with_snr:
            return lambda v, i, kk, s: sparse_batch_with_keys(
                v, i, dim, kk, cfg, s, compression)
        return lambda v, i, kk: sparse_batch_with_keys(
            v, i, dim, kk, cfg, None, compression)


def transmit_sparse_batch(values: jax.Array, indices: jax.Array, dim: int,
                          key: jax.Array, cfg, compression=None, *,
                          snr_db=None, client_offset=0):
    """Batched sparse uplink under the engine-wide fold_in key schedule.

    Client ``i`` uses ``fold_in(key, client_offset + i)`` (shared with the
    dense :func:`~repro.core.transport.transmit_batch`), so the batch is
    bit-identical to a per-client loop of :func:`transmit_sparse` over the
    same schedule. Returns ``(x_hat (M, dim), stats)`` with per-client
    :class:`~repro.core.transport.TxStats` fields.
    """
    values = jnp.asarray(values, jnp.float32)
    if values.ndim != 2 or values.shape != indices.shape:
        raise ValueError(
            f"transmit_sparse_batch wants matching (num_clients, k) values/"
            f"indices; got {values.shape} vs {jnp.shape(indices)}")
    num_clients = values.shape[0]
    snr_vec = transport_lib._resolve_batch_snr(cfg, num_clients, snr_db)
    keys = transport_lib.client_keys(key, num_clients, client_offset)
    fn = _sparse_fn(cfg, _default_compression(compression), int(dim),
                    snr_vec is not None)
    return fn(values, indices, keys) if snr_vec is None else fn(
        values, indices, keys, snr_vec)


def transmit_sparse_batch_adaptive(values: jax.Array, indices: jax.Array,
                                   dim: int, key: jax.Array, cfgs, mode_idx,
                                   compression=None, *, snr_db=None,
                                   client_offset=0, dispatch: str = "auto"):
    """Mixed-mode sparse uplink: client ``i``'s values ride ``cfgs[mode_idx[i]]``.

    The sparse analogue of
    :func:`~repro.core.transport.transmit_batch_adaptive` with a uniform
    slot budget ``k`` across modes (per-mode budgets — the CSI-adaptive
    compression column — are handled upstream by the FL engine's bucketed
    round, which must also scale each bucket's values independently).
    ``"bucketed"`` gathers per-mode client buckets and runs each mode's
    sparse batch once; ``"select"`` is a vmapped ``lax.switch`` usable with
    a traced ``mode_idx`` (kernel rows rejected, as in the dense engine).
    The fold_in key rides the client index, so both dispatches are
    bit-identical to a per-client :func:`transmit_sparse` loop.
    """
    compression = _default_compression(compression)
    values = jnp.asarray(values, jnp.float32)
    if values.ndim != 2 or values.shape != indices.shape:
        raise ValueError(
            f"transmit_sparse_batch_adaptive wants matching (num_clients, k) "
            f"values/indices; got {values.shape} vs {jnp.shape(indices)}")
    cfgs = tuple(cfgs)
    if not cfgs:
        raise ValueError("transmit_sparse_batch_adaptive needs a config table")
    num_clients = values.shape[0]
    mode_concrete = not isinstance(mode_idx, jax.core.Tracer)
    if dispatch == "auto":
        dispatch = "bucketed" if mode_concrete else "select"
    if dispatch == "select" and any(c.use_kernel for c in cfgs):
        raise ValueError(
            "use_kernel configs cannot take the select dispatch (see "
            "transport.transmit_batch_adaptive); clear them or go bucketed")
    snr_vec = transport_lib._resolve_batch_snr(cfgs[0], num_clients, snr_db)
    keys = transport_lib.client_keys(key, num_clients, client_offset)

    if dispatch == "select":
        mode_arr = jnp.clip(jnp.asarray(mode_idx, jnp.int32), 0,
                            len(cfgs) - 1)
        if snr_vec is None:
            branches = [
                lambda v, i, kc, cfg=cfg: transmit_sparse(
                    v, i, dim, kc, cfg, compression) for cfg in cfgs]
            dense, stats = jax.vmap(
                lambda v, i, kc, m: jax.lax.switch(m, branches, v, i, kc)
            )(values, indices, keys, mode_arr)
        else:
            branches = [
                lambda v, i, kc, s, cfg=cfg: transmit_sparse(
                    v, i, dim, kc, cfg, compression, snr_db=s)
                for cfg in cfgs]
            dense, stats = jax.vmap(
                lambda v, i, kc, s, m: jax.lax.switch(m, branches, v, i, kc, s)
            )(values, indices, keys, snr_vec, mode_arr)
        stats.mode_idx = jnp.asarray(mode_arr, jnp.int32)
        return dense, stats

    if dispatch != "bucketed":
        raise ValueError(f"unknown dispatch {dispatch!r}; use bucketed|select")
    mode_np = np.clip(np.asarray(mode_idx, np.int32), 0, len(cfgs) - 1)
    if mode_np.shape != (num_clients,):
        raise ValueError(
            f"mode_idx must be ({num_clients},); got {mode_np.shape}")
    order = np.argsort(mode_np, kind="stable")
    counts = np.bincount(mode_np, minlength=len(cfgs))
    starts = np.concatenate([[0], np.cumsum(counts)])
    parts_x, parts_st = [], []
    for m, cfg in enumerate(cfgs):
        count = int(counts[m])
        if count == 0:
            continue
        rows = jnp.asarray(order[starts[m]: starts[m] + count])
        fn = _sparse_fn(cfg, compression, int(dim), snr_vec is not None)
        args = (jnp.take(values, rows, axis=0), jnp.take(indices, rows, axis=0),
                jnp.take(keys, rows, axis=0))
        if snr_vec is not None:
            args = args + (jnp.take(snr_vec, rows),)
        xh, st = fn(*args)
        parts_x.append(xh)
        parts_st.append(st)
    dense, stats, _ = transport_lib._scatter_bucket_parts(
        parts_x, parts_st, order, num_clients)
    stats.mode_idx = jnp.asarray(mode_np, jnp.int32)
    return dense, stats
