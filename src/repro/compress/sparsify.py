"""Gradient sparsification with error-feedback residual memory.

The uplink so far ships every coordinate of the gradient; the only airtime
lever is the modulation order. Ma et al. (arXiv:2404.11035) extend the
paper's approximate scheme to lossy sparse updates for IoT devices, and
Amiri & Gündüz (arXiv:1907.09769) establish sparsification with error
accumulation as the standard pre-transmission step for FL over fading
channels. This module is that step, made explicit and jit-friendly:

* **selection** — ``topk`` (largest-|value| coordinates, deterministic
  lower-index tie-break), ``randk`` (a keyed uniform subset), and
  ``threshold`` (top-k capacity with a magnitude floor: slots whose
  magnitude falls below ``threshold`` transmit zero and leave their value
  in the residual). Every method returns a *fixed-size* ``(k,)`` value /
  index pair — ragged selections do not batch, and the sparse wire format
  (:mod:`repro.compress.framing`) prices a fixed slot budget.
* **error feedback** — each client keeps a dense residual of everything it
  has not yet transmitted. Per round: ``acc = residual + gradient``,
  selection reads ``acc``, and the new residual is ``acc`` with the
  *transmitted values subtracted exactly* — so transmitted + residual is
  bit-identical to the accumulated gradient (the EF identity the tests
  pin), and no mass is ever silently dropped.

Determinism: ``select_topk`` orders candidates with ``jnp.lexsort`` on
``(-|value|, index)``, so equal magnitudes resolve to the lower index both
inside and outside ``jit`` — the bucketed and select FL dispatches see the
same selection for the same accumulated gradient.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import keylanes

__all__ = [
    "SELECT_KEY_LANE",
    "CompressionConfig",
    "resolve_k",
    "select_topk",
    "select_randk",
    "select_threshold",
    "select",
    "select_batch",
    "scatter_dense",
    "scatter_dense_batch",
    "ef_select",
    "ef_select_batch",
    "selection_keys",
]

# fold_in lane (applied to a *client* key) from which rand-k selection draws
# its subset. Lives far above the chunk indices that
# ``transport._uncoded_chunked`` folds onto the same client key, and is
# distinct from the framing header lane, so the three per-client derivations
# never collide. Declared centrally in repro.core.keylanes (overlap-checked
# at import); re-exported here with the historical value ((1 << 21) + 1).
SELECT_KEY_LANE = keylanes.SELECT_KEY_LANE


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """How a client compresses its uplink payload before the sparse wire.

    ``method``
        ``"topk"`` (largest-magnitude coordinates of the accumulated
        gradient; deterministic lower-index tie-break), ``"randk"`` (keyed
        uniform subset — unbiased in expectation, no sorting cost), or
        ``"threshold"`` (top-k slot budget with a magnitude floor; see
        :func:`select_threshold`).
    ``ratio`` / ``k``
        Slot budget: ``k`` coordinates are transmitted per client per
        round. ``k=None`` (default) derives it as ``max(1, round(ratio *
        dim))``; an explicit ``k`` wins. Scenario-driven runs may override
        the ratio per link mode via ``PolicyConfig.compress_ratios`` (the
        CSI-adaptive column — deeper compression at low SNR).
    ``threshold``
        Magnitude floor for ``method="threshold"``; ignored otherwise.
    ``error_feedback``
        Keep the exact untransmitted remainder in a per-client residual and
        fold it into the next round's selection (the EF carry). ``False``
        discards the remainder every round (plain biased sparsification).
    ``header``
        How the index header rides the wire (:mod:`repro.compress.framing`):
        ``"gray"`` packs two header bits per symbol into the constellation's
        two most-protected Gray positions; ``"ecrt"`` sends the packed index
        words through the rate-1/2 LDPC transport (bits exact under the
        analytic model); ``"perfect"`` models an error-free control channel
        (still priced on the air).
    ``header_ecrt_expected_tx`` / ``header_simulate_fec``
        ECRT-header pricing: the calibrated E[transmissions] constant for
        the analytic model, or ``header_simulate_fec=True`` to run the real
        LDPC chain (outside FL loops only — it decodes every round).
    """

    method: str = "topk"  # topk | randk | threshold
    ratio: float = 0.02
    k: int | None = None
    threshold: float = 0.0
    error_feedback: bool = True
    header: str = "gray"  # gray | ecrt | perfect
    header_ecrt_expected_tx: float = 1.0
    header_simulate_fec: bool = False

    def __post_init__(self):
        if self.method not in ("topk", "randk", "threshold"):
            raise ValueError(
                f"unknown compression method {self.method!r}; "
                "use topk|randk|threshold")
        if self.header not in ("gray", "ecrt", "perfect"):
            raise ValueError(
                f"unknown header protection {self.header!r}; "
                "use gray|ecrt|perfect")
        if self.k is None and not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"ratio must be in (0, 1], got {self.ratio}")
        if self.k is not None and self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")


def resolve_k(cfg: CompressionConfig, dim: int) -> int:
    """The per-client slot budget for a ``dim``-coordinate payload."""
    if cfg.k is not None:
        return min(int(cfg.k), dim)
    return max(1, min(dim, int(round(cfg.ratio * dim))))


def select_topk(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """The ``k`` largest-|value| coordinates, deterministic tie-break.

    Candidates are ordered by ``lexsort`` on ``(-|x|, index)`` — equal
    magnitudes resolve to the lower index, identically under jit and eager
    execution (plain ``top_k`` leaves that to the backend). Returns
    ``(values, indices)`` with indices sorted ascending (the canonical wire
    order — the framing layer packs them in this order).
    """
    n = x.shape[0]
    order = jnp.lexsort((jnp.arange(n), -jnp.abs(x)))
    idx = jnp.sort(order[:k]).astype(jnp.int32)
    return x[idx], idx


def select_randk(x: jax.Array, k: int, key: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """A keyed uniform ``k``-subset of coordinates (without replacement).

    The subset depends only on ``key`` and the dimension, never on the
    values — the rand-k compressor of Amiri & Gündüz. Returns ``(values,
    indices)``, indices ascending.
    """
    n = x.shape[0]
    idx = jnp.sort(jax.random.permutation(key, n)[:k]).astype(jnp.int32)
    return x[idx], idx


def select_threshold(x: jax.Array, k: int, threshold: float
                     ) -> tuple[jax.Array, jax.Array]:
    """Magnitude thresholding under a fixed ``k``-slot budget.

    Takes the top-``k`` coordinates (deterministic, as
    :func:`select_topk`), then zeroes every selected value whose magnitude
    falls below ``threshold`` — those slots still occupy wire capacity
    (fixed framing) but transmit zero, and error feedback keeps their true
    value in the residual. The effective selection is therefore
    ``min(k, #{|x| >= threshold})`` coordinates.
    """
    vals, idx = select_topk(x, k)
    return jnp.where(jnp.abs(vals) >= threshold, vals, 0.0), idx


def select(x: jax.Array, k: int, cfg: CompressionConfig, key=None
           ) -> tuple[jax.Array, jax.Array]:
    """Dispatch one client's selection by ``cfg.method``.

    ``key`` is required for ``randk`` (see :func:`selection_keys` for the
    schedule the FL engine uses) and ignored otherwise.
    """
    if cfg.method == "topk":
        return select_topk(x, k)
    if cfg.method == "randk":
        if key is None:
            raise ValueError("method='randk' needs a selection key")
        return select_randk(x, k, key)
    return select_threshold(x, k, cfg.threshold)


def select_batch(x: jax.Array, k: int, cfg: CompressionConfig, keys=None
                 ) -> tuple[jax.Array, jax.Array]:
    """Per-client selection over a ``(num_clients, dim)`` matrix.

    One ``vmap`` of :func:`select` — batched selection is bit-identical to
    a per-client loop. ``keys``: ``(num_clients, key_size)`` for ``randk``.
    Returns ``(values, indices)`` of shape ``(num_clients, k)``.
    """
    if cfg.method == "randk":
        if keys is None:
            raise ValueError("method='randk' needs per-client selection keys")
        return jax.vmap(lambda xc, kc: select(xc, k, cfg, kc))(x, keys)
    return jax.vmap(lambda xc: select(xc, k, cfg))(x)


def scatter_dense(values: jax.Array, indices: jax.Array, dim: int
                  ) -> jax.Array:
    """Scatter ``(k,)`` sparse values back to a dense ``(dim,)`` vector.

    Out-of-range indices are dropped (the receiver's guard against a
    corrupted index header); duplicate indices accumulate — with an intact
    header, selections never repeat an index, so the transmitter-side
    scatter is exact.
    """
    return jnp.zeros((dim,), values.dtype).at[indices].add(
        values, mode="drop")


def scatter_dense_batch(values: jax.Array, indices: jax.Array, dim: int
                        ) -> jax.Array:
    """Batched :func:`scatter_dense`: ``(M, k)`` pairs -> ``(M, dim)``."""
    return jax.vmap(lambda v, i: scatter_dense(v, i, dim))(values, indices)


def ef_select(residual: jax.Array, grad: jax.Array, k: int,
              cfg: CompressionConfig, key=None, active=None):
    """One client's error-feedback selection step.

    Accumulates ``acc = residual + grad`` (or just ``grad`` when error
    feedback is off), selects ``k`` slots from ``acc``, and returns
    ``(values, indices, new_residual)`` where ``new_residual`` is ``acc``
    with the transmitted values subtracted *exactly*: ``scatter(values) +
    new_residual == acc`` bit-for-bit (the gather/scatter pair cancels in
    IEEE arithmetic — no rounding is introduced).

    ``active`` (0/1 scalar) models client availability: a dropped client
    never transmitted, so its residual keeps the whole accumulation
    (``new_residual = acc``) instead of losing the selected mass.
    """
    acc = residual + grad if cfg.error_feedback else grad
    vals, idx = select(acc, k, cfg, key)
    if not cfg.error_feedback:
        return vals, idx, jnp.zeros_like(residual)
    sent = scatter_dense(vals, idx, acc.shape[0])
    if active is not None:
        sent = sent * active
    return vals, idx, acc - sent


def ef_select_batch(residual: jax.Array, grads: jax.Array, k: int,
                    cfg: CompressionConfig, keys=None, active=None):
    """Batched :func:`ef_select` over ``(num_clients, dim)`` matrices.

    ``active``: optional ``(num_clients,)`` 0/1 availability vector (see
    :func:`ef_select`). Returns ``(values (M, k), indices (M, k),
    new_residual (M, dim))``.
    """
    acc = residual + grads if cfg.error_feedback else grads
    vals, idx = select_batch(acc, k, cfg, keys)
    if not cfg.error_feedback:
        return vals, idx, jnp.zeros_like(residual)
    sent = scatter_dense_batch(vals, idx, acc.shape[1])
    if active is not None:
        sent = sent * active[:, None]
    return vals, idx, acc - sent


def selection_keys(key: jax.Array, num_clients: int, offset=0) -> jax.Array:
    """Per-client rand-k selection keys on the reserved fold_in lane.

    Client ``i`` draws ``fold_in(fold_in(key, offset + i),
    SELECT_KEY_LANE)`` — derived from the *client* transport key, so the
    selection is identical whichever dispatch (batched, bucketed, select,
    per-client loop) carries the round.
    """
    keylanes.check_range(offset, num_clients)
    idx = jnp.arange(num_clients) + offset
    return jax.vmap(
        lambda i: jax.random.fold_in(jax.random.fold_in(key, i),
                                     SELECT_KEY_LANE))(idx)
