"""Lossy gradient compression over the approximate wire.

Two layers: :mod:`repro.compress.sparsify` (top-k / rand-k / threshold
selection with error-feedback residual memory) and
:mod:`repro.compress.framing` (the sparse wire format — protected index
header + approximate value payload). The FL engine threads a
:class:`CompressionConfig` through every round; ``compression=None``
everywhere keeps the dense engine bit-identical to its pre-compression
behavior.
"""

from repro.compress.framing import (  # noqa: F401
    HEADER_KEY_LANE,
    index_bits,
    pack_index_bits,
    scatter_received,
    sparse_batch_with_keys,
    transmit_header,
    transmit_sparse,
    transmit_sparse_batch,
    transmit_sparse_batch_adaptive,
    unpack_index_bits,
)
from repro.compress.sparsify import (  # noqa: F401
    SELECT_KEY_LANE,
    CompressionConfig,
    ef_select,
    ef_select_batch,
    resolve_k,
    scatter_dense,
    scatter_dense_batch,
    select,
    select_batch,
    select_randk,
    select_threshold,
    select_topk,
    selection_keys,
)

__all__ = [
    "CompressionConfig",
    "HEADER_KEY_LANE",
    "SELECT_KEY_LANE",
    "ef_select",
    "ef_select_batch",
    "index_bits",
    "pack_index_bits",
    "resolve_k",
    "scatter_dense",
    "scatter_dense_batch",
    "scatter_received",
    "select",
    "select_batch",
    "select_randk",
    "select_threshold",
    "select_topk",
    "selection_keys",
    "sparse_batch_with_keys",
    "transmit_header",
    "transmit_sparse",
    "transmit_sparse_batch",
    "transmit_sparse_batch_adaptive",
    "unpack_index_bits",
]
