"""Link adaptation: time-varying channels, noisy CSI, per-client mode policy.

The subsystem that turns the repro from "one channel, one mode" into the
paper's conditional system — channel state evolves per round
(:mod:`repro.link.dynamics`), the PS estimates it from pilots
(:mod:`repro.link.estimator`), a hysteresis policy picks each client's
transport mode (:mod:`repro.link.policy`), and named end-to-end scenarios
drive the FL loops (:mod:`repro.link.scenario`).
"""

from repro.link.dynamics import (
    DYNAMICS_PRESETS,
    LinkDynamicsConfig,
    LinkState,
    jakes_rho,
)
from repro.link.estimator import EstimatorConfig, estimate_snr_db
from repro.link.policy import (
    PolicyConfig,
    build_mode_cfgs,
    choose_mode,
    downlink_mode,
    ecrt_anchor_snr_db,
    fixed_policy,
)
from repro.link.scenario import (
    SCENARIOS,
    DownlinkConfig,
    LinkRound,
    Scenario,
    ScenarioDriver,
    get_scenario,
    list_scenarios,
    register_scenario,
)
