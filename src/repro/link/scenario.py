"""End-to-end link scenarios: dynamics + CSI + policy + client availability.

A :class:`Scenario` bundles everything the FL loops need to run the paper's
adaptive system under a named mobility/availability profile: how per-client
SNR evolves round to round (``link.dynamics``), how noisily the PS observes
it (``link.estimator``), how the mode policy reacts (``link.policy``), and
which clients drop out or straggle. ``SCENARIOS`` is the registry
(``get_scenario``/``register_scenario``/``list_scenarios``);
:class:`ScenarioDriver` compiles a scenario against a base transport config
into pure per-round functions that live *inside* the jitted FL round step —
one XLA program per round, link adaptation included.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.link import dynamics as dynamics_lib
from repro.link import estimator as estimator_lib
from repro.link import policy as policy_lib

__all__ = [
    "Scenario",
    "LinkRound",
    "ScenarioDriver",
    "SCENARIOS",
    "get_scenario",
    "register_scenario",
    "list_scenarios",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully specified link environment for an FL run.

    ``dropout_prob`` is the per-round probability a client is silently
    absent (no uplink, no airtime, excluded from aggregation);
    ``straggler_prob``/``straggler_slowdown`` model clients whose uplink
    takes ``slowdown``x the modeled airtime (contention, duty cycling).
    ``ecrt_expected_tx = None`` means "calibrate with the real LDPC chain at
    the protected regime's SNR" (cached); a float skips calibration —
    tests and quick sweeps set it explicitly.
    """

    name: str
    dynamics: dynamics_lib.LinkDynamicsConfig
    estimator: estimator_lib.EstimatorConfig = estimator_lib.EstimatorConfig()
    policy: policy_lib.PolicyConfig = policy_lib.PolicyConfig()
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_slowdown: float = 3.0
    ecrt_expected_tx: float | None = None
    description: str = ""


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinkRound:
    """One round's link telemetry; every field is ``(num_clients,)``.

    ``snr_db`` is ground truth (drives the channel), ``est_db`` is what the
    policy saw, ``mode`` indexes the driver's mode table, ``active`` and
    ``straggler`` are 0/1 floats.
    """

    snr_db: jax.Array
    est_db: jax.Array
    mode: jax.Array
    active: jax.Array
    straggler: jax.Array


class ScenarioDriver:
    """A scenario bound to a transport config: the FL loops' link engine.

    Construction resolves the mode table (calibrating ECRT's E[tx] if the
    scenario asks for it); ``init``/``round`` are pure jax and safe to call
    inside jit — ``round`` advances dynamics, estimates CSI, runs the
    policy, and draws availability, returning the carry for the next round
    plus the :class:`LinkRound` record the uplink and telemetry consume.
    """

    def __init__(self, scenario: Scenario,
                 base_cfg: transport_lib.TransportConfig,
                 *, calib_codewords: int = 48, calib_max_tx: int = 6):
        self.scenario = scenario
        e_tx = scenario.ecrt_expected_tx
        if e_tx is None and any(m == "ecrt" for m, _ in scenario.policy.modes):
            # Calibrate where ECRT actually operates: the protected regime
            # below the first threshold (or the fleet mean for a fixed-ECRT
            # policy table).
            thr = scenario.policy.thresholds_db
            snr_cal = float(thr[0]) if thr else scenario.dynamics.mean_snr_db
            ecrt_mod = next(
                mod for m, mod in scenario.policy.modes if m == "ecrt")
            e_tx = latency_lib.calibrate_ecrt(
                snr_cal, ecrt_mod, n_codewords=calib_codewords,
                max_tx=calib_max_tx)
        self.mode_cfgs = policy_lib.build_mode_cfgs(
            base_cfg, scenario.policy,
            ecrt_expected_tx=float(e_tx if e_tx is not None else 1.0))

    def init(self, key: jax.Array, num_clients: int
             ) -> tuple[dynamics_lib.LinkState, jax.Array, jax.Array]:
        """Stationary link state, round-0 modes, and round-0 CSI.

        Modes are the hysteresis-free mapping of each client's static
        operating point (mean SNR + frozen offset); that operating point is
        also returned as the initial "previous estimate" the first
        :meth:`round` call's staleness logic falls back on — callers thread
        both through as ``prev_mode`` / ``prev_est_db``.
        """
        state = dynamics_lib.init_state(key, num_clients,
                                        self.scenario.dynamics)
        op_point = self.scenario.dynamics.mean_snr_db + state.offset_db
        mode0 = policy_lib.initial_mode(op_point, self.scenario.policy)
        return state, mode0, op_point

    def round(self, state: dynamics_lib.LinkState, prev_mode: jax.Array,
              prev_est_db: jax.Array, key: jax.Array
              ) -> tuple[dynamics_lib.LinkState, LinkRound]:
        """One link round: dynamics -> estimator -> policy -> availability."""
        scen = self.scenario
        k_dyn, k_est, k_drop, k_strag = jax.random.split(key, 4)
        state, snr = dynamics_lib.step(state, k_dyn, scen.dynamics)
        est = estimator_lib.step_estimate(snr, prev_est_db, k_est,
                                          scen.estimator)
        mode = policy_lib.choose_mode(est, prev_mode, scen.policy)
        shape = snr.shape
        active = jax.random.bernoulli(
            k_drop, 1.0 - scen.dropout_prob, shape).astype(jnp.float32)
        straggler = jax.random.bernoulli(
            k_strag, scen.straggler_prob, shape).astype(jnp.float32)
        return state, LinkRound(snr, est, mode, active, straggler)

    def airtime(self, stats: transport_lib.TxStats, rnd: LinkRound,
                timings: latency_lib.PhyTimings) -> jax.Array:
        """Per-client airtime of the round: mode-priced, straggler-scaled,
        zero for dropped clients. ``(num_clients,)`` seconds."""
        air = latency_lib.round_airtime_adaptive(stats, timings,
                                                 self.mode_cfgs)
        slowdown = 1.0 + (self.scenario.straggler_slowdown - 1.0) * rnd.straggler
        return air * slowdown * rnd.active


SCENARIOS: dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Add (or replace) a scenario in the registry; returns it."""
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario; unknown names list what exists."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: "
            f"{', '.join(sorted(SCENARIOS))}"
        ) from None


def list_scenarios() -> list[str]:
    """Registered scenario names, sorted."""
    return sorted(SCENARIOS)


def _preset(name: str, **kw) -> Scenario:
    return register_scenario(Scenario(
        name=name, dynamics=dynamics_lib.DYNAMICS_PRESETS[kw.pop("dyn", name)],
        **kw))


_preset("static",
        description="the paper's setup: one SNR, all clients, whole run")
_preset("pedestrian",
        description="walking users: slow fading drift + moderate shadowing")
_preset("vehicular",
        description="driving users: fast fading, wide per-client spread")
_preset("shadowed-urban",
        description="urban canyon: slowly-decorrelating deep shadowing")
_preset("bursty",
        description="IoT links: good on average with Markov blockage spells")
_preset("iot-flaky", dyn="bursty",
        estimator=estimator_lib.EstimatorConfig(n_pilots=16, stale_prob=0.2),
        dropout_prob=0.1, straggler_prob=0.1, straggler_slowdown=3.0,
        description="bursty links + few pilots, stale CSI, dropout, stragglers")
