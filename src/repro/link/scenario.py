"""End-to-end link scenarios: dynamics + CSI + policy + client availability.

A :class:`Scenario` bundles everything the FL loops need to run the paper's
adaptive system under a named mobility/availability profile: how per-client
SNR evolves round to round (``link.dynamics``), how noisily the PS observes
it (``link.estimator``), how the mode policy reacts (``link.policy``), and
which clients drop out or straggle. ``SCENARIOS`` is the registry
(``get_scenario``/``register_scenario``/``list_scenarios``);
:class:`ScenarioDriver` compiles a scenario against a base transport config
into pure per-round functions that live *inside* the jitted FL round step —
one XLA program per round, link adaptation included.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.sparsify import CompressionConfig
from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.link import dynamics as dynamics_lib
from repro.link import estimator as estimator_lib
from repro.link import policy as policy_lib

__all__ = [
    "DownlinkConfig",
    "Scenario",
    "LinkRound",
    "ScenarioDriver",
    "SCENARIOS",
    "get_scenario",
    "register_scenario",
    "list_scenarios",
]


@dataclasses.dataclass(frozen=True)
class DownlinkConfig:
    """The broadcast leg of an FL round: how the global model reaches clients.

    The paper models bit errors on the uplink only; Qu et al.
    (arXiv:2310.16652) show the downlink broadcast of the global model is
    markedly *less* error-tolerant than uplink gradients, so this config
    makes the leg explicit. ``None`` on a scenario / FL loop (the default
    everywhere) keeps the historical error-free downlink and changes no
    existing result bit-wise.

    ``mode``
        Broadcast transport: ``"perfect"`` (error-free reference) or an
        uncoded mode (``"approx"``/``"naive"``) — the error-budget axis of
        the Qu et al. comparison. Any transport mode is accepted; an
        ``"ecrt"`` downlink is priced with the calibrated analytic model at
        the *shifted* operating point (the engine never runs the real LDPC
        decoder inside a round — see ``engine.RoundEngine``).
    ``modulation``
        ``None`` inherits the uplink's modulation.
    ``snr_offset_db``
        Downlink SNR = uplink SNR + Δ dB (base stations transmit with more
        power than handsets — a positive Δ; 0 is the matched-SNR study).
    ``adaptive``
        Scenario-driven runs only: pick each client's downlink mode from the
        scenario's *existing* policy table at the shifted CSI
        (``policy.downlink_mode``) instead of one fixed broadcast mode.
    """

    mode: str = "approx"
    modulation: str | None = None
    snr_offset_db: float = 0.0
    adaptive: bool = False


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully specified link environment for an FL run.

    ``dropout_prob`` is the per-round probability a client is silently
    absent (no uplink, no airtime, excluded from aggregation);
    ``straggler_prob``/``straggler_slowdown`` model clients whose uplink
    takes ``slowdown``x the modeled airtime (contention, duty cycling).
    ``ecrt_expected_tx = None`` means "calibrate with the real LDPC chain"
    (cached): the transport constant anchors at the protected regime's SNR
    and airtime interpolates E[tx] per client per round over a calibrated
    SNR grid (see :meth:`ScenarioDriver.airtime`). A float skips
    calibration and prices with that constant — tests and quick sweeps set
    it explicitly.
    """

    name: str
    dynamics: dynamics_lib.LinkDynamicsConfig
    estimator: estimator_lib.EstimatorConfig = estimator_lib.EstimatorConfig()
    policy: policy_lib.PolicyConfig = policy_lib.PolicyConfig()
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_slowdown: float = 3.0
    ecrt_expected_tx: float | None = None
    # Broadcast leg of each round; None = error-free downlink (the paper's
    # implicit assumption, and bit-identical to pre-downlink behavior).
    downlink: DownlinkConfig | None = None
    # Default uplink compression for runs under this scenario (the FL
    # loops' explicit ``compression=`` argument wins); None = dense uplinks,
    # bit-identical to pre-compression behavior. Per-mode slot budgets come
    # from ``policy.compress_ratios`` (the CSI-adaptive column).
    compression: CompressionConfig | None = None
    # Event-layer defaults for the buffered (asynchronous) engine: how long
    # local computation takes per wave and how clients churn/idle between
    # waves. Both are ignored by the synchronous engine; ``compute=None``
    # resolves to the degenerate constant-time model and ``arrival=None``
    # means always-available clients with no idle gaps.
    compute: dynamics_lib.ComputeTimeConfig | None = None
    arrival: dynamics_lib.ArrivalConfig | None = None
    description: str = ""


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinkRound:
    """One round's link telemetry; every field is ``(num_clients,)``.

    ``snr_db`` is ground truth (drives the channel), ``est_db`` is what the
    policy saw, ``mode`` indexes the driver's mode table, ``active`` and
    ``straggler`` are 0/1 floats.
    """

    snr_db: jax.Array
    est_db: jax.Array
    mode: jax.Array
    active: jax.Array
    straggler: jax.Array


class ScenarioDriver:
    """A scenario bound to a transport config: the FL loops' link engine.

    Construction resolves the mode table (calibrating ECRT's E[tx] if the
    scenario asks for it); ``init``/``round`` are pure jax and safe to call
    inside jit — ``round`` advances dynamics, estimates CSI, runs the
    policy, and draws availability, returning the carry for the next round
    plus the :class:`LinkRound` record the uplink and telemetry consume.

    ECRT pricing: ``scenario.ecrt_expected_tx = None`` calibrates E[tx] at
    the policy's anchor SNR for the *transport* constant (the analytic model
    inside the uplink) and, for *airtime*, lazily builds a small calibrated
    curve over ECRT's operating band so each client's airtime reflects its
    own SNR that round (a client in a fade retransmits more than the
    anchor average). An explicit float keeps the old constant pricing.
    """

    def __init__(self, scenario: Scenario,
                 base_cfg: transport_lib.TransportConfig,
                 *, calib_codewords: int = policy_lib.DEFAULT_CALIB_CODEWORDS,
                 calib_max_tx: int = policy_lib.DEFAULT_CALIB_MAX_TX,
                 calib_grid_points: int = 3):
        self.scenario = scenario
        self._calib = (calib_codewords, calib_max_tx, calib_grid_points)
        self._ecrt_curve = None  # lazily built by _ecrt_tx_curve
        ecrt_mods = {mod for m, mod in scenario.policy.modes if m == "ecrt"}
        # Per-client/per-round interpolated airtime only applies when the
        # scenario asked for calibration (None); an explicit float means
        # "price with this constant" (tests, controlled sweeps). Tables with
        # several distinct ECRT modulations fall back to their (per-row
        # calibrated) constants — one interpolation curve cannot serve two
        # constellations.
        self._interp_ecrt_airtime = (len(ecrt_mods) == 1) and (
            scenario.ecrt_expected_tx is None)
        # Calibration (when ecrt_expected_tx is None) happens inside
        # build_mode_cfgs — the single pricing path; the scenario's fleet
        # operating point is the anchor fallback for threshold-less tables.
        self.mode_cfgs = policy_lib.build_mode_cfgs(
            base_cfg, scenario.policy,
            ecrt_expected_tx=scenario.ecrt_expected_tx,
            calib_codewords=calib_codewords, calib_max_tx=calib_max_tx,
            anchor_fallback_db=scenario.dynamics.mean_snr_db)
        self._ecrt_rows = tuple(
            i for i, c in enumerate(self.mode_cfgs) if c.mode == "ecrt")

    def _ecrt_modulation(self) -> str:
        return next(mod for m, mod in self.scenario.policy.modes
                    if m == "ecrt")

    def _ecrt_tx_curve(self):
        """Calibrated (grid_db, E[tx]) over ECRT's operating band, cached.

        The band runs from the dynamics' SNR floor up to the first policy
        threshold plus the hysteresis window (above that the policy moves
        clients off ECRT); a fixed-ECRT table spans the whole dynamics
        range. Points go through ``latency.calibrate_ecrt``'s cache.
        """
        if self._ecrt_curve is None:
            scen = self.scenario
            codewords, max_tx, points = self._calib
            thr = scen.policy.thresholds_db
            lo = scen.dynamics.snr_floor_db
            hi = (thr[0] + scen.policy.hysteresis_db) if thr \
                else scen.dynamics.snr_ceil_db
            # The anchor joins the grid so a client sitting exactly at the
            # transport constant's calibration point gets ratio 1 (its grid
            # value is the same LRU-cached calibrate_ecrt call). Wide bands
            # (threshold-less tables span the whole dynamics range) get
            # proportionally more points — E[tx] vs SNR is convex, so a
            # sparse linear chord would overprice mid-band clients.
            hi = max(hi, lo + 1.0)
            points = max(points, int(np.ceil((hi - lo) / 12.0)) + 1)
            anchor = policy_lib.ecrt_anchor_snr_db(
                scen.policy, scen.dynamics.mean_snr_db)
            grid = np.unique(np.concatenate(
                [np.linspace(lo, hi, points), [anchor]]))
            self._ecrt_curve = latency_lib.ecrt_expected_tx_curve(
                grid, self._ecrt_modulation(), n_codewords=codewords,
                max_tx=max_tx)
        return self._ecrt_curve

    def init(self, key: jax.Array, num_clients: int
             ) -> tuple[dynamics_lib.LinkState, jax.Array, jax.Array]:
        """Stationary link state, round-0 modes, and round-0 CSI.

        Modes are the hysteresis-free mapping of each client's static
        operating point (mean SNR + frozen offset); that operating point is
        also returned as the initial "previous estimate" the first
        :meth:`round` call's staleness logic falls back on — callers thread
        both through as ``prev_mode`` / ``prev_est_db``.
        """
        state = dynamics_lib.init_state(key, num_clients,
                                        self.scenario.dynamics)
        op_point = self.scenario.dynamics.mean_snr_db + state.offset_db
        mode0 = policy_lib.initial_mode(op_point, self.scenario.policy)
        return state, mode0, op_point

    def round(self, state: dynamics_lib.LinkState, prev_mode: jax.Array,
              prev_est_db: jax.Array, key: jax.Array,
              observed: jax.Array | None = None
              ) -> tuple[dynamics_lib.LinkState, LinkRound]:
        """One link round: dynamics -> estimator -> policy -> availability.

        ``observed`` (0/1 per client, or ``None`` = everyone) marks the
        clients actually dispatched this wave: unobserved clients keep
        their previous mode (``policy.choose_mode``'s participation mask),
        so hysteresis state survives the participation gaps of a buffered
        asynchronous run. ``None`` is bit-identical to the synchronous
        behavior.
        """
        scen = self.scenario
        k_dyn, k_est, k_drop, k_strag = jax.random.split(key, 4)
        state, snr = dynamics_lib.step(state, k_dyn, scen.dynamics)
        est = estimator_lib.step_estimate(snr, prev_est_db, k_est,
                                          scen.estimator)
        mode = policy_lib.choose_mode(est, prev_mode, scen.policy,
                                      observed=observed)
        shape = snr.shape
        active = jax.random.bernoulli(
            k_drop, 1.0 - scen.dropout_prob, shape).astype(jnp.float32)
        straggler = jax.random.bernoulli(
            k_strag, scen.straggler_prob, shape).astype(jnp.float32)
        return state, LinkRound(snr, est, mode, active, straggler)

    def airtime(self, stats: transport_lib.TxStats, rnd: LinkRound,
                timings: latency_lib.PhyTimings) -> jax.Array:
        """Per-client airtime of the round: mode-priced, straggler-scaled,
        zero for dropped clients. ``(num_clients,)`` seconds.

        With calibrated ECRT (``scenario.ecrt_expected_tx = None``) each
        ECRT client's symbols/transmissions are rescaled from the anchor
        constant to E[tx] interpolated at *its* SNR *this round* — the
        analytic model is linear in E[tx], so the rescale prices the fade
        exactly as a per-client calibration would.

        Known approximation: for *sparse* frames (``repro.compress``) the
        combined stats include the uncoded index-header symbols, which the
        rescale scales along with the LDPC value leg even though the
        header is never retransmitted — an error bounded by the header's
        share of the frame (typically <= ~20%); pricing it exactly would
        need per-leg stats. Explicit ``ecrt_expected_tx`` (no rescale) is
        unaffected.
        """
        if (self._interp_ecrt_airtime and self._ecrt_rows
                and stats.mode_idx is not None):
            grid, vals = self._ecrt_tx_curve()
            e_tx = latency_lib.interp_expected_tx(rnd.snr_db, grid, vals)
            anchor = jnp.asarray(
                [c.ecrt_expected_tx for c in self.mode_cfgs], jnp.float32
            )[stats.mode_idx]
            is_ecrt = jnp.any(
                jnp.asarray(stats.mode_idx)[:, None]
                == jnp.asarray(self._ecrt_rows, jnp.int32), axis=-1)
            ratio = jnp.where(is_ecrt, e_tx / jnp.maximum(anchor, 1e-6), 1.0)
            stats = transport_lib.TxStats(
                stats.data_symbols * ratio, stats.transmissions * ratio,
                stats.bit_errors, stats.n_bits, stats.mode_idx,
                bits_on_air=None if stats.bits_on_air is None
                else stats.bits_on_air * ratio)
        air = latency_lib.round_airtime_adaptive(stats, timings,
                                                 self.mode_cfgs)
        slowdown = 1.0 + (self.scenario.straggler_slowdown - 1.0) * rnd.straggler
        return air * slowdown * rnd.active


SCENARIOS: dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Add (or replace) a scenario in the registry; returns it."""
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario; unknown names list what exists."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: "
            f"{', '.join(sorted(SCENARIOS))}"
        ) from None


def list_scenarios() -> list[str]:
    """Registered scenario names, sorted."""
    return sorted(SCENARIOS)


def _preset(name: str, **kw) -> Scenario:
    return register_scenario(Scenario(
        name=name, dynamics=dynamics_lib.DYNAMICS_PRESETS[kw.pop("dyn", name)],
        **kw))


_preset("static",
        description="the paper's setup: one SNR, all clients, whole run")
_preset("pedestrian",
        description="walking users: slow fading drift + moderate shadowing")
_preset("vehicular",
        description="driving users: fast fading, wide per-client spread")
_preset("shadowed-urban",
        description="urban canyon: slowly-decorrelating deep shadowing")
_preset("bursty",
        description="IoT links: good on average with Markov blockage spells")
_preset("iot-flaky", dyn="bursty",
        estimator=estimator_lib.EstimatorConfig(n_pilots=16, stale_prob=0.2),
        dropout_prob=0.1, straggler_prob=0.1, straggler_slowdown=3.0,
        description="bursty links + few pilots, stale CSI, dropout, stragglers")
_preset("vehicular-noisy-dl", dyn="vehicular",
        downlink=DownlinkConfig(mode="approx", snr_offset_db=3.0,
                                adaptive=True),
        description="vehicular links with a noisy adaptive broadcast "
                    "downlink 3 dB above the uplink (per-client mode via "
                    "the policy table)")
_preset("static-noisy-dl", dyn="static",
        downlink=DownlinkConfig(mode="approx", snr_offset_db=0.0),
        description="the paper's static setup plus a matched-SNR uncoded "
                    "broadcast downlink (the Qu et al. error-budget axis)")
_preset("iot-lowrate",
        estimator=estimator_lib.EstimatorConfig(n_pilots=16),
        policy=policy_lib.PolicyConfig(
            compress_ratios=(0.01, 0.02, 0.05, 0.10)),
        dropout_prob=0.05,
        compression=CompressionConfig(method="topk", ratio=0.02),
        description="narrowband low-SNR IoT links; top-k+EF sparse uplinks "
                    "on by default, compressed deepest in the protected "
                    "low-SNR modes (CSI-adaptive ratio column)")
_preset("metro-rush", dyn="vehicular",
        dropout_prob=0.05, straggler_prob=0.10, straggler_slowdown=3.0,
        compute=dynamics_lib.ComputeTimeConfig(
            mean_s=0.5, speed_spread=0.4, jitter=0.3,
            straggler_prob=0.15, straggler_factor=20.0),
        arrival=dynamics_lib.ArrivalConfig(mean_idle_s=0.25),
        description="rush-hour metro cell: vehicular links, heavy-tailed "
                    "compute stragglers (20x spells), Poisson re-arrival "
                    "gaps — the buffered engine's home turf")
_preset("global-churn", dyn="shadowed-urban",
        dropout_prob=0.05,
        compute=dynamics_lib.ComputeTimeConfig(
            mean_s=1.0, speed_spread=0.5, jitter=0.2,
            straggler_prob=0.05, straggler_factor=8.0),
        arrival=dynamics_lib.ArrivalConfig(
            mean_idle_s=1.0, p_leave=0.10, p_rejoin=0.30),
        description="planet-scale cohort: urban-canyon shadowing with "
                    "clients leaving and rejoining between waves (EF "
                    "residuals and hysteresis state must survive the gaps)")
