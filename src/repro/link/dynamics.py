"""Temporally correlated per-client link-quality evolution.

The paper fixes one average SNR for a whole run; real uplinks do not. This
module produces per-round, per-client average-SNR trajectories (in dB) that
the estimator/policy/transport stack consumes, composed of three classic
components on top of a static per-client operating point:

* **fast fading track** — a first-order Gauss-Markov process in dB,
  ``f' = rho f + sqrt(1-rho^2) sigma w``; the round-to-round correlation
  ``rho`` plays the role of the Jakes/Clarke Doppler autocorrelation
  ``J0(2 pi f_d T_round)`` (:func:`jakes_rho` maps a Doppler spread and
  round interval onto it). This models the *average* SNR drifting with
  mobility; per-symbol Rayleigh fading inside a round is still drawn by
  ``core.channel``.
* **shadowing** — log-normal (Gaussian-in-dB) AR(1) with its own, much
  longer, correlation time (Gudmundson-style exponential decorrelation).
* **blockage** — a two-state Markov on-off process (bursty deep fades:
  an obstructed client loses ``off_penalty_db`` until it recovers), the
  regime Ma et al. (arXiv:2404.11035) study for lossy IoT uplinks.

Everything is pure jax: ``step`` is jit/vmap/scan-friendly, so a whole FL
round (dynamics -> estimate -> policy -> batched transport) stays one fused
XLA program. ``DYNAMICS_PRESETS`` names the standard mobility profiles the
scenario registry builds on.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "LinkDynamicsConfig",
    "LinkState",
    "DYNAMICS_PRESETS",
    "jakes_rho",
    "init_state",
    "step",
    "trajectory",
]


@dataclasses.dataclass(frozen=True)
class LinkDynamicsConfig:
    """Parameters of the per-client SNR process (all dB quantities in dB).

    The stationary distribution of the emitted SNR (ignoring blockage and
    clipping) is ``N(mean_snr_db + offset, fast_std_db^2 + shadow_std_db^2)``
    with per-client ``offset ~ U(-spread_db, +spread_db)`` frozen at init —
    heterogeneous cohorts have persistently good and bad clients, not just
    i.i.d. noise.
    """

    mean_snr_db: float = 10.0  # fleet-average operating point
    spread_db: float = 0.0  # static per-client offset: U(-spread, +spread)
    fast_rho: float = 1.0  # Gauss-Markov round-to-round correlation
    fast_std_db: float = 0.0  # stationary std of the fast track
    shadow_rho: float = 1.0  # AR(1) correlation of shadowing
    shadow_std_db: float = 0.0  # stationary std of shadowing
    onoff: bool = False  # enable the Markov blockage process
    p_block: float = 0.0  # P(on -> off) per round
    p_recover: float = 1.0  # P(off -> on) per round
    off_penalty_db: float = 18.0  # SNR hit while blocked
    snr_floor_db: float = -5.0  # physical clipping of the emitted SNR
    snr_ceil_db: float = 40.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinkState:
    """Per-client dynamics state; every field is ``(num_clients,)`` float32.

    ``blocked`` is 0/1 float (kept float so the whole state is one dtype
    under scan/jit).
    """

    offset_db: jax.Array  # frozen per-client operating-point offset
    fast_db: jax.Array  # Gauss-Markov fast-fading track
    shadow_db: jax.Array  # AR(1) shadowing track
    blocked: jax.Array  # Markov on-off blockage indicator (0/1)


def jakes_rho(doppler_hz: float, round_interval_s: float) -> float:
    """Round-to-round fading correlation ``J0(2 pi f_d T)`` (Jakes/Clarke).

    Maps a physical Doppler spread (``f_d = v / lambda``; ~5 Hz pedestrian,
    ~100 Hz vehicular at 2.4 GHz) and the FL round interval onto the
    Gauss-Markov ``fast_rho``. Uses the Abramowitz & Stegun 9.4.1/9.4.3
    polynomial J0 (static config-time helper, plain Python floats), clipped
    to [0, 1] — negative J0 lobes mean "decorrelated by the next round" for
    our per-round abstraction.
    """
    x = abs(2.0 * math.pi * doppler_hz * round_interval_s)
    if x <= 3.0:
        t = (x / 3.0) ** 2
        j0 = (1.0 + t * (-2.2499997 + t * (1.2656208 + t * (-0.3163866
              + t * (0.0444479 + t * (-0.0039444 + t * 0.0002100))))))
    else:
        t = 3.0 / x
        f0 = (0.79788456 + t * (-0.00000077 + t * (-0.00552740
              + t * (-0.00009512 + t * (0.00137237 + t * (-0.00072805
              + t * 0.00014476))))))
        th = (x - 0.78539816 + t * (-0.04166397 + t * (-0.00003954
              + t * (0.00262573 + t * (-0.00054125 + t * (-0.00029333
              + t * 0.00013558))))))
        j0 = f0 * math.cos(th) / math.sqrt(x)
    return min(max(j0, 0.0), 1.0)


def _stationary_blocked_prob(cfg: LinkDynamicsConfig) -> float:
    if not cfg.onoff:
        return 0.0
    denom = cfg.p_block + cfg.p_recover
    return cfg.p_block / denom if denom > 0 else 0.0


def init_state(key: jax.Array, num_clients: int,
               cfg: LinkDynamicsConfig) -> LinkState:
    """Draw the stationary initial state for ``num_clients`` links."""
    k_off, k_fast, k_shadow, k_block = jax.random.split(key, 4)
    shape = (num_clients,)
    offset = jax.random.uniform(
        k_off, shape, jnp.float32, -cfg.spread_db, cfg.spread_db)
    fast = jax.random.normal(k_fast, shape, jnp.float32) * cfg.fast_std_db
    shadow = jax.random.normal(k_shadow, shape, jnp.float32) * cfg.shadow_std_db
    blocked = jax.random.bernoulli(
        k_block, _stationary_blocked_prob(cfg), shape).astype(jnp.float32)
    return LinkState(offset, fast, shadow, blocked)


def _ar1(x: jax.Array, key: jax.Array, rho: float, std: float) -> jax.Array:
    """One Gauss-Markov step preserving the stationary std."""
    innov = math.sqrt(max(1.0 - rho * rho, 0.0)) * std
    return rho * x + innov * jax.random.normal(key, x.shape, jnp.float32)


def step(state: LinkState, key: jax.Array,
         cfg: LinkDynamicsConfig) -> tuple[LinkState, jax.Array]:
    """Advance one FL round; returns ``(new_state, snr_db (num_clients,))``.

    The emitted SNR is the *true* average link quality this round — the
    policy never sees it directly (it acts on the estimator's noisy CSI),
    but the channel simulation does.
    """
    k_fast, k_shadow, k_block = jax.random.split(key, 3)
    fast = _ar1(state.fast_db, k_fast, cfg.fast_rho, cfg.fast_std_db)
    shadow = _ar1(state.shadow_db, k_shadow, cfg.shadow_rho, cfg.shadow_std_db)
    if cfg.onoff:
        u = jax.random.uniform(k_block, state.blocked.shape, jnp.float32)
        was = state.blocked > 0.5
        blocked = jnp.where(
            was, (u >= cfg.p_recover), (u < cfg.p_block)).astype(jnp.float32)
    else:
        blocked = jnp.zeros_like(state.blocked)
    new = LinkState(state.offset_db, fast, shadow, blocked)
    snr = (cfg.mean_snr_db + state.offset_db + fast + shadow
           - cfg.off_penalty_db * blocked)
    return new, jnp.clip(snr, cfg.snr_floor_db, cfg.snr_ceil_db)


def trajectory(key: jax.Array, cfg: LinkDynamicsConfig, num_clients: int,
               n_rounds: int) -> jax.Array:
    """Full ``(n_rounds, num_clients)`` SNR trajectory via ``lax.scan``."""
    k_init, k_scan = jax.random.split(key)
    state = init_state(k_init, num_clients, cfg)

    def body(st, kr):
        st, snr = step(st, kr, cfg)
        return st, snr

    _, snrs = jax.lax.scan(body, state, jax.random.split(k_scan, n_rounds))
    return snrs


# Named mobility profiles (round interval ~1 s assumed for the rho values;
# use jakes_rho to re-derive fast_rho for other cadences).
DYNAMICS_PRESETS: dict[str, LinkDynamicsConfig] = {
    # the paper's setup: one static SNR per client for the whole run
    "static": LinkDynamicsConfig(mean_snr_db=10.0),
    # walking users: slow fading drift, moderate shadowing
    "pedestrian": LinkDynamicsConfig(
        mean_snr_db=12.0, spread_db=4.0,
        fast_rho=0.9, fast_std_db=2.5,
        shadow_rho=0.98, shadow_std_db=3.0),
    # driving users: near-decorrelated fast track, faster shadowing turnover
    "vehicular": LinkDynamicsConfig(
        mean_snr_db=10.0, spread_db=6.0,
        fast_rho=0.35, fast_std_db=5.0,
        shadow_rho=0.9, shadow_std_db=4.0),
    # dense urban canyon: shadowing dominates and decorrelates very slowly
    "shadowed-urban": LinkDynamicsConfig(
        mean_snr_db=9.0, spread_db=3.0,
        fast_rho=0.95, fast_std_db=1.5,
        shadow_rho=0.995, shadow_std_db=7.0),
    # bursty IoT links: good on average, Markov blockage spells
    "bursty": LinkDynamicsConfig(
        mean_snr_db=14.0, spread_db=3.0,
        fast_rho=0.8, fast_std_db=2.0,
        onoff=True, p_block=0.08, p_recover=0.35, off_penalty_db=18.0),
    # narrowband low-rate IoT: low operating point, slow drift, shallow
    # blockage — the regime where sparse (compressed) uplinks pay off most
    # (Ma et al., arXiv:2404.11035)
    "iot-lowrate": LinkDynamicsConfig(
        mean_snr_db=6.0, spread_db=2.0,
        fast_rho=0.9, fast_std_db=1.5,
        shadow_rho=0.98, shadow_std_db=2.0,
        onoff=True, p_block=0.05, p_recover=0.5, off_penalty_db=12.0),
}
