"""Temporally correlated per-client link-quality evolution.

The paper fixes one average SNR for a whole run; real uplinks do not. This
module produces per-round, per-client average-SNR trajectories (in dB) that
the estimator/policy/transport stack consumes, composed of three classic
components on top of a static per-client operating point:

* **fast fading track** — a first-order Gauss-Markov process in dB,
  ``f' = rho f + sqrt(1-rho^2) sigma w``; the round-to-round correlation
  ``rho`` plays the role of the Jakes/Clarke Doppler autocorrelation
  ``J0(2 pi f_d T_round)`` (:func:`jakes_rho` maps a Doppler spread and
  round interval onto it). This models the *average* SNR drifting with
  mobility; per-symbol Rayleigh fading inside a round is still drawn by
  ``core.channel``.
* **shadowing** — log-normal (Gaussian-in-dB) AR(1) with its own, much
  longer, correlation time (Gudmundson-style exponential decorrelation).
* **blockage** — a two-state Markov on-off process (bursty deep fades:
  an obstructed client loses ``off_penalty_db`` until it recovers), the
  regime Ma et al. (arXiv:2404.11035) study for lossy IoT uplinks.

Everything is pure jax: ``step`` is jit/vmap/scan-friendly, so a whole FL
round (dynamics -> estimate -> policy -> batched transport) stays one fused
XLA program. ``DYNAMICS_PRESETS`` names the standard mobility profiles the
scenario registry builds on.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import keylanes

__all__ = [
    "LinkDynamicsConfig",
    "LinkState",
    "DYNAMICS_PRESETS",
    "COMPUTE_KEY_LANE",
    "EVENT_KEY_LANE",
    "EVENT_GAP_KEY_LANE",
    "ComputeTimeConfig",
    "ArrivalConfig",
    "jakes_rho",
    "init_state",
    "step",
    "trajectory",
    "client_speed_factors",
    "compute_times",
    "churn_step",
    "idle_gaps",
]

# Reserved fold_in lanes for the event layer (asynchronous FL). Uplink
# transport keys fold_in the client index directly (transport.client_keys),
# the downlink/header legs use transport.DOWNLINK_KEY_LANE (1 << 20) /
# HEADER_KEY_LANE (1 << 21), and rand-k selection uses
# sparsify.SELECT_KEY_LANE ((1 << 21) + 1). The event layer claims two more
# disjoint lanes off the same per-wave base key, so enabling compute-time /
# churn draws never perturbs any channel, header, or selection draw:
#
# * ``COMPUTE_KEY_LANE + i`` — client ``i``'s compute-time draw this wave
#   (and, on the run's base key, its frozen speed factor).
# * ``EVENT_KEY_LANE + i`` — client ``i``'s churn (join/leave) uniform;
#   ``EVENT_KEY_LANE + (1 << 20) + i`` its post-upload idle gap (a fixed
#   sub-lane offset, so both stay batching-independent).
#
# Each client draws from its own folded key, so the draws are independent
# of cohort batching: evaluating a subset of clients is bit-identical to
# slicing the full-cohort evaluation.
#
# All three are declared centrally in repro.core.keylanes (overlap-checked
# at import) and re-exported here with the historical values: COMPUTE is
# 1 << 22, EVENT is 3 << 21, and the gap sub-lane EVENT + (1 << 20) is now
# the first-class EVENT_GAP_KEY_LANE. Every client-indexed draw below
# validates the cohort against the lane span (1 << 20) — a >1M-client
# cohort raises instead of silently walking into the next lane.
COMPUTE_KEY_LANE = keylanes.COMPUTE_KEY_LANE
EVENT_KEY_LANE = keylanes.EVENT_KEY_LANE
EVENT_GAP_KEY_LANE = keylanes.EVENT_GAP_KEY_LANE


@dataclasses.dataclass(frozen=True)
class ComputeTimeConfig:
    """Per-client local-computation time model for event-driven FL rounds.

    A client dispatched at event time ``t`` finishes local work at
    ``t + mean_s * speed_i * exp(jitter * z) * straggler``, where
    ``speed_i = exp(speed_spread * z_i)`` is a frozen per-client lognormal
    speed factor (persistently slow devices, not just per-wave noise),
    ``z`` is a fresh per-(wave, client) standard normal, and ``straggler``
    is ``straggler_factor`` with probability ``straggler_prob`` (else 1) —
    the heavy tail FedBuff-style buffering is designed to escape. The
    defaults are degenerate (every client takes exactly ``mean_s`` seconds
    every wave), which the synchronous-equivalence tests rely on.
    """

    mean_s: float = 1.0  # mean local-computation time per wave
    speed_spread: float = 0.0  # lognormal spread of the frozen speed factor
    jitter: float = 0.0  # per-wave lognormal jitter
    straggler_prob: float = 0.0  # P(compute straggler) per wave per client
    straggler_factor: float = 10.0  # compute slowdown when straggling


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    """Client availability between waves of an event-driven FL run.

    ``mean_idle_s`` is the mean of the exponential idle gap a client waits
    after finishing an upload before it may be dispatched again (Poisson
    re-arrivals). ``p_leave``/``p_rejoin`` is a per-dispatch-attempt Markov
    churn process: a joined client leaves with ``p_leave``, a departed one
    rejoins with ``p_rejoin``. Clients already in flight finish their
    upload regardless — churn only gates *new* dispatches.
    """

    mean_idle_s: float = 0.0
    p_leave: float = 0.0
    p_rejoin: float = 1.0


@dataclasses.dataclass(frozen=True)
class LinkDynamicsConfig:
    """Parameters of the per-client SNR process (all dB quantities in dB).

    The stationary distribution of the emitted SNR (ignoring blockage and
    clipping) is ``N(mean_snr_db + offset, fast_std_db^2 + shadow_std_db^2)``
    with per-client ``offset ~ U(-spread_db, +spread_db)`` frozen at init —
    heterogeneous cohorts have persistently good and bad clients, not just
    i.i.d. noise.
    """

    mean_snr_db: float = 10.0  # fleet-average operating point
    spread_db: float = 0.0  # static per-client offset: U(-spread, +spread)
    fast_rho: float = 1.0  # Gauss-Markov round-to-round correlation
    fast_std_db: float = 0.0  # stationary std of the fast track
    shadow_rho: float = 1.0  # AR(1) correlation of shadowing
    shadow_std_db: float = 0.0  # stationary std of shadowing
    onoff: bool = False  # enable the Markov blockage process
    p_block: float = 0.0  # P(on -> off) per round
    p_recover: float = 1.0  # P(off -> on) per round
    off_penalty_db: float = 18.0  # SNR hit while blocked
    snr_floor_db: float = -5.0  # physical clipping of the emitted SNR
    snr_ceil_db: float = 40.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinkState:
    """Per-client dynamics state; every field is ``(num_clients,)`` float32.

    ``blocked`` is 0/1 float (kept float so the whole state is one dtype
    under scan/jit).
    """

    offset_db: jax.Array  # frozen per-client operating-point offset
    fast_db: jax.Array  # Gauss-Markov fast-fading track
    shadow_db: jax.Array  # AR(1) shadowing track
    blocked: jax.Array  # Markov on-off blockage indicator (0/1)


def jakes_rho(doppler_hz: float, round_interval_s: float) -> float:
    """Round-to-round fading correlation ``J0(2 pi f_d T)`` (Jakes/Clarke).

    Maps a physical Doppler spread (``f_d = v / lambda``; ~5 Hz pedestrian,
    ~100 Hz vehicular at 2.4 GHz) and the FL round interval onto the
    Gauss-Markov ``fast_rho``. Uses the Abramowitz & Stegun 9.4.1/9.4.3
    polynomial J0 (static config-time helper, plain Python floats), clipped
    to [0, 1] — negative J0 lobes mean "decorrelated by the next round" for
    our per-round abstraction.
    """
    x = abs(2.0 * math.pi * doppler_hz * round_interval_s)
    if x <= 3.0:
        t = (x / 3.0) ** 2
        j0 = (1.0 + t * (-2.2499997 + t * (1.2656208 + t * (-0.3163866
              + t * (0.0444479 + t * (-0.0039444 + t * 0.0002100))))))
    else:
        t = 3.0 / x
        f0 = (0.79788456 + t * (-0.00000077 + t * (-0.00552740
              + t * (-0.00009512 + t * (0.00137237 + t * (-0.00072805
              + t * 0.00014476))))))
        th = (x - 0.78539816 + t * (-0.04166397 + t * (-0.00003954
              + t * (0.00262573 + t * (-0.00054125 + t * (-0.00029333
              + t * 0.00013558))))))
        j0 = f0 * math.cos(th) / math.sqrt(x)
    return min(max(j0, 0.0), 1.0)


def _stationary_blocked_prob(cfg: LinkDynamicsConfig) -> float:
    if not cfg.onoff:
        return 0.0
    denom = cfg.p_block + cfg.p_recover
    return cfg.p_block / denom if denom > 0 else 0.0


def init_state(key: jax.Array, num_clients: int,
               cfg: LinkDynamicsConfig) -> LinkState:
    """Draw the stationary initial state for ``num_clients`` links."""
    k_off, k_fast, k_shadow, k_block = jax.random.split(key, 4)
    shape = (num_clients,)
    offset = jax.random.uniform(
        k_off, shape, jnp.float32, -cfg.spread_db, cfg.spread_db)
    fast = jax.random.normal(k_fast, shape, jnp.float32) * cfg.fast_std_db
    shadow = jax.random.normal(k_shadow, shape, jnp.float32) * cfg.shadow_std_db
    blocked = jax.random.bernoulli(
        k_block, _stationary_blocked_prob(cfg), shape).astype(jnp.float32)
    return LinkState(offset, fast, shadow, blocked)


def _ar1(x: jax.Array, key: jax.Array, rho: float, std: float) -> jax.Array:
    """One Gauss-Markov step preserving the stationary std."""
    innov = math.sqrt(max(1.0 - rho * rho, 0.0)) * std
    return rho * x + innov * jax.random.normal(key, x.shape, jnp.float32)


def step(state: LinkState, key: jax.Array,
         cfg: LinkDynamicsConfig) -> tuple[LinkState, jax.Array]:
    """Advance one FL round; returns ``(new_state, snr_db (num_clients,))``.

    The emitted SNR is the *true* average link quality this round — the
    policy never sees it directly (it acts on the estimator's noisy CSI),
    but the channel simulation does.
    """
    k_fast, k_shadow, k_block = jax.random.split(key, 3)
    fast = _ar1(state.fast_db, k_fast, cfg.fast_rho, cfg.fast_std_db)
    shadow = _ar1(state.shadow_db, k_shadow, cfg.shadow_rho, cfg.shadow_std_db)
    if cfg.onoff:
        u = jax.random.uniform(k_block, state.blocked.shape, jnp.float32)
        was = state.blocked > 0.5
        blocked = jnp.where(
            was, (u >= cfg.p_recover), (u < cfg.p_block)).astype(jnp.float32)
    else:
        blocked = jnp.zeros_like(state.blocked)
    new = LinkState(state.offset_db, fast, shadow, blocked)
    snr = (cfg.mean_snr_db + state.offset_db + fast + shadow
           - cfg.off_penalty_db * blocked)
    return new, jnp.clip(snr, cfg.snr_floor_db, cfg.snr_ceil_db)


def trajectory(key: jax.Array, cfg: LinkDynamicsConfig, num_clients: int,
               n_rounds: int) -> jax.Array:
    """Full ``(n_rounds, num_clients)`` SNR trajectory via ``lax.scan``."""
    k_init, k_scan = jax.random.split(key)
    state = init_state(k_init, num_clients, cfg)

    def body(st, kr):
        st, snr = step(st, kr, cfg)
        return st, snr

    _, snrs = jax.lax.scan(body, state, jax.random.split(k_scan, n_rounds))
    return snrs


def client_speed_factors(key: jax.Array, num_clients: int,
                         cfg: ComputeTimeConfig) -> jax.Array:
    """Frozen per-client lognormal speed multipliers, ``(num_clients,)``.

    Callers pass ``fold_in(run_key, COMPUTE_KEY_LANE)`` so the draw rides a
    reserved lane of the run's base key without consuming a split (the
    synchronous key schedule is untouched). ``speed_spread = 0`` yields
    exactly 1.0 for every client (``exp(±0.0) == 1.0`` in float32).
    """
    keylanes.check_cohort(COMPUTE_KEY_LANE, num_clients)

    def one(i):
        k = jax.random.fold_in(key, COMPUTE_KEY_LANE + i)
        return jax.random.normal(k, (), jnp.float32)

    z = jax.vmap(one)(jnp.arange(num_clients))
    # The barrier pins the draw/arithmetic fusion boundary so the result is
    # bit-identical eager vs jitted (XLA otherwise reassociates the fused
    # exp chain by a ULP).
    z = jax.lax.optimization_barrier(z)
    return jnp.exp(cfg.speed_spread * z)


def compute_times(key: jax.Array, cfg: ComputeTimeConfig, num_clients: int,
                  speed: jax.Array | None = None) -> jax.Array:
    """Per-(wave, client) local-computation seconds, ``(num_clients,)``.

    Client ``i`` draws from ``fold_in(key, COMPUTE_KEY_LANE + i)`` (``key``
    is the wave's round key), so the draw is bit-stable across dispatches
    and independent of how the cohort is batched: computing a prefix (or
    any subset) of clients equals slicing the full-cohort result. With the
    default (degenerate) config the result is exactly ``mean_s`` for every
    client — the synchronous-equivalence invariant.
    """
    keylanes.check_cohort(COMPUTE_KEY_LANE, num_clients)

    def one(i):
        k = jax.random.fold_in(key, COMPUTE_KEY_LANE + i)
        kz, ku = jax.random.split(k)
        return (jax.random.normal(kz, (), jnp.float32),
                jax.random.uniform(ku, (), jnp.float32))

    z, u = jax.vmap(one)(jnp.arange(num_clients))
    # Bit-stability barrier: see client_speed_factors.
    z, u = jax.lax.optimization_barrier((z, u))
    slow = jnp.where(u < cfg.straggler_prob, cfg.straggler_factor, 1.0)
    t = cfg.mean_s * jnp.exp(cfg.jitter * z) * slow
    if speed is not None:
        t = t * speed
    return t


def churn_step(key: jax.Array, joined: jax.Array,
               cfg: ArrivalConfig) -> jax.Array:
    """One dispatch attempt's join/leave update; ``(num_clients,)`` 0/1.

    Client ``i``'s uniform rides ``fold_in(key, EVENT_KEY_LANE + i)`` —
    per-client lanes, so the churn of any subset is independent of the
    rest of the cohort.
    """
    keylanes.check_cohort(EVENT_KEY_LANE, int(jnp.shape(joined)[0]))

    def one(i):
        k = jax.random.fold_in(key, EVENT_KEY_LANE + i)
        return jax.random.uniform(k, (), jnp.float32)

    u = jax.vmap(one)(jnp.arange(joined.shape[0]))
    j = jnp.asarray(joined) > 0
    return jnp.where(j, u >= cfg.p_leave, u < cfg.p_rejoin).astype(jnp.float32)


def idle_gaps(key: jax.Array, num_clients: int,
              cfg: ArrivalConfig) -> jax.Array:
    """Per-client exponential post-upload idle gaps (seconds).

    Rides :data:`EVENT_GAP_KEY_LANE` (``EVENT_KEY_LANE + (1 << 20)``, far
    above any plausible cohort size) so a wave's idle draws never collide
    with its churn uniforms — a *constant* offset, so slicing a full-cohort
    draw equals drawing the subcohort (batching independence, like every
    other per-client lane). ``mean_idle_s = 0`` yields exactly zero
    (immediate re-availability).
    """
    keylanes.check_cohort(EVENT_GAP_KEY_LANE, num_clients)

    def one(i):
        k = jax.random.fold_in(key, EVENT_GAP_KEY_LANE + i)
        return jax.random.exponential(k, (), jnp.float32)

    g = jax.vmap(one)(jnp.arange(num_clients))
    # Bit-stability barrier: see client_speed_factors.
    g = jax.lax.optimization_barrier(g)
    return g * cfg.mean_idle_s


# Named mobility profiles (round interval ~1 s assumed for the rho values;
# use jakes_rho to re-derive fast_rho for other cadences).
DYNAMICS_PRESETS: dict[str, LinkDynamicsConfig] = {
    # the paper's setup: one static SNR per client for the whole run
    "static": LinkDynamicsConfig(mean_snr_db=10.0),
    # walking users: slow fading drift, moderate shadowing
    "pedestrian": LinkDynamicsConfig(
        mean_snr_db=12.0, spread_db=4.0,
        fast_rho=0.9, fast_std_db=2.5,
        shadow_rho=0.98, shadow_std_db=3.0),
    # driving users: near-decorrelated fast track, faster shadowing turnover
    "vehicular": LinkDynamicsConfig(
        mean_snr_db=10.0, spread_db=6.0,
        fast_rho=0.35, fast_std_db=5.0,
        shadow_rho=0.9, shadow_std_db=4.0),
    # dense urban canyon: shadowing dominates and decorrelates very slowly
    "shadowed-urban": LinkDynamicsConfig(
        mean_snr_db=9.0, spread_db=3.0,
        fast_rho=0.95, fast_std_db=1.5,
        shadow_rho=0.995, shadow_std_db=7.0),
    # bursty IoT links: good on average, Markov blockage spells
    "bursty": LinkDynamicsConfig(
        mean_snr_db=14.0, spread_db=3.0,
        fast_rho=0.8, fast_std_db=2.0,
        onoff=True, p_block=0.08, p_recover=0.35, off_penalty_db=18.0),
    # narrowband low-rate IoT: low operating point, slow drift, shallow
    # blockage — the regime where sparse (compressed) uplinks pay off most
    # (Ma et al., arXiv:2404.11035)
    "iot-lowrate": LinkDynamicsConfig(
        mean_snr_db=6.0, spread_db=2.0,
        fast_rho=0.9, fast_std_db=1.5,
        shadow_rho=0.98, shadow_std_db=2.0,
        onoff=True, p_block=0.05, p_recover=0.5, off_penalty_db=12.0),
}
