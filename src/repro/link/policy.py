"""Per-client transmission-mode policy: the paper's conditional mechanism.

The paper's scheme "simply delivers gradients with errors when the channel
quality is satisfactory" and falls back to protection otherwise — this
module is that decision, made explicit, per client, per round:

* a **mode table** orders link modes from most protected to most aggressive
  (default: ECRT -> approx/QPSK -> approx/16-QAM -> approx/256-QAM — the
  last three being adaptive modulation-order selection over the paper's
  MSB-protected Gray-QAM transport; 64-QAM is excluded because 6 bits per
  symbol cannot pack 32-bit wire words, see ``build_mode_cfgs``);
* ``choose_mode`` maps estimated SNR to a table index by thresholds, with
  **hysteresis**: a link must clear a threshold by ``+h/2`` to move up and
  fall ``h/2`` below it to move down, so CSI jitter near a boundary does not
  flap modes (flapping is costly: every ECRT-to-approx flip changes airtime
  and error statistics round to round);
* ``build_mode_cfgs`` materializes the table as ``TransportConfig`` rows for
  ``transport.transmit_batch_adaptive``.

All decision functions are pure jnp (vmap/scan/jit-friendly): a mixed-mode
64-client round — dynamics, estimation, policy, uplink — compiles to one
XLA program.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import modulation as mod_lib
from repro.core import transport as transport_lib

__all__ = [
    "DEFAULT_CALIB_CODEWORDS",
    "DEFAULT_CALIB_MAX_TX",
    "PolicyConfig",
    "fixed_policy",
    "mode_names",
    "initial_mode",
    "choose_mode",
    "downlink_mode",
    "ecrt_anchor_snr_db",
    "build_mode_cfgs",
    "compress_k_table",
]

# Re-exported for table builders; defined next to the calibrator so the FL
# loops' fixed-ECRT path shares the exact same sample budget.
DEFAULT_CALIB_CODEWORDS = latency_lib.DEFAULT_CALIB_CODEWORDS
DEFAULT_CALIB_MAX_TX = latency_lib.DEFAULT_CALIB_MAX_TX


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Threshold policy over an ordered mode table.

    ``modes[i]`` is a ``(transport_mode, modulation)`` pair; ``modes[0]`` is
    the protected fallback. ``thresholds_db[i]`` is the estimated-SNR level
    above which mode ``i+1`` becomes eligible (``len(thresholds_db) ==
    len(modes) - 1``, ascending). Defaults: below 6 dB the link is not
    "satisfactory" and gets ECRT; uncoded QPSK to 16 dB (the paper's 10 dB
    operating point sits here); Gray 16-QAM to 26 dB; Gray 256-QAM above.
    (Approx modulations must divide the 32-bit float wire word: QPSK /
    16-QAM / 256-QAM. 64-QAM's k=6 cannot pack float32 words MSB-first —
    ``build_mode_cfgs`` rejects it up front.)
    """

    modes: tuple = (
        ("ecrt", "qpsk"),
        ("approx", "qpsk"),
        ("approx", "16qam"),
        ("approx", "256qam"),
    )
    thresholds_db: tuple = (6.0, 16.0, 26.0)
    hysteresis_db: float = 2.0
    # CSI-adaptive compression column: per-mode sparsification ratio used
    # when the FL run enables gradient compression (repro.compress) — a
    # fraction of coordinates kept, one entry per mode, typically deeper
    # compression (smaller ratio) in the protected low-SNR modes where
    # airtime is most expensive. None = one flat ratio from the
    # CompressionConfig. Consumed by the engine's *bucketed* dispatch only
    # (per-mode slot budgets are ragged, which a fused select round cannot
    # trace).
    compress_ratios: tuple | None = None

    def __post_init__(self):
        if len(self.thresholds_db) != len(self.modes) - 1:
            raise ValueError(
                f"need len(modes)-1 = {len(self.modes) - 1} thresholds, got "
                f"{len(self.thresholds_db)}"
            )
        if list(self.thresholds_db) != sorted(self.thresholds_db):
            raise ValueError(f"thresholds must ascend: {self.thresholds_db}")
        if self.compress_ratios is not None:
            if len(self.compress_ratios) != len(self.modes):
                raise ValueError(
                    f"compress_ratios needs one entry per mode "
                    f"({len(self.modes)}), got {len(self.compress_ratios)}"
                )
            if any(not 0.0 < r <= 1.0 for r in self.compress_ratios):
                raise ValueError(
                    f"compress_ratios must lie in (0, 1]: "
                    f"{self.compress_ratios}"
                )


def fixed_policy(mode: str, modulation: str = "qpsk") -> PolicyConfig:
    """A degenerate single-mode policy — the fixed-transport baseline arms
    of a link-adaptation comparison ride the same scenario machinery."""
    return PolicyConfig(modes=((mode, modulation),), thresholds_db=())


def mode_names(cfg: PolicyConfig) -> list:
    """Human-readable labels of the policy's mode table
    (``["ecrt/qpsk", "approx/qpsk", ...]``) — the axis labels the
    observability layer attaches to mode histograms (run-ledger manifests,
    ``tools/report`` tables) so ``mode_counts`` vectors stay decodable
    after the run."""
    return ["/".join(m) for m in cfg.modes]


def initial_mode(snr_est_db: jax.Array, cfg: PolicyConfig) -> jax.Array:
    """Hysteresis-free threshold mapping (used to seed round 0)."""
    thr = jnp.asarray(cfg.thresholds_db, jnp.float32)
    snr = jnp.asarray(snr_est_db, jnp.float32)
    return jnp.sum(snr[..., None] >= thr, axis=-1).astype(jnp.int32)


def choose_mode(snr_est_db: jax.Array, prev_mode: jax.Array,
                cfg: PolicyConfig, observed: jax.Array | None = None
                ) -> jax.Array:
    """Per-client mode for this round given noisy CSI and the previous mode.

    With half-window ``h = hysteresis_db / 2``: ``up`` counts thresholds
    cleared by ``+h`` (the highest mode the link may *rise* to), ``down``
    counts thresholds cleared by ``-h`` (the highest mode it may *hold*).
    ``up <= down`` always, and ``clip(prev, up, down)`` is exactly
    "move only when the margin is decisive, else keep the current mode".
    Pure jnp — broadcasts over any leading shape.

    ``observed`` (0/1, broadcastable to the client shape) marks which
    clients actually took part this round. Unobserved clients keep
    ``prev_mode`` untouched — their hysteresis band must survive
    participation gaps (an asynchronous wave only refreshes the CSI of the
    clients it dispatched; letting a stale estimate clip an absent client's
    mode would flap it on re-entry). ``observed=None`` (every synchronous
    round) is bit-identical to the pre-mask behavior.
    """
    thr = jnp.asarray(cfg.thresholds_db, jnp.float32)
    snr = jnp.asarray(snr_est_db, jnp.float32)[..., None]
    h = cfg.hysteresis_db / 2.0
    up = jnp.sum(snr >= thr + h, axis=-1).astype(jnp.int32)
    down = jnp.sum(snr >= thr - h, axis=-1).astype(jnp.int32)
    prev = jnp.asarray(prev_mode, jnp.int32)
    mode = jnp.clip(prev, up, down)
    if observed is None:
        return mode
    return jnp.where(jnp.asarray(observed) > 0, mode, prev)


def downlink_mode(snr_est_db: jax.Array, cfg: PolicyConfig,
                  snr_offset_db: float = 0.0) -> jax.Array:
    """Per-client *downlink* mode from the same policy table.

    The broadcast leg reuses the uplink's CSI shifted by the downlink SNR
    offset (downlink SNR = uplink estimate + Δ dB) through the
    hysteresis-free threshold mapping: the downlink keeps no per-leg mode
    memory — the PS re-derives the broadcast encoding from this round's CSI
    alone, so there is no previous downlink mode for hysteresis to hold.
    Pure jnp, safe under jit (the select FL round traces it).
    """
    return initial_mode(
        jnp.asarray(snr_est_db, jnp.float32) + snr_offset_db, cfg)


def ecrt_anchor_snr_db(cfg: PolicyConfig, fallback_db: float) -> float:
    """The SNR where the table's ECRT row actually operates.

    With thresholds, ECRT serves the protected regime below the first
    threshold — calibrate there. A degenerate (fixed-ECRT) table has no
    thresholds, so the caller's fleet operating point (``fallback_db``) is
    the anchor. The single rule both ``build_mode_cfgs`` and
    ``scenario.ScenarioDriver`` price ECRT from, so the two entry points
    agree on E[tx] for the same policy.
    """
    return float(cfg.thresholds_db[0]) if cfg.thresholds_db else float(
        fallback_db)


def compress_k_table(cfg: PolicyConfig, dim: int,
                     default_ratio: float) -> tuple:
    """Per-mode sparse slot budgets for a ``dim``-coordinate payload.

    Materializes the CSI-adaptive compression column: mode ``i`` keeps
    ``max(1, round(ratio_i * dim))`` coordinates, where ``ratio_i`` comes
    from ``cfg.compress_ratios`` (or ``default_ratio`` for every mode when
    the column is unset). The engine's bucketed round dispatches each mode
    bucket with its own budget.
    """
    ratios = (cfg.compress_ratios if cfg.compress_ratios is not None
              else (default_ratio,) * len(cfg.modes))
    return tuple(max(1, min(dim, int(round(r * dim)))) for r in ratios)


def build_mode_cfgs(base: transport_lib.TransportConfig, cfg: PolicyConfig,
                    *, ecrt_expected_tx: float | None = None,
                    calib_codewords: int = DEFAULT_CALIB_CODEWORDS,
                    calib_max_tx: int = DEFAULT_CALIB_MAX_TX,
                    anchor_fallback_db: float | None = None):
    """Materialize the mode table as ``TransportConfig`` rows.

    Every row inherits ``base`` (channel, interleaving, wire dtype, clamp
    bound) and overrides mode/modulation. ECRT rows use the calibrated
    analytic model (``simulate_fec=False`` with ``ecrt_expected_tx``) — the
    real decoder dispatched per client would run far too often inside FL
    loops; calibrate E[tx] at the regime where ECRT operates instead
    (:func:`ecrt_anchor_snr_db`). ``ecrt_expected_tx=None`` (the default)
    runs that calibration through ``latency.calibrate_ecrt``'s cache. This
    is the **only** calibration path — ``scenario.ScenarioDriver`` routes
    through here too, supplying its fleet operating point as
    ``anchor_fallback_db`` (the anchor when the table has no thresholds;
    defaults to the base channel's mean SNR) — so every entry point prices
    ECRT identically for the same inputs. Pass a float ``ecrt_expected_tx``
    to skip calibration (tests, quick sweeps).

    ``use_kernel`` is threaded from ``base`` onto the uncoded (approx/naive)
    rows — the bucketed adaptive dispatch runs each mode as its own fused
    single-mode batch, so those rows may take the Pallas grid. ECRT/perfect
    rows clear it (the kernel implements only the uncoded chain). Consumers
    pinned to the select dispatch (a fused jitted round, ``shard_map``)
    clear the flag via ``transport.clear_kernel_rows`` — the kernel's
    counter RNG draws a different channel realization than the jnp path, so
    the engine refuses to swap it silently.
    """
    rows = []
    wire_bits = 16 if base.wire_dtype == "bfloat16" else 32
    e_tx_by_mod = {}
    if ecrt_expected_tx is None and any(m == "ecrt" for m, _ in cfg.modes):
        if anchor_fallback_db is None:
            anchor_fallback_db = np.mean(
                np.asarray(base.channel.snr_db, np.float32))
        anchor = ecrt_anchor_snr_db(cfg, anchor_fallback_db)
        # Calibrate once per distinct ECRT modulation: E[tx] depends on the
        # constellation (16-QAM fails far more codewords than QPSK at the
        # same SNR), so one constant cannot price a mixed-ECRT table.
        for m, mod in cfg.modes:
            if m == "ecrt" and mod not in e_tx_by_mod:
                e_tx_by_mod[mod] = latency_lib.calibrate_ecrt(
                    anchor, mod, n_codewords=calib_codewords,
                    max_tx=calib_max_tx)
    for mode, modulation in cfg.modes:
        k = mod_lib.MOD_SCHEMES[modulation].bits_per_symbol
        if mode in ("approx", "naive") and wire_bits % k != 0:
            raise ValueError(
                f"{modulation} ({k} bits/symbol) cannot carry the "
                f"{wire_bits}-bit wire words MSB-first; pick a modulation "
                f"whose bits_per_symbol divides {wire_bits}"
            )
        if mode != "ecrt":
            e_tx = 1.0
        elif ecrt_expected_tx is not None:
            e_tx = ecrt_expected_tx
        else:
            e_tx = e_tx_by_mod[modulation]
        rows.append(dataclasses.replace(
            base, mode=mode, modulation=modulation,
            use_kernel=base.use_kernel and mode in ("approx", "naive"),
            simulate_fec=False,
            ecrt_expected_tx=float(e_tx),
        ))
    return tuple(rows)
