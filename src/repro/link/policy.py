"""Per-client transmission-mode policy: the paper's conditional mechanism.

The paper's scheme "simply delivers gradients with errors when the channel
quality is satisfactory" and falls back to protection otherwise — this
module is that decision, made explicit, per client, per round:

* a **mode table** orders link modes from most protected to most aggressive
  (default: ECRT -> approx/QPSK -> approx/16-QAM -> approx/256-QAM — the
  last three being adaptive modulation-order selection over the paper's
  MSB-protected Gray-QAM transport; 64-QAM is excluded because 6 bits per
  symbol cannot pack 32-bit wire words, see ``build_mode_cfgs``);
* ``choose_mode`` maps estimated SNR to a table index by thresholds, with
  **hysteresis**: a link must clear a threshold by ``+h/2`` to move up and
  fall ``h/2`` below it to move down, so CSI jitter near a boundary does not
  flap modes (flapping is costly: every ECRT-to-approx flip changes airtime
  and error statistics round to round);
* ``build_mode_cfgs`` materializes the table as ``TransportConfig`` rows for
  ``transport.transmit_batch_adaptive``.

All decision functions are pure jnp (vmap/scan/jit-friendly): a mixed-mode
64-client round — dynamics, estimation, policy, uplink — compiles to one
XLA program.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import modulation as mod_lib
from repro.core import transport as transport_lib

__all__ = [
    "PolicyConfig",
    "fixed_policy",
    "initial_mode",
    "choose_mode",
    "build_mode_cfgs",
]


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Threshold policy over an ordered mode table.

    ``modes[i]`` is a ``(transport_mode, modulation)`` pair; ``modes[0]`` is
    the protected fallback. ``thresholds_db[i]`` is the estimated-SNR level
    above which mode ``i+1`` becomes eligible (``len(thresholds_db) ==
    len(modes) - 1``, ascending). Defaults: below 6 dB the link is not
    "satisfactory" and gets ECRT; uncoded QPSK to 16 dB (the paper's 10 dB
    operating point sits here); Gray 16-QAM to 26 dB; Gray 256-QAM above.
    (Approx modulations must divide the 32-bit float wire word: QPSK /
    16-QAM / 256-QAM. 64-QAM's k=6 cannot pack float32 words MSB-first —
    ``build_mode_cfgs`` rejects it up front.)
    """

    modes: tuple = (
        ("ecrt", "qpsk"),
        ("approx", "qpsk"),
        ("approx", "16qam"),
        ("approx", "256qam"),
    )
    thresholds_db: tuple = (6.0, 16.0, 26.0)
    hysteresis_db: float = 2.0

    def __post_init__(self):
        if len(self.thresholds_db) != len(self.modes) - 1:
            raise ValueError(
                f"need len(modes)-1 = {len(self.modes) - 1} thresholds, got "
                f"{len(self.thresholds_db)}"
            )
        if list(self.thresholds_db) != sorted(self.thresholds_db):
            raise ValueError(f"thresholds must ascend: {self.thresholds_db}")


def fixed_policy(mode: str, modulation: str = "qpsk") -> PolicyConfig:
    """A degenerate single-mode policy — the fixed-transport baseline arms
    of a link-adaptation comparison ride the same scenario machinery."""
    return PolicyConfig(modes=((mode, modulation),), thresholds_db=())


def initial_mode(snr_est_db: jax.Array, cfg: PolicyConfig) -> jax.Array:
    """Hysteresis-free threshold mapping (used to seed round 0)."""
    thr = jnp.asarray(cfg.thresholds_db, jnp.float32)
    snr = jnp.asarray(snr_est_db, jnp.float32)
    return jnp.sum(snr[..., None] >= thr, axis=-1).astype(jnp.int32)


def choose_mode(snr_est_db: jax.Array, prev_mode: jax.Array,
                cfg: PolicyConfig) -> jax.Array:
    """Per-client mode for this round given noisy CSI and the previous mode.

    With half-window ``h = hysteresis_db / 2``: ``up`` counts thresholds
    cleared by ``+h`` (the highest mode the link may *rise* to), ``down``
    counts thresholds cleared by ``-h`` (the highest mode it may *hold*).
    ``up <= down`` always, and ``clip(prev, up, down)`` is exactly
    "move only when the margin is decisive, else keep the current mode".
    Pure jnp — broadcasts over any leading shape.
    """
    thr = jnp.asarray(cfg.thresholds_db, jnp.float32)
    snr = jnp.asarray(snr_est_db, jnp.float32)[..., None]
    h = cfg.hysteresis_db / 2.0
    up = jnp.sum(snr >= thr + h, axis=-1).astype(jnp.int32)
    down = jnp.sum(snr >= thr - h, axis=-1).astype(jnp.int32)
    return jnp.clip(jnp.asarray(prev_mode, jnp.int32), up, down)


def build_mode_cfgs(base: transport_lib.TransportConfig, cfg: PolicyConfig,
                    *, ecrt_expected_tx: float = 2.2):
    """Materialize the mode table as ``TransportConfig`` rows.

    Every row inherits ``base`` (channel, interleaving, wire dtype, clamp
    bound) and overrides mode/modulation. ECRT rows use the calibrated
    analytic model (``simulate_fec=False`` with ``ecrt_expected_tx``) — the
    real decoder inside a vmapped ``lax.switch`` would run for every client
    whatever their mode; calibrate E[tx] once at the protected regime's SNR
    instead (see ``latency.calibrate_ecrt``). ``use_kernel`` is force-cleared
    (the Pallas path cannot be switched per client).
    """
    rows = []
    wire_bits = 16 if base.wire_dtype == "bfloat16" else 32
    for mode, modulation in cfg.modes:
        k = mod_lib.MOD_SCHEMES[modulation].bits_per_symbol
        if mode in ("approx", "naive") and wire_bits % k != 0:
            raise ValueError(
                f"{modulation} ({k} bits/symbol) cannot carry the "
                f"{wire_bits}-bit wire words MSB-first; pick a modulation "
                f"whose bits_per_symbol divides {wire_bits}"
            )
        rows.append(dataclasses.replace(
            base, mode=mode, modulation=modulation, use_kernel=False,
            simulate_fec=False,
            ecrt_expected_tx=ecrt_expected_tx if mode == "ecrt" else 1.0,
        ))
    return tuple(rows)
