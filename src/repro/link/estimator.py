"""Pilot-based SNR estimation: the policy acts on noisy CSI, not oracle truth.

The parameter server estimates each client's average SNR from ``n_pilots``
known pilot symbols. With coherent detection (the PS knows the composite
gain, ``core.channel``), the residuals ``y_i - c s_i`` are i.i.d.
``CN(0, sigma^2)``, so the method-of-moments noise-power estimate

    sigma_hat^2 = (1/N_p) sum_i |y_i - c s_i|^2  =  sigma^2 * G,
    G ~ Gamma(N_p, 1/N_p)   (mean 1, var 1/N_p)

is exact in distribution — we sample ``G`` directly instead of simulating
pilot symbols, which keeps the estimator O(num_clients) regardless of pilot
count. In dB the estimate is ``snr_db - 10 log10(G) + bias_db``: unbiased-ish
for large ``N_p``, heavy-tailed for small ``N_p`` (few pilots -> the policy
misjudges links and picks wrong modes — exactly the effect worth studying).

``stale_prob`` models CSI aging: with that probability a client's report
this round is its *previous* estimate (the feedback channel missed a round).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["EstimatorConfig", "estimate_snr_db", "step_estimate"]


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    """Pilot/CSI quality knobs.

    ``n_pilots = 0`` is the oracle: the true SNR is returned unchanged
    (useful to isolate policy behavior from estimation noise).
    """

    n_pilots: int = 64  # pilot symbols per estimate (0 = oracle CSI)
    bias_db: float = 0.0  # systematic calibration bias
    stale_prob: float = 0.0  # P(this round's CSI is last round's estimate)


def estimate_snr_db(true_snr_db: jax.Array, key: jax.Array,
                    cfg: EstimatorConfig) -> jax.Array:
    """One fresh per-client estimate; shapes follow ``true_snr_db``."""
    true_snr_db = jnp.asarray(true_snr_db, jnp.float32)
    if cfg.n_pilots <= 0:
        return true_snr_db + cfg.bias_db
    g = jax.random.gamma(
        key, float(cfg.n_pilots), true_snr_db.shape, jnp.float32
    ) / float(cfg.n_pilots)
    return true_snr_db - 10.0 * jnp.log10(jnp.maximum(g, 1e-12)) + cfg.bias_db


def step_estimate(true_snr_db: jax.Array, prev_est_db: jax.Array,
                  key: jax.Array, cfg: EstimatorConfig) -> jax.Array:
    """Fresh estimate with per-client staleness: stale links reuse
    ``prev_est_db``. Returns the ``(num_clients,)`` CSI the policy sees
    (also the next round's ``prev_est_db``)."""
    k_est, k_stale = jax.random.split(key)
    fresh = estimate_snr_db(true_snr_db, k_est, cfg)
    if cfg.stale_prob <= 0.0:
        return fresh
    stale = jax.random.bernoulli(k_stale, cfg.stale_prob, fresh.shape)
    return jnp.where(stale, jnp.asarray(prev_est_db, jnp.float32), fresh)
