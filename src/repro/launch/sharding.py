"""Sharding rules + the sharded multi-client uplink dispatch.

``shard_transmit_batch`` scales ``transport.transmit_batch`` across a device
mesh: the client dim is sharded over the data axes, each shard runs the fused
batched PHY on its cohort with *globally indexed* fold_in keys, so the result
is bit-identical to the unsharded batch regardless of mesh shape.

Param/input/cache PartitionSpecs per architecture family.

Rules are path-pattern based and *divisibility-checked*: if a dim is not
divisible by the product of requested mesh axes, the axis is dropped for
that dim (replication) — guaranteeing every (arch x shape x mesh) combo
lowers. Strategy:

* tensor parallelism over ``model`` on head/FFN/expert-inner dims;
* FSDP (param + grad sharding) over the data axes on the other matmul dim,
  enabled per-arch via ``fsdp`` (required for kimi-k2's 2 TB of weights;
  disabled for the paper-faithful per-client uplink step, which needs
  params replicated over the client axes);
* MoE expert dim over the data axes (expert parallelism);
* batch dims of inputs/caches over the data axes; KV-cache head dim over
  ``model`` when divisible, else the sequence dim.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

Axis = Any  # str | tuple[str, ...] | None


def shard_transmit_batch(x, key, cfg, mesh, *, axis_names=None, snr_db=None):
    """Run the batched uplink with the client dim sharded over ``axis_names``.

    Args:
      x: ``(num_clients, N)`` payload matrix; ``num_clients`` must divide
        evenly over the product of the mesh's ``axis_names`` sizes.
      key: base PRNG key. Client ``i`` (global index) uses
        ``fold_in(key, i)`` — each shard offsets by its cohort start, so
        sharded == unsharded bit-for-bit.
      cfg: ``transport.TransportConfig``.
      mesh: a ``jax.sharding.Mesh``; ``axis_names`` defaults to every axis
        except ``model`` (see :func:`repro.launch.mesh.data_axes`).
      snr_db: optional per-client ``(num_clients,)`` SNR array (sharded along
        with the clients) or scalar.

    Returns:
      ``(x_hat, stats)`` exactly as ``transport.transmit_batch`` — global
      ``(num_clients, N)`` outputs and per-client ``TxStats``.
    """
    from repro.core import transport as transport_lib

    axes = tuple(axis_names) if axis_names is not None else data_axes(mesh)
    if not axes:  # e.g. a pure tensor-parallel mesh: nothing to shard over
        return transport_lib.transmit_batch(x, key, cfg, snr_db=snr_db)
    n_shards = math.prod(mesh.shape[a] for a in axes)
    num_clients = x.shape[0]
    if num_clients % n_shards != 0:
        raise ValueError(
            f"{num_clients} clients do not shard evenly over {n_shards} devices"
        )
    local_clients = num_clients // n_shards
    ax_spec = axes if len(axes) > 1 else axes[0]

    snr_vec = transport_lib._resolve_batch_snr(cfg, num_clients, snr_db)

    def shard_index():
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    if snr_vec is None:

        def local(xl):
            offset = shard_index() * local_clients
            return transport_lib.transmit_batch(
                xl, key, cfg, client_offset=offset)

        return jax.shard_map(
            local, mesh=mesh,
            in_specs=P(ax_spec, None),
            out_specs=(P(ax_spec, None), P(ax_spec)),
        )(x)

    def local(xl, sl):
        offset = shard_index() * local_clients
        return transport_lib.transmit_batch(
            xl, key, cfg, snr_db=sl, client_offset=offset)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(ax_spec, None), P(ax_spec)),
        out_specs=(P(ax_spec, None), P(ax_spec)),
    )(x, snr_vec)


def shard_transmit_batch_adaptive(x, key, cfgs, mode_idx, mesh, *,
                                  axis_names=None, snr_db=None):
    """Sharded mixed-mode uplink: the client dim over the mesh's data axes.

    Each shard runs ``transport.transmit_batch_adaptive`` on its cohort with
    globally indexed fold_in keys; ``mode_idx`` (and a per-client ``snr_db``)
    shard along the clients, so the result — received payloads and per-client
    ``TxStats`` including ``mode_idx`` — is bit-identical, whatever the mesh
    shape, to the unsharded call *on the kernel-cleared table* (for
    kernel-free tables that is simply the unsharded call; ``use_kernel``
    rows are cleared here, so their jnp rows draw a different channel
    realization than an unsharded bucketed call that kept the kernel).

    Inside the ``shard_map`` body the mode vector is traced, so the per-shard
    dispatch is necessarily ``"select"`` (every shard pays every mode's
    FLOPs for its cohort). ``use_kernel`` rows are cleared up front — the
    Pallas grid cannot lower in the traced select body, and the jnp rows
    draw their own (equally valid) channel realization; the single-host
    bucketed dispatch is the fast path when the cohort fits one process.
    """
    from repro.core import transport as transport_lib

    cfgs = transport_lib.clear_kernel_rows(cfgs)
    axes = tuple(axis_names) if axis_names is not None else data_axes(mesh)
    if not axes:
        return transport_lib.transmit_batch_adaptive(
            x, key, cfgs, mode_idx, snr_db=snr_db)
    n_shards = math.prod(mesh.shape[a] for a in axes)
    num_clients = x.shape[0]
    if num_clients % n_shards != 0:
        raise ValueError(
            f"{num_clients} clients do not shard evenly over {n_shards} devices"
        )
    local_clients = num_clients // n_shards
    ax_spec = axes if len(axes) > 1 else axes[0]

    snr_vec = transport_lib._resolve_batch_snr(cfgs[0], num_clients, snr_db)
    mode_arr = jnp.asarray(mode_idx, jnp.int32)

    def shard_index():
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    if snr_vec is None:

        def local(xl, ml):
            offset = shard_index() * local_clients
            return transport_lib.transmit_batch_adaptive(
                xl, key, cfgs, ml, client_offset=offset, dispatch="select")

        return jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(ax_spec, None), P(ax_spec)),
            out_specs=(P(ax_spec, None), P(ax_spec)),
        )(x, mode_arr)

    def local(xl, ml, sl):
        offset = shard_index() * local_clients
        return transport_lib.transmit_batch_adaptive(
            xl, key, cfgs, ml, snr_db=sl, client_offset=offset,
            dispatch="select")

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(ax_spec, None), P(ax_spec), P(ax_spec)),
        out_specs=(P(ax_spec, None), P(ax_spec)),
    )(x, mode_arr, snr_vec)


import re


def normalize_path(keystr: str) -> str:
    """"['layers']['attn']['wq']" / "['blocks'][0]['wq']" -> "layers/attn/wq"."""
    return "/".join(re.findall(r"[A-Za-z_0-9]+", keystr)).lower()


def leaf_name(path: str) -> str:
    return path.rsplit("/", 1)[-1]


def _fits(shape_dim: int, axes: Axis, mesh) -> bool:
    if axes is None:
        return True
    ax = (axes,) if isinstance(axes, str) else axes
    n = math.prod(mesh.shape[a] for a in ax)
    return shape_dim % n == 0 and shape_dim >= n


def checked_spec(shape, axes_per_dim, mesh) -> P:
    """Drop axes on dims where divisibility fails."""
    out = []
    for dim, axes in zip(shape, axes_per_dim):
        out.append(axes if _fits(dim, axes, mesh) else None)
    return P(*out)


def param_rules(path: str, shape, cfg, mesh, *, fsdp: bool) -> P:
    d = data_axes(mesh)
    F = d if fsdp else None  # FSDP axis group
    low = normalize_path(path)

    def spec(*axes_per_dim):
        return checked_spec(shape, axes_per_dim, mesh)

    # embeddings / heads. NOTE: the embedding table is fully REPLICATED.
    # XLA's PartitionGather cost evaluation hard-crashes (Check failure in
    # ExpandDeviceGroupsWithIota, spmd_partitioner_util.cc:504) for several
    # of our (vocab, d_model) shapes when either operand dim is sharded —
    # measured on yi-6b/chatglm3/deepseek train_4k; qwen2 happened to pass.
    # Replicating costs <= 2.3 GB/device (kimi-k2) and sidesteps the bug;
    # the lm_head projection (a matmul, not a gather) stays tensor-sharded.
    if "pos_embed" in low:
        return spec(None, None)
    if "embed" in low:
        return spec(None, None)
    if "lm_head" in low or "vision_proj" in low:
        return spec(F, "model")
    # MoE
    if "router" in low:
        return spec(*([None] * (len(shape) - 2)), None, None)
    if "shared" in low:  # shared-expert MLP, stacked (L, D, Fs)/(L, Fs, D)
        if leaf_name(low) in ("wi", "wg"):
            return spec(None, F, "model") if len(shape) == 3 else spec(F, "model")
        return spec(None, "model", F) if len(shape) == 3 else spec("model", F)
    if "moe" in low and leaf_name(low) in ("wi", "wg"):
        # (L, E, D, F): experts over data axes (expert parallel), F over model
        return spec(None, d, None, "model") if len(shape) == 4 else spec(d, None, "model")
    if "moe" in low and leaf_name(low) == "wo":
        return spec(None, d, "model", None) if len(shape) == 4 else spec(d, "model", None)
    # attention & dense mlp (stacked (L, in, out) or flat (in, out))
    two = {"wq", "wk", "wv", "wi", "wg", "w_x", "w_gate", "w_r", "w_i",
           "in_proj", "dt_proj"}
    back = {"wo", "w_out", "out_proj"}
    leaf = leaf_name(low)
    for name in two:
        if name == leaf:
            if len(shape) == 3:
                return spec(None, F, "model")
            return spec(F, "model")
    for name in back:
        if name == leaf:
            if len(shape) == 3:
                return spec(None, "model", F)
            return spec("model", F)
    if leaf == "x_proj":  # (L, Di, R+2N): Di is model-sharded upstream
        if len(shape) == 3:
            return spec(None, "model", None)
        return spec("model", None)
    if leaf in ("a_log", "d_skip"):
        if len(shape) == 3:
            return spec(None, "model", None)
        return spec("model", None) if len(shape) == 2 else spec("model")
    if leaf == "conv_w":
        return spec(*([None] * (len(shape) - 1)), "model")
    if leaf in ("bq", "bk", "bv", "bi", "bo", "conv_b", "dt_bias", "lam"):
        if len(shape) == 2:
            return spec(None, "model")
        return spec("model") if _fits(shape[-1], "model", mesh) else P(None)
    # norms, biases, everything else: replicated
    return P(*([None] * len(shape)))


def tree_shardings(tree, cfg, mesh, *, fsdp: bool):
    """NamedSharding pytree for a param(-like) pytree or its ShapeDtype tree."""

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return NamedSharding(mesh, param_rules(pstr, leaf.shape, cfg, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(one, tree)


def spec_tree(shardings):
    return jax.tree_util.tree_map(lambda s: s.spec, shardings)


def batch_specs(cfg, shape_cfg, mesh) -> dict:
    """PartitionSpecs for the input batch dict."""
    d = data_axes(mesh)
    B = shape_cfg.global_batch
    bdim = d if _fits(B, d, mesh) else None
    specs = {"tokens": P(bdim, None)}
    if shape_cfg.kind == "train":
        specs["labels"] = P(bdim, None)
    if cfg.family == "vlm" and shape_cfg.kind in ("train", "prefill"):
        specs["patch_embeds"] = P(bdim, None, None)
    if cfg.family == "audio" and shape_cfg.kind in ("train", "prefill"):
        specs["frames"] = P(bdim, None, None)
    return specs


def cache_specs(cfg, shape_cfg, mesh, cache_tree) -> Any:
    """Shard KV caches: batch over data axes; heads over model if divisible,
    else the sequence/window dim; SSM inner dim over model."""
    d = data_axes(mesh)

    def one(path, leaf):
        pstr = normalize_path(jax.tree_util.keystr(path))
        s = leaf.shape
        if "conv" in pstr and cfg.family == "ssm":  # (L,B,K-1,Di)
            return NamedSharding(mesh, checked_spec(s, (None, d, None, "model"), mesh))
        if pstr.endswith("/h") and len(s) == 4:  # ssm state (L,B,Di,N)
            return NamedSharding(mesh, checked_spec(s, (None, d, "model", None), mesh))
        if pstr.endswith("/h") and len(s) == 3:  # rglru state (G,B,W)
            return NamedSharding(mesh, checked_spec(s, (None, d, "model"), mesh))
        if pstr.endswith("/h") and len(s) == 2:  # rglru tail state (B,W)
            return NamedSharding(mesh, checked_spec(s, (d, "model"), mesh))
        if "conv" in pstr and len(s) == 4:  # rglru conv (G,B,3,W)
            return NamedSharding(mesh, checked_spec(s, (None, d, None, "model"), mesh))
        if "conv" in pstr and len(s) == 3:  # rglru tail conv (B,3,W)
            return NamedSharding(mesh, checked_spec(s, (d, None, "model"), mesh))
        if len(s) == 5:  # (L,B,S,KVH,hd)
            if _fits(s[3], "model", mesh):
                return NamedSharding(mesh, checked_spec(s, (None, d, None, "model", None), mesh))
            return NamedSharding(mesh, checked_spec(s, (None, d, "model", None, None), mesh))
        if len(s) == 4:  # per-block (B,S,KVH,hd)
            if _fits(s[2], "model", mesh):
                return NamedSharding(mesh, checked_spec(s, (d, None, "model", None), mesh))
            return NamedSharding(mesh, checked_spec(s, (d, "model", None, None), mesh))
        return NamedSharding(mesh, P(*([None] * len(s))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
