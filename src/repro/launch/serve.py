"""Serving driver: batched greedy decode against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_lib
from repro.models import registry as R


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ring", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    cache_len = args.prompt_len + args.gen if not args.ring else cfg.decode_window
    cache = R.init_cache(cfg, args.batch, cache_len)
    step = jax.jit(steps_lib.make_serve_step(cfg, ring=args.ring))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    # prefill token-by-token (exercises the cache path end to end)
    tok = prompt[:, :1]
    t0 = time.time()
    for pos in range(args.prompt_len + args.gen - 1):
        nxt, cache = step(params, cache, tok, jnp.int32(pos))
        tok = prompt[:, pos + 1 : pos + 2] if pos + 1 < args.prompt_len else nxt
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"{args.arch}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, batch={args.batch}, ring={args.ring})")
    print("sample continuation:", jnp.concatenate([prompt[:1, -4:], nxt[:1]], 1).tolist())


if __name__ == "__main__":
    main()
