import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers and compiles.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds ShapeDtypeStruct stand-ins for params, optimizer state, inputs
     and caches (``jax.eval_shape`` — zero allocation),
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  4. prints ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs /
     bytes for the roofline), parses the post-SPMD HLO for collective
     bytes (while-body collectives multiplied by the loop trip count), and
  5. writes a JSON artifact consumed by ``launch.roofline``.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun

NOTE the XLA_FLAGS line above MUST run before any other import (jax locks
the device count on first init); keep it the first statement in this file.
Smoke tests and benchmarks never import this module.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core import channel as channel_lib
from repro.core import transport as transport_lib
from repro.launch import sharding as sh
from repro.launch import steps as steps_lib
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import registry as R
from repro.optim.sgd import sgd as make_sgd

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        d = m.group(1)
        d = "f8" if d.startswith("f8") else d
        dims = m.group(2)
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * _DTYPE_BYTES[d]
    return total


def parse_collectives(hlo_text: str, default_trip: int) -> dict:
    """Sum collective bytes from post-SPMD HLO, weighting while bodies.

    Returns {op_kind: bytes_per_device} plus {"_total": ...}. Collectives in
    a while-body computation are multiplied by the loop trip count, parsed
    from the condition's comparison constant when recognizable, else
    ``default_trip`` (the layer count — our scans are the only loops).
    """
    # computation name -> list of (kind, result_bytes)
    comps: dict[str, list] = {}
    cur = None
    trip_counts: dict[str, int] = {}  # body computation -> trip count
    cond_const: dict[str, int] = {}  # condition computation -> max constant
    body_of: dict[str, str] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{$", ls)
        if (ls.startswith("ENTRY") or (m and ls.endswith("{"))) and "=" not in ls:
            name = ls.split()[1] if ls.startswith("ENTRY") else ls.split()[0]
            cur = name.strip("%").split("(")[0].strip()
            comps.setdefault(cur, [])
            continue
        if ls.startswith("}"):
            continue
        if cur is None:
            continue
        for kind in _COLLECTIVES:
            if f" {kind}(" in ls or ls.startswith(f"{kind}("):
                lhs = ls.split(" = ", 1)[-1]
                shape_part = lhs.split(kind + "(")[0]
                comps[cur].append((kind, _bytes_of_shapes(shape_part)))
                break
        if " while(" in ls:
            mb = re.search(r"body=%?([\w\.\-]+)", ls)
            mc = re.search(r"condition=%?([\w\.\-]+)", ls)
            if mb and mc:
                body_of[mb.group(1)] = mc.group(1)
        mc2 = re.search(r"s32\[\]\s+constant\((\d+)\)", ls)
        if mc2:
            cond_const[cur] = max(cond_const.get(cur, 0), int(mc2.group(1)))

    for body, cond in body_of.items():
        trip_counts[body] = cond_const.get(cond, default_trip) or default_trip

    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for comp, items in comps.items():
        mult = trip_counts.get(comp, 1)
        for kind, nbytes in items:
            # ring cost model: AR moves ~2x, others ~1x the buffer
            factor = 2.0 if kind == "all-reduce" else 1.0
            out[kind] += factor * nbytes * mult
    out["_total"] = sum(v for k, v in out.items() if not k.startswith("_"))
    out["_ops"] = sum(len(v) for v in comps.values())
    return out


def build_step_and_args(cfg, shape, mesh, uplink: str, wire_dtype: str = "float32",
                        fsdp_mode: str = "auto"):
    """Returns (fn, arg_shapes (ShapeDtypeStructs), in_shardings, out_shardings)."""
    opt = make_sgd(1e-2)
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(lambda: R.init_params(key, cfg))
    if fsdp_mode == "auto":
        fsdp = uplink != "per_client"
    else:
        fsdp = fsdp_mode == "on"
    pshard = sh.tree_shardings(param_shapes, cfg, mesh, fsdp=fsdp)
    ospec = jax.eval_shape(lambda: opt.init(param_shapes))
    oshard = jax.tree_util.tree_map(
        lambda l: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), ospec
    )
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    ishapes = R.input_specs(cfg, shape)
    bspecs = sh.batch_specs(cfg, shape, mesh)
    bshard = {k: jax.sharding.NamedSharding(mesh, v) for k, v in bspecs.items()}
    keyspec = jax.ShapeDtypeStruct((2,), jnp.uint32)

    tcfg = transport_lib.TransportConfig(
        mode="approx",
        channel=channel_lib.ChannelConfig(snr_db=10.0),
        chunk_elems=1 << 22,  # bound the PHY live set to ~150 MiB/chunk
        wire_dtype=wire_dtype,
    )

    if shape.kind == "train":
        if uplink == "per_client":
            fn = steps_lib.make_train_step_approx(cfg, opt, tcfg, mesh)
        elif uplink == "per_shard":
            fn = steps_lib.make_train_step(cfg, opt, transport_cfg=tcfg, mesh=mesh)
        else:
            fn = steps_lib.make_train_step(cfg, opt)
        args = (param_shapes, ospec, ishapes, keyspec)
        in_sh = (pshard, oshard, bshard, repl)
        out_sh = (pshard, oshard, repl) + ((repl,) if uplink == "per_client" else ())
        if uplink == "per_client":
            def wrapped(p, o, b, k):
                pp, oo, loss, stats = fn(p, o, b, k)
                return pp, oo, loss, stats
            return wrapped, args, in_sh, (pshard, oshard, repl, repl)
        return fn, args, in_sh, out_sh

    if shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg)
        d = data_axes(mesh)
        out_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(
            d if shape.global_batch % _nd(mesh) == 0 else None, "model"
            if cfg.vocab_size % mesh.shape["model"] == 0 else None))
        return fn, (param_shapes, ishapes), (pshard, bshard), out_sh

    # decode
    ring = R.uses_ring_cache(cfg, shape)
    clen = R.cache_len_for(cfg, shape)
    cache_shapes = jax.eval_shape(
        lambda: R.init_cache(cfg, shape.global_batch, clen))
    cshard = sh.cache_specs(cfg, shape, mesh, cache_shapes)
    fn = steps_lib.make_serve_step(cfg, ring=ring)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    d = data_axes(mesh)
    tokshard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(
        d if shape.global_batch % _nd(mesh) == 0 else None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tokens, pos):
        return fn(params, cache, tokens, pos)

    return (step, (param_shapes, cache_shapes, tok, pos),
            (pshard, cshard, tokshard, repl), (tokshard, cshard))


def _nd(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def run_one(arch: str, shape_name: str, mesh_kind: str, uplink: str,
            out_dir: str | None, reduced_layers: int = 0,
            overrides: dict | None = None, wire_dtype: str = "float32",
            fsdp_mode: str = "auto") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if reduced_layers:
        # cost-extraction compile: shallow AND unrolled so cost_analysis sees
        # every layer (scan bodies are otherwise counted once)
        over = {"n_layers": reduced_layers, "scan_unroll": True}
        if cfg.encoder_layers:
            over["encoder_layers"] = reduced_layers
        if cfg.first_dense_layers:
            over["first_dense_layers"] = min(cfg.first_dense_layers, 1)
        cfg = dataclasses.replace(cfg, **over)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = R.supports_shape(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "uplink": uplink,
        "reduced_layers": reduced_layers, "status": "skip", "reason": reason,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "wire_dtype": wire_dtype,
    }
    if not ok:
        print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    with jax.set_mesh(mesh):
        fn, args, in_sh, out_sh = build_step_and_args(cfg, shape, mesh, uplink,
                                                      wire_dtype, fsdp_mode)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo, cfg.n_layers)

    n_chips = int(jnp.prod(jnp.array(list(mesh.shape.values()))))
    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_per_device=cost.get("flops", 0.0),
        bytes_per_device=cost.get("bytes accessed", 0.0),
        collective_bytes_per_device=coll,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    )
    print(f"[dryrun] OK {arch} x {shape_name} x {mesh_kind} (uplink={uplink}, "
          f"L={reduced_layers or cfg.n_layers}): compile {t_compile:.1f}s, "
          f"args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev, "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev, "
          f"flops/dev {cost.get('flops', 0):.3g}, "
          f"coll {coll['_total']/2**20:.1f} MiB/dev")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_kind}__{uplink}"
        if reduced_layers:
            tag += f"__L{reduced_layers}"
        for k, v in (overrides or {}).items():
            tag += f"__{k}-{v}"
        if wire_dtype != "float32":
            tag += f"__wire-{wire_dtype}"
        if fsdp_mode != "auto":
            tag += f"__fsdp-{fsdp_mode}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def default_uplink(arch: str, shape_name: str) -> str:
    if INPUT_SHAPES[shape_name].kind != "train":
        return "none"
    # kimi-k2's 2 TB of weights cannot replicate over the client axes; it
    # uses the per-shard uplink (DESIGN.md Sec. 4) with FSDP sharding.
    return "per_shard" if arch == "kimi-k2-1t-a32b" else "per_client"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--uplink", default=None,
                    choices=[None, "none", "per_client", "per_shard"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--reduced-layers", type=int, default=0,
                    help="override layer count (cost-extrapolation compiles)")
    ap.add_argument("--moe-impl", default="", choices=["", "dense", "expert_parallel"])
    ap.add_argument("--attn-impl", default="", choices=["", "naive", "blockwise"])
    ap.add_argument("--wire-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    args = ap.parse_args()
    overrides = {}
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                uplink = args.uplink or default_uplink(arch, shape)
                try:
                    run_one(arch, shape, mk, uplink, args.out, args.reduced_layers,
                            overrides or None, args.wire_dtype, args.fsdp)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mk, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape} x {mk}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
