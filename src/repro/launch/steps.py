"""Step builders: train / train-approx (per-client uplink) / serve / prefill.

``make_train_step``        — plain pjit step (baseline; optional per-shard
                             uplink corruption for arbitrarily-sharded
                             params, e.g. kimi-k2's FSDP+expert-parallel).
``make_train_step_approx`` — the paper's technique as a first-class runtime
                             feature: partial-manual ``shard_map`` over the
                             client (data/pod) axes; each shard computes its
                             cohort gradient, corrupts it through the
                             simulated PHY with an independent channel, and
                             the PS aggregation is the ``psum``. The model
                             axis stays auto (XLA SPMD tensor parallelism).
``make_serve_step``        — one-token decode against a KV cache.
``make_prefill_step``      — full-sequence forward (inference prefill).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg_lib
from repro.core import transport as transport_lib
from repro.launch.mesh import data_axes
from repro.models import registry as R


def make_train_step(cfg, opt, *, transport_cfg=None, mesh=None):
    """pjit train step. If ``transport_cfg`` is set, applies *per-shard*
    uplink corruption: a fully-manual elementwise shard_map where every chip
    corrupts the gradient values it owns under an independent channel
    (semantics documented in DESIGN.md Sec. 4: chip = radio)."""

    def step(params, opt_state, batch, key):
        loss, grads = jax.value_and_grad(R.loss_fn)(params, batch, cfg)
        if transport_cfg is not None:
            grads = corrupt_per_shard(grads, key, transport_cfg, mesh)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def corrupt_per_shard(grads, key, transport_cfg, mesh):
    """Elementwise PHY corruption of each chip's gradient shard."""
    from repro.launch import sharding as sh

    shardings = sh.tree_shardings(grads, None, mesh, fsdp=True)
    specs = jax.tree_util.tree_map(lambda s: s.spec, shardings)
    axes = set(mesh.axis_names)

    def local(key, *leaves):
        idx = jnp.int32(0)
        for ax in mesh.axis_names:
            idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        # mesh-shard keyspace on a dedicated per-shard key (bounded by
        # the mesh size), not the lane table: lint: ignore[keylane]
        k = jax.random.fold_in(key, idx)
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        flat_hat, _ = transport_lib.transmit_flat(flat, k, transport_cfg)
        out, off = [], 0
        for l in leaves:
            out.append(flat_hat[off : off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return tuple(out)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    spec_leaves = jax.tree_util.tree_leaves(specs)
    fn = jax.shard_map(
        local,
        mesh=mesh,
        axis_names=axes,
        in_specs=(P(),) + tuple(spec_leaves),
        out_specs=tuple(spec_leaves),
        check_vma=False,
    )
    out = fn(key, *leaves)
    return jax.tree_util.tree_unflatten(treedef, out)


def make_train_step_approx(cfg, opt, transport_cfg, mesh):
    """Paper-faithful per-client uplink: manual over the data/pod axes."""
    d = data_axes(mesh)

    def local_step(params, opt_state, batch, key):
        def local_loss(p):
            return R.loss_fn(p, batch, cfg)

        loss, grads = jax.value_and_grad(local_loss)(params)
        # grads travel (and psum) in the wire dtype: bf16 wire halves both
        # airtime and the all-reduce bytes (see TransportConfig.wire_dtype)
        wire = (jnp.bfloat16 if transport_cfg.wire_dtype == "bfloat16"
                else jnp.float32)
        grads = jax.tree_util.tree_map(lambda g: g.astype(wire), grads)
        grads, stats = agg_lib.approx_allreduce(grads, key, transport_cfg, d)
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        loss = jax.lax.pmean(loss, d)
        # stats are per-client: aggregate so the output is truly replicated
        stats = jax.tree_util.tree_map(lambda s: jax.lax.pmean(s, d), stats)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss, stats

    def in_batch_specs(batch):
        return {
            k: P(d, *([None] * (v.ndim - 1))) for k, v in batch.items()
        }

    def step(params, opt_state, batch, key):
        fn = jax.shard_map(
            local_step,
            mesh=mesh,
            axis_names=set(d),
            in_specs=(P(), P(), in_batch_specs(batch), P()),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )
        return fn(params, opt_state, batch, key)

    return step


def make_serve_step(cfg, *, ring: bool = False):
    def serve_step(params, cache, tokens, pos):
        logits, cache = R.decode_step(params, cache, tokens, pos, cfg, ring=ring)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        logits, _ = R.forward(params, batch, cfg)
        # return only the last-position logits (what a server samples from)
        return logits[:, -1]

    return prefill_step
