"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Terms (seconds per step, per chip):
    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

``cost_analysis()`` counts ``while`` (scan) bodies once, so per-layer costs
are recovered by *linear extrapolation over two unrolled reduced-depth
compiles* (k1/k2 layers): delta = (c2 - c1)/(k2 - k1); total(L) = c1 +
(L - k1) * delta. Collective bytes come from the HLO parser (while bodies
weighted by trip count) and are extrapolated the same way.

MODEL_FLOPS (the "useful compute" yardstick, per the brief):
    train:  6 * N_active * tokens      decode/prefill: 2 * N_active * tokens
The ratio MODEL_FLOPS / HLO_FLOPS catches remat/redundancy waste (remat is
ON for training, so ~0.75 is the expected ceiling there).
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import jax

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_WIRE_BITS = {"float32": 32, "bfloat16": 16}


def uplink_traffic(num_clients: int, *, bits_per_symbol: int = 2,
                   wire_dtype: str = "float32",
                   n_floats: int | None = None) -> dict:
    """Analytic HBM bytes per payload float for the three uplink paths.

    The layered jnp pipeline materializes every intermediate in HBM; per
    payload float with ``wb``-bit wire words and ``n_sym = wb / k`` symbols
    (``k = bits_per_symbol``):

        wire words in+out (r/w each)      4 * wb/8
        tx symbol indices, int32 (w+r)    8 * n_sym
        complex64 channel stream (w+r)   16 * n_sym
        equalized stream (read)           8 * n_sym
        rx symbol indices, int32 (w+r)    8 * n_sym
        ------------------------------------------
        uplink total             wb/2 + 40 * n_sym   (= 656 B at QPSK f32)

    The Pallas batch kernel keeps all of that in registers/VMEM: 4 B in +
    4 B out per float. The fused-aggregate kernel also folds the PS mean
    into the grid loop, writing each aggregate tile once for all C clients:
    4 B in + 4/C B out (the per-client error counters are C * 4 B total —
    negligible and ignored). A full *round* appends the aggregation pass
    (read x_hat + amortized aggregate write = 4 + 4/C) to the unfused
    paths. Each intermediate is counted for its actual passes; no cache
    reuse is assumed, which if anything favours the layered baseline on a
    real TPU where short-lived buffers may stay resident.

    Returns bytes/float per implementation for one full round, ratios vs
    the fused kernel, and — when ``n_floats`` is given — memory-bound
    seconds per round on a TPU v5e chip (``HBM_BW``).
    """
    wb = _WIRE_BITS[wire_dtype]
    c = float(num_clients)
    n_sym = wb / bits_per_symbol
    layered_uplink = wb / 2.0 + 40.0 * n_sym
    agg_pass = 4.0 + 4.0 / c  # read x_hat + amortized aggregate write
    bpf = {
        "jnp_layered": layered_uplink + agg_pass,
        "kernel_batch": 8.0 + agg_pass,
        "kernel_fused": 4.0 + 4.0 / c,
    }
    out = {
        "num_clients": num_clients,
        "bits_per_symbol": bits_per_symbol,
        "wire_dtype": wire_dtype,
        "bytes_per_float": bpf,
        "ratio_vs_fused": {k: v / bpf["kernel_fused"] for k, v in bpf.items()},
    }
    if n_floats is not None:
        out["hbm_s"] = {k: num_clients * n_floats * v / HBM_BW
                        for k, v in bpf.items()}
    return out


def transport_traffic(cfg, num_clients: int,
                      n_floats: int | None = None) -> dict:
    """:func:`uplink_traffic` with modulation order and wire dtype read off
    a ``repro.core.transport.TransportConfig`` (the real config, not a
    hard-coded QPSK/f32 assumption)."""
    return uplink_traffic(num_clients,
                          bits_per_symbol=cfg.scheme.bits_per_symbol,
                          wire_dtype=cfg.wire_dtype, n_floats=n_floats)


def n_active_params(cfg) -> float:
    """Active (per-token) parameter count, MoE-aware, incl. lm_head."""
    from repro.models import registry as R

    shapes = jax.eval_shape(lambda: R.init_params(jax.random.PRNGKey(0), cfg))
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        pstr = jax.tree_util.keystr(path).lower()
        n = 1.0
        for s in leaf.shape:
            n *= s
        total += n
        if "embed" in pstr and "pos" not in pstr:
            continue  # gather, not matmul
        if "moe" in pstr and "router" not in pstr and "shared" not in pstr:
            # stacked (L, E, ...): only top_k of E experts fire per token
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return active, total


def model_flops(cfg, shape) -> float:
    act, _ = n_active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * act * tokens


def load_artifacts(art_dir: str) -> dict:
    recs = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        key = (r["arch"], r["shape"], r["mesh"], r.get("reduced_layers", 0))
        recs[key] = r
    return recs


def _body_counts(cfg, k: int):
    """Layers contributing to the extrapolation at reduced depth k."""
    nd = cfg.first_dense_layers
    return k - nd if nd else k


def extrapolate(cfg, r1, r2, full_layers: int):
    """Linear extrapolation of per-device costs to the full depth."""
    k1 = _body_counts(cfg, r1["reduced_layers"])
    k2 = _body_counts(cfg, r2["reduced_layers"])
    L = _body_counts(cfg, full_layers)
    out = {}
    for key in ("flops_per_device", "bytes_per_device"):
        c1, c2 = r1[key], r2[key]
        d = (c2 - c1) / (k2 - k1)
        out[key] = c1 + (L - k1) * d
    coll = {}
    for kind in list(_COLL_KINDS) + ["_total"]:
        c1 = r1["collective_bytes_per_device"].get(kind, 0.0)
        c2 = r2["collective_bytes_per_device"].get(kind, 0.0)
        d = (c2 - c1) / (k2 - k1)
        coll[kind] = max(0.0, c1 + (L - k1) * d)
    out["collective_bytes_per_device"] = coll
    return out


def analyze(art_dir: str, arch: str, shape_name: str) -> dict | None:
    from repro.configs import INPUT_SHAPES, get_config

    recs = load_artifacts(art_dir)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    full = recs.get((arch, shape_name, "single", 0))
    if full is None or full.get("status") != "ok":
        return None
    # find the two reduced-depth cost compiles
    reduced = sorted(
        [r for (a, s, m, k), r in recs.items()
         if a == arch and s == shape_name and m == "single" and k > 0
         and r.get("status") == "ok"],
        key=lambda r: r["reduced_layers"])
    if len(reduced) >= 2:
        est = extrapolate(cfg, reduced[0], reduced[-1], cfg.n_layers)
    else:  # fall back to raw (underestimates scan bodies; flagged)
        est = {k: full[k] for k in ("flops_per_device", "bytes_per_device")}
        est["collective_bytes_per_device"] = full["collective_bytes_per_device"]
        est["_fallback"] = True

    t_comp = est["flops_per_device"] / PEAK_FLOPS
    t_mem = est["bytes_per_device"] / HBM_BW
    t_coll = est["collective_bytes_per_device"]["_total"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = est["flops_per_device"] * full["n_chips"]
    return {
        "arch": arch,
        "shape": shape_name,
        "status": "ok",
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "collective_breakdown": est["collective_bytes_per_device"],
        "memory_bytes": full["memory"],
        "extrapolated": "_fallback" not in est,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline.json")
    args = ap.parse_args()
    from repro.configs import ARCH_IDS, INPUT_SHAPES

    rows = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            r = analyze(args.art, arch, shape)
            if r:
                rows.append(r)
                print(f"{arch:24s} {shape:12s} comp {r['compute_s']*1e3:8.2f}ms "
                      f"mem {r['memory_s']*1e3:8.2f}ms coll {r['collective_s']*1e3:8.2f}ms "
                      f"-> {r['dominant']:10s} useful {r['useful_ratio']*100:5.1f}%")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
