"""Training driver: FedSGD with the approximate wireless uplink.

Runs a *real* training loop (concrete arrays) on whatever devices exist —
on this CPU container use a reduced config + host-device mesh, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --mesh-shape 4,2 --steps 20 --batch 8 --seq 256 --mode approx

The full production meshes are exercised by ``launch.dryrun`` (compile-only
on this container). This driver is the end-to-end example harness.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.core import channel as channel_lib
from repro.core import transport as transport_lib
from repro.data.tokens import TokenStream
from repro.launch import sharding as sh
from repro.launch import steps as steps_lib
from repro.models import registry as R
from repro.optim.sgd import sgd as make_sgd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh-shape", default="")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--mode", default="approx",
                    choices=["perfect", "naive", "approx", "ecrt"])
    ap.add_argument("--snr-db", type=float, default=10.0)
    ap.add_argument("--modulation", default="qpsk")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=256, d_ff=512, vocab_size=1024)

    tcfg = transport_lib.TransportConfig(
        mode=args.mode,
        modulation=args.modulation,
        channel=channel_lib.ChannelConfig(snr_db=args.snr_db),
        simulate_fec=False,
        ecrt_expected_tx=1.1,
        use_kernel=args.use_kernel,
    )
    opt = make_sgd(args.lr)

    n_dev = len(jax.devices())
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
    else:
        shape = (n_dev, 1)
    mesh = jax.make_mesh(shape, ("data", "model"))
    print(f"mesh {dict(mesh.shape)} devices={n_dev}")

    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    opt_state = opt.init(params)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"{args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params/1e6:.1f}M params, mode={args.mode}")

    stream = TokenStream(cfg.vocab_size, args.seq, args.batch)
    with jax.set_mesh(mesh):
        if args.mode in ("approx", "naive"):
            step = jax.jit(steps_lib.make_train_step_approx(cfg, opt, tcfg, mesh))
        else:
            t = None if args.mode == "perfect" else tcfg
            step = jax.jit(steps_lib.make_train_step(
                cfg, opt, transport_cfg=t, mesh=mesh))
        for i in range(args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
            key, sk = jax.random.split(key)
            out = step(params, opt_state, batch, sk)
            params, opt_state, loss = out[0], out[1], out[2]
            loss = float(loss)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {loss:.4f} ({time.time()-t0:.2f}s)")
    if args.checkpoint:
        from repro import checkpoint as ckpt

        ckpt.save(args.checkpoint, params, step=args.steps)
        print("saved", args.checkpoint)
    return loss


if __name__ == "__main__":
    main()
