"""Production mesh builders (TPU v5e pods; CPU placeholder devices in CI).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "data_axes", "MESH_SHAPES"]

MESH_SHAPES = {
    "single": ((16, 16), ("data", "model")),
    "multi": ((2, 16, 16), ("pod", "data", "model")),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """The client/batch axes: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")
