"""Mergeable constant-memory sketches for per-client link telemetry.

The massive-cohort milestone (ROADMAP) needs per-client SNR/BER/airtime
*distributions* — the quantities that drive mode policy and error
resilience in the approximate-communication scheme — without O(clients)
host transfer per round. This module provides the three primitives:

* **Bucketed histograms** (:class:`BucketLayout`, :func:`bucket_counts`):
  a fixed-size ``int32`` count vector per metric, computed on device as a
  pure ``segment_sum`` reduction. Integer counts make the merge
  (element-wise add) *exactly* associative and commutative, and the
  reduction bit-identical across eager, ``jit`` and ``vmap`` — the same
  shape hierarchical/streaming cohort aggregation needs.
* **Quantile estimates** (:class:`Sketch`): DDSketch-style log-bucketed
  layouts give a guaranteed relative-error bound of ``sqrt(gamma) - 1``
  with ``gamma = (hi / lo) ** (1 / n)`` for values inside ``[lo, hi]``
  (the exact order statistic provably lies in the reported bucket, and
  the geometric bucket midpoint is at most that factor away from either
  edge). Linear layouts (for dB-domain metrics, which are already
  logarithmic) give an absolute bound of ``(hi - lo) / (2 n)``.
* **Deterministic keyed reservoirs** (:func:`reservoir_tags`,
  :func:`reservoir_sample`, :func:`worst_k`): a handful of concrete
  exemplar clients survive at constant size. Per-client tags are drawn by
  ``fold_in`` on the reserved ``OBS_KEY_LANE`` (see
  ``repro.core.keylanes``), so the sample is a pure function of the round
  key and the client index — batched evaluation is bit-identical to a
  per-client loop, and merging two reservoirs (keep the k smallest tags)
  is associative.

Out-of-range values are never silently clamped: every count vector has
``n + 2`` slots — ``n`` buckets plus an *underflow* slot (index ``n``,
values below ``lo``; for log layouts this is where exact zeros land, e.g.
clients with zero bit errors) and an *overflow* slot (index ``n + 1``).
Quantiles that land in those slots report ``0.0`` / ``lo`` / ``hi``
respectively, keeping the error bound honest inside the layout's range.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keylanes

__all__ = [
    "BucketLayout",
    "Sketch",
    "bucket_counts",
    "reservoir_tags",
    "reservoir_sample",
    "worst_k",
]


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """A fixed bucketing of one metric: ``n`` buckets spanning ``[lo, hi]``.

    ``scale`` is ``"log"`` (DDSketch-style geometric buckets; ``lo`` must
    be > 0) or ``"linear"`` (equal-width buckets; the right choice for
    dB-domain metrics, which are already logarithmic in the underlying
    power). The layout is pure metadata — it is stamped into every ledger
    line next to its counts so readers can re-derive edges, and two counts
    vectors merge only if their layouts are equal.
    """

    name: str
    scale: str
    lo: float
    hi: float
    n: int

    def __post_init__(self) -> None:
        """Validate the range and precompute nothing (edges are derived)."""
        if self.scale not in ("log", "linear"):
            raise ValueError(f"layout {self.name!r}: scale must be 'log' or "
                             f"'linear', got {self.scale!r}")
        if self.scale == "log" and self.lo <= 0:
            raise ValueError(f"layout {self.name!r}: log scale needs lo > 0")
        if not self.lo < self.hi:
            raise ValueError(f"layout {self.name!r}: need lo < hi")
        if self.n < 1:
            raise ValueError(f"layout {self.name!r}: need n >= 1 buckets")

    @property
    def gamma(self) -> float:
        """Geometric bucket growth factor (log layouts only)."""
        return (self.hi / self.lo) ** (1.0 / self.n)

    def edges(self) -> np.ndarray:
        """The ``n + 1`` bucket edges as float64 (edge 0 = lo, edge n = hi)."""
        if self.scale == "log":
            return np.geomspace(self.lo, self.hi, self.n + 1)
        return np.linspace(self.lo, self.hi, self.n + 1)

    def representatives(self) -> np.ndarray:
        """Per-bucket point estimates: geometric (log) / arithmetic mids."""
        e = self.edges()
        if self.scale == "log":
            return np.sqrt(e[:-1] * e[1:])
        return 0.5 * (e[:-1] + e[1:])

    def error_bound(self) -> float:
        """The documented estimation bound for in-range values.

        Relative for ``"log"`` layouts (``sqrt(gamma) - 1``), absolute for
        ``"linear"`` layouts (half a bucket width).
        """
        if self.scale == "log":
            return math.sqrt(self.gamma) - 1.0
        return (self.hi - self.lo) / (2.0 * self.n)

    def to_dict(self) -> dict:
        """Plain-dict form for ledger lines / OpenMetrics labels."""
        return {"name": self.name, "scale": self.scale, "lo": self.lo,
                "hi": self.hi, "n": self.n}

    @classmethod
    def from_dict(cls, d: dict) -> "BucketLayout":
        """Rebuild a layout from :meth:`to_dict` output."""
        return cls(name=d["name"], scale=d["scale"], lo=float(d["lo"]),
                   hi=float(d["hi"]), n=int(d["n"]))


def bucket_counts(values, layout: BucketLayout, mask=None):
    """Device-side histogram: ``(n + 2,)`` int32 counts for ``values``.

    A pure ``jnp`` reduction (``searchsorted`` over the precomputed edges
    + ``segment_sum`` of integer ones), safe to call inside jitted round
    steps and under ``vmap``; integer accumulation makes the result
    bit-identical across eager/jit/vmap and the merge (element-wise add)
    exactly associative. Slot ``n`` counts underflow (``v < lo``; exact
    zeros for log layouts), slot ``n + 1`` overflow (``v > hi``). Entries
    where ``mask`` is falsy are dropped entirely (they appear in no slot).
    """
    v = jnp.asarray(values, jnp.float32).reshape(-1)
    edges = jnp.asarray(layout.edges()[1:-1], jnp.float32)
    inner = jnp.searchsorted(edges, v, side="right").astype(jnp.int32)
    seg = jnp.where(v < jnp.float32(layout.lo), jnp.int32(layout.n),
                    jnp.where(v > jnp.float32(layout.hi),
                              jnp.int32(layout.n + 1), inner))
    if mask is not None:
        m = jnp.asarray(mask).reshape(-1)
        seg = jnp.where(m, seg, jnp.int32(layout.n + 2))
    ones = jnp.ones_like(seg)
    counts = jax.ops.segment_sum(ones, seg, num_segments=layout.n + 3)
    return counts[: layout.n + 2]


def reservoir_tags(key, num_clients: int):
    """Deterministic per-client reservoir tags on the reserved obs lane.

    Client ``i`` draws ``uniform(fold_in(key, OBS_KEY_LANE + i))`` — a
    pure function of the round key and the client index, so the tags (and
    any sample derived from them) are identical whether clients are
    processed batched, sharded, or one at a time. The ``k`` clients with
    the smallest tags form a uniform random sample whose merge (keep the
    k smallest across a union) is associative.
    """
    keylanes.check_cohort(keylanes.OBS_KEY_LANE, num_clients)
    idx = jnp.arange(num_clients) + keylanes.OBS_KEY_LANE
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(idx)


def reservoir_sample(tags, k: int):
    """Indices of the ``k`` smallest tags (ascending tag order).

    ``top_k`` on negated tags gives a deterministic, batching-invariant
    selection (ties broken by lower index, matching ``lax.top_k``).
    Returns ``(sel_tags, sel_idx)`` each of shape ``(k,)``.
    """
    neg, idx = jax.lax.top_k(-jnp.asarray(tags), k)
    return -neg, idx


def worst_k(values, k: int, mask=None):
    """Indices and values of the ``k`` largest entries (worst clients).

    Masked-out entries are sent to ``-inf`` so they never win. Returns
    ``(top_values, top_idx)`` each of shape ``(k,)``, descending.
    """
    v = jnp.asarray(values, jnp.float32)
    if mask is not None:
        v = jnp.where(jnp.asarray(mask).astype(bool), v, -jnp.inf)
    return jax.lax.top_k(v, k)


class Sketch:
    """Host-side mergeable histogram + quantile estimator over one layout.

    Wraps a ``(n + 2,)`` integer count vector (see :func:`bucket_counts`)
    with merge/quantile/serialization. State is *counts only* — no float
    accumulators — so :meth:`merge` is exactly associative and commutative
    and two sketches built from the same observations in any grouping are
    equal. Counts are held as int64 on host so merging many int32 round
    partials cannot overflow.
    """

    def __init__(self, layout: BucketLayout, counts=None) -> None:
        """Create an empty sketch, or adopt an existing count vector."""
        self.layout = layout
        if counts is None:
            self.counts = np.zeros(layout.n + 2, np.int64)
        else:
            c = np.asarray(counts, np.int64).reshape(-1)
            if c.shape[0] != layout.n + 2:
                raise ValueError(
                    f"sketch {layout.name!r}: counts length {c.shape[0]}, "
                    f"layout wants {layout.n + 2}")
            self.counts = c.copy()

    @property
    def total(self) -> int:
        """Number of observed values (including under/overflow)."""
        return int(self.counts.sum())

    def observe(self, values, mask=None) -> "Sketch":
        """Fold raw values into this sketch via the device reduction."""
        self.counts += np.asarray(
            bucket_counts(values, self.layout, mask), np.int64)
        return self

    def add_counts(self, counts) -> "Sketch":
        """Fold a raw ``(n + 2,)`` count vector (e.g. a device partial)."""
        c = np.asarray(counts, np.int64).reshape(-1)
        if c.shape[0] != self.layout.n + 2:
            raise ValueError(
                f"sketch {self.layout.name!r}: partial length {c.shape[0]}, "
                f"layout wants {self.layout.n + 2}")
        self.counts += c
        return self

    def merge(self, other: "Sketch") -> "Sketch":
        """Element-wise-add merge; layouts must match exactly."""
        if self.layout != other.layout:
            raise ValueError(f"cannot merge sketch {other.layout.name!r} "
                             f"into {self.layout.name!r}: layouts differ")
        return Sketch(self.layout, self.counts + other.counts)

    def quantile(self, q: float) -> float:
        """Rank-``floor(q * (total - 1))`` estimate (np.quantile 'lower').

        The exact order statistic of the observed data at that rank lies
        in the reported bucket, so the estimate is within
        :meth:`BucketLayout.error_bound` for in-range values. Underflow
        ranks report ``0.0`` for log layouts (below-resolution, e.g. zero
        BER) and ``lo`` for linear; overflow ranks report ``hi``.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        total = self.total
        if total == 0:
            return 0.0
        rank = int(math.floor(q * (total - 1)))
        n = self.layout.n
        # rank order: underflow slot first, then buckets, then overflow.
        order = np.concatenate(([self.counts[n]], self.counts[:n],
                                [self.counts[n + 1]]))
        cum = np.cumsum(order)
        pos = int(np.searchsorted(cum, rank + 1))
        if pos == 0:
            return 0.0 if self.layout.scale == "log" else float(self.layout.lo)
        if pos == n + 1:
            return float(self.layout.hi)
        return float(self.layout.representatives()[pos - 1])

    def mean(self) -> float:
        """Bucket-representative mean (under/overflow use ``lo`` / ``hi``)."""
        total = self.total
        if total == 0:
            return 0.0
        reps = self.layout.representatives()
        lo_rep = 0.0 if self.layout.scale == "log" else self.layout.lo
        s = (float(self.counts[: self.layout.n] @ reps)
             + float(self.counts[self.layout.n]) * lo_rep
             + float(self.counts[self.layout.n + 1]) * self.layout.hi)
        return s / total

    def to_dict(self) -> dict:
        """JSON-safe form: layout metadata + the full count vector.

        Size is a function of the layout alone — never of how many values
        were observed — which is what makes ``detail="sketch"`` ledger
        lines cohort-independent.
        """
        return {"layout": self.layout.to_dict(),
                "counts": [int(c) for c in self.counts],
                "total": self.total}

    @classmethod
    def from_dict(cls, d: dict) -> "Sketch":
        """Rebuild a sketch from :meth:`to_dict` output."""
        return cls(BucketLayout.from_dict(d["layout"]), d["counts"])

    def __eq__(self, other) -> bool:
        """Equal layouts and identical counts."""
        return (isinstance(other, Sketch) and self.layout == other.layout
                and bool(np.array_equal(self.counts, other.counts)))

    def __repr__(self) -> str:
        """Compact debugging form with the headline quantiles."""
        return (f"Sketch({self.layout.name!r}, total={self.total}, "
                f"p50={self.quantile(0.5):.4g}, "
                f"p99={self.quantile(0.99):.4g})")
