"""Live metrics layer: per-round sketch computation + OpenMetrics export.

Builds on :mod:`repro.obs.sketch` to give the engines a constant-overhead
distributional view of every round:

* :data:`DEFAULT_LAYOUTS` — the repo's canonical bucket layouts for the
  per-client metrics the paper's scheme actually steers on: true and
  estimated SNR (linear dB buckets — dB is already a log domain), payload
  BER (log buckets, DDSketch-style relative-error bound), per-client
  airtime, mode-dwell (rounds since the client's last mode switch), and
  aggregation staleness (buffered engine).
* :class:`RoundSketcher` — owned by an engine; one jitted device reduction
  per round/wave turns the already-resident link arrays into fixed-size
  ``int32`` bucket counts plus ``k`` worst-client / reservoir exemplars.
  Only those constant-size arrays cross to host, so the cost per round is
  independent of cohort size. The sketcher also folds every round into
  run-level :class:`~repro.obs.sketch.Sketch` accumulators (merge =
  element-wise add — exactly associative).
* :class:`MetricsRegistry` — counters / gauges / histograms with an
  OpenMetrics text exposition (:meth:`MetricsRegistry.render`), plus
  :func:`registry_from_ledger` to rebuild a registry from any run ledger
  (the path ``tools/metrics_export.py`` drives).

Neutrality: the sketcher reads the round key only through ``fold_in`` on
the reserved ``OBS_KEY_LANE`` and consumes arrays the round step already
produced, so sketches-on runs are bit-identical to sketches-off runs on
model weights and accuracy (pinned by ``tests/test_metrics.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keylanes
from repro.obs.sketch import (BucketLayout, Sketch, bucket_counts,
                              reservoir_sample, reservoir_tags, worst_k)

__all__ = [
    "DEFAULT_LAYOUTS",
    "RoundSketcher",
    "resolve_sketches",
    "MetricsRegistry",
    "registry_from_ledger",
    "render_openmetrics",
]

# Canonical per-client metric layouts. dB metrics use linear buckets (the
# dB scale is already logarithmic in power; absolute bound = half a bucket
# = 0.625 dB); ratio/time metrics use log buckets with the DDSketch
# relative-error bound sqrt(gamma) - 1 (~7.5% for the BER layout).
DEFAULT_LAYOUTS = {
    "snr_db": BucketLayout("snr_db", "linear", -20.0, 60.0, 64),
    "est_db": BucketLayout("est_db", "linear", -20.0, 60.0, 64),
    "ber": BucketLayout("ber", "log", 1e-8, 1.0, 128),
    "airtime_s": BucketLayout("airtime_s", "log", 1e-7, 1e3, 96),
    "dwell_rounds": BucketLayout("dwell_rounds", "linear", 0.0, 64.0, 64),
    "staleness": BucketLayout("staleness", "linear", 0.0, 32.0, 32),
    "downlink_ber": BucketLayout("downlink_ber", "log", 1e-8, 1.0, 128),
}


# The sketchable round metrics, in the order their layouts travel through
# the static ``layouts`` argument of :func:`_round_reduce` (``downlink_ber``
# last: it is only computed when the round had a downlink leg).
_ROUND_METRICS = ("snr_db", "est_db", "ber", "airtime_s", "dwell_rounds",
                  "downlink_ber")


@functools.partial(jax.jit,
                   static_argnames=("layouts", "k", "with_dl"))
def _round_reduce(key, snr_db, est_db, ber, airtime_s, mode, active,
                  member, prev_mode, dwell, dl_ber, *,
                  layouts: tuple, k: int, with_dl: bool):
    """The pure per-round reduction (jitted; fixed-size outputs).

    Module-level so the compile cache is shared across
    :class:`RoundSketcher` instances: ``layouts`` is the tuple of
    :class:`BucketLayout` objects for :data:`_ROUND_METRICS` (hashable
    frozen dataclasses, so they ride as static arguments), and two
    sketchers with equal layouts / ``k`` / cohort shape hit the same
    executable.

    ``member`` masks the observed cohort (async wave membership; all ones
    for the sync engine); ``active`` additionally masks clients whose
    uplink actually happened (BER/airtime observations).
    """
    snr_lay, est_lay, ber_lay, air_lay, dwell_lay, dl_lay = layouts
    member_b = member > 0
    eff_b = (member * active) > 0
    dwell = jnp.where(
        member_b,
        jnp.where(mode == prev_mode, dwell + 1, jnp.int32(1)), dwell)
    prev_mode = jnp.where(member_b, mode, prev_mode)
    counts = {
        "snr_db": bucket_counts(snr_db, snr_lay, mask=member_b),
        "est_db": bucket_counts(est_db, est_lay, mask=member_b),
        "ber": bucket_counts(ber, ber_lay, mask=eff_b),
        "airtime_s": bucket_counts(airtime_s, air_lay, mask=eff_b),
        "dwell_rounds": bucket_counts(
            dwell.astype(jnp.float32), dwell_lay, mask=member_b),
    }
    if with_dl:
        counts["downlink_ber"] = bucket_counts(dl_ber, dl_lay,
                                               mask=member_b)
    w_ber, w_idx = worst_k(ber, k, mask=eff_b)
    tags = reservoir_tags(key, snr_db.shape[0])
    tags = jnp.where(member_b, tags, jnp.inf)
    r_tags, r_idx = reservoir_sample(tags, k)
    ex = {
        "w_ber": w_ber, "w_idx": w_idx,
        "w_snr": jnp.take(snr_db, w_idx), "w_mode": jnp.take(mode, w_idx),
        "r_tags": r_tags, "r_idx": r_idx,
        "r_snr": jnp.take(snr_db, r_idx), "r_ber": jnp.take(ber, r_idx),
    }
    return counts, dwell, prev_mode, ex


class RoundSketcher:
    """Per-round device-side sketch computation for one engine.

    One instance rides one engine run: :meth:`round_group` consumes the
    round's already-resident device arrays (per-client SNR/BER/airtime,
    the mode vector, the activity masks) and returns the JSON-safe
    ``sketches`` group for that round's
    :class:`~repro.obs.records.RoundRecord`, while folding the same counts
    into run-level accumulators (:attr:`run`). The sketcher owns the
    mode-dwell device state (rounds since each client's last mode switch)
    because the engines overwrite their ``prev_mode`` before telemetry
    runs.

    Exemplars: the ``k`` worst clients by BER (with their SNR and mode)
    and a ``k``-client keyed reservoir — tags ride ``fold_in`` on the
    reserved ``OBS_KEY_LANE``, so the selection is a pure function of the
    round key and batching-invariant.
    """

    def __init__(self, num_clients: int, *, layouts: dict | None = None,
                 exemplar_k: int = 4):
        """Set up layouts, dwell state, and the jitted device reductions."""
        keylanes.check_cohort(keylanes.OBS_KEY_LANE, num_clients)
        self.num_clients = int(num_clients)
        self.exemplar_k = min(int(exemplar_k), self.num_clients)
        self.layouts = dict(DEFAULT_LAYOUTS)
        if layouts:
            self.layouts.update(layouts)
        self.run = {name: Sketch(lay) for name, lay in self.layouts.items()}
        self._dwell = jnp.zeros((self.num_clients,), jnp.int32)
        self._prev_mode = jnp.full((self.num_clients,), -1, jnp.int32)
        # Static layout tuple for the shared jitted reduction.
        self._layout_args = tuple(self.layouts[m] for m in _ROUND_METRICS)

    def round_group(self, key, *, snr_db, est_db, ber, airtime_s, mode,
                    active, member=None, downlink_ber=None) -> dict:
        """Sketch one round; returns the record's ``sketches`` group.

        Runs the jitted reduction, folds the counts into the run-level
        accumulators, and formats the constant-size JSON group (per-metric
        ``{layout, counts, total}`` + the exemplar lists). ``member=None``
        means the full cohort was observed (synchronous engine).
        """
        if member is None:
            member = jnp.ones((self.num_clients,), jnp.float32)
        with_dl = downlink_ber is not None
        if not with_dl:
            downlink_ber = jnp.zeros((self.num_clients,), jnp.float32)
        counts, self._dwell, self._prev_mode, ex = _round_reduce(
            key, snr_db, est_db, ber, airtime_s, mode,
            jnp.asarray(active, jnp.float32),
            jnp.asarray(member, jnp.float32),
            self._prev_mode, self._dwell, downlink_ber,
            layouts=self._layout_args, k=self.exemplar_k, with_dl=with_dl)
        group = {}
        for name, c in counts.items():
            c = np.asarray(c, np.int64)
            self.run[name].add_counts(c)
            group[name] = {"layout": self.layouts[name].to_dict(),
                           "counts": [int(x) for x in c],
                           "total": int(c.sum())}
        group["exemplars"] = self._format_exemplars(ex)
        return group

    def _format_exemplars(self, ex) -> dict:
        """Host-side JSON form of the device exemplar arrays (masked-out
        sentinel winners — ``-inf`` / ``+inf`` tags — are dropped)."""
        worst, reservoir = [], []
        w_ber = np.asarray(ex["w_ber"])
        for j in range(w_ber.shape[0]):
            if not np.isfinite(w_ber[j]):
                continue
            worst.append({"client": int(ex["w_idx"][j]),
                          "ber": float(w_ber[j]),
                          "snr_db": float(ex["w_snr"][j]),
                          "mode": int(ex["w_mode"][j])})
        r_tags = np.asarray(ex["r_tags"])
        for j in range(r_tags.shape[0]):
            if not np.isfinite(r_tags[j]):
                continue
            reservoir.append({"client": int(ex["r_idx"][j]),
                              "tag": float(r_tags[j]),
                              "snr_db": float(ex["r_snr"][j]),
                              "ber": float(ex["r_ber"][j])})
        return {"worst_ber": worst, "reservoir": reservoir}

    def observe_staleness(self, values) -> None:
        """Fold host-side staleness observations (buffered aggregations)
        into the run-level ``staleness`` sketch."""
        vals = np.asarray(values, np.float32).reshape(-1)
        if vals.size:
            self.run["staleness"].observe(vals)

    def summary(self) -> dict:
        """Run-level sketch group (non-empty sketches only) for the
        ledger's summary line."""
        return {name: sk.to_dict() for name, sk in self.run.items()
                if sk.total > 0}


def resolve_sketches(sketches, num_clients: int) -> RoundSketcher | None:
    """The engines' ``sketches=`` argument -> a :class:`RoundSketcher`.

    ``None``/``False`` -> no sketching; ``True`` -> default layouts; a
    :class:`RoundSketcher` passes through; a dict is treated as layout
    overrides (``{metric_name: BucketLayout}``).
    """
    if sketches is None or sketches is False:
        return None
    if isinstance(sketches, RoundSketcher):
        return sketches
    if sketches is True:
        return RoundSketcher(num_clients)
    if isinstance(sketches, dict):
        return RoundSketcher(num_clients, layouts=sketches)
    raise ValueError(
        f"sketches= must be None/True/RoundSketcher/layout-dict, got "
        f"{type(sketches).__name__}")


# ---------------------------------------------------------------- registry


def _metric_name_ok(name: str) -> bool:
    """OpenMetrics metric-name validity (``[a-zA-Z_:][a-zA-Z0-9_:]*``)."""
    if not name:
        return False
    ok = set("abcdefghijklmnopqrstuvwxyz"
             "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")
    return name[0] not in "0123456789" and all(c in ok for c in name)


class MetricsRegistry:
    """A flat registry of counters, gauges, and sketch-backed histograms.

    The in-process twin of a Prometheus client: engines / tools register
    metrics by name, and :meth:`render` emits the whole registry as
    OpenMetrics text (``# HELP`` / ``# TYPE`` metadata, cumulative
    ``_bucket{le=...}`` series for histograms, terminated by ``# EOF``).
    Registration is idempotent per name; re-registering with a different
    type is an error.
    """

    def __init__(self) -> None:
        """Start empty."""
        self._metrics: dict[str, dict] = {}

    def _register(self, name: str, kind: str, help_text: str) -> dict:
        if not _metric_name_ok(name):
            raise ValueError(f"invalid OpenMetrics metric name {name!r}")
        m = self._metrics.get(name)
        if m is None:
            m = {"kind": kind, "help": help_text, "value": 0.0,
                 "sketch": None}
            self._metrics[name] = m
        elif m["kind"] != kind:
            raise ValueError(
                f"metric {name!r} already registered as {m['kind']}")
        return m

    def counter(self, name: str, help_text: str = "") -> "MetricsRegistry":
        """Declare a counter (monotone; rendered with a ``_total`` sample)."""
        self._register(name, "counter", help_text)
        return self

    def inc(self, name: str, amount: float = 1.0) -> None:
        """Increment a counter (declares it on first use)."""
        m = self._register(name, "counter", "")
        if amount < 0:
            raise ValueError(f"counter {name!r}: negative increment")
        m["value"] += amount

    def gauge(self, name: str, value: float, help_text: str = "") -> None:
        """Set a gauge to ``value`` (declares it on first use)."""
        m = self._register(name, "gauge", help_text)
        m["value"] = float(value)

    def histogram(self, name: str, sketch: Sketch,
                  help_text: str = "") -> None:
        """Attach (or merge) a :class:`Sketch` as a histogram metric."""
        m = self._register(name, "histogram", help_text)
        m["sketch"] = (sketch if m["sketch"] is None
                       else m["sketch"].merge(sketch))

    def sketches(self) -> dict:
        """The registered histogram sketches by metric name."""
        return {n: m["sketch"] for n, m in self._metrics.items()
                if m["kind"] == "histogram" and m["sketch"] is not None}

    def render(self) -> str:
        """The registry as OpenMetrics text exposition (ends ``# EOF``)."""
        return render_openmetrics(self._metrics)


def _fmt_num(v: float) -> str:
    """OpenMetrics sample-value formatting (int-valued floats stay short)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_openmetrics(metrics: dict) -> str:
    """Render a ``{name: {kind, help, value, sketch}}`` table as
    OpenMetrics text.

    Histograms emit the cumulative ``_bucket{le="..."}`` series derived
    from the sketch's bucket layout: the underflow slot folds into every
    bucket (underflow means ``v < lo`` <= every upper edge), the overflow
    slot only into ``+Inf``; ``_sum`` is the bucket-representative
    estimate (documented in :meth:`Sketch.mean`).
    """
    lines = []
    for name in sorted(metrics):
        m = metrics[name]
        kind, help_text = m["kind"], m["help"]
        if help_text:
            lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {kind}")
        if kind == "counter":
            lines.append(f"{name}_total {_fmt_num(m['value'])}")
        elif kind == "gauge":
            lines.append(f"{name} {_fmt_num(m['value'])}")
        elif kind == "histogram":
            sk = m["sketch"]
            if sk is None:
                continue
            lay = sk.layout
            under = int(sk.counts[lay.n])
            cum = under
            for edge, c in zip(lay.edges()[1:], sk.counts[: lay.n]):
                cum += int(c)
                lines.append(f'{name}_bucket{{le="{edge:.6g}"}} {cum}')
            lines.append(f'{name}_bucket{{le="+Inf"}} {sk.total}')
            lines.append(f"{name}_sum {_fmt_num(sk.mean() * sk.total)}")
            lines.append(f"{name}_count {sk.total}")
        else:  # pragma: no cover - _register restricts kinds
            raise ValueError(f"unknown metric kind {kind!r}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def registry_from_ledger(path) -> MetricsRegistry:
    """Build a :class:`MetricsRegistry` from a run ledger.

    Round counts / final accuracy / airtime become counters and gauges.
    Histograms come from the summary line's ``sketches`` group when the
    run finished (it is already the element-wise-add merge of every round
    group, plus host-only metrics like the buffered engine's staleness);
    a crashed run (no summary) falls back to merging the per-round groups
    — the merge is exact, so both paths agree on the shared metrics.
    """
    from repro.obs import ledger as ledger_lib

    data = ledger_lib.read_ledger(path)
    reg = MetricsRegistry()
    reg.counter("repro_rounds", "rounds (or waves) recorded in the ledger")
    reg.inc("repro_rounds", len(data.rounds))
    reg.counter("repro_events", "event-clock records in the ledger")
    reg.inc("repro_events", len(data.events))
    if data.summary is not None:
        if "final_accuracy" in data.summary:
            reg.gauge("repro_final_accuracy",
                      data.summary["final_accuracy"],
                      "final eval accuracy of the run")
        if "airtime_s" in data.summary:
            reg.gauge("repro_airtime_seconds", data.summary["airtime_s"],
                      "cumulative cohort airtime at the end of the run")
    if data.summary is not None and isinstance(
            data.summary.get("sketches"), dict):
        groups = [data.summary["sketches"]]
    else:
        groups = [r.sketches for r in data.rounds if r.sketches]
    for group in groups:
        for metric, d in group.items():
            if metric == "exemplars" or not isinstance(d, dict):
                continue
            if "counts" not in d:
                continue
            reg.histogram(f"repro_client_{metric}", Sketch.from_dict(d),
                          f"per-client {metric} distribution "
                          f"(mergeable bucket sketch)")
    return reg
