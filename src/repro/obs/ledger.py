"""JSONL run ledger: durable, append-only telemetry for every FL run.

A ledger is one JSON object per line, flushed as it is written so a crashed
run keeps every completed round:

    {"kind": "manifest", "schema": 1, "fingerprint": ..., "provenance": ...}
    {"kind": "round", "round": 0, "mean_snr_db": ..., ...}
    {"kind": "event", "t": 0.0, "event": "wave", ...}      (async engine)
    {"kind": "eval", "round": 0, "accuracy": ..., ...}
    {"kind": "summary", "final_accuracy": ..., "phases": ...}

The **manifest** carries everything needed to compare two runs honestly:
a config fingerprint (stable hash of the run's algorithm/transport/
scenario/compression/downlink setup), the seed, and a provenance block
(jax/numpy/python versions, platform, backend, git sha, UTC timestamp) —
the same block ``benchmarks/common.bench_meta`` stamps into every
``BENCH_*.json``. Round lines are :class:`~repro.obs.records.RoundRecord`
serializations; event lines wrap
:class:`~repro.obs.records.EventRecord`. ``read_ledger`` parses a file back
into typed records and ``validate_ledger`` is the schema gate the obs
benchmark smoke and the tests run.

Attaching a ledger never changes a run's numbers: sinks only observe values
the engine already computed (``tests/test_obs.py`` pins sink-on == sink-off
bit equality).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform as platform_lib
import subprocess
import sys

from repro.obs import records as records_lib

__all__ = [
    "provenance",
    "config_fingerprint",
    "RunLedger",
    "as_ledger",
    "LedgerData",
    "read_ledger",
    "validate_ledger",
]

# Manifest keys every ledger must carry (validate_ledger enforces these).
MANIFEST_KEYS = ("kind", "schema", "fingerprint", "engine", "algorithm",
                 "n_rounds", "num_clients", "seed", "provenance")
PROVENANCE_KEYS = ("schema", "jax", "numpy", "python", "platform", "backend",
                   "git_sha", "timestamp")


def _git_sha() -> str | None:
    """Current repo HEAD sha, or ``None`` outside a git checkout (the
    ledger must never fail a run over provenance)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance() -> dict:
    """The environment block stamped into ledgers and ``BENCH_*.json``:
    library versions, platform, accelerator backend, git sha, UTC time."""
    import datetime

    import jax
    import numpy as np

    return {
        "schema": records_lib.SCHEMA_VERSION,
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": sys.version.split()[0],
        "platform": platform_lib.platform(),
        "backend": jax.default_backend(),
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def _canonical(obj) -> str:
    """Deterministic string form of a config object for fingerprinting:
    dataclasses render as sorted field dicts, containers recurse, leaves
    fall back to ``repr``."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        fields = {f.name: _canonical(getattr(obj, f.name))
                  for f in dataclasses.fields(obj)}
        return f"{type(obj).__name__}({sorted(fields.items())})"
    if isinstance(obj, dict):
        return repr(sorted((k, _canonical(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return repr([_canonical(v) for v in obj])
    return repr(obj)


def config_fingerprint(*objs) -> str:
    """Stable 12-hex-digit digest of a run configuration.

    Two runs with the same fingerprint were launched with the same
    algorithm/transport/scenario/compression/downlink arguments — the
    primary join key when diffing ledgers across PRs
    (``python -m tools.report a.jsonl b.jsonl``).
    """
    text = "|".join(_canonical(o) for o in objs)
    return hashlib.sha256(text.encode()).hexdigest()[:12]


def _json_scalar(obj):
    """``json.dumps`` fallback: engines keep telemetry values in whatever
    host scalar type the pricing produced (numpy floats included) to stay
    bit-identical with the dict era, so the ledger coerces at the wire."""
    if hasattr(obj, "item"):  # numpy scalars / 0-d arrays
        return obj.item()
    raise TypeError(
        f"ledger value of type {type(obj).__name__} is not JSON-serializable")


class RunLedger:
    """Append-only JSONL sink for one FL run (see module docstring).

    ``events=False`` drops the per-event lines (the buffered engine can
    emit thousands per run) while keeping manifest/round/eval/summary.
    ``detail`` selects the large-cohort profile: ``"full"`` (default)
    keeps everything; ``"sketch"`` additionally drops event lines and
    stamps ``detail`` into the manifest — combined with an engine-side
    :class:`~repro.obs.metrics.RoundSketcher` the per-round line size is
    then a function of the sketch layouts alone, independent of cohort
    size. The file opens lazily on first write and every line is flushed,
    so a crashed run keeps all completed records. Usable as a context
    manager; the engines close it from ``run()``'s tail, and ``close`` is
    idempotent.
    """

    def __init__(self, path, *, events: bool = True, detail: str = "full"):
        if detail not in ("full", "sketch"):
            raise ValueError(
                f"detail must be 'full' or 'sketch', got {detail!r}")
        self.path = os.fspath(path)
        self.detail = detail
        self.events = events and detail == "full"
        self._f = None
        self._wrote_manifest = False

    def _write(self, obj: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "w")
        self._f.write(json.dumps(obj, default=_json_scalar) + "\n")
        self._f.flush()

    def write_manifest(self, manifest: dict) -> None:
        """First line of the ledger; later calls are ignored so an engine
        re-run against the same ledger object cannot corrupt the header."""
        if self._wrote_manifest:
            return
        out = {"kind": "manifest", "schema": records_lib.SCHEMA_VERSION,
               "detail": self.detail}
        out.update(manifest)
        self._write(out)
        self._wrote_manifest = True

    def write_round(self, rec: records_lib.RoundRecord) -> None:
        """One per-round (or per-wave) record line."""
        self._write({"kind": "round", **rec.to_dict()})

    def write_event(self, ev: records_lib.EventRecord) -> None:
        """One event-clock line (no-op when ``events=False``)."""
        if not self.events:
            return
        d = ev.to_dict()
        d["event"] = d.pop("kind")
        self._write({"kind": "event", **d})

    def write_eval(self, rnd: int, accuracy: float, airtime_s: float,
                   event_s: float | None = None) -> None:
        """One accuracy-curve point (round, accuracy, cumulative airtime,
        and — buffered engine only — the event-clock timestamp)."""
        out = {"kind": "eval", "round": int(rnd),
               "accuracy": float(accuracy), "airtime_s": float(airtime_s)}
        if event_s is not None:
            out["event_s"] = float(event_s)
        self._write(out)

    def write_summary(self, summary: dict) -> None:
        """Final line: run outcome (final accuracy, wall time, phase-timer
        summary, ...)."""
        self._write({"kind": "summary", **summary})

    def close(self) -> None:
        """Close the file handle (idempotent)."""
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def as_ledger(ledger) -> RunLedger | None:
    """``ledger=`` engine argument -> a :class:`RunLedger` (a path-like
    opens a fresh ledger; an existing ledger object passes through)."""
    if ledger is None or isinstance(ledger, RunLedger):
        return ledger
    return RunLedger(ledger)


@dataclasses.dataclass
class LedgerData:
    """A parsed ledger: the manifest dict, typed round/event records, eval
    points, and the summary dict (``None`` if the run crashed early)."""

    manifest: dict
    rounds: list
    events: list
    evals: list
    summary: dict | None

    @property
    def link(self) -> list:
        """The run's ``FLResult.link`` view, rebuilt from the round
        records (bit-identical to what the engine returned)."""
        return [r.to_link_dict() for r in self.rounds
                if r.has_link_fields()]


def read_ledger(path) -> LedgerData:
    """Parse a JSONL ledger back into typed records.

    Tolerates a truncated final line (the crash case the incremental
    flushing exists for). Accepts every schema version in
    ``records.SUPPORTED_SCHEMAS`` (v1 ledgers read unchanged); rejects
    unknown schema versions, unknown record kinds, unknown record fields,
    and **mixed-version lines** — a v1-stamped ledger whose round lines
    carry v2-only fields (e.g. ``sketches``) — each with a
    ``path:lineno:`` error so the offending line is findable.
    """
    manifest, rounds, events, evals, summary = None, [], [], [], None
    schema = records_lib.SCHEMA_VERSION
    with open(path) as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            # A torn final line is the expected crash artifact; a torn
            # *interior* line is corruption.
            if i == len(lines) - 1:
                break
            raise
        kind = obj.pop("kind", None)
        if kind == "manifest":
            schema = obj.get("schema")
            if schema not in records_lib.SUPPORTED_SCHEMAS:
                raise ValueError(
                    f"{path}:{i + 1}: ledger schema {schema!r}, reader "
                    f"supports {records_lib.SUPPORTED_SCHEMAS}")
            manifest = obj
        elif kind == "round":
            if schema < 2:
                v2 = [k for k in records_lib.V2_ROUND_FIELDS if k in obj]
                if v2:
                    raise ValueError(
                        f"{path}:{i + 1}: schema-{schema} ledger has a "
                        f"round line with v2-only field(s) {v2} "
                        f"(mixed-version line)")
            try:
                rounds.append(records_lib.RoundRecord.from_dict(obj))
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: {e}") from None
        elif kind == "event":
            obj["kind"] = obj.pop("event")
            try:
                events.append(records_lib.EventRecord.from_dict(obj))
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: {e}") from None
        elif kind == "eval":
            evals.append(obj)
        elif kind == "summary":
            summary = obj
        else:
            raise ValueError(
                f"{path}:{i + 1}: unknown ledger record kind {kind!r}")
    if manifest is None:
        raise ValueError(f"{path}: no manifest line (not a run ledger?)")
    return LedgerData(manifest, rounds, events, evals, summary)


def validate_ledger(path) -> list:
    """Schema-validate a ledger file; returns a list of problem strings
    (empty = valid). The gate behind ``make bench-obs`` and the tests."""
    problems = []
    try:
        data = read_ledger(path)
    except (ValueError, OSError) as e:
        msg = str(e)
        # Per-line reader errors already carry the "path:lineno:" locator;
        # pass them through so the caller sees exactly which line broke.
        if msg.startswith(f"{path}:"):
            return [msg]
        return [f"{path}: unreadable: {e}"]
    for key in MANIFEST_KEYS[1:]:  # "kind" was consumed by the reader
        if key not in data.manifest:
            problems.append(f"{path}: manifest missing key {key!r}")
    prov = data.manifest.get("provenance", {})
    for key in PROVENANCE_KEYS:
        if key not in prov:
            problems.append(f"{path}: provenance missing key {key!r}")
    for i, ev in enumerate(data.events):
        if ev.kind in ("wave", "compute", "uplink") and ev.dur is None:
            problems.append(
                f"{path}: event {i} ({ev.kind}) is a span but has no dur")
    seen = [r.round for r in data.rounds]
    if seen != sorted(seen):
        problems.append(f"{path}: round records out of order")
    for ev in data.evals:
        for key in ("round", "accuracy", "airtime_s"):
            if key not in ev:
                problems.append(f"{path}: eval record missing {key!r}")
    return problems
