"""Observability layer: typed records, run ledgers, traces, phase timers.

The cross-cutting telemetry subsystem of the FL engines:

* :mod:`repro.obs.records` — versioned :class:`RoundRecord` /
  :class:`EventRecord` dataclasses both engines emit natively
  (``FLResult.link`` stays available as a bit-identical dict view);
* :mod:`repro.obs.ledger` — the JSONL :class:`RunLedger` sink (manifest
  with config fingerprint + provenance, incremental per-round flushing) and
  its reader/validator;
* :mod:`repro.obs.trace` — the Chrome/Perfetto :class:`TraceRecorder` for
  the async engine's event clock (waves, client spans, aggregations,
  churn, buffer fill);
* :mod:`repro.obs.timers` — :class:`PhaseTimers` wall-clock scopes with
  first-call (compile) time split from the steady state;
* :mod:`repro.obs.sketch` — mergeable constant-memory bucket sketches
  (device-side ``int32`` histograms, quantile estimates with documented
  error bounds, keyed reservoir exemplars);
* :mod:`repro.obs.metrics` — the per-round :class:`RoundSketcher` the
  engines drive, plus the :class:`MetricsRegistry` OpenMetrics exporter.

Everything here is an *observer*: attaching any sink to a run changes none
of its numeric results (pinned by ``tests/test_obs.py``).
"""

from repro.obs.ledger import (  # noqa: F401
    LedgerData,
    RunLedger,
    config_fingerprint,
    provenance,
    read_ledger,
    validate_ledger,
)
from repro.obs.records import (  # noqa: F401
    EVENT_KINDS,
    LINK_FIELDS,
    SCHEMA_VERSION,
    EventRecord,
    RoundRecord,
)
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_LAYOUTS,
    MetricsRegistry,
    RoundSketcher,
    registry_from_ledger,
    resolve_sketches,
)
from repro.obs.sketch import BucketLayout, Sketch  # noqa: F401
from repro.obs.timers import NULL_TIMERS, PhaseStat, PhaseTimers  # noqa: F401
from repro.obs.trace import TraceRecorder  # noqa: F401

__all__ = [
    "SCHEMA_VERSION",
    "LINK_FIELDS",
    "EVENT_KINDS",
    "RoundRecord",
    "EventRecord",
    "RunLedger",
    "LedgerData",
    "read_ledger",
    "validate_ledger",
    "provenance",
    "config_fingerprint",
    "TraceRecorder",
    "PhaseTimers",
    "PhaseStat",
    "NULL_TIMERS",
    "BucketLayout",
    "Sketch",
    "DEFAULT_LAYOUTS",
    "RoundSketcher",
    "resolve_sketches",
    "MetricsRegistry",
    "registry_from_ledger",
]
