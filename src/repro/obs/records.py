"""Typed per-round / per-event telemetry records (schema v2).

Before this module, per-round FL telemetry was a pile of ad-hoc dicts in
``FLResult.link`` whose schema lived in a comment on the dataclass, and the
asynchronous engine's event clock was invisible outside ``event_s``
scalars. This module is the single source of truth for both shapes:

* :class:`RoundRecord` — one synchronous round (or one dispatched wave of
  the buffered engine): the scenario link fields, the compression fields,
  the downlink fields, plus observability-only extras (per-leg BER
  aggregates from ``TxStats``, the event-clock dispatch time). Engines
  build these natively; :meth:`RoundRecord.to_link_dict` reproduces the
  historical ``FLResult.link`` dict **bit-identically** (same keys, same
  insertion order, same values — pinned by ``tests/test_obs.py``).
* :class:`EventRecord` — one event-clock happening of the buffered engine
  (wave dispatch, per-client compute/uplink spans, arrivals, aggregations,
  churn, buffer-fill samples). The run ledger persists them as JSONL and
  the Perfetto exporter (:mod:`repro.obs.trace`) renders them as tracks.

Records serialize losslessly: ``to_dict`` drops unset (``None``) fields,
``from_dict`` restores them, and ``SCHEMA_VERSION`` stamps every ledger so
readers can refuse records they do not understand.
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "SCHEMA_VERSION",
    "SUPPORTED_SCHEMAS",
    "V2_ROUND_FIELDS",
    "LINK_FIELDS",
    "EVENT_KINDS",
    "RoundRecord",
    "EventRecord",
    "scenario_round_record",
]

# Versioned record schema: bump when a field changes meaning or a field
# group is added that old readers must not misparse. v1 = the original
# typed-record layer; v2 adds the per-round ``sketches`` group (mergeable
# per-client distribution sketches, see ``repro.obs.sketch``). Readers
# accept every version in SUPPORTED_SCHEMAS; writers stamp SCHEMA_VERSION.
SCHEMA_VERSION = 2
SUPPORTED_SCHEMAS = (1, 2)

# Fields that only exist from schema v2 on: a v1-stamped ledger line
# carrying one of these is a mixed-version line and is rejected with a
# per-line error by ``repro.obs.ledger.read_ledger``.
V2_ROUND_FIELDS = ("sketches",)

# The historical ``FLResult.link`` dict keys, in the exact insertion order
# the engines produced before the typed-record layer existed: scenario
# fields first, then compression, then downlink. ``to_link_dict`` walks
# this tuple, so the dict view stays bit-identical to the pre-record dicts.
LINK_FIELDS = (
    "round",
    "mean_snr_db",
    "mean_est_db",
    "mode_counts",
    "n_active",
    "n_stragglers",
    "airtime_s",
    "comp_ratio",
    "comp_bits_on_air",
    "comp_residual_norm",
    "downlink_airtime_s",
    "downlink_ber",
    "downlink_mode_counts",
)

# Event-record kinds the buffered engine emits. Span kinds carry ``dur``;
# instant kinds carry only ``t``; ``buffer`` is a counter sample (``value``
# = updates buffered after the event).
EVENT_KINDS = (
    "wave",       # span: one dispatch wave, t .. t + dur (last arrival)
    "compute",    # span: one client's local computation
    "uplink",     # span: one client's uplink airtime
    "arrival",    # instant: an update landed in the server buffer
    "aggregate",  # instant: the buffer folded into a new model version
    "join",       # instant: a churned-out client rejoined
    "leave",      # instant: a client churned out
    "buffer",     # counter: buffer fill level after an event
)


@dataclasses.dataclass
class RoundRecord:
    """Typed telemetry of one FL round (or one buffered-engine wave).

    Only ``round`` is mandatory; every other field is ``None`` until the
    engine fills it, and ``None`` fields are dropped from both serialized
    forms. The first three field groups mirror the historical link-dict
    keys exactly (see :data:`LINK_FIELDS`); the observability-only group is
    new with this layer and never appears in :meth:`to_link_dict`.
    """

    round: int
    # -- scenario link fields (driver-backed rounds only)
    mean_snr_db: float | None = None
    mean_est_db: float | None = None
    mode_counts: list | None = None
    n_active: int | None = None
    n_stragglers: int | None = None
    airtime_s: float | None = None
    # -- compression fields (compressed uplinks only)
    comp_ratio: float | None = None
    comp_bits_on_air: float | None = None
    comp_residual_norm: float | None = None
    # -- downlink fields (noisy broadcast leg only)
    downlink_airtime_s: float | None = None
    downlink_ber: float | None = None
    downlink_mode_counts: list | None = None
    # -- observability-only fields (never in the link-dict view)
    t_event: float | None = None  # event-clock dispatch time (async engine)
    uplink_symbols: float | None = None  # cohort data symbols on air
    uplink_bits: float | None = None  # cohort payload bits offered
    uplink_bit_errors: float | None = None  # cohort residual bit errors
    uplink_ber: float | None = None  # cohort end-to-end payload BER
    uplink_mean_tx: float | None = None  # mean PHY transmissions/client
    uplink_bits_on_air: float | None = None  # cohort bits actually on air
    # -- schema v2: constant-size per-client distribution sketches
    # (``repro.obs.metrics.RoundSketcher.round_group`` output: per-metric
    # bucket counts + reservoir/worst-client exemplars)
    sketches: dict | None = None

    def to_link_dict(self) -> dict:
        """The historical ``FLResult.link`` dict: link-view fields only, in
        the pre-record insertion order, ``None`` fields omitted."""
        return {k: getattr(self, k) for k in LINK_FIELDS
                if getattr(self, k) is not None}

    def has_link_fields(self) -> bool:
        """Whether any link-view field beyond ``round`` is set — the
        condition under which the pre-record engines appended a dict."""
        return any(getattr(self, k) is not None for k in LINK_FIELDS[1:])

    def to_dict(self) -> dict:
        """All set fields (link view + observability extras) as one flat
        JSON-ready dict."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RoundRecord":
        """Inverse of :meth:`to_dict`; unknown keys are rejected so ledger
        corruption fails loudly instead of round-tripping silently."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(
                f"RoundRecord.from_dict: unknown field(s) {sorted(unknown)}")
        if "round" not in d:
            raise ValueError("RoundRecord.from_dict: missing 'round'")
        return cls(**d)


@dataclasses.dataclass
class EventRecord:
    """One event-clock happening of the buffered asynchronous engine.

    ``t`` is the simulated event-clock time in seconds; ``kind`` is one of
    :data:`EVENT_KINDS`. Span kinds (``wave``/``compute``/``uplink``) set
    ``dur``; ``buffer`` samples set ``value`` (the fill level); client- and
    wave-scoped kinds set ``client``/``wave``; ``aggregate`` sets
    ``version`` (the model version the aggregation produced) and ``value``
    (how many updates it folded).
    """

    t: float
    kind: str
    wave: int | None = None
    client: int | None = None
    version: int | None = None
    dur: float | None = None
    value: float | None = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown event kind {self.kind!r}; one of {EVENT_KINDS}")

    def to_dict(self) -> dict:
        """Set fields as a flat JSON-ready dict (``None`` omitted)."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "EventRecord":
        """Inverse of :meth:`to_dict`; unknown keys fail loudly."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(
                f"EventRecord.from_dict: unknown field(s) {sorted(unknown)}")
        return cls(**d)


def scenario_round_record(r, rnd, per_client_air, n_modes) -> RoundRecord:
    """One round's scenario fields as a :class:`RoundRecord`.

    The typed twin of the pre-record ``engine.link_telemetry`` — same
    arithmetic on the same arrays, so ``to_link_dict()`` of the result is
    bit-identical to the dict that function produced.
    """
    import numpy as np

    mode = np.asarray(rnd.mode)
    return RoundRecord(
        round=r,
        mean_snr_db=float(np.mean(np.asarray(rnd.snr_db))),
        mean_est_db=float(np.mean(np.asarray(rnd.est_db))),
        mode_counts=np.bincount(mode, minlength=n_modes).tolist(),
        n_active=int(np.asarray(rnd.active).sum()),
        n_stragglers=int(np.asarray(rnd.straggler).sum()),
        airtime_s=float(np.asarray(per_client_air).sum()),
    )
