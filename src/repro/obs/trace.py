"""Chrome/Perfetto trace export for the buffered asynchronous engine.

The async engine's event clock (dispatch waves, per-client compute and
uplink-airtime spans, buffer fills, aggregations, join/leave churn) is the
quantity its whole design optimizes, yet until this layer it surfaced only
as ``FLResult.event_s`` scalars. :class:`TraceRecorder` consumes the
engine's :class:`~repro.obs.records.EventRecord` stream and renders it in
the Chrome trace-event JSON format, loadable directly in
``https://ui.perfetto.dev`` (or ``chrome://tracing``):

* **waves** track (pid "server") — one span per dispatched wave, from its
  dispatch instant to its last member's arrival;
* **aggregate** track — an instant per buffer fold, labeled with the model
  version and how many updates it folded;
* **buffer** counter track — the server buffer's fill level over time;
* **client i** tracks (pid "clients") — each client's compute span followed
  by its uplink-airtime span, per wave;
* **churn** track — join/leave instants for scenarios with churn.

Timestamps are the *simulated* event clock (seconds), emitted in the
format's microseconds; one simulated second reads as one "second" in the
UI. Event ingestion is pure bookkeeping on host floats the engine already
computed, so attaching a recorder never changes a run's numbers.
"""

from __future__ import annotations

import json
import os

from repro.obs import records as records_lib

__all__ = ["TraceRecorder", "as_trace"]

# Synthetic pid/tid layout: one "process" per track family. Perfetto
# renders each (pid, tid) pair as its own named track.
_PID_SERVER = 1
_PID_CLIENTS = 2
_TID_WAVES = 1
_TID_AGG = 2
_TID_CHURN = 3


def _us(t_s: float) -> float:
    """Simulated seconds -> trace microseconds."""
    return float(t_s) * 1e6


class TraceRecorder:
    """Collects :class:`EventRecord` streams into a Chrome trace.

    ``path=None`` keeps the trace in memory (``to_chrome`` /
    ``export(path)``); a path set at construction lets the engine call
    :meth:`export` with no arguments at the end of the run. Track metadata
    (process/thread names) is emitted lazily, only for tracks that actually
    received events.
    """

    def __init__(self, path=None):
        self.path = None if path is None else os.fspath(path)
        self.events: list = []  # EventRecords, in arrival order
        self._chrome: list = []
        self._named: set = set()

    # ------------------------------------------------------------ naming

    def _name(self, pid: int, tid: int | None, name: str) -> None:
        key = (pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        if tid is None:  # process metadata
            self._chrome.append({
                "ph": "M", "name": "process_name", "pid": pid,
                "args": {"name": name}})
        else:
            self._name(pid, None,
                       "server" if pid == _PID_SERVER else "clients")
            self._chrome.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": name}})

    def _client_tid(self, client: int) -> int:
        tid = int(client) + 1  # tid 0 renders oddly in some viewers
        self._name(_PID_CLIENTS, tid, f"client {int(client)}")
        return tid

    # ----------------------------------------------------------- ingest

    def add(self, ev: records_lib.EventRecord) -> None:
        """Ingest one engine event (see :data:`repro.obs.records.EVENT_KINDS`
        for the kinds and which carry spans vs instants vs counters)."""
        self.events.append(ev)
        k = ev.kind
        if k == "wave":
            self._name(_PID_SERVER, _TID_WAVES, "waves")
            self._chrome.append({
                "ph": "X", "name": f"wave {ev.wave}", "cat": "wave",
                "pid": _PID_SERVER, "tid": _TID_WAVES,
                "ts": _us(ev.t), "dur": _us(ev.dur or 0.0),
                "args": {"wave": ev.wave, "members": ev.value}})
        elif k in ("compute", "uplink"):
            tid = self._client_tid(ev.client)
            self._chrome.append({
                "ph": "X", "name": k, "cat": k,
                "pid": _PID_CLIENTS, "tid": tid,
                "ts": _us(ev.t), "dur": _us(ev.dur or 0.0),
                "args": {"wave": ev.wave}})
        elif k == "arrival":
            tid = self._client_tid(ev.client)
            self._chrome.append({
                "ph": "i", "name": "arrival", "cat": "arrival", "s": "t",
                "pid": _PID_CLIENTS, "tid": tid, "ts": _us(ev.t),
                "args": {"wave": ev.wave}})
        elif k == "aggregate":
            self._name(_PID_SERVER, _TID_AGG, "aggregate")
            self._chrome.append({
                "ph": "i", "name": f"v{ev.version}", "cat": "aggregate",
                "s": "p", "pid": _PID_SERVER, "tid": _TID_AGG,
                "ts": _us(ev.t),
                "args": {"version": ev.version, "folded": ev.value}})
        elif k in ("join", "leave"):
            self._name(_PID_SERVER, _TID_CHURN, "churn")
            self._chrome.append({
                "ph": "i", "name": f"{k} {ev.client}", "cat": "churn",
                "s": "t", "pid": _PID_SERVER, "tid": _TID_CHURN,
                "ts": _us(ev.t), "args": {"client": ev.client}})
        elif k == "buffer":
            self._chrome.append({
                "ph": "C", "name": "buffer_fill", "cat": "buffer",
                "pid": _PID_SERVER, "ts": _us(ev.t),
                "args": {"updates": ev.value}})

    # ----------------------------------------------------------- export

    def track_types(self) -> set:
        """Distinct track families present (``wave``/``client-span``/
        ``aggregate``/``churn``/``buffer``/``arrival``) — the acceptance
        axis of the obs benchmark smoke."""
        out = set()
        for e in self._chrome:
            cat = e.get("cat")
            if cat in ("compute", "uplink"):
                out.add("client-span")
            elif cat:
                out.add(cat)
        return out

    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event JSON object."""
        return {"traceEvents": list(self._chrome),
                "displayTimeUnit": "ms",
                "otherData": {"clock": "simulated event seconds",
                              "schema": records_lib.SCHEMA_VERSION}}

    def export(self, path=None) -> str:
        """Write the trace JSON to ``path`` (default: the constructor's
        path) and return the path written."""
        path = self.path if path is None else os.fspath(path)
        if path is None:
            raise ValueError("TraceRecorder.export: no path given")
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def as_trace(trace) -> TraceRecorder | None:
    """``trace=`` engine argument -> a :class:`TraceRecorder` (a path-like
    opens a fresh recorder that exports there; an existing recorder passes
    through)."""
    if trace is None or isinstance(trace, TraceRecorder):
        return trace
    return TraceRecorder(trace)
