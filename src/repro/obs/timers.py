"""Phase timers: wall-clock scopes with compile time split from steady state.

JAX wall-clock numbers are bimodal — the first call of a jitted function
pays tracing + XLA compilation, every later call pays only execution — so a
single mean/median over a run conflates two different quantities. Every
benchmark in this repo needs the split (``benchmarks/common.timeit`` reports
it per-measurement), and the FL engines need it *per phase* so a 100-round
run can say "the bucketed uplink cost 80 µs steady after a 2.1 s compile".

:class:`PhaseTimers` keeps one :class:`PhaseStat` per named scope:

    timers = PhaseTimers()
    with timers.scope("uplink"):
        ...host work / dispatch...
    timers.summary()["uplink"]  # first_s vs steady_median_s

Scopes measure *host* wall time between ``__enter__`` and ``__exit__``. JAX
dispatch is asynchronous, so a scope that only enqueues device work charges
the wait to whichever later scope blocks (in the engines: telemetry and
eval, which pull values to the host). That is the honest accounting for a
host-driven loop — the first call still captures trace+compile time, which
is synchronous. ``NULL_TIMERS`` is a shared no-op sink so engine code can
always write ``with self.phase_timers.scope(...)`` without branching.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time

__all__ = ["PhaseStat", "PhaseTimers", "NULL_TIMERS", "resolve_timers"]


@dataclasses.dataclass
class PhaseStat:
    """Accumulated wall-clock samples of one named phase."""

    name: str
    first_s: float | None = None  # the first call: includes trace + compile
    steady_s: list = dataclasses.field(default_factory=list)  # later calls

    @property
    def calls(self) -> int:
        """Total number of completed scopes."""
        return (0 if self.first_s is None else 1) + len(self.steady_s)

    @property
    def total_s(self) -> float:
        """Wall-clock seconds across every call, first included."""
        return (self.first_s or 0.0) + sum(self.steady_s)

    def steady_median_s(self) -> float:
        """Median of the post-first calls (0.0 with fewer than two calls)."""
        if not self.steady_s:
            return 0.0
        ss = sorted(self.steady_s)
        n = len(ss)
        mid = n // 2
        return ss[mid] if n % 2 else 0.5 * (ss[mid - 1] + ss[mid])

    def record(self, seconds: float) -> None:
        """Add one completed scope's duration."""
        if self.first_s is None:
            self.first_s = seconds
        else:
            self.steady_s.append(seconds)


class PhaseTimers:
    """A bag of named :class:`PhaseStat` scopes (see module docstring)."""

    def __init__(self):
        self.phases: dict[str, PhaseStat] = {}

    @contextlib.contextmanager
    def scope(self, name: str):
        """Context manager timing one occurrence of phase ``name``."""
        stat = self.phases.get(name)
        if stat is None:
            stat = self.phases[name] = PhaseStat(name)
        t0 = time.perf_counter()
        try:
            yield stat
        finally:
            stat.record(time.perf_counter() - t0)

    def summary(self) -> dict:
        """JSON-ready per-phase summary: calls, first (compile) seconds,
        steady-state median/total seconds."""
        return {
            name: {
                "calls": st.calls,
                "first_s": st.first_s or 0.0,
                "steady_median_s": st.steady_median_s(),
                "steady_total_s": sum(st.steady_s),
                "total_s": st.total_s,
            }
            for name, st in self.phases.items()
        }

    def report(self) -> str:
        """Human-readable fixed-width table of :meth:`summary`."""
        lines = [f"{'phase':<14} {'calls':>5} {'first':>10} "
                 f"{'steady med':>10} {'total':>10}"]
        for name, s in self.summary().items():
            lines.append(
                f"{name:<14} {s['calls']:>5} {s['first_s'] * 1e3:>8.1f}ms "
                f"{s['steady_median_s'] * 1e3:>8.2f}ms "
                f"{s['total_s']:>9.2f}s")
        return "\n".join(lines)


class _NullTimers(PhaseTimers):
    """Shared do-nothing sink: ``scope`` costs one context switch and
    records nothing, so uninstrumented runs stay unperturbed."""

    @contextlib.contextmanager
    def scope(self, name: str):
        """No-op scope."""
        yield None


NULL_TIMERS = _NullTimers()


def resolve_timers(phase_timers) -> PhaseTimers:
    """``phase_timers=`` engine argument -> a usable sink (``None`` maps to
    the shared no-op)."""
    return NULL_TIMERS if phase_timers is None else phase_timers
