"""Procedural MNIST-like digit dataset (offline container — no downloads).

Deterministic 7-segment-style digit glyphs rendered into 28x28 float images
with per-sample jitter (translation, stroke intensity, pixel noise). Same
class structure as MNIST (10 digits); the paper's non-iid split (2 digits
per client, ~300 images each, 100 clients) is built on top in
``repro.fl.partition``. Learning curves are qualitatively comparable to
MNIST for the paper's 2conv+2fc CNN; this substitution is recorded in
DESIGN.md Sec. 6.
"""

from __future__ import annotations

import numpy as np

# segment -> (row0, row1, col0, col1) in a 20x12 glyph box
_SEGS = {
    "A": (0, 2, 1, 11),
    "B": (1, 10, 10, 12),
    "C": (10, 19, 10, 12),
    "D": (18, 20, 1, 11),
    "E": (10, 19, 0, 2),
    "F": (1, 10, 0, 2),
    "G": (9, 11, 1, 11),
}

_DIGIT_SEGS = {
    0: "ABCDEF",
    1: "BC",
    2: "ABGED",
    3: "ABGCD",
    4: "FGBC",
    5: "AFGCD",
    6: "AFGEDC",
    7: "ABC",
    8: "ABCDEFG",
    9: "ABCDFG",
}


def _glyph(digit: int) -> np.ndarray:
    g = np.zeros((20, 12), np.float32)
    for s in _DIGIT_SEGS[digit]:
        r0, r1, c0, c1 = _SEGS[s]
        g[r0:r1, c0:c1] = 1.0
    return g

_GLYPHS = np.stack([_glyph(d) for d in range(10)])


def make_dataset(n_per_class: int, seed: int = 0):
    """Returns (images (N,28,28) f32 in [0,1], labels (N,) int32), shuffled."""
    rng = np.random.default_rng(seed)
    imgs, labels = [], []
    for d in range(10):
        base = _GLYPHS[d]
        for _ in range(n_per_class):
            canvas = np.zeros((28, 28), np.float32)
            dy = rng.integers(0, 8)
            dx = rng.integers(0, 16)
            inten = rng.uniform(0.7, 1.0)
            canvas[dy : dy + 20, dx : dx + 12] = base * inten
            canvas += rng.normal(0.0, 0.12, (28, 28)).astype(np.float32)
            imgs.append(np.clip(canvas, 0.0, 1.0))
            labels.append(d)
    imgs = np.stack(imgs)
    labels = np.array(labels, np.int32)
    order = rng.permutation(len(labels))
    return imgs[order], labels[order]


def train_test(n_train_per_class: int = 600, n_test_per_class: int = 100, seed: int = 0):
    tr = make_dataset(n_train_per_class, seed=seed)
    te = make_dataset(n_test_per_class, seed=seed + 10_000)
    return tr, te
