from repro.data.synth_mnist import make_dataset, train_test
from repro.data.tokens import TokenStream
