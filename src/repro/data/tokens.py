"""Synthetic token pipeline for LM training drivers (offline container).

Deterministic, shardable stream with learnable structure: each next token is
an affine function of the previous one (mod vocab) with occasional uniform
noise — a pattern a small LM drives to low loss quickly, which makes e2e
training examples meaningful without any corpus on disk.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, noise: float = 0.05):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch_size
        self.noise = noise
        self._rng = np.random.default_rng(seed)
        # affine next-token rule, coprime multiplier
        self.a = 5
        self.b = 131

    def next_batch(self):
        rng = self._rng
        first = rng.integers(0, self.vocab, (self.batch, 1))
        seq = [first]
        for _ in range(self.seq_len):
            nxt = (seq[-1] * self.a + self.b) % self.vocab
            noise_mask = rng.random((self.batch, 1)) < self.noise
            rand = rng.integers(0, self.vocab, (self.batch, 1))
            seq.append(np.where(noise_mask, rand, nxt))
        arr = np.concatenate(seq, axis=1).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
