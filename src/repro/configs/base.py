"""Architecture / input-shape / run configuration schema and registry."""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "register", "get_config", "list_configs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    # rotary / attention
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 = full causal attention (training variant)
    attn_impl: str = "naive"  # "naive" | "blockwise" (flash-style online softmax)
    decode_window: int = 4096  # ring-buffer window used for long_500k decode
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0  # FFN width of the leading dense layers (MoE models)
    first_dense_layers: int = 0
    capacity_factor: float = 1.5
    aux_loss_coef: float = 0.01
    moe_impl: str = "dense"  # "dense" | "expert_parallel" (shard_map all_to_all)
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    expand: int = 2
    # hybrid (RG-LRU + local attention)
    attn_period: int = 0  # every attn_period-th block is local attention
    local_window: int = 0
    lru_width: int = 0  # 0 -> d_model
    # audio (enc-dec) / vlm frontends (stubs provide embeddings)
    encoder_layers: int = 0
    encoder_seq: int = 0
    n_patches: int = 0
    vision_dim: int = 0
    max_position: int = 8192  # learned-positional models only (audio)
    # misc
    tie_embeddings: bool = False
    scan_unroll: bool = False  # unroll layer scans (dry-run cost extraction)
    dtype: str = "bfloat16"
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_subquadratic(self) -> bool:
        """Can this config decode a 500k context? (constant/windowed state)"""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense-family archs run long_500k via the sliding-window variant
        return self.family in ("dense", "moe", "vlm")

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (CPU friendly)."""
        small = dict(
            n_layers=2,
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            max_position=512,
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=min(self.top_k, 2), moe_d_ff=128,
                         dense_d_ff=256,
                         first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            small.update(ssm_state=8)
        if self.attn_period:
            small.update(attn_period=self.attn_period, local_window=64, lru_width=128)
        if self.encoder_layers:
            small.update(encoder_layers=2, encoder_seq=64)
        if self.n_patches:
            small.update(n_patches=16, vision_dim=64)
        if self.sliding_window:
            small.update(sliding_window=64)
        small.update(decode_window=128)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from repro import configs as _c  # ensure submodules imported

        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
