"""Pixtral-12B — ViT frontend (STUB) + Mistral-Nemo-style decoder
[hf:mistralai/Pixtral-12B-2409].

The vision encoder is a stub per the brief: ``input_specs()`` supplies
precomputed patch embeddings (n_patches x vision_dim); the framework
implements the projector + 40-layer language decoder (GQA kv=8).
"""

from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        n_patches=256,
        vision_dim=1024,
        rope_theta=1e6,
        source="hf:mistralai/Pixtral-12B-2409",
    )
