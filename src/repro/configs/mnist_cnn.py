"""The paper's own FL model: 2xconv(k5) + 2xmaxpool(2) + 2xFC on 28x28
digits, ReLU hidden, log-softmax output, eta=0.01 (paper Sec. V)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class MnistCnnConfig:
    image_size: int = 28
    conv_channels: tuple = (10, 20)
    kernel: int = 5
    fc_hidden: int = 50
    n_classes: int = 10
    lr: float = 0.01


def config() -> MnistCnnConfig:
    return MnistCnnConfig()
