"""Whisper-large-v3 — encoder-decoder transformer [arXiv:2212.04356].

Conv/mel frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (encoder_seq=1500 x d_model). We implement the
32+32 layer enc-dec backbone (d_model 1280, 20 heads, full attention,
learned positions). long_500k is SKIPPED (enc-dec full attention; see
DESIGN.md Sec. 4).
"""

from repro.configs.base import ModelConfig, register


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        encoder_layers=32,
        encoder_seq=1500,
        max_position=40960,
        source="arXiv:2212.04356",
    )
