"""Falcon-Mamba-7B — attention-free Mamba-1 SSM [arXiv:2410.05355].

64 layers, d_model 4096, expand 2 (inner 8192), ssm_state 16, conv 4.
Constant-size recurrent state => runs long_500k decode natively.
"""

from repro.configs.base import ModelConfig, register


@register("falcon-mamba-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        vocab_size=65024,
        ssm_state=16,
        ssm_conv=4,
        expand=2,
        source="arXiv:2410.05355",
    )
