"""Config registry: one module per assigned architecture (+ the paper's CNN).

Importing this package registers every architecture; ``--arch <id>`` in the
launchers resolves through :func:`repro.configs.get_config`.
"""

from repro.configs.base import (
    ModelConfig,
    InputShape,
    INPUT_SHAPES,
    get_config,
    list_configs,
    register,
)

# architecture modules (registration side effects)
from repro.configs import kimi_k2_1t_a32b  # noqa: F401
from repro.configs import yi_6b  # noqa: F401
from repro.configs import pixtral_12b  # noqa: F401
from repro.configs import chatglm3_6b  # noqa: F401
from repro.configs import falcon_mamba_7b  # noqa: F401
from repro.configs import recurrentgemma_2b  # noqa: F401
from repro.configs import whisper_large_v3  # noqa: F401
from repro.configs import phi35_moe_42b_a66b  # noqa: F401
from repro.configs import qwen2_1_5b  # noqa: F401
from repro.configs import deepseek_coder_33b  # noqa: F401
from repro.configs import mnist_cnn  # noqa: F401

ARCH_IDS = [
    "kimi-k2-1t-a32b",
    "yi-6b",
    "pixtral-12b",
    "chatglm3-6b",
    "falcon-mamba-7b",
    "recurrentgemma-2b",
    "whisper-large-v3",
    "phi3.5-moe-42b-a6.6b",
    "qwen2-1.5b",
    "deepseek-coder-33b",
]
