"""Kimi K2 — trillion-parameter MoE (paper-table config) [arXiv:2501.kimi2].

61 layers, d_model 7168, 64 heads (GQA kv=8, head_dim 128), MoE with 384
experts top-8 (expert d_ff 2048) + 1 shared expert; the first layer is dense
(d_ff 18432, the DeepSeek-V3-style warm dense layer). Vocab 163840.
"""

from repro.configs.base import ModelConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=0,
        vocab_size=163840,
        n_experts=384,
        top_k=8,
        moe_d_ff=2048,
        n_shared_experts=1,
        dense_d_ff=18432,
        first_dense_layers=1,
        rope_theta=5e4,
        source="arXiv:2501.kimi2",
    )
