"""RecurrentGemma-2B — Griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427].

26 layers, pattern (rec, rec, attn) repeating; d_model 2560, 10 heads
(MQA kv=1), GeGLU d_ff 7680, local window 2048, vocab 256000.
"""

from repro.configs.base import ModelConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        attn_period=3,
        local_window=2048,
        lru_width=2560,
        source="arXiv:2402.19427",
    )
