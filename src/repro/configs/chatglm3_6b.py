"""ChatGLM3-6B — dense decoder, 2D-RoPE (partial rotary, fraction 0.5),
GQA kv=2, QKV bias [arXiv:2406.12793]."""

from repro.configs.base import ModelConfig, register


@register("chatglm3-6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rope_fraction=0.5,
        qkv_bias=True,
        source="arXiv:2406.12793",
    )
