"""Checkpointing: pytree <-> .npz with a json manifest (offline-friendly)."""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in flat]
    vals = [np.asarray(v) for _, v in flat]
    return keys, vals, treedef


def save(path: str, tree: Any, step: int = 0, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    keys, vals, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(path, "arrays.npz"), **{f"a{i}": v for i, v in enumerate(vals)})
    manifest = {"step": step, "keys": keys, "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys_like, vals_like, treedef = _flatten_with_paths(like)
    if manifest["keys"] != keys_like:
        raise ValueError("checkpoint structure mismatch")
    vals = [data[f"a{i}"].astype(v.dtype) for i, v in enumerate(vals_like)]
    import jax.numpy as jnp

    tree = jax.tree_util.tree_unflatten(treedef, [jnp.asarray(v) for v in vals])
    return tree, manifest["step"]
