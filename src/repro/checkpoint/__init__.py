from repro.checkpoint.io import save, restore
