"""Mamba-1 selective SSM (falcon-mamba family) — attention-free decoder.

Block: RMSNorm -> in_proj (D -> 2*Di) -> [x: causal depthwise conv(k=4) ->
SiLU -> selective scan] * SiLU(z) -> out_proj (Di -> D).

Selective scan (parallel form): per token t and channel c,
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t      (state N per channel)
    y_t = C_t . h_t + D_skip * x_t
computed with ``jax.lax.associative_scan`` over the sequence; decode keeps a
constant-size state (B, Di, N) + conv window (B, K-1, Di) — O(1) per token,
which is what makes ``long_500k`` native for this family.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_params(key, cfg):
    dtype = L.dtype_of(cfg)
    D = cfg.d_model
    Di = cfg.expand * D
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    K = cfg.ssm_conv

    def layer(k):
        ks = jax.random.split(k, 8)
        A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Di, 1))
        return {
            "ln": jnp.zeros((D,), dtype),
            "in_proj": L.dense_init(ks[0], (D, 2 * Di), dtype=dtype),
            "conv_w": (jax.random.normal(ks[1], (K, Di), jnp.float32) * 0.1).astype(dtype),
            "conv_b": jnp.zeros((Di,), dtype),
            "x_proj": L.dense_init(ks[2], (Di, R + 2 * N), dtype=dtype),
            "dt_proj": L.dense_init(ks[3], (R, Di), dtype=dtype),
            "dt_bias": jnp.full((Di,), -4.0, jnp.float32),  # softplus ~ 0.018
            "A_log": jnp.log(A),
            "D_skip": jnp.ones((Di,), jnp.float32),
            "out_proj": L.dense_init(ks[4], (Di, D), dtype=dtype),
        }

    ks = jax.random.split(key, 3)
    lk = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.embed_init(ks[1], (cfg.vocab_size, D), dtype),
        "layers": jax.vmap(layer)(lk),
        "final_norm": jnp.zeros((D,), dtype),
        "lm_head": L.dense_init(ks[2], (D, cfg.vocab_size), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,Di); w: (K,Di)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, j : j + x.shape[1]].astype(jnp.float32) * w[j].astype(jnp.float32)
              for j in range(K))
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_scan(xc, p, cfg, h0=None):
    """Selective scan. xc: (B,S,Di) post-conv. Returns (y, h_last)."""
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", proj[..., :R], p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"]
    )  # (B,S,Di)
    Bm = proj[..., R : R + N]  # (B,S,N)
    Cm = proj[..., R + N :]  # (B,S,N)
    A = -jnp.exp(p["A_log"])  # (Di,N)
    xf = xc.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A)  # (B,S,Di,N)
    b = (dt * xf)[..., None] * Bm[..., None, :]  # (B,S,Di,N)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(comb, (a, b), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm) + p["D_skip"] * xf
    return y.astype(xc.dtype), hs[:, -1]


def _block(x, p, cfg):
    h = L.rmsnorm(x, p["ln"])
    Di = cfg.expand * cfg.d_model
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    xb, z = xz[..., :Di], xz[..., Di:]
    xb = _causal_conv(xb, p["conv_w"], p["conv_b"])
    xb = jax.nn.silu(xb.astype(jnp.float32)).astype(x.dtype)
    y, _ = _ssm_scan(xb, p, cfg)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    x = L.maybe_shard(x, ("pod", "data"), None, None)  # see transformer._embed_tokens

    def body(carry, pl):
        return _block(carry, pl, cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"], unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg):
    from repro.models.transformer import _gold_logit

    logits, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(lse - _gold_logit(logits, labels))


def init_cache(cfg, batch_size: int, cache_len: int = 0, dtype=None):
    """Constant-size state: cache_len is ignored (kept for API parity)."""
    dtype = dtype or L.dtype_of(cfg)
    Di = cfg.expand * cfg.d_model
    return {
        "h": jnp.zeros((cfg.n_layers, batch_size, Di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1, Di), dtype),
    }


def decode_step(params, cache, tokens, pos, cfg, *, ring: bool = False):
    x = params["embed"][tokens]  # (B,1,D)
    x = L.maybe_shard(x, ("pod", "data"), None, None)
    Di = cfg.expand * cfg.d_model
    N = cfg.ssm_state
    R = _dt_rank(cfg)

    def body(carry, inp):
        h = carry
        pl, hstate, conv = inp
        hh = L.rmsnorm(h, pl["ln"])
        xz = jnp.einsum("btd,de->bte", hh, pl["in_proj"])[:, 0]
        xb, z = xz[..., :Di], xz[..., Di:]
        win = jnp.concatenate([conv, xb[:, None]], axis=1)  # (B,K,Di)
        w = pl["conv_w"].astype(jnp.float32)
        xc = (jnp.sum(win.astype(jnp.float32) * w[None], axis=1)
              + pl["conv_b"].astype(jnp.float32))
        xc = jax.nn.silu(xc)
        proj = (xc @ pl["x_proj"].astype(jnp.float32))
        dt = jax.nn.softplus(proj[..., :R] @ pl["dt_proj"].astype(jnp.float32) + pl["dt_bias"])
        Bm = proj[..., R : R + N]
        Cm = proj[..., R + N :]
        A = -jnp.exp(pl["A_log"])
        a = jnp.exp(dt[..., None] * A)  # (B,Di,N)
        hnew = a * hstate + (dt * xc)[..., None] * Bm[:, None, :]
        y = jnp.einsum("bdn,bn->bd", hnew, Cm) + pl["D_skip"] * xc
        y = y * jax.nn.silu(z.astype(jnp.float32))
        out = jnp.einsum("be,ed->bd", y.astype(h.dtype), pl["out_proj"])
        return h + out[:, None], (hnew, win[:, 1:])

    x, (hs, convs) = jax.lax.scan(body, x, (params["layers"], cache["h"], cache["conv"]), unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"]).astype(jnp.float32)
    return logits, {"h": hs, "conv": convs}
