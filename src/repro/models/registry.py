"""Family -> implementation dispatch + input specs for every shape."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import audio, ssm, transformer


def family_module(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        return transformer
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "audio":
        return audio
    raise ValueError(cfg.family)


def init_params(key, cfg: ModelConfig):
    return family_module(cfg).init_params(key, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    return family_module(cfg).loss_fn(params, batch, cfg)


def forward(params, batch, cfg: ModelConfig):
    return family_module(cfg).forward(params, batch, cfg)


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int):
    return family_module(cfg).init_cache(cfg, batch_size, cache_len)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, *, ring=False):
    return family_module(cfg).decode_step(params, cache, tokens, pos, cfg, ring=ring)


def uses_ring_cache(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k decodes through ring (sliding-window) caches."""
    return shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm")


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    if uses_ring_cache(cfg, shape):
        return cfg.decode_window
    if cfg.family == "hybrid":
        return min(shape.seq_len, cfg.local_window)
    return shape.seq_len


def supports_shape(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-not). The skip list documented in DESIGN.md."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return False, ("enc-dec full attention; decoder spec'd <=448 positions, "
                           "500k-token transcript decode has no analogue (DESIGN.md Sec.4)")
        return True, ""
    if shape.kind == "decode" and cfg.family == "audio":
        return True, ""  # decoder-with-cache exists
    return True, ""


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": sd((B, S), i32),
        }
        if shape.kind == "train":
            specs["labels"] = sd((B, S), i32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = sd((B, cfg.n_patches, cfg.vision_dim), f32)
        if cfg.family == "audio":
            specs["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), f32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sd((B, 1), i32)}


def make_batch(cfg: ModelConfig, shape: InputShape, key) -> dict:
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    specs = input_specs(cfg, shape)
    ks = jax.random.split(key, len(specs))
    out = {}
    for (name, spec), k in zip(sorted(specs.items()), ks):
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out
