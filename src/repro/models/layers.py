"""Shared neural-net building blocks (pure JAX, pytree params).

Conventions:
* params are nested dicts of jnp arrays; layer-stacked leaves have a leading
  L dimension and are consumed by ``jax.lax.scan``.
* compute dtype is bf16 (configurable), normalizations and softmax in f32.
* initializers take explicit PRNG keys (no global state).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.bfloat16):
    """LeCun-normal in f32, cast to param dtype."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, fraction: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary half-pairs actually rotated."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float, theta: float):
    """Rotary embedding on the leading ``fraction`` of the head dim.

    x: (..., S, H, hd); positions: broadcastable to (..., S).
    ``fraction < 1`` implements partial rotary (e.g. ChatGLM's 2D-RoPE uses
    half the head dim; the rest passes through unrotated).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, fraction, theta)
    rot = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    # NOTE: reshape+slice, NOT xr[..., 0::2] — strided indexing lowers to a
    # stablehlo.gather whose SPMD partitioning check-crashes XLA (see
    # transformer._embed_tokens); the reshaped pair-slice lowers to plain
    # slices and partitions cleanly.
    xp = xr.reshape(*xr.shape[:-1], rot // 2, 2)
    x1 = xp[..., 0]
    x2 = xp[..., 1]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), x[..., rot:]], axis=-1)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, wi)
    g = jnp.einsum("...d,df->...f", x, wg)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h, wo)


def gelu_mlp(x: jax.Array, wi: jax.Array, bi, wo: jax.Array, bo) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, wi) + bi
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, wo) + bo


def sinusoidal_positions(max_len: int, dim: int) -> jax.Array:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / dim))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def unstack_tree(params: Params, idx: int) -> Params:
    """Take layer ``idx`` from a stacked param tree (for unrolled loops)."""
    return jax.tree_util.tree_map(lambda p: p[idx], params)


def maybe_shard(x: jax.Array, *axes_per_dim) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op off-mesh.

    Each entry is an axis name, tuple of names, or None. Axes absent from
    the ambient abstract mesh, or not dividing the dim, are dropped — so
    model code can carry sharding hints without knowing the launch config
    (smoke tests run mesh-less and skip the constraint entirely).
    """
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    # usable axes: present AND not manual (inside shard_map, manual axes are
    # already collapsed out of the local view)
    usable = {
        name for name, ty in zip(mesh.axis_names, mesh.axis_types)
        if "Manual" not in str(ty)
    }
    spec = []
    for dim, axes in zip(x.shape, axes_per_dim):
        if axes is None:
            spec.append(None)
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        ax = tuple(a for a in ax if a in usable)
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        spec.append(ax if ax and dim % n == 0 and dim >= n else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
