"""Whisper-style encoder-decoder backbone (audio family).

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings (B, encoder_seq,
d_model). We implement the transformer backbone: pre-LN encoder (full
bidirectional attention, sinusoidal positions) and decoder (causal self
attention + cross attention to the encoder output, learned positions, GELU
MLPs, biased projections — the standard Whisper recipe).

Decode caches: per-layer self-attn KV (ring or full) plus the cross-attn K/V
computed once from the encoder output at prefill time. ``long_500k`` is
skipped for this family (see DESIGN.md Sec. 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L


def _init_ln(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _ln(x, p):
    return L.layernorm(x, p["scale"], p["bias"])


def _init_mha(key, cfg, dtype):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], (D, H * hd), dtype=dtype),
        "bq": jnp.zeros((H * hd,), dtype),
        "wk": L.dense_init(ks[1], (D, H * hd), dtype=dtype),
        "wv": L.dense_init(ks[2], (D, H * hd), dtype=dtype),
        "bv": jnp.zeros((H * hd,), dtype),
        "wo": L.dense_init(ks[3], (H * hd, D), dtype=dtype),
        "bo": jnp.zeros((D,), dtype),
    }


def _init_mlp(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "wi": L.dense_init(k1, (D, F), dtype=dtype),
        "bi": jnp.zeros((F,), dtype),
        "wo": L.dense_init(k2, (F, D), dtype=dtype),
        "bo": jnp.zeros((D,), dtype),
    }


def init_params(key, cfg):
    dtype = L.dtype_of(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _init_ln(D, dtype),
            "attn": _init_mha(k1, cfg, dtype),
            "ln2": _init_ln(D, dtype),
            "mlp": _init_mlp(k2, cfg, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _init_ln(D, dtype),
            "self_attn": _init_mha(k1, cfg, dtype),
            "ln_x": _init_ln(D, dtype),
            "cross_attn": _init_mha(k2, cfg, dtype),
            "ln2": _init_ln(D, dtype),
            "mlp": _init_mlp(k3, cfg, dtype),
        }

    return {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, D), dtype),
        "pos_embed": L.embed_init(ks[1], (cfg.max_position, D), dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[2], cfg.encoder_layers)),
        "enc_norm": _init_ln(D, dtype),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[3], cfg.n_layers)),
        "dec_norm": _init_ln(D, dtype),
    }


def _mha(x, kv, p, cfg, causal):
    """x: (B,Sq,D) queries; kv: (B,Sk,D) keys/values source."""
    B, Sq, D = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    q = (jnp.einsum("bsd,dh->bsh", x, p["wq"]) + p["bq"]).reshape(B, Sq, H, hd)
    k = jnp.einsum("bsd,dh->bsh", kv, p["wk"]).reshape(B, kv.shape[1], H, hd)
    v = (jnp.einsum("bsd,dh->bsh", kv, p["wv"]) + p["bv"]).reshape(B, kv.shape[1], H, hd)
    o = A.attend(q, k, v, causal=causal, impl=cfg.attn_impl)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, Sq, -1), p["wo"]) + p["bo"]


def encode(params, frames, cfg):
    """frames: (B, encoder_seq, D) stub frontend embeddings."""
    D = cfg.d_model
    pos = L.sinusoidal_positions(frames.shape[1], D).astype(frames.dtype)
    x = frames + pos[None]

    def body(carry, pl):
        h = carry
        h = h + _mha(_ln(h, pl["ln1"]), _ln(h, pl["ln1"]), pl["attn"], cfg, causal=False)
        h = h + L.gelu_mlp(_ln(h, pl["ln2"]), pl["mlp"]["wi"], pl["mlp"]["bi"],
                           pl["mlp"]["wo"], pl["mlp"]["bo"])
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"], unroll=cfg.scan_unroll)
    return _ln(x, params["enc_norm"])


def forward(params, batch, cfg):
    """batch: frames (B, enc_seq, D) + tokens/labels (B, S)."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][:S][None]
    x = L.maybe_shard(x, ("pod", "data"), None, None)  # see transformer._embed_tokens

    def body(carry, pl):
        h = carry
        h = h + _mha(_ln(h, pl["ln1"]), _ln(h, pl["ln1"]), pl["self_attn"], cfg, causal=True)
        h = h + _mha(_ln(h, pl["ln_x"]), enc, pl["cross_attn"], cfg, causal=False)
        h = h + L.gelu_mlp(_ln(h, pl["ln2"]), pl["mlp"]["wi"], pl["mlp"]["bi"],
                           pl["mlp"]["wo"], pl["mlp"]["bo"])
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = _ln(x, params["dec_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg):
    from repro.models.transformer import _gold_logit

    logits, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(lse - _gold_logit(logits, labels))


def init_cache(cfg, batch_size: int, cache_len: int, dtype=None):
    dtype = dtype or L.dtype_of(cfg)
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    nL = cfg.n_layers
    return {
        "k": jnp.zeros((nL, batch_size, cache_len, H, hd), dtype),
        "v": jnp.zeros((nL, batch_size, cache_len, H, hd), dtype),
        # cross-attention K/V precomputed from the encoder output at prefill
        "xk": jnp.zeros((nL, batch_size, cfg.encoder_seq, H, hd), dtype),
        "xv": jnp.zeros((nL, batch_size, cfg.encoder_seq, H, hd), dtype),
    }


def decode_step(params, cache, tokens, pos, cfg, *, ring: bool = False):
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    x = params["embed"][tokens] + params["pos_embed"][pos][None, None]
    x = L.maybe_shard(x, ("pod", "data"), None, None)

    def body(carry, inp):
        h = carry
        pl, kc, vc, xk, xv = inp
        # self attention with cache
        hn = _ln(h, pl["ln1"])
        sa = pl["self_attn"]
        q = (jnp.einsum("btd,dh->bth", hn, sa["wq"]) + sa["bq"]).reshape(B, 1, H, hd)
        k = jnp.einsum("btd,dh->bth", hn, sa["wk"]).reshape(B, 1, H, hd)
        v = (jnp.einsum("btd,dh->bth", hn, sa["wv"]) + sa["bv"]).reshape(B, 1, H, hd)
        if ring:
            kc, vc = A.update_cache_ring(kc, vc, k, v, pos)
            o = A.decode_attend_ring(q, kc, vc, pos)
        else:
            kc, vc = A.update_cache_full(kc, vc, k, v, pos)
            o = A.decode_attend_full(q, kc, vc, pos)
        h = h + (jnp.einsum("bth,hd->btd", o.reshape(B, 1, -1), sa["wo"]) + sa["bo"]).astype(h.dtype)
        # cross attention against precomputed encoder K/V
        hx = _ln(h, pl["ln_x"])
        ca = pl["cross_attn"]
        qx = (jnp.einsum("btd,dh->bth", hx, ca["wq"]) + ca["bq"]).reshape(B, 1, H, hd)
        ox = A.attend_train(qx, xk, xv, causal=False)
        h = h + (jnp.einsum("bth,hd->btd", ox.reshape(B, 1, -1), ca["wo"]) + ca["bo"]).astype(h.dtype)
        h = h + L.gelu_mlp(_ln(h, pl["ln2"]), pl["mlp"]["wi"], pl["mlp"]["bi"],
                           pl["mlp"]["wo"], pl["mlp"]["bo"])
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll,
    )
    x = _ln(x, params["dec_norm"])
    logits = jnp.einsum("btd,vd->btv", x, params["embed"]).astype(jnp.float32)
    return logits, dict(cache, k=ks, v=vs)
