"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-native dispatch (MaxText/MegaBlocks-style, no (T, E, C) one-hot blowup):

  1. route: softmax router, ``lax.top_k`` -> (T, K) experts + weights
  2. sort the T*K assignments by expert id
  3. position-in-run via an associative max-scan (no one-hot)
  4. scatter tokens into an (E, C, D) buffer (capacity C static), dropping
     overflow (capacity factor configurable)
  5. batched expert matmuls (E-dim shardable as expert-parallel)
  6. gather back, combine with routing weights (dropped slots contribute 0)

A load-balance auxiliary loss (Switch-style) is returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe(key, cfg, dtype):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (D, E), dtype=jnp.float32),
        "wi": L.dense_init(ks[1], (E, D, F), in_axis=-2, dtype=dtype),
        "wg": L.dense_init(ks[2], (E, D, F), in_axis=-2, dtype=dtype),
        "wo": L.dense_init(ks[3], (E, F, D), in_axis=-2, dtype=dtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": L.dense_init(kk[0], (D, Fs), dtype=dtype),
            "wg": L.dense_init(kk[1], (D, Fs), dtype=dtype),
            "wo": L.dense_init(kk[2], (Fs, D), dtype=dtype),
        }
    return p


def capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    # keep the expert batch MXU-friendly but never above the token count
    c = min(max(c, 8), n_tokens)
    return c


def moe_ffn(x: jax.Array, p, cfg):
    """x: (..., D) -> (out (..., D), aux_loss scalar f32)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    T = x2.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)

    logits = (x2.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)  # (T,K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss.
    density = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0
    )
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    flat_e = topi.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = topv.reshape(-1)

    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]

    # position within each expert's contiguous run (associative max-scan)
    n = T * K
    ar = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(change, ar, 0))
    pos = ar - run_start
    keep = pos < C
    slot_c = jnp.where(keep, pos, C)  # column C is the overflow trash slot

    # (E, C+1, D): the expert dim stays explicit (expert-parallel shardable);
    # column C is a trash slot for capacity overflow. NOTE: under pjit, XLA
    # replicates these data-dependent scatter/gather buffers across shards
    # (measured ~1 TiB/device temp on kimi-k2 train_4k) — the shard-local
    # all_to_all dispatch in ``moe_ffn_shardmap`` is the production fix;
    # this dense form is the recorded baseline (EXPERIMENTS.md Sec. Perf).
    buf = jnp.zeros((E, C + 1, D), x.dtype).at[se, slot_c].set(x2[st])
    h = buf[:, :C]
    hi = jnp.einsum("ecd,edf->ecf", h, p["wi"])
    hg = jnp.einsum("ecd,edf->ecf", h, p["wg"])
    act = jax.nn.silu(hg.astype(jnp.float32)).astype(hi.dtype) * hi
    y = jnp.einsum("ecf,efd->ecd", act, p["wo"])
    y = jnp.concatenate([y, jnp.zeros((E, 1, D), y.dtype)], axis=1)

    contrib = y[se, slot_c] * sw[:, None].astype(y.dtype)
    out = jnp.zeros((T, D), x.dtype).at[st].add(contrib)

    if cfg.n_shared_experts:
        s = p["shared"]
        out = out + L.swiglu(x2, s["wi"], s["wg"], s["wo"])
    return out.reshape(orig_shape), aux


# ------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map + all_to_all (the production path).
#
# Under plain pjit, the data-dependent scatter/gather through the (E, C, D)
# dispatch buffers defeats XLA's sharding propagation: it replicates the
# buffers across shards (~1 TiB/device temp measured on kimi-k2 train_4k).
# This variant makes the communication pattern explicit: tokens are routed
# locally on each data shard, exchanged with the expert-owner shards by a
# pair of all_to_alls, and each shard runs only its E/n_d experts — the
# canonical expert-parallel schedule (Switch/DeepSpeed-MoE), expressed in
# jax.shard_map over the data axes with the tensor axis left auto.
# ------------------------------------------------------------------------


def _usable_data_axes(cfg):
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return (), 1
    manual = {n for n, t in zip(mesh.axis_names, mesh.axis_types)
              if "Manual" in str(t)}
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names and a not in manual)
    nd = 1
    for a in axes:
        nd *= mesh.shape[a]
    return axes, nd


def _local_dispatch(x2, p, cfg, C):
    """Route + scatter local tokens into an (E, C, D) buffer. Returns
    (buf, se, slot_c, st, sw, aux)."""
    T, D = x2.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x2.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(density * jnp.mean(probs, axis=0))

    flat_e = topi.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    n = T * K
    ar = jnp.arange(n, dtype=jnp.int32)
    change = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(change, ar, 0))
    pos = ar - run_start
    slot_c = jnp.where(pos < C, pos, C)
    buf = jnp.zeros((E, C + 1, D), x2.dtype).at[se, slot_c].set(x2[st])
    return buf[:, :C], se, slot_c, st, sw, aux


def moe_ffn_shardmap(x: jax.Array, p, cfg):
    """Expert-parallel MoE: (B, S, D) -> (out, aux). Falls back to the dense
    dispatch when no auto data axes exist (e.g. inside the per-client
    uplink shard_map, where experts are replicated per client cohort)."""
    from repro.compat import LEGACY_JAX

    axes, nd = _usable_data_axes(cfg)
    E = cfg.n_experts
    if not axes or nd == 1 or E % nd != 0 or x.ndim != 3 or x.shape[0] % nd != 0:
        return moe_ffn(x, p, cfg)
    if LEGACY_JAX:
        # Legacy XLA crashes on tiled all_to_all inside a partial-manual
        # shard_map (spmd_partitioner IsManualSubgroup CHECK); use the dense
        # dispatch there — numerically identical, just without the
        # expert-parallel communication schedule.
        return moe_ffn(x, p, cfg)
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E_loc = E // nd
    T_l = (B // nd) * S
    C = capacity(T_l, cfg)

    def local(xl, router, wi_l, wg_l, wo_l):
        Bl = xl.shape[0]
        x2 = xl.reshape(-1, D)
        buf, se, slot_c, st, sw, aux = _local_dispatch(
            x2, {"router": router}, cfg, C)
        # keep the dispatch buffers sharded over the (auto) tensor axis: the
        # per-shard (E, C, D) buffer can exceed 2^31 elements at kimi-k2
        # scale, which breaks XLA CPU if propagation replicates it
        buf = L.maybe_shard(buf, None, None, "model")
        # exchange with expert owners (tiled all_to_all: (E,C,D)->(E/nd,nd*C,D))
        h = jax.lax.all_to_all(buf, axes, split_axis=0, concat_axis=1, tiled=True)
        h = L.maybe_shard(h, None, None, "model")
        # f32 expert compute: with D model-sharded, the contractions (and
        # their VJPs) emit partial-sum all-reduces; f32 matches MXU
        # accumulate practice and sidesteps an XLA CPU AllReducePromotion
        # check-crash on large bf16 copy-reduction ARs. The all_to_all
        # payloads on either side stay bf16.
        h32 = h.astype(jnp.float32)
        hi = jnp.einsum("ecd,edf->ecf", h32, wi_l.astype(jnp.float32))
        hg = jnp.einsum("ecd,edf->ecf", h32, wg_l.astype(jnp.float32))
        act = jax.nn.silu(hg) * hi
        y = jnp.einsum("ecf,efd->ecd", act, wo_l.astype(jnp.float32)).astype(h.dtype)
        y = L.maybe_shard(y, None, None, "model")
        y_loc = jax.lax.all_to_all(y, axes, split_axis=1, concat_axis=0, tiled=True)
        y_loc = L.maybe_shard(y_loc, None, None, "model")
        y_pad = jnp.concatenate([y_loc, jnp.zeros((E, 1, D), y_loc.dtype)], axis=1)
        contrib = y_pad[se, slot_c] * sw[:, None].astype(y_loc.dtype)
        out = jnp.zeros_like(x2).at[st].add(contrib)
        aux = jax.lax.pmean(aux, axes)
        return out.reshape(Bl, S, D), aux

    fn = jax.shard_map(
        local,
        axis_names=set(axes),
        in_specs=(P(axes, None, None), P(), P(axes, None, None),
                  P(axes, None, None), P(axes, None, None)),
        out_specs=(P(axes, None, None), P()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], p["wi"], p["wg"], p["wo"])
    if cfg.n_shared_experts:
        # routing-independent: computed at the pjit level. Keeping replicated
        # bf16 params out of the shard_map also avoids an XLA CPU
        # AllReducePromotion crash on their cotangent psum (copy-reduction AR).
        s_ = p["shared"]
        out = out + L.swiglu(x.reshape(-1, D), s_["wi"], s_["wg"], s_["wo"]).reshape(x.shape)
    return out, aux
