"""GQA attention: training (full / sliding-window / local) and cached decode.

* ``attend_train``: full causal, sliding-window causal, or non-causal
  (whisper encoder / cross attention) over (B, S, H, hd) projections.
* ``decode_attend``: one-token decode against a KV cache. Full-attention
  caches are (B, S_max, KVH, hd) with positions < ``pos`` valid.
  Sliding-window caches are ring buffers (B, W, KVH, hd) indexed ``pos % W``
  — this is what makes ``long_500k`` (524288-token context) feasible: the
  live cache is O(window), not O(context).

Softmax is computed in f32; logits scaled by 1/sqrt(hd).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scores(q, k):  # q (B,Sq,H,hd) k (B,Sk,KVH,hd) -> (B,H,Sq,Sk)
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    qg = q.reshape(B, Sq, KVH, rep, hd)
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(B, KVH * rep, Sq, k.shape[1]) / math.sqrt(hd)


def _combine(p, v, H):  # p (B,H,Sq,Sk), v (B,Sk,KVH,hd) -> (B,Sq,H,hd)
    B, _, Sq, Sk = p.shape
    KVH = v.shape[2]
    rep = H // KVH
    pg = p.reshape(B, KVH, rep, Sq, Sk)
    o = jnp.einsum("bgrqk,bkgh->bqgrh", pg, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1])


def attend_train(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Full-materialized attention. window>0 adds a sliding-window mask."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    s = _scores(q, k)
    if causal or window:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _combine(p, v, H).astype(q.dtype)


def attend_train_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jax.Array:
    """Flash-style blockwise attention with online softmax (pure JAX).

    Never materializes the (Sq, Sk) score matrix: peak live set per layer is
    O(block_q x block_kv) scores + O(Sq x hd) accumulators. This is the
    XLA-level equivalent of flash attention (MaxText-style) and is the
    memory-term hillclimb lever for the roofline (Sec. Perf). FLOPs match
    full attention (masked blocks are still computed — acceptable at S=4k,
    and XLA cannot skip data-dependent blocks inside scan anyway).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KVH = k.shape[2]
    rep = H // KVH
    assert Sq % block_q == 0 and Sk % block_kv == 0, (Sq, Sk, block_q, block_kv)
    nq, nk = Sq // block_q, Sk // block_kv
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(B, nq, block_q, KVH, rep, hd)
    kb = k.reshape(B, nk, block_kv, KVH, hd)
    vb = v.reshape(B, nk, block_kv, KVH, hd)
    offs = Sk - Sq  # query positions offset (prefill: 0)

    def q_block(qi, i):
        # qi: (B, block_q, KVH, rep, hd); i: () block index
        qpos = i * block_q + jnp.arange(block_q)[:, None] + offs
        m0 = jnp.full((B, KVH, rep, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, rep, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, rep, block_q, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            kpos = j * block_kv + jnp.arange(block_kv)[None, :]
            s = jnp.einsum(
                "bqgrh,bkgh->bgrqk", qi.astype(jnp.float32), kj.astype(jnp.float32)
            ) * scale  # (B,KVH,rep,bq,bk)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,KVH,rep,bq,hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B,bq,KVH,rep,hd)

    ob = jax.vmap(q_block, in_axes=(1, 0), out_axes=1)(
        qb, jnp.arange(nq))  # (B,nq,bq,KVH,rep,hd)
    return ob.reshape(B, Sq, H, hd).astype(q.dtype)


def _pick_block(seq: int, target: int) -> int:
    """Largest power-of-two-ish divisor of ``seq`` not above ``target``."""
    for b in (target, target // 2, target // 4, target // 8, 64, 32):
        if b and seq % b == 0:
            return b
    return 0


def attend(q, k, v, *, causal=True, window=0, impl="naive",
           block_q=512, block_kv=1024):
    if impl == "blockwise":
        bq = _pick_block(q.shape[1], block_q)
        bk = _pick_block(k.shape[1], block_kv)
        if bq and bk:
            return attend_train_blockwise(q, k, v, causal=causal, window=window,
                                          block_q=bq, block_kv=bk)
    return attend_train(q, k, v, causal=causal, window=window)


def decode_attend_full(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S_max, KVH, hd)
    v_cache: jax.Array,
    pos: jax.Array,  # () int32 -- current position (0-based)
) -> jax.Array:
    s = _scores(q, k_cache)  # (B,H,1,S_max)
    valid = jnp.arange(k_cache.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _combine(p, v_cache, q.shape[2]).astype(q.dtype)


def decode_attend_ring(
    q: jax.Array,  # (B, 1, H, hd)
    k_ring: jax.Array,  # (B, W, KVH, hd) ring buffer
    v_ring: jax.Array,
    pos: jax.Array,  # () int32
) -> jax.Array:
    """Sliding-window decode: slots with ring_pos > pos - W are live."""
    W = k_ring.shape[1]
    s = _scores(q, k_ring)  # (B,H,1,W)
    slot = jnp.arange(W)
    # absolute position currently stored in each slot
    cycle = (pos // W) * W
    abs_pos = jnp.where(slot <= (pos % W), cycle + slot, cycle - W + slot)
    valid = (abs_pos >= 0) & (abs_pos >= pos - W + 1) & (abs_pos <= pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _combine(p, v_ring, q.shape[2]).astype(q.dtype)


def update_cache_full(k_cache, v_cache, k_new, v_new, pos):
    """Insert one token's K/V at ``pos``. k_new: (B, 1, KVH, hd)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


def update_cache_ring(k_ring, v_ring, k_new, v_new, pos):
    W = k_ring.shape[1]
    slot = pos % W
    k_ring = jax.lax.dynamic_update_slice_in_dim(k_ring, k_new.astype(k_ring.dtype), slot, axis=1)
    v_ring = jax.lax.dynamic_update_slice_in_dim(v_ring, v_new.astype(v_ring.dtype), slot, axis=1)
    return k_ring, v_ring
