"""Decoder-only transformer families: dense, moe, vlm, hybrid.

One implementation covers:
* ``dense``  — llama-style: RMSNorm, RoPE (optionally partial), GQA,
  SwiGLU; optional QKV bias (qwen2/chatglm), optional sliding window.
* ``moe``    — same attention; FFN replaced by top-k expert routing
  (``repro.models.moe``), optional leading dense layers + shared experts.
* ``vlm``    — dense decoder consuming a projected patch-embedding prefix
  (vision encoder is a stub per the brief).
* ``hybrid`` — Griffin/RecurrentGemma: RG-LRU recurrent blocks with a local
  sliding-window attention block every ``attn_period`` layers; layers are
  scanned in stacked (rec, ..., rec, attn) groups with an unscanned tail.

Uniform-layer families are scanned (``lax.scan`` over stacked params) to
keep HLO size O(1) in depth — essential for the 61-layer 1T-param dry-run.

API (used by launchers, smoke tests and the dry-run):
    init_params(key, cfg)                       -> params
    forward(params, batch, cfg)                 -> (logits, aux_loss)
    loss_fn(params, batch, cfg)                 -> scalar loss
    init_cache(cfg, batch, cache_len)           -> cache
    decode_step(params, cache, tokens, pos, cfg)-> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE

Params = Any


# ---------------------------------------------------------------- params


def _init_attn(key, cfg, dtype):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (D, H * hd), dtype=dtype),
        "wk": L.dense_init(ks[1], (D, KVH * hd), dtype=dtype),
        "wv": L.dense_init(ks[2], (D, KVH * hd), dtype=dtype),
        "wo": L.dense_init(ks[3], (H * hd, D), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KVH * hd,), dtype)
        p["bv"] = jnp.zeros((KVH * hd,), dtype)
    return p


def _init_mlp(key, cfg, dtype, d_ff):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "wi": L.dense_init(ks[0], (D, d_ff), dtype=dtype),
        "wg": L.dense_init(ks[1], (D, d_ff), dtype=dtype),
        "wo": L.dense_init(ks[2], (d_ff, D), dtype=dtype),
    }


def _init_dense_layer(key, cfg, dtype, d_ff=None):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn(k1, cfg, dtype),
        "mlp": _init_mlp(k2, cfg, dtype, d_ff or cfg.d_ff),
    }


def _init_moe_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn(k1, cfg, dtype),
        "moe": MOE.init_moe(k2, cfg, dtype),
    }


def _init_rglru_block(key, cfg, dtype):
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 7)
    return {
        "ln1": jnp.zeros((D,), dtype),
        "ln2": jnp.zeros((D,), dtype),
        "rec": {
            "w_x": L.dense_init(ks[0], (D, W), dtype=dtype),
            "w_gate": L.dense_init(ks[1], (D, W), dtype=dtype),
            "conv_w": (jax.random.normal(ks[2], (4, W), jnp.float32) * 0.1).astype(dtype),
            "w_r": L.dense_init(ks[3], (W, W), dtype=dtype),
            "w_i": L.dense_init(ks[4], (W, W), dtype=dtype),
            "lam": jnp.full((W,), 2.0, jnp.float32),  # softplus-param of decay
            "w_out": L.dense_init(ks[5], (W, D), dtype=dtype),
        },
        "mlp": _init_mlp(ks[6], cfg, dtype, cfg.d_ff),
    }


def _stack(keys, fn):
    return jax.vmap(fn)(keys)


def init_params(key, cfg) -> Params:
    dtype = L.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)

    if cfg.family in ("dense", "vlm"):
        lk = jax.random.split(ks[2], cfg.n_layers)
        params["layers"] = _stack(lk, lambda k: _init_dense_layer(k, cfg, dtype))
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dk = jax.random.split(ks[3], nd)
            params["dense_layers"] = _stack(
                dk, lambda k: _init_dense_layer(k, cfg, dtype, cfg.dense_d_ff)
            )
        mk = jax.random.split(ks[4], cfg.n_layers - nd)
        params["layers"] = _stack(mk, lambda k: _init_moe_layer(k, cfg, dtype))
    elif cfg.family == "hybrid":
        # (p-1) recurrent blocks + 1 local-attention block per group; the
        # groups are stacked and scanned (compile-time O(1) in depth), with
        # a short unscanned tail of recurrent blocks for the remainder.
        p = cfg.attn_period
        G, tail_n = cfg.n_layers // p, cfg.n_layers % p

        def group(k):
            gk = jax.random.split(k, p)
            g = {f"rec{i}": _init_rglru_block(gk[i], cfg, dtype) for i in range(p - 1)}
            g["attn"] = _init_dense_layer(gk[p - 1], cfg, dtype)
            return g

        params["groups"] = _stack(jax.random.split(ks[5], G), group)
        tk = jax.random.split(ks[7], max(tail_n, 1))
        params["tail"] = [_init_rglru_block(tk[i], cfg, dtype) for i in range(tail_n)]
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        params["vision_proj"] = L.dense_init(ks[6], (cfg.vision_dim, cfg.d_model), dtype=dtype)
    return params


def _is_attn_layer(i: int, cfg) -> bool:
    return cfg.attn_period > 0 and (i % cfg.attn_period) == (cfg.attn_period - 1)


# ---------------------------------------------------------------- forward


def _project_qkv(x, p, cfg, positions):
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def _attn_block(x, p, cfg, positions, window):
    h = L.rmsnorm(x, p["ln1"])
    q, k, v = _project_qkv(h, p["attn"], cfg, positions)
    o = A.attend(q, k, v, causal=True, window=window, impl=cfg.attn_impl)
    o = jnp.einsum("bsh,he->bse", o.reshape(o.shape[0], o.shape[1], -1), p["attn"]["wo"])
    return x + o.astype(x.dtype)


def _mlp_block(x, p, cfg):
    h = L.rmsnorm(x, p["ln2"])
    return x + L.swiglu(h, p["mlp"]["wi"], p["mlp"]["wg"], p["mlp"]["wo"])


def _moe_block(x, p, cfg):
    h = L.rmsnorm(x, p["ln2"])
    if cfg.moe_impl == "expert_parallel":
        out, aux = MOE.moe_ffn_shardmap(h, p["moe"], cfg)
    else:
        out, aux = MOE.moe_ffn(h, p["moe"], cfg)
    return x + out, aux


def _rglru_scan(xg, rec, h0=None):
    """RG-LRU over a sequence. xg: (B, S, W) post-conv activations.

    Returns (y (B,S,W), h_last (B,W)). Associative-scan formulation:
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t).
    """
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xg, rec["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xg, rec["w_i"]).astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(rec["lam"]) * r  # (B,S,W) f32
    a = jnp.exp(log_a)
    gated = i * xg.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, y = jax.lax.associative_scan(comb, (a, b), axis=1)
    return y, y[:, -1]


def _causal_conv(x, w):
    """Depthwise causal conv over sequence. x: (B,S,W); w: (K,W)."""
    K = w.shape[0]
    xf = x.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, j : j + x.shape[1]] * w[j].astype(jnp.float32) for j in range(K))
    return out.astype(x.dtype)


def _rglru_block_fwd(x, p, cfg):
    h = L.rmsnorm(x, p["ln1"])
    rec = p["rec"]
    xb = jnp.einsum("bsd,dw->bsw", h, rec["w_x"])
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", h, rec["w_gate"]).astype(jnp.float32)
    )
    xb = _causal_conv(xb, rec["conv_w"])
    y, _ = _rglru_scan(xb, rec)
    y = (y * gate).astype(x.dtype)
    o = jnp.einsum("bsw,wd->bsd", y, rec["w_out"])
    x = x + o
    return _mlp_block(x, p, cfg)


def _embed_tokens(params, tokens, cfg):
    # Pin the lookup to batch-sharded / feature-replicated: letting sharding
    # propagation push a tensor-sharded layout INTO the gather trips an XLA
    # GSPMD check-crash (PartitionGather / ExpandDeviceGroupsWithIota) at
    # several of our table shapes. The following matmul reshards cheaply.
    x = params["embed"][tokens]
    return L.maybe_shard(x, ("pod", "data"), *([None] * (x.ndim - 1)))


def forward(params: Params, batch: dict, cfg) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward. Returns (logits f32 (B,S,V), aux_loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    prefix = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype)
        proj = jnp.einsum("bpv,vd->bpd", patches, params["vision_proj"])
        x = jnp.concatenate([proj, x], axis=1)
        prefix = patches.shape[1]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    window = cfg.sliding_window

    aux_total = jnp.float32(0.0)
    if cfg.family in ("dense", "vlm"):
        def body(carry, pl):
            h = _attn_block(carry, pl, cfg, positions, window)
            h = _mlp_block(h, pl, cfg)
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"], unroll=cfg.scan_unroll)
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            def dbody(carry, pl):
                h = _attn_block(carry, pl, cfg, positions, window)
                h = _mlp_block(h, pl, cfg)
                return h, None

            x, _ = jax.lax.scan(jax.checkpoint(dbody), x, params["dense_layers"], unroll=cfg.scan_unroll)

        def mbody(carry, pl):
            h, aux = carry
            h = _attn_block(h, pl, cfg, positions, window)
            h, a = _moe_block(h, pl, cfg)
            return (h, aux + a), None

        (x, aux_total), _ = jax.lax.scan(jax.checkpoint(mbody), (x, aux_total), params["layers"], unroll=cfg.scan_unroll)
    elif cfg.family == "hybrid":
        p = cfg.attn_period

        def gbody(carry, gp):
            h = carry
            for i in range(p - 1):
                h = _rglru_block_fwd(h, gp[f"rec{i}"], cfg)
            h = _attn_block(h, gp["attn"], cfg, positions, cfg.local_window)
            h = _mlp_block(h, gp["attn"], cfg)
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(gbody), x, params["groups"],
                            unroll=cfg.scan_unroll)
        for blk in params["tail"]:
            x = jax.checkpoint(lambda h, b: _rglru_block_fwd(h, b, cfg))(x, blk)
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(x, params["final_norm"])
    if prefix:
        x = x[:, prefix:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return logits, aux_total


def _gold_logit(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """sum(where(v == label)) instead of take_along_axis: gathers along a
    tensor-sharded vocab dim hard-crash XLA's SPMD partitioner (PartitionGather
    check failure); the iota-compare reduce partitions cleanly."""
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    mask = vocab_iota == labels[..., None]
    return jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)


def loss_fn(params: Params, batch: dict, cfg) -> jax.Array:
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = jnp.mean(lse - _gold_logit(logits, labels))
    return nll + cfg.aux_loss_coef * aux


# ----------------------------------------------------------------- decode


def init_cache(cfg, batch_size: int, cache_len: int, dtype=None) -> dict:
    """KV cache pytree. cache_len == window size for ring (sliding) caches."""
    dtype = dtype or L.dtype_of(cfg)
    hd = cfg.resolved_head_dim
    KVH = cfg.n_kv_heads
    nL = cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        nd = cfg.first_dense_layers if cfg.family == "moe" else 0
        cache = {
            "k": jnp.zeros((nL - nd, batch_size, cache_len, KVH, hd), dtype),
            "v": jnp.zeros((nL - nd, batch_size, cache_len, KVH, hd), dtype),
        }
        if nd:
            cache["dk"] = jnp.zeros((nd, batch_size, cache_len, KVH, hd), dtype)
            cache["dv"] = jnp.zeros((nd, batch_size, cache_len, KVH, hd), dtype)
        return cache
    if cfg.family == "hybrid":
        W = cfg.lru_width or cfg.d_model
        p = cfg.attn_period
        G, tail_n = nL // p, nL % p
        wlen = min(cache_len, cfg.local_window)

        def rec_cache(lead=()):
            return {
                "h": jnp.zeros((*lead, batch_size, W), jnp.float32),
                "conv": jnp.zeros((*lead, batch_size, 3, W), dtype),
            }

        groups = {f"rec{i}": rec_cache((G,)) for i in range(p - 1)}
        groups["attn"] = {
            "k": jnp.zeros((G, batch_size, wlen, KVH, hd), dtype),
            "v": jnp.zeros((G, batch_size, wlen, KVH, hd), dtype),
        }
        return {"groups": groups, "tail": [rec_cache() for _ in range(tail_n)]}
    raise ValueError(cfg.family)


def _decode_attn(x, p, cfg, kc, vc, pos, ring: bool):
    """One-token attention for a single layer. x: (B,1,D)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    h = L.rmsnorm(x, p["ln1"])
    q = jnp.einsum("btd,dh->bth", h, p["attn"]["wq"])
    k = jnp.einsum("btd,dh->bth", h, p["attn"]["wk"])
    v = jnp.einsum("btd,dh->bth", h, p["attn"]["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["attn"]["bq"], k + p["attn"]["bk"], v + p["attn"]["bv"]
    q = q.reshape(B, 1, cfg.n_heads, hd)
    k = k.reshape(B, 1, cfg.n_kv_heads, hd)
    v = v.reshape(B, 1, cfg.n_kv_heads, hd)
    posb = jnp.full((1, 1), pos, jnp.int32)
    q = L.apply_rope(q, posb, cfg.rope_fraction, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_fraction, cfg.rope_theta)
    if ring:
        kc, vc = A.update_cache_ring(kc, vc, k, v, pos)
        o = A.decode_attend_ring(q, kc, vc, pos)
    else:
        kc, vc = A.update_cache_full(kc, vc, k, v, pos)
        o = A.decode_attend_full(q, kc, vc, pos)
    o = jnp.einsum("bth,he->bte", o.reshape(B, 1, -1), p["attn"]["wo"])
    return x + o.astype(x.dtype), kc, vc


def decode_step(params, cache, tokens, pos, cfg, *, ring: bool = False):
    """One decode step. tokens: (B, 1) int32; pos: () int32.

    ``ring=True`` uses sliding-window ring caches (long_500k path).
    Returns (logits (B, 1, V) f32, new cache).
    """
    x = _embed_tokens(params, tokens, cfg)

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_dense_layers:
            def dbody(carry, inp):
                h = carry
                pl, kc, vc = inp
                h, kc, vc = _decode_attn(h, pl, cfg, kc, vc, pos, ring)
                h = _mlp_block(h, pl, cfg)
                return h, (kc, vc)

            x, (dk, dv) = jax.lax.scan(
                dbody, x, (params["dense_layers"], cache["dk"], cache["dv"]),
                unroll=cfg.scan_unroll,
            )
            cache = dict(cache, dk=dk, dv=dv)

        def body(carry, inp):
            h = carry
            pl, kc, vc = inp
            h, kc, vc = _decode_attn(h, pl, cfg, kc, vc, pos, ring)
            if cfg.family == "moe":
                h, _ = _moe_block(h, pl, cfg)
            else:
                h = _mlp_block(h, pl, cfg)
            return h, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll)
        cache = dict(cache, k=k_new, v=v_new)
    elif cfg.family == "hybrid":
        p = cfg.attn_period

        def gbody(carry, inp):
            h = carry
            gp, gc = inp
            new_c = {}
            for i in range(p - 1):
                h, rc = _rglru_decode(h, gp[f"rec{i}"], cfg, gc[f"rec{i}"])
                new_c[f"rec{i}"] = rc
            h, kc, vc = _decode_attn(h, gp["attn"], cfg,
                                     gc["attn"]["k"], gc["attn"]["v"], pos, True)
            h = _mlp_block(h, gp["attn"], cfg)
            new_c["attn"] = {"k": kc, "v": vc}
            return h, new_c

        x, new_groups = jax.lax.scan(
            gbody, x, (params["groups"], cache["groups"]), unroll=cfg.scan_unroll)
        new_tail = []
        for blk, c in zip(params["tail"], cache["tail"]):
            x, rc = _rglru_decode(x, blk, cfg, c)
            new_tail.append(rc)
        cache = {"groups": new_groups, "tail": new_tail}
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head).astype(jnp.float32)
    return logits, cache


def _rglru_decode(x, p, cfg, c):
    """Single-step RG-LRU. x: (B,1,D); cache {h (B,W) f32, conv (B,3,W)}."""
    rec = p["rec"]
    h = L.rmsnorm(x, p["ln1"])
    xb = jnp.einsum("btd,dw->btw", h, rec["w_x"])[:, 0]  # (B,W)
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", h, rec["w_gate"]).astype(jnp.float32)
    )[:, 0]
    # causal conv with kernel 4: state holds previous 3 inputs
    win = jnp.concatenate([c["conv"], xb[:, None]], axis=1)  # (B,4,W)
    w = rec["conv_w"].astype(jnp.float32)
    xc = jnp.sum(win.astype(jnp.float32) * w[None], axis=1).astype(x.dtype)
    r = jax.nn.sigmoid((xc @ rec["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xc @ rec["w_i"]).astype(jnp.float32))
    a = jnp.exp(-8.0 * jax.nn.softplus(rec["lam"]) * r)
    hnew = a * c["h"] + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * xc.astype(jnp.float32))
    y = (hnew * gate).astype(x.dtype)
    o = jnp.einsum("bw,wd->bd", y, rec["w_out"])[:, None]
    x = x + o
    x = _mlp_block(x, p, cfg)
    return x, {"h": hnew, "conv": win[:, 1:]}
