"""Fused Pallas TPU kernel for the approximate-channel gradient pipeline.

The paper's receive pipeline is elementwise bit manipulation over every
gradient float. A layer-by-layer jnp implementation (see ``ref.py``) streams
each intermediate through HBM:

    u32 words (4 B) -> symbols (32/k x 4 B) -> complex stream (32/k x 8 B)
    -> noise/fading (2 x that) -> rx symbols -> words

i.e. >= 36 B of HBM traffic per 4 B gradient at QPSK — memory-bound by 9x
more traffic than necessary. This kernel fuses the whole chain inside one
VMEM tile: 4 B in, 4 B out, plus a 4 B/tile error counter. Channel noise and
Rayleigh fading are generated *inside* the kernel from a counter-based RNG
(murmur3-finalizer hash + Box-Muller over the global symbol index), so no
randomness is streamed from HBM. On real TPUs ``pltpu.prng_random_bits``
could replace the hash; we keep the hash so interpret-mode CPU validation is
bit-exact against the oracle.

Tiling: a ``(clients, tiles)`` grid, each tile ``block_words`` float32 words
(default 1024 = 8 sublanes x 128 lanes of f32); the single-client entry point
is the C=1 view. Each tile expands to (32/k, block_words)
symbols in VMEM — at QPSK that is 16 x 1024 x 4 B x ~6 live arrays ~ 400 KiB,
comfortably inside the ~16 MiB v5e VMEM budget; the MXU is not used (this is
a VPU/bit-op kernel). The symbol interleaver is block-local (row/column
within the tile), matching one PHY frame per tile.

The multi-client uplink (``approx_channel_batch_pallas``) runs a 2-D
``(clients, tiles)`` grid over a ``(C, N)`` payload matrix with per-client
seed/noise/gain scalars — one fused launch for the whole cohort, each row
bit-identical to the single-client kernel with that client's seed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

__all__ = [
    "approx_channel_pallas",
    "approx_channel_batch_pallas",
    "approx_channel_batch_aggregate_pallas",
]

_U32 = jnp.uint32


def approx_channel_pallas(
    x: jax.Array,
    seed: jax.Array,
    noise_power: jax.Array,
    large_scale_gain: jax.Array,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
):
    """Fused PHY pipeline. x: (N,) f32 (or bf16 with word_bits=16),
    N % block_words == 0. Returns (x_hat (N,), bit_errors () int32).

    One-client view of the batched kernel: the batch body restarts the
    symbol counter per client, so a C=1 grid is the single-client program.
    """
    x_hat, errs = approx_channel_batch_pallas(
        x[None, :],
        jnp.reshape(seed, (1,)),
        jnp.reshape(noise_power, (1,)),
        jnp.reshape(large_scale_gain, (1,)),
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        interpret=interpret,
    )
    return x_hat[0], errs[0]


def _batch_tile_body(
    tile,
    seed_ref,
    noise_ref,
    gain_ref,
    x_ref,
    out_ref,
    err_ref,
    *,
    bits_per_symbol: int,
    fading: str,
    fade_block: int,
    clamp_mask: int,
    block_words: int,
    word_bits: int,
):
    """Per-(client, tile) body. The symbol counter restarts per client and the
    RNG is keyed by the client's own seed, so each grid row reproduces the
    single-client kernel's stream bit-for-bit. ``tile`` is ``program_id(1)``,
    hoisted to the caller: the masked grid stages this body inside a
    ``pl.when`` branch, where a ``program_id`` call would not resolve under
    the interpret-mode evaluator."""
    s_per_word = word_bits // bits_per_symbol
    base_sym = tile.astype(_U32) * _U32(block_words * s_per_word)

    x = x_ref[0]
    if word_bits == 16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(_U32)
    else:
        u = jax.lax.bitcast_convert_type(x, _U32)
    u_hat = _ref.channel_tile(
        u,
        seed_ref[0],
        base_sym,
        noise_ref[0],
        gain_ref[0],
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        word_bits=word_bits,
    )
    u_hat = u_hat & _U32(clamp_mask)
    if word_bits == 16:
        out_ref[0] = jax.lax.bitcast_convert_type(
            u_hat.astype(jnp.uint16), jnp.bfloat16)
    else:
        out_ref[0] = jax.lax.bitcast_convert_type(u_hat, jnp.float32)
    err_ref[0, 0] = jnp.sum(_ref._popcount(u ^ u_hat)).astype(jnp.int32)


def _make_batch_kernel(masked: bool, **params):
    """Grid body, optionally masked to the first ``num_active`` client rows.

    The masked variant (partial-batch grid) serves padded per-mode buckets
    of the adaptive dispatch: rows at or beyond ``num_active`` skip the
    whole PHY chain and write zeros, so a bucket padded to its power-of-two
    capacity only pays for its real clients.
    """
    if not masked:
        def kernel(seed_ref, noise_ref, gain_ref, x_ref, out_ref, err_ref):
            _batch_tile_body(pl.program_id(1), seed_ref, noise_ref, gain_ref,
                             x_ref, out_ref, err_ref, **params)

        return kernel

    def kernel(na_ref, seed_ref, noise_ref, gain_ref, x_ref, out_ref, err_ref):
        tile = pl.program_id(1)
        active = pl.program_id(0) < na_ref[0]

        @pl.when(active)
        def _():
            _batch_tile_body(tile, seed_ref, noise_ref, gain_ref, x_ref,
                             out_ref, err_ref, **params)

        @pl.when(jnp.logical_not(active))
        def _():
            out_ref[0] = jnp.zeros_like(out_ref[0])
            err_ref[0, 0] = jnp.int32(0)

    return kernel


def _aggregate_tile_body(
    tile,
    w_ref,
    seed_ref,
    noise_ref,
    gain_ref,
    x_ref,
    agg_ref,
    err_ref,
    *,
    bits_per_symbol: int,
    fading: str,
    fade_block: int,
    clamp_mask: int,
    block_words: int,
    word_bits: int,
    valid_words: int,
):
    """Per-(tile, client) body of the fused-aggregate grid.

    Identical PHY chain to ``_batch_tile_body``, but instead of writing the
    demapped payload back to HBM it folds ``w * x_hat`` into the f32
    accumulator block — a separate multiply then add, never an fma, so the
    sum is bit-identical to ``aggregation.fedsgd_aggregate_batch`` over the
    batched kernel's rows. Bit errors are masked to the first
    ``valid_words`` global words in-kernel (transmitted pad words are
    exactly 0, so this equals the layered path's pad-error subtraction).
    """
    s_per_word = word_bits // bits_per_symbol
    base_sym = tile.astype(_U32) * _U32(block_words * s_per_word)

    x = x_ref[0]
    if word_bits == 16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(_U32)
    else:
        u = jax.lax.bitcast_convert_type(x, _U32)
    u_hat = _ref.channel_tile(
        u,
        seed_ref[0],
        base_sym,
        noise_ref[0],
        gain_ref[0],
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        word_bits=word_bits,
    )
    u_hat = u_hat & _U32(clamp_mask)
    if word_bits == 16:
        x_hat = jax.lax.bitcast_convert_type(
            u_hat.astype(jnp.uint16), jnp.bfloat16).astype(jnp.float32)
    else:
        x_hat = jax.lax.bitcast_convert_type(u_hat, jnp.float32)
    agg_ref[0] = agg_ref[0] + w_ref[0] * x_hat

    # 2-D iota (1-D iota does not lower on TPU), global word index per lane.
    local = jax.lax.broadcasted_iota(jnp.int32, (1, block_words), 1)
    gidx = tile * block_words + local
    flips = _ref._popcount(u ^ u_hat)[None, :]
    err_ref[0, 0] = jnp.sum(
        jnp.where(gidx < valid_words, flips, _U32(0))).astype(jnp.int32)


def _make_aggregate_kernel(masked: bool, **params):
    """Fused-aggregate grid body over a ``(tiles, clients)`` grid.

    The client axis is innermost, so the accumulator's output block
    (``lambda ti, ci: (0, ti)``) is revisited across the whole client sweep
    of a tile — it stays resident in VMEM and is flushed to HBM once per
    tile, which is what removes the per-client payload round-trip. Client 0
    zero-initializes the block; the masked variant skips the PHY chain for
    rows at or beyond ``num_active`` (their weight never touches the sum).
    """
    def body(tile, client, na_ref, w_ref, seed_ref, noise_ref, gain_ref,
             x_ref, agg_ref, err_ref):
        @pl.when(client == 0)
        def _():
            agg_ref[0] = jnp.zeros_like(agg_ref[0])

        if na_ref is None:
            _aggregate_tile_body(tile, w_ref, seed_ref, noise_ref, gain_ref,
                                 x_ref, agg_ref, err_ref, **params)
            return

        active = client < na_ref[0]

        @pl.when(active)
        def _():
            _aggregate_tile_body(tile, w_ref, seed_ref, noise_ref, gain_ref,
                                 x_ref, agg_ref, err_ref, **params)

        @pl.when(jnp.logical_not(active))
        def _():
            err_ref[0, 0] = jnp.int32(0)

    if not masked:
        def kernel(w_ref, seed_ref, noise_ref, gain_ref, x_ref,
                   agg_ref, err_ref):
            body(pl.program_id(0), pl.program_id(1), None, w_ref, seed_ref,
                 noise_ref, gain_ref, x_ref, agg_ref, err_ref)

        return kernel

    def kernel(na_ref, w_ref, seed_ref, noise_ref, gain_ref, x_ref,
               agg_ref, err_ref):
        body(pl.program_id(0), pl.program_id(1), na_ref, w_ref, seed_ref,
             noise_ref, gain_ref, x_ref, agg_ref, err_ref)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits_per_symbol",
        "fading",
        "fade_block",
        "clamp_mask",
        "block_words",
        "word_bits",
        "valid_words",
        "interpret",
    ),
)
def approx_channel_batch_aggregate_pallas(
    x: jax.Array,
    seeds: jax.Array,
    noise_powers: jax.Array,
    large_scale_gains: jax.Array,
    weights: jax.Array,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    valid_words: int | None = None,
    interpret: bool = True,
    num_active=None,
):
    """Fused modulate -> channel -> demodulate -> accumulate, one launch.

    Runs the same per-client PHY chain as ``approx_channel_batch_pallas``
    but never materializes the ``(C, N)`` demapped payload in HBM: a
    ``(tiles, clients)`` grid (client axis innermost) folds each client's
    received tile into a single f32 accumulator block that is written once
    per tile. HBM traffic drops from ``C*N`` wire words out + ``C*N`` f32
    read back (plus the aggregation write) to ``N`` f32 out.

    Args:
      x: ``(C, N)`` f32 (or bf16 with ``word_bits=16``),
        ``N % block_words == 0``.
      seeds / noise_powers / large_scale_gains: ``(C,)`` per-client link
        params, exactly as in ``approx_channel_batch_pallas``.
      weights: ``(C,)`` f32 aggregation weights (pre-normalized by the
        caller; masked rows' weights are ignored).
      valid_words: count only bit errors in the first ``valid_words`` words
        of each row (``None`` = all of N). The accumulator always covers
        all N words — callers slice off their padding.
      num_active: optional scalar — rows at or beyond it skip the PHY chain
        and contribute nothing to the sum (padded adaptive buckets).

    Returns:
      ``(agg (N,) float32, bit_errors (C,) int32)`` with
      ``agg == sum_c weights[c] * x_hat[c]`` accumulated in client order,
      bit-identical to ``fedsgd_aggregate_batch`` over the batched kernel.
    """
    c, n = x.shape
    if n % block_words != 0:
        raise ValueError(f"N={n} must be a multiple of block_words={block_words}")
    tiles = n // block_words
    if valid_words is None:
        valid_words = n

    masked = num_active is not None
    kernel = _make_aggregate_kernel(
        masked,
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        valid_words=valid_words,
    )
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    client_scalar = pl.BlockSpec((1,), lambda ti, ci: (ci,))
    in_specs = [
        client_scalar,  # aggregation weight
        client_scalar,  # seed
        client_scalar,  # noise power
        client_scalar,  # large-scale gain
        pl.BlockSpec((1, block_words), lambda ti, ci: (ci, ti)),
    ]
    operands = [
        weights.reshape(c).astype(jnp.float32),
        seeds.reshape(c).astype(_U32),
        noise_powers.reshape(c).astype(jnp.float32),
        large_scale_gains.reshape(c).astype(jnp.float32),
        x.astype(wire),
    ]
    if masked:
        in_specs.insert(0, pl.BlockSpec((1,), lambda ti, ci: (0,)))
        operands.insert(
            0, jnp.reshape(jnp.asarray(num_active, jnp.int32), (1,)))
    agg, errs = pl.pallas_call(
        kernel,
        grid=(tiles, c),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_words), lambda ti, ci: (0, ti)),
            pl.BlockSpec((1, 1), lambda ti, ci: (ci, ti)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((c, tiles), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return agg[0], jnp.sum(errs, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits_per_symbol",
        "fading",
        "fade_block",
        "clamp_mask",
        "block_words",
        "word_bits",
        "interpret",
    ),
)
def approx_channel_batch_pallas(
    x: jax.Array,
    seeds: jax.Array,
    noise_powers: jax.Array,
    large_scale_gains: jax.Array,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
    num_active=None,
):
    """Batched fused PHY pipeline over a 2-D ``(clients, tiles)`` grid.

    Args:
      x: ``(C, N)`` f32 (or bf16 with ``word_bits=16``), ``N % block_words == 0``.
      seeds: ``(C,)`` uint32 — one independent RNG stream per client.
      noise_powers / large_scale_gains: ``(C,)`` f32 per-client link params
        (heterogeneous SNR = varying ``noise_powers``).
      num_active: optional scalar (may be traced): only the first
        ``num_active`` client rows are computed; rows beyond it are masked —
        zero output, zero error count, no PHY work. This is the
        partial-batch grid the adaptive dispatch's padded buckets ride;
        ``None`` computes every row.

    Returns:
      ``(x_hat (C, N), bit_errors (C,) int32)``. Active row ``i`` is
      bit-identical to ``approx_channel_pallas(x[i], seeds[i], ...)``.
    """
    c, n = x.shape
    if n % block_words != 0:
        raise ValueError(f"N={n} must be a multiple of block_words={block_words}")
    tiles = n // block_words

    masked = num_active is not None
    kernel = _make_batch_kernel(
        masked,
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
    )
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    client_scalar = pl.BlockSpec((1,), lambda ci, ti: (ci,))
    in_specs = [
        client_scalar,  # seed
        client_scalar,  # noise power
        client_scalar,  # large-scale gain
        pl.BlockSpec((1, block_words), lambda ci, ti: (ci, ti)),
    ]
    operands = [
        seeds.reshape(c).astype(_U32),
        noise_powers.reshape(c).astype(jnp.float32),
        large_scale_gains.reshape(c).astype(jnp.float32),
        x.astype(wire),
    ]
    if masked:
        in_specs.insert(0, pl.BlockSpec((1,), lambda ci, ti: (0,)))
        operands.insert(
            0, jnp.reshape(jnp.asarray(num_active, jnp.int32), (1,)))
    x_hat, errs = pl.pallas_call(
        kernel,
        grid=(c, tiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_words), lambda ci, ti: (ci, ti)),
            pl.BlockSpec((1, 1), lambda ci, ti: (ci, ti)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, n), wire),
            jax.ShapeDtypeStruct((c, tiles), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return x_hat, jnp.sum(errs, axis=1)
