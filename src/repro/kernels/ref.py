"""Pure-jnp oracle for the fused approximate-channel kernel.

Implements EXACTLY the same math as ``approx_channel.py`` — including the
counter-based RNG (murmur3-finalizer hash + Box–Muller) — so kernel-vs-ref
tests are bit-exact, not just statistically close. The reference materializes
every intermediate (symbols, complex stream, noise) in HBM; the kernel fuses
the whole pipeline in VMEM. Shared helpers live here and are imported by the
kernel body (they are plain jnp and trace fine inside ``pallas_call``).

Pipeline (paper Sec. IV, per tile of ``block_words`` float32 words):

    bitcast -> MSB-first k-bit symbols -> block-local row/column interleave
    -> Gray square-QAM modulate -> Rayleigh/AWGN channel (counter RNG)
    -> coherent equalize -> closed-form ML demod -> de-interleave
    -> reassemble words -> exponent-bit clamp -> bitcast back.

Returns ``(x_hat, bit_errors)`` where bit_errors counts residual flipped
bits vs. the transmitted words (post-clamp).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["ref_approx_channel", "CHANNEL_STATIC_ARGS"]

_U32 = jnp.uint32
_TWO_PI = 6.283185307179586

# Streams for the counter RNG (arbitrary odd constants).
_STREAM_NOISE = 0x9E3779B9
_STREAM_FADE = 0x7FEB352D
_STREAM_PHASE = 0x68E31DA4


def fmix32(x: jax.Array) -> jax.Array:
    """murmur3 finalizer — a well-mixed 32-bit hash."""
    x = x.astype(_U32)
    x = x ^ (x >> 16)
    x = x * _U32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * _U32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_u32(seed: jax.Array, idx: jax.Array, stream: int) -> jax.Array:
    return fmix32(seed.astype(_U32) ^ fmix32(idx.astype(_U32) * _U32(0x9E3779B9) + _U32(stream)))


def uniform01(h: jax.Array) -> jax.Array:
    """uint32 hash -> uniform float32 in (0, 1]."""
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / 16777216.0) + jnp.float32(2.0**-25)


def gauss_pair(seed: jax.Array, idx: jax.Array, stream: int):
    """Two iid N(0,1) float32 via Box-Muller on counter-RNG uniforms."""
    u1 = uniform01(hash_u32(seed, idx, stream))
    u2 = uniform01(hash_u32(seed, idx, stream ^ _STREAM_PHASE))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    ang = jnp.float32(_TWO_PI) * u2
    return r * jnp.cos(ang), r * jnp.sin(ang)


def gray_encode(n):
    n = n.astype(_U32)
    return n ^ (n >> 1)


def gray_decode(g):
    g = g.astype(_U32)
    for s in (1, 2, 4):
        g = g ^ (g >> s)
    return g


def _popcount(x):
    x = x.astype(_U32)
    x = x - ((x >> 1) & _U32(0x55555555))
    x = (x & _U32(0x33333333)) + ((x >> 2) & _U32(0x33333333))
    x = (x + (x >> 4)) & _U32(0x0F0F0F0F)
    return (x * _U32(0x01010101)) >> 24


# Static (python-level) parameters shared by kernel and reference.
CHANNEL_STATIC_ARGS = (
    "bits_per_symbol",
    "fading",
    "fade_block",
    "clamp_mask",
    "block_words",
)


def channel_tile(
    u: jax.Array,  # (BW,) uint32 words of one tile (low word_bits used)
    seed: jax.Array,  # () uint32
    base_sym: jax.Array,  # () uint32 — global index of this tile's 1st symbol
    noise_power: jax.Array,  # () f32
    large_scale_gain: jax.Array,  # () f32
    *,
    bits_per_symbol: int,
    fading: str,
    fade_block: int,
    word_bits: int = 32,
) -> jax.Array:
    """Shared tile body: words -> noisy received words (pre-clamp).

    ``word_bits=16`` implements the bf16 wire format (same exponent layout
    as f32, so the clamp prior transfers; half the symbols per word)."""
    k = bits_per_symbol
    p = k // 2
    L = 1 << p
    bw = u.shape[0]
    s_per_word = word_bits // k
    amp = math.sqrt(3.0 / (2.0 * (L * L - 1)))

    # words -> symbols, MSB-first: (BW, S)
    shifts = _U32(word_bits - k * (jnp.arange(s_per_word, dtype=_U32) + 1))
    sym = (u[:, None] >> shifts[None, :]) & _U32((1 << k) - 1)
    # block-local row/column interleave -> transmit order (S, BW)
    stream = jnp.transpose(sym)

    # split to Gray axis bits (alternating I/Q allocation, MSB-first)
    gi = jnp.zeros_like(stream)
    gq = jnp.zeros_like(stream)
    for j in range(p):
        bi = (stream >> _U32(k - 1 - 2 * j)) & _U32(1)
        bq = (stream >> _U32(k - 2 - 2 * j)) & _U32(1)
        gi = gi | (bi << _U32(p - 1 - j))
        gq = gq | (bq << _U32(p - 1 - j))
    li = gray_decode(gi).astype(jnp.float32)
    lq = gray_decode(gq).astype(jnp.float32)
    s_re = (2.0 * li - (L - 1)) * jnp.float32(amp)
    s_im = (2.0 * lq - (L - 1)) * jnp.float32(amp)

    # global symbol index in transmit order
    gidx = base_sym + jax.lax.broadcasted_iota(_U32, stream.shape, 0) * _U32(bw) \
        + jax.lax.broadcasted_iota(_U32, stream.shape, 1)

    # channel: r = c s + n ; receiver equalizes y = s + n/c
    n_re, n_im = gauss_pair(seed, gidx, _STREAM_NOISE)
    nscale = jnp.sqrt(noise_power * 0.5)
    n_re = n_re * nscale
    n_im = n_im * nscale
    if fading == "awgn":
        c_re = jnp.sqrt(large_scale_gain) * jnp.ones_like(s_re)
        c_im = jnp.zeros_like(s_re)
    else:
        fidx = gidx // _U32(fade_block) if fading == "block_rayleigh" else gidx
        h_re, h_im = gauss_pair(seed, fidx, _STREAM_FADE)
        hs = jnp.sqrt(jnp.float32(0.5))
        c_re = jnp.sqrt(large_scale_gain) * h_re * hs
        c_im = jnp.sqrt(large_scale_gain) * h_im * hs
    c2 = jnp.maximum(c_re * c_re + c_im * c_im, jnp.float32(1e-20))
    # n / c = n * conj(c) / |c|^2
    y_re = s_re + (n_re * c_re + n_im * c_im) / c2
    y_im = s_im + (n_im * c_re - n_re * c_im) / c2

    # closed-form ML demod per axis
    inv = jnp.float32(1.0 / amp)

    def axis_level(x):
        lvl = jnp.round((x * inv + (L - 1)) * 0.5)
        return jnp.clip(lvl, 0, L - 1).astype(_U32)

    gi_hat = gray_encode(axis_level(y_re))
    gq_hat = gray_encode(axis_level(y_im))
    rx = jnp.zeros_like(stream)
    for j in range(p):
        bi = (gi_hat >> _U32(p - 1 - j)) & _U32(1)
        bq = (gq_hat >> _U32(p - 1 - j)) & _U32(1)
        rx = rx | (bi << _U32(k - 1 - 2 * j))
        rx = rx | (bq << _U32(k - 2 - 2 * j))

    # de-interleave, reassemble words
    rx_sym = jnp.transpose(rx)  # (BW, S)
    u_hat = jnp.sum(rx_sym << shifts[None, :], axis=-1, dtype=_U32)
    return u_hat


def ref_approx_channel(
    x: jax.Array,
    seed: jax.Array,
    noise_power: jax.Array,
    large_scale_gain: jax.Array,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
):
    """Oracle for the fused kernel. x: (N,) f32 (or bf16 when word_bits=16),
    N % block_words == 0."""
    n = x.shape[0]
    assert n % block_words == 0, (n, block_words)
    s_per_word = word_bits // bits_per_symbol
    if word_bits == 16:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16).astype(_U32)
    else:
        u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), _U32)
    tiles = u.reshape(-1, block_words)
    base = (jnp.arange(tiles.shape[0], dtype=_U32) * _U32(block_words * s_per_word))

    def per_tile(tile, b):
        return channel_tile(
            tile, seed.astype(_U32), b,
            jnp.float32(noise_power), jnp.float32(large_scale_gain),
            bits_per_symbol=bits_per_symbol, fading=fading, fade_block=fade_block,
            word_bits=word_bits,
        )

    u_hat = jax.vmap(per_tile)(tiles, base).reshape(-1)
    u_hat = u_hat & _U32(clamp_mask)
    errs = jnp.sum(_popcount(u ^ u_hat), dtype=jnp.int32)
    if word_bits == 16:
        out = jax.lax.bitcast_convert_type(u_hat.astype(jnp.uint16), jnp.bfloat16)
    else:
        out = jax.lax.bitcast_convert_type(u_hat, jnp.float32)
    return out, errs
