"""Pallas TPU kernels for the paper's compute hot-spot.

``approx_channel.py`` — fused PHY pipeline (bitcast -> interleave -> Gray-QAM
-> Rayleigh/AWGN via counter RNG -> closed-form ML demod -> bit clamp) with
explicit BlockSpec VMEM tiling; ``ops.py`` jit'd wrappers; ``ref.py`` the
pure-jnp oracle (bit-exact, shared tile math). Validated interpret=True on
CPU; compiled pallas_call on real TPUs.
"""

from repro.kernels.ops import (
    approx_channel,
    approx_channel_batch,
    approx_channel_transmit,
    approx_channel_transmit_batch,
)
