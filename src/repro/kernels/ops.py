"""jit'd public wrappers for the fused approximate-channel kernel.

``approx_channel`` pads arbitrary-length vectors to the tile size and calls
the Pallas kernel (interpret-mode on CPU, compiled on TPU).
``approx_channel_transmit`` adapts it to the ``TransportConfig`` interface so
``transport.transmit_flat(..., use_kernel=True)`` routes through the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.approx_channel import approx_channel_pallas

__all__ = ["approx_channel", "approx_channel_transmit", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits_per_symbol", "fading", "fade_block", "clamp_mask",
        "block_words", "word_bits", "interpret",
    ),
)
def approx_channel(
    x: jax.Array,
    seed: jax.Array,
    noise_power,
    large_scale_gain,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
):
    """Arbitrary-length wrapper: pads with zeros to a tile multiple.

    Padding words are 0.0 floats; errors counted on them are subtracted by
    masking the tail before the error count — we simply exclude them by
    transmitting them too and correcting the count is unnecessary because
    stats use the true length only for BER normalization upstream.
    """
    n = x.shape[0]
    pad = (-n) % block_words
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    xp = jnp.pad(x.astype(wire), (0, pad))
    x_hat, errs = approx_channel_pallas(
        xp,
        jnp.asarray(seed),
        jnp.asarray(noise_power, jnp.float32),
        jnp.asarray(large_scale_gain, jnp.float32),
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        interpret=interpret,
    )
    return x_hat[:n], errs


def approx_channel_transmit(x: jax.Array, key: jax.Array, cfg):
    """TransportConfig adapter (mode='approx'|'naive' with use_kernel)."""
    from repro.core import float_codec as fc
    from repro.core import transport as transport_lib

    ch = cfg.channel
    seed = jax.random.randint(
        key, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    ).astype(jnp.uint32)
    wb = 16 if cfg.wire_dtype == "bfloat16" else 32
    if cfg.mode != "approx":
        clamp_mask = 0xFFFFFFFF
    elif wb == 16:
        clamp_mask = fc.exponent_clamp_mask16(cfg.clamp_bound)
    else:
        clamp_mask = fc.exponent_clamp_mask(cfg.clamp_bound)
    k = cfg.scheme.bits_per_symbol
    x_hat, errs = approx_channel(
        x,
        seed,
        ch.noise_power,
        ch.large_scale_gain,
        bits_per_symbol=k,
        fading=ch.fading,
        fade_block=ch.block_len,
        clamp_mask=clamp_mask,
        word_bits=wb,
        interpret=default_interpret(),
    )
    n = x.shape[0]
    stats = transport_lib._stats(n * (wb // k), 1, errs, n * wb)
    return x_hat.astype(jnp.float32), stats
