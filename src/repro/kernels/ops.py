"""jit'd public wrappers for the fused approximate-channel kernel.

``approx_channel`` pads arbitrary-length vectors to the tile size and calls
the Pallas kernel (interpret-mode on CPU, compiled on TPU).
``approx_channel_transmit`` adapts it to the ``TransportConfig`` interface so
``transport.transmit_flat(..., use_kernel=True)`` routes through the kernel.
``approx_channel_batch`` / ``approx_channel_transmit_batch`` are the
multi-client variants backing ``transport.transmit_batch``: a ``(C, N)``
payload matrix through the 2-D-grid kernel in one launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.approx_channel import (
    approx_channel_batch_aggregate_pallas,
    approx_channel_batch_pallas,
    approx_channel_pallas,
)

__all__ = [
    "approx_channel",
    "approx_channel_batch",
    "approx_channel_batch_aggregate",
    "approx_channel_transmit",
    "approx_channel_transmit_batch",
    "approx_channel_transmit_batch_aggregate",
    "default_interpret",
    "donation_supported",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def donation_supported() -> bool:
    """Whether ``donate_argnums`` actually releases buffers on this backend.

    XLA CPU ignores donation (and warns); only gpu/tpu honour it, so the
    ``donate=`` fast paths fall back to the plain jit twin elsewhere.
    """
    return jax.default_backend() in ("gpu", "tpu")


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits_per_symbol", "fading", "fade_block", "clamp_mask",
        "block_words", "word_bits", "interpret",
    ),
)
def approx_channel(
    x: jax.Array,
    seed: jax.Array,
    noise_power,
    large_scale_gain,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
):
    """Arbitrary-length wrapper: pads with zeros to a tile multiple.

    The kernel counts bit errors over the whole tile, padding included; since
    the transmitted pad words are exactly 0, every set bit in a *received*
    pad word is a counted error — we subtract them here so ``bit_errors``
    covers only the true payload.
    """
    n = x.shape[0]
    pad = (-n) % block_words
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    xp = jnp.pad(x.astype(wire), (0, pad))
    x_hat, errs = approx_channel_pallas(
        xp,
        jnp.asarray(seed),
        jnp.asarray(noise_power, jnp.float32),
        jnp.asarray(large_scale_gain, jnp.float32),
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        interpret=interpret,
    )
    errs = errs - _padding_errors(x_hat[n:], word_bits)
    return x_hat[:n], errs


def _padding_errors(pad_hat: jax.Array, word_bits: int) -> jax.Array:
    """Bit errors the kernel counted on zero pad words (= received popcount)."""
    from repro.kernels import ref as _ref

    if word_bits == 16:
        u = jax.lax.bitcast_convert_type(pad_hat, jnp.uint16).astype(jnp.uint32)
    else:
        u = jax.lax.bitcast_convert_type(pad_hat, jnp.uint32)
    return jnp.sum(_ref._popcount(u), dtype=jnp.int32)


def _transport_kernel_params(cfg):
    """(wire_bits, clamp_mask, bits_per_symbol) for a TransportConfig."""
    from repro.core import float_codec as fc

    wb = 16 if cfg.wire_dtype == "bfloat16" else 32
    if cfg.mode != "approx":
        clamp_mask = 0xFFFFFFFF
    elif wb == 16:
        clamp_mask = fc.exponent_clamp_mask16(cfg.clamp_bound)
    else:
        clamp_mask = fc.exponent_clamp_mask(cfg.clamp_bound)
    return wb, clamp_mask, cfg.scheme.bits_per_symbol


def _seed_from_key(key: jax.Array) -> jax.Array:
    return jax.random.randint(
        key, (), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    ).astype(jnp.uint32)


def approx_channel_transmit(x: jax.Array, key: jax.Array, cfg, *, snr_db=None):
    """TransportConfig adapter (mode='approx'|'naive' with use_kernel).

    ``snr_db`` optionally overrides ``cfg.channel.snr_db`` (traced scalar ok).
    """
    from repro.core import channel as channel_lib
    from repro.core import transport as transport_lib

    ch = cfg.channel
    seed = _seed_from_key(key)
    wb, clamp_mask, k = _transport_kernel_params(cfg)
    npow = (ch.noise_power if snr_db is None
            else channel_lib.noise_power_for(ch, snr_db))
    x_hat, errs = approx_channel(
        x,
        seed,
        npow,
        ch.large_scale_gain,
        bits_per_symbol=k,
        fading=ch.fading,
        fade_block=ch.block_len,
        clamp_mask=clamp_mask,
        word_bits=wb,
        interpret=default_interpret(),
    )
    n = x.shape[0]
    stats = transport_lib._stats(n * (wb // k), 1, errs, n * wb, n * wb)
    return x_hat.astype(jnp.float32), stats


def _batch_impl(
    x: jax.Array,
    seeds: jax.Array,
    noise_powers,
    large_scale_gains,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
    num_active=None,
):
    """Batched arbitrary-length wrapper: pads ``(C, N)`` payloads along the
    payload dim to a tile multiple, one fused kernel launch for all clients.
    Returns ``(x_hat (C, N), bit_errors (C,) int32)``; errors counted on the
    zero padding are subtracted per client (see ``approx_channel``).
    ``num_active`` masks the tail client rows (partial-batch grid): masked
    rows cost no PHY work and return zeros — the adaptive dispatch's padded
    buckets discard them."""
    c, n = x.shape
    pad = (-n) % block_words
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    xp = jnp.pad(x.astype(wire), ((0, 0), (0, pad)))
    x_hat, errs = approx_channel_batch_pallas(
        xp,
        jnp.asarray(seeds),
        jnp.asarray(noise_powers, jnp.float32),
        jnp.asarray(large_scale_gains, jnp.float32),
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        interpret=interpret,
        num_active=num_active,
    )
    errs = errs - jax.vmap(lambda row: _padding_errors(row[n:], word_bits))(x_hat)
    return x_hat[:, :n], errs


_BATCH_STATIC = (
    "bits_per_symbol", "fading", "fade_block", "clamp_mask",
    "block_words", "word_bits", "interpret",
)
approx_channel_batch = jax.jit(_batch_impl, static_argnames=_BATCH_STATIC)
# Donated twin (see approx_channel_batch_aggregate below): the uplink payload
# buffer is released into the launch on backends that honour donation.
_batch_donated = jax.jit(
    _batch_impl, static_argnames=_BATCH_STATIC, donate_argnums=(0,))


def approx_channel_transmit_batch(x: jax.Array, keys: jax.Array, cfg,
                                  snr_db=None, *, num_active=None,
                                  donate: bool = False):
    """Batched TransportConfig adapter behind ``transport.transmit_batch``.

    Args:
      x: ``(C, N)`` float32 payload matrix.
      keys: ``(C, key_size)`` per-client keys (the fold_in schedule built by
        ``transport.client_keys`` — each row seeds that client's kernel RNG
        exactly as ``approx_channel_transmit`` would).
      cfg: TransportConfig with mode 'approx'|'naive'.
      snr_db: optional ``(C,)`` per-client SNR; ``None`` = config scalar.
      num_active: optional scalar — compute only the first ``num_active``
        client rows (masked partial-batch grid for padded adaptive buckets).
      donate: release the ``x`` buffer into the launch (donated jit twin) on
        backends that honour donation.

    Returns ``(x_hat (C, N) float32, TxStats with (C,) fields)``.
    """
    from repro.core import channel as channel_lib
    from repro.core import transport as transport_lib

    ch = cfg.channel
    c, n = x.shape
    seeds = jax.vmap(_seed_from_key)(keys)
    wb, clamp_mask, k = _transport_kernel_params(cfg)
    if snr_db is None:
        npow = jnp.full((c,), ch.noise_power, jnp.float32)
    else:
        npow = channel_lib.noise_power_for(ch, snr_db)
    gains = jnp.full((c,), ch.large_scale_gain, jnp.float32)
    batch_fn = (_batch_donated if donate and donation_supported()
                else approx_channel_batch)
    x_hat, errs = batch_fn(
        x,
        seeds,
        npow,
        gains,
        bits_per_symbol=k,
        fading=ch.fading,
        fade_block=ch.block_len,
        clamp_mask=clamp_mask,
        word_bits=wb,
        interpret=default_interpret(),
        num_active=num_active,
    )
    ones = jnp.ones((c,), jnp.float32)
    stats = transport_lib.TxStats(
        ones * (n * (wb // k)), ones, errs.astype(jnp.float32),
        ones * (n * wb), bits_on_air=ones * (n * wb),
    )
    return x_hat.astype(jnp.float32), stats


def _batch_aggregate_impl(
    x: jax.Array,
    seeds: jax.Array,
    noise_powers,
    large_scale_gains,
    weights,
    *,
    bits_per_symbol: int = 2,
    fading: str = "rayleigh",
    fade_block: int = 64,
    clamp_mask: int = 0xBFFFFFFF,
    block_words: int = 1024,
    word_bits: int = 32,
    interpret: bool = True,
    num_active=None,
):
    """Fused batch + in-kernel weighted aggregation over the client axis.

    Pads ``(C, N)`` payloads to a tile multiple and runs the aggregating
    kernel: the per-client demapped payload never materializes in HBM — the
    only payload-sized output is the f32 accumulator. Bit errors are masked
    to the first ``N`` words *inside* the kernel (``valid_words``), so no
    pad-error subtraction (which would need the per-client x_hat) happens
    here. Returns ``(agg (N,) float32, bit_errors (C,) int32)``.
    """
    c, n = x.shape
    pad = (-n) % block_words
    wire = jnp.bfloat16 if word_bits == 16 else jnp.float32
    xp = jnp.pad(x.astype(wire), ((0, 0), (0, pad)))
    agg, errs = approx_channel_batch_aggregate_pallas(
        xp,
        jnp.asarray(seeds),
        jnp.asarray(noise_powers, jnp.float32),
        jnp.asarray(large_scale_gains, jnp.float32),
        jnp.asarray(weights, jnp.float32),
        bits_per_symbol=bits_per_symbol,
        fading=fading,
        fade_block=fade_block,
        clamp_mask=clamp_mask,
        block_words=block_words,
        word_bits=word_bits,
        valid_words=n,
        interpret=interpret,
        num_active=num_active,
    )
    return agg[:n], errs


_AGG_STATIC = (
    "bits_per_symbol", "fading", "fade_block", "clamp_mask",
    "block_words", "word_bits", "interpret",
)
approx_channel_batch_aggregate = jax.jit(
    _batch_aggregate_impl, static_argnames=_AGG_STATIC)
# Donated twin: same impl, uplink payload buffer released to the output
# allocator. Only meaningful at an outermost jit boundary on gpu/tpu
# (donation_supported); callers pick between the twins.
_batch_aggregate_donated = jax.jit(
    _batch_aggregate_impl, static_argnames=_AGG_STATIC, donate_argnums=(0,))


def approx_channel_transmit_batch_aggregate(
        x: jax.Array, keys: jax.Array, cfg, snr_db, weights, *,
        num_active=None, donate: bool = False):
    """Batched TransportConfig adapter with in-kernel aggregation.

    Same contract as ``approx_channel_transmit_batch`` except the per-client
    demapped rows collapse to ``sum_c weights[c] * x_hat[c]`` inside the
    kernel (weights are used as given — normalize first). ``donate=True``
    releases the ``x`` buffer on backends that honour donation.

    Returns ``(agg (N,) float32, TxStats with (C,) fields)``.
    """
    from repro.core import channel as channel_lib
    from repro.core import transport as transport_lib

    ch = cfg.channel
    c, n = x.shape
    seeds = jax.vmap(_seed_from_key)(keys)
    wb, clamp_mask, k = _transport_kernel_params(cfg)
    if snr_db is None:
        npow = jnp.full((c,), ch.noise_power, jnp.float32)
    else:
        npow = channel_lib.noise_power_for(ch, snr_db)
    gains = jnp.full((c,), ch.large_scale_gain, jnp.float32)
    fn = (_batch_aggregate_donated if donate and donation_supported()
          else approx_channel_batch_aggregate)
    agg, errs = fn(
        x,
        seeds,
        npow,
        gains,
        weights,
        bits_per_symbol=k,
        fading=ch.fading,
        fade_block=ch.block_len,
        clamp_mask=clamp_mask,
        word_bits=wb,
        interpret=default_interpret(),
        num_active=num_active,
    )
    ones = jnp.ones((c,), jnp.float32)
    stats = transport_lib.TxStats(
        ones * (n * (wb // k)), ones, errs.astype(jnp.float32),
        ones * (n * wb), bits_on_air=ones * (n * wb),
    )
    return agg, stats
