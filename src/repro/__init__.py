"""Reproduction of "Approximate Wireless Communication for Federated Learning".

Importing the package installs :mod:`repro.compat`, which backfills the
modern jax sharding API (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.lax.axis_size``) on older jax
releases — a no-op on current jax.
"""

from repro import compat as _compat  # noqa: F401  (side-effect import)
