"""FL aggregation, including the distributed approximate-uplink all-reduce.

``fedsgd_aggregate`` is the PS-side weighted sum of client gradients,
paper eq. (5). ``approx_allreduce`` maps the paper's uplink onto a TPU mesh:
each data-parallel shard plays the role of a client cohort — its *local*
gradient contribution passes through the simulated PHY (encode -> Gray-QAM ->
fading channel -> demod -> bit-clamp) with an independent channel
realization, and the parameter-server aggregation is the ``psum`` over the
data axes. The PHY is elementwise, so this costs zero extra collective
traffic versus plain data parallelism.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import transport as transport_lib

__all__ = [
    "fedsgd_aggregate",
    "fedsgd_aggregate_batch",
    "normalize_weights",
    "approx_allreduce",
    "corrupt_local",
]


def fedsgd_aggregate(grads: Sequence[Any], weights: Sequence[float]):
    """Weighted aggregation g = sum_m (|D_m|/|D|) g_m  (paper eq. (5))."""
    total = float(sum(weights))
    scale = [w / total for w in weights]

    def comb(*leaves):
        return sum(s * l for s, l in zip(scale, leaves))

    return jax.tree_util.tree_map(comb, *grads)


def normalize_weights(weights: jax.Array) -> jax.Array:
    """f32 weights scaled to sum 1 (all-zero input passes through).

    The device-side twin of ``fedsgd_aggregate``'s host-float ``w / total``;
    the ``where``-form denominator matches ``engine.dropout_weighted_mean``'s
    zero-cohort convention (no movement rather than NaN).
    """
    w = jnp.asarray(weights, jnp.float32)
    total = jnp.sum(w)
    return w / jnp.where(total > 0, total, 1.0)


def fedsgd_aggregate_batch(stacked: jax.Array, weights: jax.Array):
    """Paper eq. (5) over a stacked ``(C, ...)`` gradient batch.

    The layered twin of the fused in-kernel accumulator
    (``kernels.approx_channel_batch_aggregate_pallas``): a ``lax.scan`` over
    the client axis whose body is one multiply + one add per element —
    the same arithmetic shape as the kernel's grid-loop accumulation, so the
    two are bit-identical (an unrolled sum is NOT: LLVM contracts the first
    multiply of an add chain into an fma). Weights are normalized to sum 1
    here, mirroring ``fedsgd_aggregate``; pass pre-normalized weights through
    ``lambda``-free call sites via :func:`normalize_weights` + the raw scan
    if the normalization must happen once globally.
    """
    w = normalize_weights(weights)
    rows = stacked.astype(jnp.float32)
    zero = jnp.zeros(rows.shape[1:], jnp.float32)

    def body(acc, wx):
        wc, xc = wx
        return acc + wc * xc, None

    agg, _ = jax.lax.scan(body, zero, (w, rows))
    return agg


def corrupt_local(grads: Any, key: jax.Array, cfg: transport_lib.TransportConfig):
    """Pass a local gradient pytree through the PHY; returns (grads, stats)."""
    return transport_lib.transmit_pytree(grads, key, cfg)


def approx_allreduce(
    local_grads: Any,
    key: jax.Array,
    cfg: transport_lib.TransportConfig,
    axis_names: Sequence[str] = ("data",),
):
    """Mean-reduce gradients over ``axis_names`` with a noisy uplink.

    Must be called inside ``shard_map`` (or any context where ``axis_names``
    are bound). Each shard corrupts its contribution with an independent
    channel realization (key folded by the shard's linear index), modeling M
    clients each transmitting to the PS over its own fading channel.
    """
    # Independent channel per shard.
    idx = jnp.int32(0)
    mul = 1
    for ax in axis_names:
        idx = idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        mul *= jax.lax.axis_size(ax)
    # mesh-shard keyspace on a dedicated aggregation key (bounded by the
    # mesh size), not the round/client lane table: lint: ignore[keylane]
    shard_key = jax.random.fold_in(key, idx)
    corrupted, stats = corrupt_local(local_grads, shard_key, cfg)
    # reduce in f32: bf16 psum additionally halves the all-reduce bytes but
    # trips an XLA CPU AllReducePromotion check-crash at the 16x16 mesh
    # (EXPERIMENTS.md Perf log); the airtime win is independent of this.
    summed = jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis_names) / mul, corrupted
    )
    return summed, stats
