"""Gradient-bound certificates (paper Sec. III).

The paper proves that, for fully connected networks with cross-entropy loss
and softmax output, the final-layer error delta^L = p - y lies in (-1, 1)
(eq. 15), and that with sigmoid hidden activations (sigma' in (0, 1/4)) and
weights bounded in (-1, 1), the gradient dC/dw^l is bounded by a layer-wise
constant B^l that depends on the fan-outs of the layers above l (eq. 10) —
and similarly for the 3-layer CNN sketch (eq. 16-17).

This module computes those certificates for concrete layer stacks so the
transport layer can choose a *certified* exponent-clamp mask
(``float_codec.exponent_clamp_mask``) rather than only the empirical |g| < 1
assumption. The recursion implemented here is exactly the paper's:

    |delta^L_j| <= 1
    |delta^l_j| <= n_{l+1} * W * S' * max_j |delta^{l+1}_j|
    |dC/dw^l_{jk}| <= |delta^l_j| * A

with W the weight bound, S' the activation-derivative bound, A the
activation-output bound (1 for sigmoid; input bound for the first layer).
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["ActivationInfo", "ACTIVATIONS", "LayerSpec", "gradient_bound", "certified_clamp_bound"]


@dataclasses.dataclass(frozen=True)
class ActivationInfo:
    """Worst-case activation bounds used by the gradient certificate."""

    name: str
    output_bound: float  # sup |a| (inf -> depends on input)
    deriv_bound: float  # sup |sigma'|


ACTIVATIONS = {
    "sigmoid": ActivationInfo("sigmoid", 1.0, 0.25),
    "tanh": ActivationInfo("tanh", 1.0, 1.0),
    "relu": ActivationInfo("relu", math.inf, 1.0),
    "softmax_xent": ActivationInfo("softmax_xent", 1.0, 1.0),  # final layer
}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One fully-connected layer of the certified stack (input->output)."""

    fan_out: int  # neurons in this layer (summation width seen from below)
    activation: str = "sigmoid"
    weight_bound: float = 1.0


def gradient_bound(layers: list[LayerSpec], input_bound: float = 1.0) -> list[float]:
    """Per-layer bound B^l on |dC/dw^l| for an FC stack, paper Sec. III-A.

    ``layers`` is ordered input->output; the final layer is assumed
    softmax+cross-entropy (delta^L in (-1,1)). Returns one bound per layer.
    Unbounded activations (ReLU with unbounded input) yield ``inf`` — the
    honest answer; the paper's certificate needs sigmoid-family hidden acts.
    """
    L = len(layers)
    delta = [math.inf] * L
    delta[L - 1] = 1.0  # |p - y| < 1, eq. (15)
    for l in range(L - 2, -1, -1):
        nxt = layers[l + 1]
        act = ACTIVATIONS[layers[l].activation]
        delta[l] = nxt.fan_out * nxt.weight_bound * act.deriv_bound * delta[l + 1]
    bounds = []
    for l in range(L):
        if l == 0:
            a_prev = input_bound
        else:
            a_prev = ACTIVATIONS[layers[l - 1].activation].output_bound
            if math.isinf(a_prev):
                a_prev = math.inf
        bounds.append(delta[l] * a_prev)
    return bounds


def certified_clamp_bound(layers: list[LayerSpec], input_bound: float = 1.0) -> float:
    """Tightest power-of-two clamp bound covering every layer's certificate.

    Falls back to the paper's default 2.0 (bit-30-only clamp) when any layer
    is uncertified (inf) or the certificate exceeds 2.
    """
    bs = gradient_bound(layers, input_bound)
    worst = max(bs)
    if math.isinf(worst) or worst >= 2.0:
        return 2.0
    return 2.0 ** math.ceil(math.log2(worst))
