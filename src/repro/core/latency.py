"""Airtime / latency model for one FL uplink round (paper Sec. V, Fig. 3).

The paper quantifies time saved vs ECRT under an IEEE 802.11-style PHY with
rate-1/2 LDPC. We model airtime analytically (the radio is not computation):

    t_round(mode) = transmissions * t_overhead + data_symbols / symbol_rate

* ``symbol_rate``: effective complex-symbol rate. Default models a 20 MHz
  802.11n-like OFDM link: 52 data subcarriers / 4 us OFDM symbol = 13 Msym/s.
* ``t_overhead``: per-PHY-transmission cost (preamble + SIFS + ACK) paid once
  per (re)transmission — ECRT pays it E[tx] times, approx/naive exactly once.
* ECRT sends 2x coded bits (rate 1/2) and retransmits failed codewords;
  its expected transmissions per codeword E[tx] is calibrated by running the
  real min-sum decoder (``calibrate_ecrt``) and cached per (SNR, modulation).

The paper's headline — approx saves >= 2x at 20 dB and >= 3x at 10 dB to the
same accuracy — falls out of (rate-1/2 overhead) x (E[tx]) x (per-tx MAC
overhead); see benchmarks/accuracy_vs_time.py.

The downlink leg reuses the same per-transmission formula; the difference is
the medium-access rule: uplink rounds pay the TDMA *sum* over clients, a
broadcast round pays each distinct downlink encoding *once*
(:func:`broadcast_airtime`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as channel_lib
from repro.core import ecrt as ecrt_lib
from repro.core import modulation as mod_lib
from repro.core import transport as transport_lib

__all__ = ["DEFAULT_CALIB_CODEWORDS", "DEFAULT_CALIB_MAX_TX", "PhyTimings",
           "round_airtime", "round_airtime_adaptive", "broadcast_airtime",
           "arrival_times", "sync_round_duration",
           "calibrate_ecrt", "ecrt_expected_tx_curve", "interp_expected_tx",
           "ecrt_expected_tx_profile"]

# ECRT E[tx] pricing sample budget — the one default shared by every
# pricing entry point (policy.build_mode_cfgs, scenario.ScenarioDriver,
# the FL loops' resolve_ecrt_analytic), so the same channel always
# resolves to the same Monte-Carlo estimate whichever door it came in.
# (calibrate_ecrt's own larger defaults serve standalone measurement.)
DEFAULT_CALIB_CODEWORDS = 48
DEFAULT_CALIB_MAX_TX = 6


@dataclasses.dataclass(frozen=True)
class PhyTimings:
    """PHY timing constants that convert transport stats into airtime."""

    symbol_rate: float = 13e6  # complex symbols / s (52 subcarriers / 4us)
    t_overhead: float = 200e-6  # preamble + SIFS + ACK per transmission
    fec_encode_overhead: float = 0.05  # fractional airtime stall for FEC proc


def round_airtime(stats: transport_lib.TxStats, timings: PhyTimings, mode: str):
    """Airtime (seconds) of one uplink round given transport stats."""
    t_data = stats.data_symbols / timings.symbol_rate
    t_ovh = stats.transmissions * timings.t_overhead
    if mode == "ecrt":
        t_data = t_data * (1.0 + timings.fec_encode_overhead)
    return t_data + t_ovh


def round_airtime_adaptive(stats: transport_lib.TxStats, timings: PhyTimings,
                           cfgs):
    """Per-client airtime of a mixed-mode round (link-adaptation dispatch).

    ``stats`` must come from ``transport.transmit_batch_adaptive`` (its
    ``mode_idx`` selects each client's row of the ``cfgs`` table); ECRT
    clients pay the FEC-processing stall, everyone else does not — the
    per-client generalization of :func:`round_airtime`'s static ``mode``
    argument. Returns ``(num_clients,)`` seconds.
    """
    if stats.mode_idx is None:
        raise ValueError(
            "round_airtime_adaptive needs TxStats.mode_idx (from "
            "transmit_batch_adaptive); for single-mode stats use round_airtime"
        )
    fec_stall = jnp.asarray(
        [timings.fec_encode_overhead if c.mode == "ecrt" else 0.0 for c in cfgs],
        jnp.float32,
    )[stats.mode_idx]
    t_data = stats.data_symbols / timings.symbol_rate * (1.0 + fec_stall)
    return t_data + stats.transmissions * timings.t_overhead


def broadcast_airtime(per_client_air, mode_idx=None) -> float:
    """Wall-clock seconds the PS spends on one downlink broadcast round.

    The uplink is TDMA — every client transmits its own payload, so the
    round's uplink cost is the *sum* of ``round_airtime`` entries. The
    downlink is a broadcast: the PS transmits each encoding **once** and
    every client of that mode listens to the same transmission. So the
    round's downlink cost is, per distinct mode in the cohort, one
    representative airtime (the per-mode max, which also covers per-client
    E[tx]-rescaled ECRT rows), summed over the modes actually present.

    Args:
      per_client_air: ``(num_clients,)`` per-client *reception* airtime —
        ``round_airtime`` (homogeneous broadcast) or
        ``round_airtime_adaptive`` (per-client downlink modes) applied to
        the broadcast's :class:`~repro.core.transport.TxStats`.
      mode_idx: the stats' per-client mode vector, or ``None`` for a
        single-mode broadcast (one transmission total).

    Returns:
      Airtime in seconds (a host float — this prices the accumulator, not a
      traced value).
    """
    air = np.asarray(per_client_air, np.float32).reshape(-1)
    if air.size == 0:
        return 0.0
    if mode_idx is None:
        return float(air.max())
    modes = np.asarray(mode_idx).reshape(-1)
    return float(sum(float(air[modes == m].max()) for m in np.unique(modes)))


def arrival_times(t_dispatch: float, compute_s, air_s,
                  downlink_s: float = 0.0) -> np.ndarray:
    """Event-clock upload-arrival times of one dispatched wave (float64).

    A client dispatched at event time ``t_dispatch`` first receives the
    broadcast (``downlink_s``, the wall time the PS spends on the wave's
    downlink leg — zero without one), computes locally for ``compute_s[i]``
    seconds, then occupies the uplink for ``air_s[i]`` seconds; its update
    lands at the sum. The event clock is host-side float64 — arrival
    *ordering* drives the buffered engine's aggregation schedule, so the
    accumulation must not lose float32 bits across thousands of events.
    Dropped clients (``air_s[i] == 0``) get their ready-again time from the
    same formula.
    """
    return (np.float64(t_dispatch) + np.float64(downlink_s)
            + np.asarray(compute_s, np.float64)
            + np.asarray(air_s, np.float64))


def sync_round_duration(compute_s, air_s, active=None) -> float:
    """Wall-clock seconds of one synchronous (barrier) round.

    Every active client computes in parallel, then the TDMA uplink
    serializes transmissions: the barrier closes at
    ``max_i(compute_i) + sum_i(air_i)`` over active clients. The honest
    yardstick the buffered engine's wall-clock claims are measured
    against (``benchmarks/async_fl.py``).
    """
    comp = np.asarray(compute_s, np.float64).reshape(-1)
    air = np.asarray(air_s, np.float64).reshape(-1)
    if active is not None:
        act = np.asarray(active, bool).reshape(-1)
        comp, air = comp[act], air[act]
    if comp.size == 0:
        return 0.0
    return float(comp.max() + air.sum())


def calibrate_ecrt(
    snr_db: float,
    modulation: str = "qpsk",
    fading: str = "block_rayleigh",
    n_codewords: int = 256,
    max_tx: int = 8,
    seed: int = 0,
    decoder: str = "minsum",  # "minsum" (soft) | "bounded" (paper's 7-bit)
) -> float:
    """Measure E[transmissions per codeword] for the real LDPC chain.

    Runs the full encode -> channel -> soft min-sum decode -> retransmit loop
    on random payloads and returns the mean transmission count. Cached: FL
    loops reuse the scalar instead of decoding every round. Arguments are
    canonicalized (SNR round-trips through float32, everything hits the
    cache positionally) so keyword vs positional call forms and
    float64-vs-float32 representations of the same SNR share one cache
    entry — the anchor-point / curve-point consistency the per-client
    airtime interpolation relies on.

    Default fading is *per-codeword block Rayleigh* (coherence time >= packet
    airtime): with per-symbol iid fading + perfect CSI the rate-1/2 LDPC has
    so much diversity it essentially never fails, while a packet caught in a
    deep fade fails regardless of coding and must be retransmitted — this is
    the regime behind the paper's 3x (10 dB) vs 2x (20 dB) ECRT slowdown.

    ``decoder="bounded"`` reproduces the paper's abstraction exactly: the
    802.11n LDPC(648, R=1/2) has d_min = 15 and corrects 7 hard bit errors;
    a transmission fails iff the hard-decision error count exceeds 7. This
    is pessimistic vs. our real soft min-sum chain (``decoder="minsum"``) —
    both are recorded in EXPERIMENTS.md.
    """
    return _calibrate_ecrt(
        float(np.float32(snr_db)), str(modulation), str(fading),
        int(n_codewords), int(max_tx), int(seed), str(decoder))


@functools.lru_cache(maxsize=64)
def _calibrate_ecrt(snr_db, modulation, fading, n_codewords, max_tx, seed,
                    decoder) -> float:
    """The canonicalized, cached body of :func:`calibrate_ecrt`."""
    code = ecrt_lib.LdpcCode()
    scheme = mod_lib.MOD_SCHEMES[modulation]
    key = jax.random.PRNGKey(seed)
    k_msg, k_ch = jax.random.split(key)
    msgs = jax.random.randint(k_msg, (n_codewords, code.k), 0, 2).astype(jnp.uint32)
    cw = ecrt_lib.encode(msgs, code)
    n_cw, n_code = cw.shape
    k_mod = scheme.bits_per_symbol
    sym_per_cw = n_code // k_mod
    ch_cfg = channel_lib.ChannelConfig(
        snr_db=snr_db, fading=fading, block_len=sym_per_cw
    )

    weights = jnp.uint32(1) << jnp.uint32(k_mod - 1 - jnp.arange(k_mod))

    @jax.jit
    def run(keys):
        def tx_round(carry, kr):
            ok, tx_count = carry
            b = cw.reshape(n_cw, sym_per_cw, k_mod)
            sym = jnp.sum(b * weights, axis=-1, dtype=jnp.uint32).reshape(-1)
            tx = mod_lib.modulate(sym, scheme)
            r, c = channel_lib.transmit(tx, kr, ch_cfg)
            y = channel_lib.equalize(r, c)
            if decoder == "bounded":
                rx = mod_lib.demod_hard(y, scheme).reshape(n_cw, sym_per_cw)
                errs = jnp.sum(
                    mod_lib.popcount(rx ^ sym.reshape(n_cw, sym_per_cw)), axis=-1
                )
                ok_new = errs <= 7
            else:
                nv = channel_lib.noise_var_post_eq(c, ch_cfg)
                llr = mod_lib.bit_llrs(y, nv, scheme).reshape(n_cw, n_code)
                _, ok_new = ecrt_lib.decode(llr, code)
            tx_count = tx_count + (~ok).astype(jnp.int32)
            ok = ok | ok_new
            return (ok, tx_count), None

        init = (jnp.zeros((n_cw,), bool), jnp.zeros((n_cw,), jnp.int32))
        (ok, tx_count), _ = jax.lax.scan(tx_round, init, keys)
        return jnp.mean(tx_count.astype(jnp.float32)), jnp.mean(ok)

    e_tx, frac_ok = run(jax.random.split(k_ch, max_tx))
    return float(e_tx)


def ecrt_expected_tx_curve(grid_db, modulation: str = "qpsk", *,
                           fading: str = "block_rayleigh",
                           n_codewords: int = DEFAULT_CALIB_CODEWORDS,
                           max_tx: int = DEFAULT_CALIB_MAX_TX):
    """Calibrate E[transmissions] on an SNR grid (one cached point each).

    E[tx] is *not* a constant under time-varying or heterogeneous SNR: a
    client in a fade retransmits far more than the fleet average, so pricing
    every ECRT uplink with one scenario-wide constant underprices exactly
    the rounds where ECRT is slowest. This builds the lookup the airtime
    models interpolate per client per round; each grid point goes through
    :func:`calibrate_ecrt`'s LRU cache, so repeated curves are free.

    Returns ``(grid_db, e_tx)`` as ascending float32 jnp arrays.
    """
    grid = np.asarray(sorted(float(s) for s in np.asarray(grid_db).reshape(-1)),
                      np.float32)
    if grid.size == 0:
        raise ValueError("ecrt_expected_tx_curve needs a non-empty SNR grid")
    vals = np.asarray(
        [calibrate_ecrt(float(s), modulation, fading, n_codewords, max_tx)
         for s in grid],
        np.float32,
    )
    return jnp.asarray(grid), jnp.asarray(vals)


def interp_expected_tx(snr_db, grid, e_tx) -> jax.Array:
    """Per-entry E[tx] at ``snr_db`` by linear interpolation on a calibrated
    curve (clamped at the grid edges). Pure jnp — safe under jit; broadcasts
    over any ``snr_db`` shape."""
    return jnp.interp(jnp.asarray(snr_db, jnp.float32),
                      jnp.asarray(grid, jnp.float32),
                      jnp.asarray(e_tx, jnp.float32))


def ecrt_expected_tx_profile(snr_db, modulation: str = "qpsk", *,
                             fading: str = "block_rayleigh",
                             n_codewords: int = DEFAULT_CALIB_CODEWORDS,
                             max_tx: int = DEFAULT_CALIB_MAX_TX,
                             max_grid: int = 4) -> np.ndarray:
    """Per-client E[tx] for a static SNR vector (the fixed-ECRT FL loops).

    Calibrates at each distinct SNR when there are at most ``max_grid`` of
    them (interpolation is then exact), else on a ``max_grid``-point linear
    grid spanning the cohort's range. Returns a float32 vector matching
    ``snr_db``'s length (scalars give length 1).
    """
    snr = np.asarray(snr_db, np.float32).reshape(-1)
    uniq = np.unique(snr)
    if uniq.size <= max_grid:
        grid = uniq
    else:
        grid = np.linspace(float(snr.min()), float(snr.max()), max_grid,
                           dtype=np.float32)
    grid_j, vals_j = ecrt_expected_tx_curve(
        grid, modulation, fading=fading, n_codewords=n_codewords,
        max_tx=max_tx)
    return np.interp(snr, np.asarray(grid_j), np.asarray(vals_j)).astype(
        np.float32)
