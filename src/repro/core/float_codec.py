"""Float32 <-> bit/symbol codec for approximate gradient transmission.

Implements the paper's encoding layer (Sec. IV-A):

* IEEE-754 float32 gradients are bitcast to 32-bit words.
* Words are split into ``32/k`` modulation symbols of ``k`` bits each,
  MSB-first, so the sign and exponent bits land in the earliest symbols and,
  within a symbol, the more significant float bit occupies the more protected
  Gray-constellation position (see ``modulation.py``).
* A symbol-level block interleaver spreads each float's symbols across the
  transmitted stream so a fading burst corrupts many floats once each rather
  than one float catastrophically (paper Sec. IV-A "interleaving").
* On receive, the second bit (bit 30 — the exponent MSB) is forced to 0:
  gradients are bounded with |g| < 2 (paper Sec. III), so that bit is always
  0 at the transmitter and any received 1 there is an error (paper Fig. 1).
  Forcing it also makes NaN/Inf unrepresentable (exponent 0xFF needs bit 30).

Everything here is pure jnp and jit-friendly; the fused Pallas kernel in
``repro.kernels`` implements the same pipeline for TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "f32_to_bits",
    "bits_to_f32",
    "words_to_symbols",
    "symbols_to_words",
    "interleave",
    "deinterleave",
    "clamp_exponent_bits",
    "exponent_clamp_mask",
    "BIT30_MASK",
    "WIRE_DTYPES",
]

# The declared wire dtype set: every array a wire-format module (codec,
# modulation, channel, transport, framing, sparsify, kernels) materializes
# must carry one of these dtypes explicitly. float64 never rides the wire —
# the format is 32-bit words — and host numpy's implicit float64 default is
# banned in those modules (the ``dtype-discipline`` rule of ``tools/lint``
# parses this tuple and enforces both).
WIRE_DTYPES = ("float32", "bfloat16", "float16", "uint8", "uint16",
               "uint32", "int32", "complex64", "bool_")

# ~(1 << 30): clears the exponent MSB.
BIT30_MASK = jnp.uint32(0xBFFFFFFF)


def f32_to_bits(x: jax.Array) -> jax.Array:
    """Bitcast float32 -> uint32 (same shape)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def bits_to_f32(u: jax.Array) -> jax.Array:
    """Bitcast uint32 -> float32 (same shape)."""
    return jax.lax.bitcast_convert_type(u.astype(jnp.uint32), jnp.float32)


def bf16_to_bits(x: jax.Array) -> jax.Array:
    """Bitcast bfloat16 -> uint16. bf16 shares float32's exponent layout
    (8 bits, bias 127), so the paper's exponent-MSB clamp applies verbatim
    at half the airtime — the beyond-paper 16-bit uplink (EXPERIMENTS Perf)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)


def bits_to_bf16(u: jax.Array) -> jax.Array:
    """Bitcast uint16 -> bfloat16 (same shape; inverse of bf16_to_bits)."""
    return jax.lax.bitcast_convert_type(u.astype(jnp.uint16), jnp.bfloat16)


def words_to_symbols(u: jax.Array, bits_per_symbol: int, word_bits: int = 32) -> jax.Array:
    """Split uint words (N,) into symbol indices (N, word_bits/k), MSB-first.

    Symbol ``s`` of a word carries float bits [wb-1 - s*k, ..., wb - (s+1)*k]
    with the more significant float bit in the higher bit of the symbol index.
    """
    k = bits_per_symbol
    if word_bits % k != 0:
        raise ValueError(f"bits_per_symbol={k} must divide {word_bits}")
    s_per_word = word_bits // k
    u = u.astype(jnp.uint32)
    shifts = jnp.uint32(word_bits - k * (jnp.arange(s_per_word, dtype=jnp.uint32) + 1))
    mask = jnp.uint32((1 << k) - 1)
    return (u[..., None] >> shifts) & mask


def symbols_to_words(sym: jax.Array, bits_per_symbol: int, word_bits: int = 32) -> jax.Array:
    """Inverse of :func:`words_to_symbols`: (N, wb/k) -> (N,) uint32."""
    k = bits_per_symbol
    s_per_word = word_bits // k
    shifts = jnp.uint32(word_bits - k * (jnp.arange(s_per_word, dtype=jnp.uint32) + 1))
    return jnp.sum(
        (sym.astype(jnp.uint32) & jnp.uint32((1 << k) - 1)) << shifts,
        axis=-1,
        dtype=jnp.uint32,
    )


def interleave(sym: jax.Array) -> jax.Array:
    """Row-column symbol interleaver.

    ``sym`` is (N, S) — N floats x S symbols each. The transmitted stream is
    read column-major so adjacent airtime symbols come from different floats.
    Returns the flat stream (N*S,).
    """
    return jnp.transpose(sym).reshape(-1)


def deinterleave(stream: jax.Array, n_words: int, s_per_word: int) -> jax.Array:
    """Inverse of :func:`interleave`: (N*S,) -> (N, S)."""
    return jnp.transpose(stream.reshape(s_per_word, n_words))


def exponent_clamp_mask16(bound: float) -> int:
    """bf16 analogue of :func:`exponent_clamp_mask` (exponent bits 14..7)."""
    m32 = exponent_clamp_mask(bound)
    return (m32 >> 16) & 0xFFFF


def clamp_exponent_bits16(u: jax.Array, bound: float = 2.0) -> jax.Array:
    """bf16 receiver clamp: force provably-zero exponent bits to 0.

    ``u``: (...,) uint16 received words; returns the same shape/dtype."""
    return (u.astype(jnp.uint32) & jnp.uint32(exponent_clamp_mask16(bound))).astype(jnp.uint16)


def exponent_clamp_mask(bound: float) -> int:
    """AND-mask forcing exponent bits that are provably 0 for |g| < bound.

    The paper's scheme (bound <= 2) clears only bit 30. Tighter certified
    bounds (Sec. III gives B^l; empirically |g| << 1) let us clear more
    leading exponent bits: if bound <= 2**(1 - 2**m) ... in practice we clear
    the top ``j`` exponent bits such that the max biased exponent
    ``E_max = 127 + floor(log2(bound_strict))`` fits in ``8 - j`` bits.
    """
    import math

    if bound <= 0:
        raise ValueError("bound must be positive")
    # Largest representable magnitude strictly below `bound` has biased
    # exponent E_max = 127 + ceil(log2(bound)) - 1.
    e_max = 127 + math.ceil(math.log2(bound)) - 1
    e_max = max(0, min(254, e_max))
    j = 8 - max(1, e_max.bit_length())  # leading exponent bits that must be 0
    mask = 0xFFFFFFFF
    for b in range(j):
        mask &= ~(1 << (30 - b))
    return mask


def clamp_exponent_bits(u: jax.Array, bound: float = 2.0) -> jax.Array:
    """Force provably-zero exponent bits to 0 in received words (Fig. 1)."""
    return u & jnp.uint32(exponent_clamp_mask(bound))
