"""Wireless uplink channel model (paper Sec. II-B, eq. (7)).

r = sqrt(p d^-alpha) h s + n,   h ~ CN(0,1),   n ~ CN(0, sigma^2)

The parameter server knows the composite gain c = sqrt(p d^-alpha) h
(coherent detection); only noise is an error source. ``snr_db`` is the
*average received symbol SNR*: sigma^2 = p d^-alpha / snr_lin, so E|h|^2 = 1
gives the configured average SNR at the receiver, matching the paper's
"receiver SNR is set at gamma = 10 dB".

``block_len`` > 1 models block fading: the fading coefficient is constant
over runs of symbols — this is what makes the symbol interleaver matter.

Heterogeneous links (multi-client uplink): ``snr_db`` may be a per-client
sequence/array instead of a scalar — ``transport.transmit_batch`` resolves it
to one scalar per client and threads it through the ``snr_db`` override of
:func:`transmit` / :func:`noise_var_post_eq`, so each client sees an
independent fading realization *and* its own average link quality.
"""

from __future__ import annotations

import dataclasses
import numbers
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ChannelConfig",
    "transmit",
    "equalize",
    "noise_var_post_eq",
    "noise_power_for",
    "per_client_snr_db",
    "snr_db_vector",
]


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Uplink parameters. All powers are linear (not dB) except ``snr_db``.

    ``snr_db`` is either a scalar (every client sees the same average SNR, the
    paper's setup) or a per-client sequence/array (heterogeneous link quality;
    prefer a tuple so the config stays hashable). Per-client values are only
    consumed by the batched transport path — the scalar helpers below
    (``noise_power``) require a scalar.
    """

    snr_db: Any = 10.0  # float, or per-client tuple/array of floats
    fading: str = "rayleigh"  # "rayleigh" | "awgn" | "block_rayleigh"
    block_len: int = 64  # symbols per fading block (block_rayleigh only)
    tx_power: float = 1.0
    distance: float = 10.0
    pathloss_exp: float = 3.0

    @property
    def large_scale_gain(self) -> float:
        """Mean received power ``p * d^-alpha`` (linear path-loss model)."""
        return self.tx_power * self.distance ** (-self.pathloss_exp)

    @property
    def noise_power(self) -> float:
        """Scalar receiver noise power sigma^2 = p d^-alpha / snr_lin.

        Raises if ``snr_db`` is per-client — use :func:`noise_power_for` with
        an explicit per-client SNR in that case.
        """
        if not _is_scalar_snr(self.snr_db):
            raise TypeError(
                "ChannelConfig.noise_power needs a scalar snr_db; per-client "
                "arrays go through transport.transmit_batch / noise_power_for()"
            )
        return self.large_scale_gain / (10.0 ** (float(self.snr_db) / 10.0))

    def with_snr(self, snr_db) -> "ChannelConfig":
        """Copy of this config at a different average SNR.

        The static (rebuild-the-config) counterpart of the traced per-round
        ``snr_db=`` override that :func:`transmit` and the batched transport
        accept — scenario code uses the override for per-round trajectories
        and ``with_snr`` when it wants a distinct static operating point
        (e.g. fixed-mode baseline arms of a link-adaptation sweep).
        """
        return dataclasses.replace(self, snr_db=snr_db)


def _is_scalar_snr(snr_db) -> bool:
    """True for Python/numpy real scalars (incl. 0-d arrays), False for
    per-client sequences/arrays."""
    if isinstance(snr_db, numbers.Real):
        return True
    return getattr(snr_db, "ndim", None) == 0


def noise_power_for(cfg: ChannelConfig, snr_db) -> jax.Array:
    """Noise power for an explicit (possibly traced, possibly (C,)) SNR in dB."""
    snr = jnp.asarray(snr_db, jnp.float32)
    return cfg.large_scale_gain / (10.0 ** (snr / 10.0))


def snr_db_vector(snr_db, num_clients: int) -> jax.Array:
    """Broadcast/validate an explicit per-client SNR to ``(num_clients,)``.

    Accepts a scalar, single-element, or length-``num_clients`` value (static
    or traced); anything else raises ValueError. The single shared rule for
    both the config path and the ``snr_db=`` call override. Arrays with more
    than one dimension are rejected rather than flattened — a silently
    flattened ``(2, C/2)`` grid would pass the length check while scrambling
    the client <-> SNR pairing.
    """
    arr = jnp.asarray(snr_db, jnp.float32)
    if arr.ndim > 1:
        raise ValueError(
            f"snr_db must be a scalar or 1-D per-client vector; got shape "
            f"{arr.shape}"
        )
    arr = arr.reshape(-1)
    if arr.shape[0] == 1:
        return jnp.broadcast_to(arr, (num_clients,))
    if arr.shape[0] != num_clients:
        raise ValueError(
            f"snr_db has {arr.shape[0]} entries but batch has {num_clients} clients"
        )
    return arr


def per_client_snr_db(cfg: ChannelConfig, num_clients: int):
    """Resolve ``cfg.snr_db`` to a per-client view for the batched uplink.

    Returns ``None`` when ``snr_db`` is a scalar (callers then use the exact
    scalar code path, which is bit-identical to ``transmit_flat``), else a
    ``(num_clients,)`` float32 array (broadcast if a single-element sequence).
    """
    if _is_scalar_snr(cfg.snr_db):
        return None
    return snr_db_vector(np.asarray(cfg.snr_db, np.float32), num_clients)


def _cn(key: jax.Array, shape, var) -> jax.Array:
    """Complex normal CN(0, var)."""
    kr, ki = jax.random.split(key)
    s = jnp.sqrt(var / 2.0)
    return jax.lax.complex(
        jax.random.normal(kr, shape, dtype=jnp.float32) * s,
        jax.random.normal(ki, shape, dtype=jnp.float32) * s,
    )


def transmit(symbols: jax.Array, key: jax.Array, cfg: ChannelConfig, *,
             snr_db=None):
    """Pass unit-energy symbols through the uplink.

    Args:
      symbols: ``(n_sym,)`` complex64 unit-average-energy constellation points.
      key: PRNG key consumed for the fading and noise draws.
      cfg: channel parameters.
      snr_db: optional scalar override of ``cfg.snr_db`` (may be traced) —
        the per-client hook used by ``transport.transmit_batch``.

    Returns:
      ``(r, c)``: received symbols ``(n_sym,)`` complex64 and the composite
      channel gain ``c`` ``(n_sym,)`` complex64 known at the PS.
    """
    (n_sym,) = symbols.shape
    k_h, k_n = jax.random.split(key)
    amp = jnp.sqrt(cfg.large_scale_gain).astype(jnp.float32)
    if cfg.fading == "awgn":
        h = jnp.ones((n_sym,), dtype=jnp.complex64)
    elif cfg.fading == "rayleigh":
        h = _cn(k_h, (n_sym,), 1.0)
    elif cfg.fading == "block_rayleigh":
        n_blocks = -(-n_sym // cfg.block_len)
        hb = _cn(k_h, (n_blocks,), 1.0)
        h = jnp.repeat(hb, cfg.block_len)[:n_sym]
    else:
        raise ValueError(f"unknown fading {cfg.fading!r}")
    c = amp * h
    npow = cfg.noise_power if snr_db is None else noise_power_for(cfg, snr_db)
    n = _cn(k_n, (n_sym,), npow)
    return c * symbols + n, c


def equalize(r: jax.Array, c: jax.Array) -> jax.Array:
    """Coherent (zero-forcing) equalization: ML detection on y = r/c."""
    return r / c


def noise_var_post_eq(c: jax.Array, cfg: ChannelConfig, *, snr_db=None) -> jax.Array:
    """Per-symbol noise variance after equalization (for soft LLRs).

    ``c``: ``(n_sym,)`` composite gains. ``snr_db`` overrides ``cfg.snr_db``
    (same contract as :func:`transmit`). Returns ``(n_sym,)`` float32.
    """
    npow = cfg.noise_power if snr_db is None else noise_power_for(cfg, snr_db)
    return npow / jnp.maximum(jnp.abs(c) ** 2, 1e-20)
