"""Wireless uplink channel model (paper Sec. II-B, eq. (7)).

r = sqrt(p d^-alpha) h s + n,   h ~ CN(0,1),   n ~ CN(0, sigma^2)

The parameter server knows the composite gain c = sqrt(p d^-alpha) h
(coherent detection); only noise is an error source. ``snr_db`` is the
*average received symbol SNR*: sigma^2 = p d^-alpha / snr_lin, so E|h|^2 = 1
gives the configured average SNR at the receiver, matching the paper's
"receiver SNR is set at gamma = 10 dB".

``block_len`` > 1 models block fading: the fading coefficient is constant
over runs of symbols — this is what makes the symbol interleaver matter.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["ChannelConfig", "transmit", "equalize", "noise_var_post_eq"]


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    snr_db: float = 10.0
    fading: str = "rayleigh"  # "rayleigh" | "awgn" | "block_rayleigh"
    block_len: int = 64  # symbols per fading block (block_rayleigh only)
    tx_power: float = 1.0
    distance: float = 10.0
    pathloss_exp: float = 3.0

    @property
    def large_scale_gain(self) -> float:
        return self.tx_power * self.distance ** (-self.pathloss_exp)

    @property
    def noise_power(self) -> float:
        return self.large_scale_gain / (10.0 ** (self.snr_db / 10.0))


def _cn(key: jax.Array, shape, var) -> jax.Array:
    """Complex normal CN(0, var)."""
    kr, ki = jax.random.split(key)
    s = jnp.sqrt(var / 2.0)
    return jax.lax.complex(
        jax.random.normal(kr, shape, dtype=jnp.float32) * s,
        jax.random.normal(ki, shape, dtype=jnp.float32) * s,
    )


def transmit(symbols: jax.Array, key: jax.Array, cfg: ChannelConfig):
    """Pass unit-energy symbols through the uplink. Returns (r, c).

    ``c`` is the composite channel gain known at the PS.
    """
    (n_sym,) = symbols.shape
    k_h, k_n = jax.random.split(key)
    amp = jnp.sqrt(cfg.large_scale_gain).astype(jnp.float32)
    if cfg.fading == "awgn":
        h = jnp.ones((n_sym,), dtype=jnp.complex64)
    elif cfg.fading == "rayleigh":
        h = _cn(k_h, (n_sym,), 1.0)
    elif cfg.fading == "block_rayleigh":
        n_blocks = -(-n_sym // cfg.block_len)
        hb = _cn(k_h, (n_blocks,), 1.0)
        h = jnp.repeat(hb, cfg.block_len)[:n_sym]
    else:
        raise ValueError(f"unknown fading {cfg.fading!r}")
    c = amp * h
    n = _cn(k_n, (n_sym,), cfg.noise_power)
    return c * symbols + n, c


def equalize(r: jax.Array, c: jax.Array) -> jax.Array:
    """Coherent (zero-forcing) equalization: ML detection on y = r/c."""
    return r / c


def noise_var_post_eq(c: jax.Array, cfg: ChannelConfig) -> jax.Array:
    """Per-symbol noise variance after equalization (for soft LLRs)."""
    return cfg.noise_power / jnp.maximum(jnp.abs(c) ** 2, 1e-20)
