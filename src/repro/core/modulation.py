"""Gray-coded square-QAM modulation with unequal bit protection.

Supports QPSK (k=2), 16-QAM (k=4), 64-QAM (k=6) and 256-QAM (k=8).

Bit-to-axis mapping (paper Sec. IV-A, Fig. 2 / Table I): symbol-index bits
MSB-first ``b0 b1 b2 ...`` alternate between the I and Q axes —

    b0 -> I Gray MSB,  b1 -> Q Gray MSB,  b2 -> I 2nd bit,  b3 -> Q 2nd, ...

so the protection order of the symbol-index bits is monotonically decreasing:
in a Gray-coded PAM, the level MSB has the lowest error probability and each
subsequent bit roughly doubles it. Combined with MSB-first float packing
(``float_codec.words_to_symbols``) the float sign/exponent bits receive the
constellation's built-in protection — the paper's Table I effect.

ML detection (paper eq. (8)): for coherent reception over a known channel
``r = c s + n``, ``argmin_s ||r - c s||`` equals nearest-point detection on
the equalized ``y = r/c``, which for square Gray QAM separates per axis into
clamp+round to the PAM grid followed by Gray encoding. ``demod_hard`` is this
closed form; ``demod_ml`` is the brute-force argmin oracle — tests prove they
match exactly.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "ModScheme",
    "MOD_SCHEMES",
    "gray_encode",
    "gray_decode",
    "constellation",
    "modulate",
    "demod_hard",
    "demod_ml",
    "bit_llrs",
    "rayleigh_qpsk_ber",
    "measure_ber",
]


@dataclasses.dataclass(frozen=True)
class ModScheme:
    """Static description of a square-QAM scheme."""

    name: str
    bits_per_symbol: int  # k

    @property
    def bits_per_axis(self) -> int:
        """Bits per I/Q axis (``k / 2`` for square QAM)."""
        return self.bits_per_symbol // 2

    @property
    def levels(self) -> int:
        """``L``: PAM levels per axis."""
        return 1 << self.bits_per_axis

    @property
    def points(self) -> int:
        """``M = L^2`` constellation points."""
        return 1 << self.bits_per_symbol

    @property
    def amp_norm(self) -> float:
        """Scale so the constellation has unit average symbol energy."""
        L = self.levels
        return math.sqrt(3.0 / (2.0 * (L * L - 1)))


MOD_SCHEMES = {
    "qpsk": ModScheme("qpsk", 2),
    "16qam": ModScheme("16qam", 4),
    "64qam": ModScheme("64qam", 6),
    "256qam": ModScheme("256qam", 8),
}


def scheme_for_bits(k: int) -> ModScheme:
    """The registered square-QAM scheme with ``bits_per_symbol == k``."""
    for s in MOD_SCHEMES.values():
        if s.bits_per_symbol == k:
            return s
    raise ValueError(f"unsupported bits_per_symbol={k}")


def gray_encode(n: jax.Array) -> jax.Array:
    """Binary-reflected Gray code of a level index."""
    n = n.astype(jnp.uint32)
    return n ^ (n >> 1)


def gray_decode(g: jax.Array) -> jax.Array:
    """Inverse Gray code (valid for up to 32-bit values)."""
    g = g.astype(jnp.uint32)
    for shift in (1, 2, 4, 8, 16):
        g = g ^ (g >> shift)
    return g


def _split_axes(sym: jax.Array, scheme: ModScheme) -> tuple[jax.Array, jax.Array]:
    """Symbol index -> (I Gray bits, Q Gray bits), alternating allocation."""
    p = scheme.bits_per_axis
    k = scheme.bits_per_symbol
    sym = sym.astype(jnp.uint32)
    gi = jnp.zeros_like(sym)
    gq = jnp.zeros_like(sym)
    for j in range(p):
        # bit positions within the symbol index, MSB-first: even -> I, odd -> Q
        bi = (sym >> jnp.uint32(k - 1 - 2 * j)) & jnp.uint32(1)
        bq = (sym >> jnp.uint32(k - 2 - 2 * j)) & jnp.uint32(1)
        gi = gi | (bi << jnp.uint32(p - 1 - j))
        gq = gq | (bq << jnp.uint32(p - 1 - j))
    return gi, gq


def _merge_axes(gi: jax.Array, gq: jax.Array, scheme: ModScheme) -> jax.Array:
    """Inverse of :func:`_split_axes`."""
    p = scheme.bits_per_axis
    k = scheme.bits_per_symbol
    sym = jnp.zeros_like(gi, dtype=jnp.uint32)
    for j in range(p):
        bi = (gi >> jnp.uint32(p - 1 - j)) & jnp.uint32(1)
        bq = (gq >> jnp.uint32(p - 1 - j)) & jnp.uint32(1)
        sym = sym | (bi << jnp.uint32(k - 1 - 2 * j))
        sym = sym | (bq << jnp.uint32(k - 2 - 2 * j))
    return sym


def modulate(sym: jax.Array, scheme: ModScheme) -> jax.Array:
    """Symbol indices -> complex64 constellation points (unit avg energy)."""
    L = scheme.levels
    gi, gq = _split_axes(sym, scheme)
    li = gray_decode(gi).astype(jnp.float32)
    lq = gray_decode(gq).astype(jnp.float32)
    a = (2.0 * li - (L - 1)) * scheme.amp_norm
    b = (2.0 * lq - (L - 1)) * scheme.amp_norm
    return jax.lax.complex(a, b)


def constellation(scheme: ModScheme) -> jax.Array:
    """The full constellation, indexed by symbol value (M,) complex64."""
    return modulate(jnp.arange(scheme.points, dtype=jnp.uint32), scheme)


def demod_hard(y_eq: jax.Array, scheme: ModScheme) -> jax.Array:
    """Closed-form ML detection on equalized symbols -> symbol indices."""
    L = scheme.levels
    inv = 1.0 / scheme.amp_norm

    def axis_level(x: jax.Array) -> jax.Array:
        lvl = jnp.round((x * inv + (L - 1)) * 0.5)
        return jnp.clip(lvl, 0, L - 1).astype(jnp.uint32)

    gi = gray_encode(axis_level(jnp.real(y_eq)))
    gq = gray_encode(axis_level(jnp.imag(y_eq)))
    return _merge_axes(gi, gq, scheme)


def demod_ml(y_eq: jax.Array, scheme: ModScheme) -> jax.Array:
    """Brute-force nearest-point ML detection (oracle; paper eq. (8))."""
    pts = constellation(scheme)
    d2 = jnp.abs(y_eq[..., None] - pts) ** 2
    return jnp.argmin(d2, axis=-1).astype(jnp.uint32)


def bit_llrs(y_eq: jax.Array, noise_var: jax.Array, scheme: ModScheme) -> jax.Array:
    """Exact per-bit LLRs (..., k) for soft-decision decoding (ECRT path).

    LLR(b) = log P(b=0|y) - log P(b=1|y), max-log approximation.
    """
    k = scheme.bits_per_symbol
    pts = constellation(scheme)
    idx = jnp.arange(scheme.points, dtype=jnp.uint32)
    d2 = jnp.abs(y_eq[..., None] - pts) ** 2 / jnp.maximum(noise_var[..., None], 1e-12)
    llrs = []
    for j in range(k):
        bit = (idx >> (k - 1 - j)) & 1
        m0 = jnp.min(jnp.where(bit == 0, d2, jnp.inf), axis=-1)
        m1 = jnp.min(jnp.where(bit == 1, d2, jnp.inf), axis=-1)
        llrs.append(m1 - m0)
    return jnp.stack(llrs, axis=-1)


def rayleigh_qpsk_ber(snr_db: float) -> float:
    """Closed-form QPSK BER over flat Rayleigh fading with coherent detection.

    ``snr_db`` is the average received *symbol* SNR Es/N0 (the paper's
    convention — it quotes 4e-2 @ 10 dB and 5e-3 @ 20 dB, which this
    formula reproduces): with gamma_b = Es/N0 / 2,
        Pb = 1/2 (1 - sqrt(gamma_b / (1 + gamma_b))).
    """
    gamma_b = 10.0 ** (snr_db / 10.0) / 2.0
    return 0.5 * (1.0 - math.sqrt(gamma_b / (1.0 + gamma_b)))


def measure_ber(
    key: jax.Array,
    scheme: ModScheme,
    snr_db: float,
    n_symbols: int = 1 << 17,
    fading: str = "rayleigh",
) -> jax.Array:
    """Empirical BER of the full mod/channel/demod chain (no coding)."""
    from repro.core import channel as _channel

    k_sym, k_ch = jax.random.split(key)
    sym = jax.random.randint(k_sym, (n_symbols,), 0, scheme.points).astype(jnp.uint32)
    tx = modulate(sym, scheme)
    cfg = _channel.ChannelConfig(snr_db=snr_db, fading=fading)
    r, c = _channel.transmit(tx, k_ch, cfg)
    y = _channel.equalize(r, c)
    rx = demod_hard(y, scheme)
    diff = sym ^ rx
    nbits = jnp.sum(jax.vmap(lambda d: jnp.sum(_popcount(d)))(diff[None])[0])
    return nbits / (n_symbols * scheme.bits_per_symbol)


def _popcount(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


popcount = _popcount
