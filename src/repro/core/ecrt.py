"""ECRT baseline: rate-1/2 QC-LDPC FEC + retransmission (paper Sec. V).

The paper's baseline uses the IEEE 802.11n LDPC code, n = 648, R = 1/2
(Z = 27, d_min = 15 -> corrects 7 hard errors). We build a QC-LDPC code with
the 802.11n *structure* — base matrix Hb = [A | T] of 12 x 24 circulant
blocks, with a dual-diagonal parity part T (identity on the diagonal and
sub-diagonal) which is lower-bidiagonal and hence invertible over GF(2) —
and decode with normalized min-sum belief propagation (soft decision).

Encoding uses a dense GF(2) precomputed map P = T^-1 A (numpy, done once per
code and cached); decoding runs a fixed number of min-sum iterations as a
``lax.scan`` with a final syndrome check. Retransmission (new channel
realization) is issued per failed codeword, up to ``max_tx`` rounds — that
loop lives in ``transport.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LdpcCode", "make_code", "encode", "decode", "syndrome_ok"]

N_DEFAULT = 648
Z_DEFAULT = 27


@dataclasses.dataclass(frozen=True)
class LdpcCode:
    """Immutable code description (hashable; arrays exposed via properties)."""

    n: int = N_DEFAULT
    z: int = Z_DEFAULT
    seed: int = 0
    iters: int = 30
    alpha: float = 0.8  # min-sum normalization factor

    @property
    def k(self) -> int:
        """Information bits per codeword (rate-1/2: ``n // 2``)."""
        return self.n // 2

    @functools.cached_property
    def _matrices(self):
        return _build_matrices(self.n, self.z, self.seed)

    @property
    def H(self) -> np.ndarray:
        """``(n-k, n)`` uint8 parity-check matrix."""
        return self._matrices[0]

    @property
    def P(self) -> np.ndarray:
        """``(n-k, k)`` uint8 generator part: ``parity = P @ m (mod 2)``."""
        return self._matrices[1]


def _circulant(z: int, shift: int) -> np.ndarray:
    return np.roll(np.eye(z, dtype=np.uint8), shift, axis=1)


def _build_matrices(n: int, z: int, seed: int):
    nb = n // z  # block columns (24)
    mb = nb // 2  # block rows (12)
    kb = nb - mb
    rng = np.random.default_rng(seed)
    # Information part A: column weight 3 per block-column.
    base = -np.ones((mb, nb), dtype=np.int64)  # -1 = zero block
    for c in range(kb):
        rows = rng.choice(mb, size=3, replace=False)
        for r in rows:
            base[r, c] = rng.integers(0, z)
    # Dual-diagonal parity part T (shift-0 identities).
    for r in range(mb):
        base[r, kb + r] = 0
        if r > 0:
            base[r, kb + r - 1] = 0
    H = np.zeros((mb * z, nb * z), dtype=np.uint8)
    for r in range(mb):
        for c in range(nb):
            if base[r, c] >= 0:
                H[r * z : (r + 1) * z, c * z : (c + 1) * z] = _circulant(z, base[r, c])
    A = H[:, : kb * z]
    T = H[:, kb * z :]
    # Invert lower-bidiagonal-by-blocks T over GF(2) by forward substitution.
    m = mb * z
    Tinv = np.zeros((m, m), dtype=np.uint8)
    # Solve T x = e_j column by column; T is lower block-bidiagonal with
    # identity diagonal blocks, so x_0 = b_0, x_r = b_r + x_{r-1}.
    for j in range(m):
        b = np.zeros(m, dtype=np.uint8)
        b[j] = 1
        x = np.zeros(m, dtype=np.uint8)
        for r in range(mb):
            blk = b[r * z : (r + 1) * z].copy()
            if r > 0:
                blk ^= x[(r - 1) * z : r * z]
            x[r * z : (r + 1) * z] = blk
        Tinv[:, j] = x
    P = (Tinv @ A) % 2
    assert ((H[:, : kb * z] @ np.eye(kb * z, dtype=np.uint8) % 2).shape[0]) == m
    # Sanity: H @ [m ; P m] = A m + T (Tinv A m) = 0.
    mtest = rng.integers(0, 2, size=(kb * z,)).astype(np.uint8)
    cw = np.concatenate([mtest, (P @ mtest) % 2])
    assert not ((H @ cw) % 2).any(), "LDPC construction failed H c != 0"
    return H.astype(np.uint8), P.astype(np.uint8)


def make_code(**kw) -> LdpcCode:
    """Build an :class:`LdpcCode` (convenience constructor; same kwargs)."""
    return LdpcCode(**kw)


def encode(msg_bits: jax.Array, code: LdpcCode) -> jax.Array:
    """Systematic encode. msg_bits: (..., k) in {0,1} -> (..., n)."""
    P = jnp.asarray(code.P, dtype=jnp.uint32)
    parity = jnp.mod(msg_bits.astype(jnp.uint32) @ P.T, 2)
    return jnp.concatenate([msg_bits.astype(jnp.uint32), parity], axis=-1)


def syndrome_ok(hard_bits: jax.Array, code: LdpcCode) -> jax.Array:
    """True where H c = 0 (per codeword). hard_bits: (..., n)."""
    H = jnp.asarray(code.H, dtype=jnp.uint32)
    syn = jnp.mod(hard_bits.astype(jnp.uint32) @ H.T, 2)
    return jnp.all(syn == 0, axis=-1)


def decode(llr: jax.Array, code: LdpcCode) -> tuple[jax.Array, jax.Array]:
    """Normalized min-sum decode.

    llr: (..., n) channel LLRs (positive = bit 0 likelier).
    Returns (hard_bits (..., n) uint32, ok (...,) bool).
    """
    H = jnp.asarray(code.H, dtype=jnp.float32)  # (m, n) 0/1 mask
    mask = H[None] if llr.ndim == 2 else H
    # Work in (..., m, n) edge space, dense-masked.
    batch_shape = llr.shape[:-1]
    m, n = code.H.shape
    msk = jnp.broadcast_to(H, batch_shape + (m, n))

    def body(carry, _):
        v2c = carry  # (..., m, n) variable->check messages
        # Check node update: for each row, product of signs and min of
        # magnitudes over the row excluding self.
        mag = jnp.where(msk > 0, jnp.abs(v2c), jnp.inf)
        sgn = jnp.where(v2c < 0, -1.0, 1.0) * msk + (1.0 - msk)
        row_sign = jnp.prod(sgn, axis=-1, keepdims=True)
        min1 = jnp.min(mag, axis=-1, keepdims=True)
        argmin1 = jnp.argmin(mag, axis=-1)
        mag2 = jnp.where(
            jax.nn.one_hot(argmin1, n, dtype=bool), jnp.inf, mag
        )
        min2 = jnp.min(mag2, axis=-1, keepdims=True)
        use_min = jnp.where(mag == min1, min2, min1)
        self_sign = jnp.where(v2c < 0, -1.0, 1.0)
        c2v = code.alpha * row_sign * self_sign * jnp.where(msk > 0, use_min, 0.0)
        c2v = jnp.where(jnp.isfinite(c2v), c2v, 0.0)
        # Variable node update.
        total = llr[..., None, :] + jnp.sum(c2v, axis=-2, keepdims=True)
        v2c_new = (total - c2v) * msk
        post = total[..., 0, :]
        return v2c_new, post

    v2c0 = llr[..., None, :] * msk
    v2c_final, posts = jax.lax.scan(body, v2c0, None, length=code.iters)
    post = posts[-1]
    hard = (post < 0).astype(jnp.uint32)
    return hard, syndrome_ok(hard, code)
