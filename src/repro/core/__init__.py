"""Core: the paper's approximate-wireless-communication contribution."""

from repro.core import keylanes
from repro.core.channel import ChannelConfig, transmit, equalize, per_client_snr_db
from repro.core.float_codec import (
    f32_to_bits,
    bits_to_f32,
    clamp_exponent_bits,
    exponent_clamp_mask,
)
from repro.core.modulation import MOD_SCHEMES, ModScheme, modulate, demod_hard, demod_ml
from repro.core.transport import (
    TransportConfig,
    TxStats,
    client_keys,
    transmit_batch,
    transmit_flat,
    transmit_pytree,
    transmit_pytree_batch,
)
from repro.core.aggregation import fedsgd_aggregate, approx_allreduce
from repro.core.latency import PhyTimings, round_airtime, calibrate_ecrt
from repro.core.bounds import LayerSpec, gradient_bound, certified_clamp_bound
