"""Composable gradient-transport pipeline (the paper's Sec. IV protocol).

Modes
-----
``perfect``  error-free delivery (genie; used as the no-wireless reference).
``naive``    raw float bits through the fading channel, no prior — the
             paper's collapse-to-10%-accuracy baseline.
``approx``   the paper's proposed scheme: MSB-first packing + Gray-QAM
             unequal protection + symbol interleaving + bit-30 clamp at the
             receiver (optionally a tighter certified exponent mask).
``ecrt``     rate-1/2 LDPC FEC + retransmission until every codeword decodes
             (bits exact at the PS, >= 2x airtime). ``simulate_fec=False``
             swaps the real min-sum decoder for the calibrated analytic
             model (bits exact + measured E[tx]) — used inside long FL loops
             where decoding every round would only re-measure a constant.

The entry points operate on flat float32 vectors or whole pytrees and return
``(values_hat, TxStats)``; ``TxStats`` carries what the latency model needs.

Single-client vs batched
------------------------
``transmit_flat`` carries one client's payload. ``transmit_batch`` carries a
``(num_clients, payload)`` matrix through per-client *independent* fading
channels in one fused computation (vmap in the jnp paths, a 2-D grid in the
Pallas kernel path) and returns per-client ``TxStats`` with ``(num_clients,)``
fields. The key schedule is ``fold_in``-based (:func:`client_keys`): client
``i`` uses ``jax.random.fold_in(key, client_offset + i)``, so a batched call
is bit-identical to a Python loop of ``transmit_flat`` calls over the same
schedule, and a sharded batch (``launch.sharding.shard_transmit_batch``)
reproduces the unsharded batch exactly. Heterogeneous link quality is
expressed either via a per-client ``ChannelConfig.snr_db`` sequence or the
``snr_db`` override argument.

Mixed-mode dispatch
-------------------
``transmit_batch_adaptive`` carries a cohort where client ``i`` uses
``cfgs[mode_idx[i]]`` (the link-adaptation hook). Two dispatch strategies:

``bucketed`` (default when ``mode_idx`` is concrete)
    Stable-argsort clients by mode, gather payload rows into contiguous
    per-mode buckets, run each mode **once** as a fused single-mode batch on
    its bucket, scatter results back to original client order. Total work is
    O(num_clients) payload pipelines instead of O(modes x num_clients), and
    each bucket may take the fused Pallas kernel path (``cfg.use_kernel``).
    Bucket capacities round up on a quarter-octave schedule (masked tail
    rows, outputs discarded; see ``_bucket_capacity``) so the per-mode jit
    traces are bounded (``~4 log2(num_clients)`` shapes per mode for any
    sequence of mode mixes) and reused as the mix changes round to round.
    The fold_in key rides the *client index*, not the bucket slot, so the
    result is bit-identical to the select path and to per-client
    ``transmit_flat`` calls.

``select`` (default when ``mode_idx`` is traced)
    One ``lax.switch`` over the config table, vmapped over clients: a single
    fused XLA program, but the switch lowers to a select over **all**
    branches, so every client pays every mode's FLOPs (~``len(cfgs)``x) and
    the Pallas kernel path cannot lower. Kept for fully-traced contexts
    (``jax.jit`` round steps with a traced mode vector, ``shard_map``
    bodies).

Downlink broadcast
------------------
``transmit_broadcast`` (and the ``_adaptive``/``_pytree`` variants) carry
**one** payload — the PS's global model — through ``num_clients``
independent *downlink* channels: the broadcast leg of an FL round, where
each client receives its own corrupted copy of the same bits. The engine is
the same ``_batch_with_keys`` as the uplink; only the key schedule differs:
client ``i`` draws ``fold_in(key, DOWNLINK_KEY_LANE + i)`` instead of
``fold_in(key, i)``, so a round may feed its *uplink* base key to the
broadcast leg and the two legs' fading/noise realizations stay independent
— and, critically, adding a downlink leg leaves every uplink draw of an
existing run untouched (no extra ``jax.random.split`` is consumed).

Sparse uplinks
--------------
``transmit_sparse`` / ``transmit_sparse_batch`` carry a *compressed* payload:
``k`` selected values plus their coordinate indices (see
:mod:`repro.compress`). The value payload rides the existing pipeline
(MSB-first/Gray-QAM for uncoded modes, LDPC for ECRT) under the client's
transport key; the index header rides protected bits (the constellation's
two most-protected Gray positions, an ECRT-coded leg, or an error-free
control channel) under ``fold_in(client_key, HEADER_KEY_LANE)``. The
batched form shares :func:`client_keys`' fold_in schedule, so it is
bit-identical to a per-client loop of ``transmit_sparse`` — the same
contract as the dense engine. These entry points delegate to
``repro.compress.framing`` (imported lazily to keep ``core`` free of an
upward dependency).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as channel_lib
from repro.core import keylanes
from repro.core import ecrt as ecrt_lib
from repro.core import float_codec as fc
from repro.core import modulation as mod_lib

__all__ = [
    "DOWNLINK_KEY_LANE",
    "TransportConfig",
    "TxStats",
    "clear_kernel_rows",
    "client_keys",
    "transmit_flat",
    "transmit_pytree",
    "transmit_batch",
    "transmit_pytree_batch",
    "transmit_batch_adaptive",
    "transmit_pytree_batch_adaptive",
    "transmit_batch_aggregate",
    "transmit_pytree_batch_aggregate",
    "transmit_batch_adaptive_aggregate",
    "transmit_pytree_batch_adaptive_aggregate",
    "transmit_sparse",
    "transmit_sparse_batch",
    "transmit_broadcast",
    "transmit_broadcast_adaptive",
    "transmit_pytree_broadcast",
    "transmit_pytree_broadcast_adaptive",
]

# fold_in lane where downlink-broadcast client keys live: uplink client i
# draws fold_in(key, i), downlink client i draws fold_in(key, LANE + i), so
# one round key serves both legs with independent channel realizations.
# Cohorts must stay below the lane width (~1M clients) or the two schedules
# would collide; transmit_broadcast validates this. Declared centrally in
# repro.core.keylanes (overlap-checked at import); re-exported here with
# the historical value (1 << 20), which the goldens pin.
DOWNLINK_KEY_LANE = keylanes.DOWNLINK_KEY_LANE


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """One uplink transport: wire mode, modulation, channel, and FEC knobs."""

    mode: str = "approx"  # perfect | naive | approx | ecrt
    modulation: str = "qpsk"
    channel: channel_lib.ChannelConfig = dataclasses.field(
        default_factory=channel_lib.ChannelConfig
    )
    interleave: bool = True
    clamp_bound: float = 2.0  # paper: |g| < 2 -> clear bit 30 only
    # Wire format: "float32" (paper) or "bfloat16" (beyond-paper: bf16 shares
    # the f32 exponent layout, so the bit-clamp prior applies verbatim while
    # halving airtime and, in the distributed uplink, psum bytes).
    wire_dtype: str = "float32"
    # Process the payload in chunks of this many floats (0 = whole payload).
    # The uncoded pipeline materializes ~36 B of intermediates per 4 B float
    # (symbols + complex stream + noise); chunking via lax.map bounds the
    # live set to chunk_elems x 36 B — required for multi-GB gradients.
    chunk_elems: int = 0
    ldpc: ecrt_lib.LdpcCode = dataclasses.field(default_factory=ecrt_lib.LdpcCode)
    max_tx: int = 8  # ECRT retransmission cap
    simulate_fec: bool = True
    ecrt_expected_tx: float = 1.0  # analytic model (calibrated; see latency)
    use_kernel: bool = False  # route through the fused Pallas kernel

    @property
    def scheme(self) -> mod_lib.ModScheme:
        """The resolved :class:`~repro.core.modulation.ModScheme`."""
        return mod_lib.MOD_SCHEMES[self.modulation]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxStats:
    """Per-uplink transmission statistics.

    Unit conventions (the single source of truth — ``latency.round_airtime``
    and every benchmark consume these):

    * ``data_symbols`` — **complex modulation symbols** put on the air,
      including every ECRT retransmission and FEC parity. Airtime is
      ``data_symbols / symbol_rate``; this is *not* a bit count.
    * ``transmissions`` — PHY transmissions (preamble+ACK overheads paid).
      Exactly 1 for perfect/naive/approx; mean transmissions per codeword
      for ECRT (can be fractional for the analytic model).
    * ``bit_errors`` — residual flipped **payload bits** after the full
      receiver pipeline (post-clamp for approx); 0 for perfect/ECRT.
    * ``n_bits`` — **payload bits offered**, i.e. ``n_floats * wire_bits``
      (32 for float32 wire, 16 for bfloat16). FEC parity and retransmitted
      copies are *not* counted here — they show up in ``data_symbols`` only,
      so ``ber = bit_errors / n_bits`` is the end-to-end payload BER.
    * ``bits_on_air`` — bits actually put **on the air**:
      ``data_symbols * bits_per_symbol`` of the scheme, so FEC parity,
      retransmissions, and the sparse framing's index header all count,
      and the value is exactly proportional to data airtime. Equals
      ``n_bits`` for uncoded dense modes; ``2 * n_bits * E[tx]`` for ECRT;
      value + header bits for sparse uplinks — the telemetry axis the
      compression subsystem's 10–50x reduction is measured on.

    Fields are float32 jnp scalars for a single uplink (``transmit_flat``),
    or ``(num_clients,)`` arrays for a batched one (``transmit_batch``) —
    every formula above applies elementwise.

    ``mode_idx`` is the link-adaptation extension: ``None`` for single-mode
    calls, or the ``(num_clients,)`` int32 vector of per-client mode choices
    for :func:`transmit_batch_adaptive` — indices into the config table the
    caller dispatched over, so ``latency.round_airtime_adaptive`` can price
    each client's airtime under its own mode.
    """

    data_symbols: jax.Array  # symbols of payload actually sent (incl. retx)
    transmissions: jax.Array  # number of PHY transmissions (1 unless ECRT)
    bit_errors: jax.Array  # residual bit errors after the receiver pipeline
    n_bits: jax.Array
    mode_idx: Any = None  # (num_clients,) int32 for adaptive batches
    bits_on_air: Any = None  # total bits on air (payload + header + parity)

    @property
    def ber(self) -> jax.Array:
        """End-to-end payload bit-error rate (``bit_errors / n_bits``)."""
        return self.bit_errors / jnp.maximum(self.n_bits, 1)

    def round_summary(self) -> dict:
        """Cohort-level aggregates as plain Python floats — the
        ``uplink_*`` field group of :class:`repro.obs.records.RoundRecord`.

        Sums/means the per-client fields to the host once (a device
        transfer), so the observability layer calls this only when a sink
        is attached; all units follow the class docstring (``uplink_ber``
        is the cohort's pooled payload BER, total errors over total offered
        bits).
        """
        # Host-side stats accumulator — never touches the wire format.
        f64 = np.float64  # lint: ignore[dtype-discipline]
        symbols = np.asarray(self.data_symbols, f64)
        bits = np.asarray(self.n_bits, f64)
        errors = np.asarray(self.bit_errors, f64)
        out = {
            "uplink_symbols": float(symbols.sum()),
            "uplink_bits": float(bits.sum()),
            "uplink_bit_errors": float(errors.sum()),
            "uplink_ber": float(errors.sum() / max(bits.sum(), 1.0)),
            "uplink_mean_tx": float(
                np.mean(np.asarray(self.transmissions, f64))),
        }
        if self.bits_on_air is not None:
            out["uplink_bits_on_air"] = float(
                np.asarray(self.bits_on_air, f64).sum())
        return out

    def client_metrics(self) -> dict:
        """Per-client *device* arrays for the sketch layer, keyed by the
        metric names of ``repro.obs.metrics.DEFAULT_LAYOUTS``.

        Unlike :meth:`round_summary` this never syncs to the host — the
        values feed ``RoundSketcher.round_group``'s jitted reduction, so
        the only host transfer is the fixed-size bucket counts.
        """
        out = {"ber": self.ber, "transmissions": self.transmissions,
               "n_bits": self.n_bits}
        if self.bits_on_air is not None:
            out["bits_on_air"] = self.bits_on_air
        return out


def _stats(data_symbols, transmissions, bit_errors, n_bits,
           bits_on_air=None) -> TxStats:
    f = lambda v: jnp.asarray(v, jnp.float32)
    return TxStats(f(data_symbols), f(transmissions), f(bit_errors), f(n_bits),
                   bits_on_air=None if bits_on_air is None else f(bits_on_air))


def _through_channel(sym_stream: jax.Array, key: jax.Array, cfg: TransportConfig,
                     snr_db=None):
    tx = mod_lib.modulate(sym_stream, cfg.scheme)
    r, c = channel_lib.transmit(tx, key, cfg.channel, snr_db=snr_db)
    y = channel_lib.equalize(r, c)
    return y, c


def _uncoded(x: jax.Array, key: jax.Array, cfg: TransportConfig, clamp: bool,
             snr_db=None):
    """Shared path for naive/approx: bits -> QAM -> channel -> bits."""
    k = cfg.scheme.bits_per_symbol
    n = x.shape[0]
    wb = 16 if cfg.wire_dtype == "bfloat16" else 32
    s_per_word = wb // k
    u = fc.bf16_to_bits(x) if wb == 16 else fc.f32_to_bits(x)
    sym = fc.words_to_symbols(u, k, wb)  # (N, S)
    stream = fc.interleave(sym) if cfg.interleave else sym.reshape(-1)
    y, _ = _through_channel(stream, key, cfg, snr_db)
    rx_stream = mod_lib.demod_hard(y, cfg.scheme)
    rx = (
        fc.deinterleave(rx_stream, n, s_per_word)
        if cfg.interleave
        else rx_stream.reshape(n, s_per_word)
    )
    u_hat = fc.symbols_to_words(rx, k, wb)
    if clamp:
        u_hat = (fc.clamp_exponent_bits16(u_hat, cfg.clamp_bound) if wb == 16
                 else fc.clamp_exponent_bits(u_hat, cfg.clamp_bound))
    bit_errors = jnp.sum(mod_lib.popcount(u.astype(jnp.uint32) ^ u_hat.astype(jnp.uint32)))
    # NOTE: bit_errors counts *post-clamp* discrepancies vs the true words —
    # the clamp can only reduce this count since the true exponent MSB is 0.
    out = fc.bits_to_bf16(u_hat).astype(jnp.float32) if wb == 16 else fc.bits_to_f32(u_hat)
    return out, _stats(n * s_per_word, 1, bit_errors, n * wb, n * wb)


def _ecrt_real(x: jax.Array, key: jax.Array, cfg: TransportConfig, snr_db=None):
    """Real LDPC + retransmission loop (fixed max_tx rounds, masked)."""
    code = cfg.ldpc
    k_info = code.k
    u = fc.f32_to_bits(x)
    n_words = u.shape[0]
    # words -> bit matrix (n_bits,)
    shifts = jnp.uint32(31 - jnp.arange(32, dtype=jnp.uint32))
    bits = ((u[:, None] >> shifts) & jnp.uint32(1)).reshape(-1)
    pad = (-bits.shape[0]) % k_info
    bits_p = jnp.pad(bits, (0, pad))
    msgs = bits_p.reshape(-1, k_info)  # (C, k)
    cw = ecrt_lib.encode(msgs, code)  # (C, n)
    n_cw, n_code = cw.shape
    k_mod = cfg.scheme.bits_per_symbol
    assert n_code % k_mod == 0
    sym_per_cw = n_code // k_mod

    def tx_round(carry, kr):
        decoded, ok, tx_count = carry
        # Map codeword bits to symbols (k_mod bits per symbol, MSB-first).
        b = cw.reshape(n_cw, sym_per_cw, k_mod)
        weights = jnp.uint32(1) << jnp.uint32(k_mod - 1 - jnp.arange(k_mod))
        sym = jnp.sum(b * weights, axis=-1, dtype=jnp.uint32).reshape(-1)
        y, c = _through_channel(sym, kr, cfg, snr_db)
        nv = channel_lib.noise_var_post_eq(c, cfg.channel, snr_db=snr_db)
        llr = mod_lib.bit_llrs(y, nv, cfg.scheme).reshape(n_cw, n_code)
        hard, ok_new = ecrt_lib.decode(llr, code)
        take = (~ok) & ok_new
        decoded = jnp.where(take[:, None], hard, decoded)
        tx_count = tx_count + (~ok).astype(jnp.int32)
        ok = ok | ok_new
        return (decoded, ok, tx_count), None

    init = (
        jnp.zeros_like(cw),
        jnp.zeros((n_cw,), dtype=bool),
        jnp.zeros((n_cw,), dtype=jnp.int32),
    )
    keys = jax.random.split(key, cfg.max_tx)
    (decoded, ok, tx_count), _ = jax.lax.scan(tx_round, init, keys)
    # Failed codewords after max_tx: fall back to their last hard decision --
    # in practice ok -> all True at sane SNRs; tests assert this.
    decoded = jnp.where(ok[:, None], decoded, cw)  # genie fallback, counted
    info = decoded[:, :k_info].reshape(-1)[: bits.shape[0]]
    u_hat = jnp.sum(
        (info.reshape(n_words, 32).astype(jnp.uint32)) << shifts, axis=-1,
        dtype=jnp.uint32,
    )
    bit_errors = jnp.sum(mod_lib.popcount(u ^ u_hat))
    total_tx = jnp.sum(tx_count)
    return fc.bits_to_f32(u_hat), _stats(
        total_tx * sym_per_cw, jnp.mean(tx_count.astype(jnp.float32)),
        bit_errors, n_words * 32, total_tx * sym_per_cw * k_mod,
    )


def _ecrt_analytic(x: jax.Array, cfg: TransportConfig):
    """Calibrated ECRT model: exact bits, measured expected transmissions.

    Note: the model is SNR-blind by construction — ``ecrt_expected_tx`` is a
    single constant calibrated for one link quality, so per-client ``snr_db``
    does not vary these stats. Heterogeneous-SNR ECRT airtime needs the real
    chain (``simulate_fec=True``) or per-client calibration upstream.
    """
    n_words = x.shape[0]
    n_bits = n_words * 32
    k_mod = cfg.scheme.bits_per_symbol
    coded_bits = 2 * n_bits  # rate 1/2
    sym = coded_bits / k_mod * cfg.ecrt_expected_tx
    return x, _stats(sym, cfg.ecrt_expected_tx, 0, n_bits,
                     coded_bits * cfg.ecrt_expected_tx)


def _uncoded_chunked(x: jax.Array, key: jax.Array, cfg: TransportConfig,
                     clamp: bool, snr_db=None):
    """lax.map over fixed-size chunks: bounds the 36 B/float live set."""
    n = x.shape[0]
    chunk = cfg.chunk_elems
    pad = (-n) % chunk
    xp = jnp.pad(x, (0, pad)).reshape(-1, chunk)
    n_chunks = xp.shape[0]
    # chunk indices ride the client-space chunk lane of the client key
    keylanes.check_range(0, n_chunks, space="client")
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_chunks))

    def one(args):
        xc, kc = args
        return _uncoded(xc, kc, cfg, clamp=clamp, snr_db=snr_db)

    x_hat, stats = jax.lax.map(one, (xp, keys))
    x_hat = x_hat.reshape(-1)
    # The chunk pipeline counts errors over the padding too; the transmitted
    # pad words are exactly 0, so every set bit in a received pad word is a
    # counted error — subtract them so stats cover only the true payload.
    wb = 16 if cfg.wire_dtype == "bfloat16" else 32
    pad_bits = (fc.bf16_to_bits(x_hat[n:]).astype(jnp.uint32) if wb == 16
                else fc.f32_to_bits(x_hat[n:]))
    pad_errs = jnp.sum(mod_lib.popcount(pad_bits))
    k = cfg.scheme.bits_per_symbol
    return x_hat[:n], _stats(
        n * (wb // k), 1, jnp.sum(stats.bit_errors) - pad_errs, n * wb, n * wb
    )


def transmit_flat(x: jax.Array, key: jax.Array, cfg: TransportConfig, *,
                  snr_db=None):
    """Transmit one client's flat float vector.

    Args:
      x: ``(N,)`` payload (cast to float32; wire format per ``cfg.wire_dtype``).
      key: PRNG key for this uplink's fading + noise realization.
      cfg: transport configuration (mode, modulation, channel, ...).
      snr_db: optional scalar override of ``cfg.channel.snr_db`` (may be a
        traced scalar — this is the per-client hook ``transmit_batch`` vmaps
        over).

    Returns:
      ``(x_hat, stats)``: the received ``(N,)`` float32 payload and scalar
      :class:`TxStats`.
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    wb = 16 if cfg.wire_dtype == "bfloat16" else 32
    if cfg.mode == "perfect":
        k = cfg.scheme.bits_per_symbol
        return x, _stats(n * wb // k, 1, 0, n * wb, n * wb)
    if cfg.mode in ("naive", "approx") and cfg.use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.approx_channel_transmit(x, key, cfg, snr_db=snr_db)
    if cfg.mode in ("naive", "approx") and cfg.chunk_elems and n > cfg.chunk_elems:
        return _uncoded_chunked(x, key, cfg, clamp=cfg.mode == "approx",
                                snr_db=snr_db)
    if cfg.mode == "naive":
        return _uncoded(x, key, cfg, clamp=False, snr_db=snr_db)
    if cfg.mode == "approx":
        return _uncoded(x, key, cfg, clamp=True, snr_db=snr_db)
    if cfg.mode == "ecrt":
        if cfg.simulate_fec:
            return _ecrt_real(x, key, cfg, snr_db=snr_db)
        return _ecrt_analytic(x, cfg)
    raise ValueError(f"unknown transport mode {cfg.mode!r}")


def client_keys(key: jax.Array, num_clients: int, offset=0) -> jax.Array:
    """The batched uplink's key schedule: ``key_i = fold_in(key, offset + i)``.

    ``offset`` may be a traced int — ``shard_transmit_batch`` passes each
    shard's global client offset so sharded and unsharded batches agree
    (the key-lane span check only runs on concrete offsets).
    Returns ``(num_clients, key_size)`` keys.
    """
    keylanes.check_range(offset, num_clients)
    idx = jnp.arange(num_clients) + offset
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


def _resolve_batch_snr(cfg: TransportConfig, num_clients: int, snr_db):
    """Per-client SNR column for a batch: explicit override > config > None.

    ``None`` means "homogeneous, use the config scalar" — that path is kept
    distinct so it stays bit-identical to ``transmit_flat`` (no dB->linear
    recomputation under trace). Shape validation happens up front in
    ``channel.snr_db_vector`` (the single shared rule): anything that is not
    a scalar, a single element, or exactly ``(num_clients,)`` raises
    ValueError naming both sizes.
    """
    if snr_db is not None:
        return channel_lib.snr_db_vector(snr_db, num_clients)
    return channel_lib.per_client_snr_db(cfg.channel, num_clients)


def _donation_supported() -> bool:
    """Whether this backend honours ``donate_argnums`` (XLA CPU ignores it
    with a warning, so the ``donate=`` plumbing silently no-ops there)."""
    return jax.default_backend() in ("gpu", "tpu")


def transmit_batch(x: jax.Array, key: jax.Array, cfg: TransportConfig, *,
                   snr_db=None, client_offset=0, donate: bool = False):
    """Transmit ``num_clients`` payloads through independent fading uplinks.

    One fused computation (single jittable call): the uncoded/ECRT paths vmap
    the per-client pipeline; the kernel path (``cfg.use_kernel``) lowers to a
    2-D ``(clients, tiles)`` Pallas grid.

    Args:
      x: ``(num_clients, N)`` payload matrix (cast to float32).
      key: base PRNG key; client ``i`` uses
        ``fold_in(key, client_offset + i)`` (see :func:`client_keys`), so the
        result is bit-identical to looping ``transmit_flat`` over that
        schedule.
      cfg: transport configuration. ``cfg.channel.snr_db`` may be a
        per-client sequence (heterogeneous links).
      snr_db: optional per-client SNR override — scalar or ``(num_clients,)``;
        takes precedence over the config. Varies the channel realization for
        every mode except the SNR-blind analytic ECRT model
        (``mode='ecrt', simulate_fec=False`` — see ``_ecrt_analytic``).
      client_offset: global index of row 0 (used by the sharded dispatch).
      donate: release the ``x`` buffer into the kernel launch (the uplink
        payload is dead after transmission). Honoured on the kernel path on
        backends that support donation (gpu/tpu); a no-op elsewhere.

    Returns:
      ``(x_hat, stats)``: ``(num_clients, N)`` float32 received payloads and
      :class:`TxStats` with ``(num_clients,)`` fields.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"transmit_batch wants (num_clients, N); got {x.shape}")
    num_clients = x.shape[0]
    snr_vec = _resolve_batch_snr(cfg, num_clients, snr_db)
    keys = client_keys(key, num_clients, client_offset)

    return _batch_with_keys(x, keys, cfg, snr_vec, donate=donate)


def _batch_with_keys(x: jax.Array, keys: jax.Array, cfg: TransportConfig,
                     snr_vec, *, num_active=None, donate: bool = False):
    """Single-mode batch over explicit per-client keys.

    The shared engine under ``transmit_batch`` (keys from the fold_in
    schedule) and each bucket of the bucketed adaptive dispatch (keys
    gathered by client index). ``num_active`` masks the tail of a padded
    bucket on the kernel path (masked rows skip the grid work); the jnp
    paths compute padded rows and the caller discards them.
    """
    if cfg.mode in ("naive", "approx") and cfg.use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.approx_channel_transmit_batch(
            x, keys, cfg, snr_vec, num_active=num_active,
            donate=donate and _donation_supported())

    # All jnp paths (perfect/naive/approx/ecrt, chunked or not) are one vmap
    # over the single-client pipeline — batch semantics == loop semantics by
    # construction (vmap broadcasts the constant stats of perfect/analytic).
    if snr_vec is None:
        return jax.vmap(lambda xc, kc: transmit_flat(xc, kc, cfg))(x, keys)
    return jax.vmap(lambda xc, kc, s: transmit_flat(xc, kc, cfg, snr_db=s))(
        x, keys, snr_vec)


def _scan_weighted_sum(rows, weights, num_active=None):
    """``sum_c weights[c] * rows[c]`` as a ``lax.scan`` over the client axis.

    The arithmetic contract of the fused path: one multiply + one add per
    client per element, in client order — the same shape as the Pallas
    kernel's grid-loop accumulation and ``aggregation.fedsgd_aggregate_batch``
    (an unrolled sum is NOT bit-identical: LLVM contracts the first multiply
    of an add chain into an fma). ``num_active`` masks tail rows by carrying
    the accumulator through unchanged (a select, not a zero weight — a zero
    weight would still turn NaN payload lanes into NaN aggregates).
    """
    w = jnp.asarray(weights, jnp.float32)
    rows = rows.astype(jnp.float32)
    zero = jnp.zeros(rows.shape[1:], jnp.float32)
    if num_active is None:
        def body(acc, wx):
            wc, xc = wx
            return acc + wc * xc, None

        agg, _ = jax.lax.scan(body, zero, (w, rows))
        return agg
    na = jnp.asarray(num_active, jnp.int32)

    def body_masked(acc, iwx):
        i, wc, xc = iwx
        return jnp.where(i < na, acc + wc * xc, acc), None

    agg, _ = jax.lax.scan(
        body_masked, zero, (jnp.arange(rows.shape[0]), w, rows))
    return agg


def _batch_aggregate_with_keys(x, keys, cfg, snr_vec, weights, *,
                               num_active=None, donate=False):
    """Single-mode batch + weighted aggregation over explicit keys.

    The fused-round engine under :func:`transmit_batch_aggregate` and each
    bucket of :func:`transmit_batch_adaptive_aggregate`. On the kernel path
    the weighted sum happens *inside* the Pallas grid (the per-client
    demapped payload never reaches HBM); every other mode layers
    :func:`_scan_weighted_sum` over the standard batch — bit-identical to
    the kernel accumulator by the scan contract. ``weights`` are applied as
    given (normalize first: :func:`repro.core.aggregation.normalize_weights`).
    Returns ``(agg (N,) float32, stats)`` with per-client ``(C,)`` stats.
    """
    if cfg.mode in ("naive", "approx") and cfg.use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.approx_channel_transmit_batch_aggregate(
            x, keys, cfg, snr_vec, weights, num_active=num_active,
            donate=donate and _donation_supported())
    x_hat, stats = _batch_with_keys(x, keys, cfg, snr_vec)
    return _scan_weighted_sum(x_hat, weights, num_active), stats


def _same_channel(a: channel_lib.ChannelConfig,
                  b: channel_lib.ChannelConfig) -> bool:
    """ChannelConfig equality that tolerates array-valued ``snr_db``.

    Plain dataclass ``==`` on two distinct configs with per-client snr_db
    arrays evaluates an ambiguous-truth array comparison, and a bare
    ``np.array_equal`` on the snr_db values is shape-sensitive: a scalar, a
    0-d array, and a length-1 sequence all mean "one homogeneous SNR" but
    compare unequal. Normalize both sides to flat vectors first; a size-1
    value equals any vector it would broadcast to.
    """
    if a is b:
        return True
    if dataclasses.replace(a, snr_db=0.0) != dataclasses.replace(b, snr_db=0.0):
        return False
    sa = np.asarray(a.snr_db, np.float32).reshape(-1)
    sb = np.asarray(b.snr_db, np.float32).reshape(-1)
    if sa.size != sb.size and sa.size != 1 and sb.size != 1:
        return False
    if sa.size == 0 or sb.size == 0:
        return sa.size == sb.size
    return bool(np.all(sa == sb))


def clear_kernel_rows(cfgs):
    """A mode table with every ``use_kernel`` flag cleared.

    The single transform behind every select-pinned consumer (the fused FL
    round, ``shard_map`` dispatch): the Pallas grid cannot lower inside a
    vmapped switch, and the jnp rows draw their own — equally valid, but
    *different* — channel realization, so the engine refuses to swap the
    flag silently and callers opt in through this helper instead.
    """
    return tuple(
        dataclasses.replace(c, use_kernel=False) if c.use_kernel else c
        for c in cfgs
    )


def _bucket_capacity(count: int) -> int:
    """Static bucket capacity for ``count`` clients: quarter-octave rounding.

    Rounds up to the next multiple of ``2^(floor(log2 count) - 2)`` (counts
    <= 4 are exact), i.e. at most 4 capacities per power-of-two octave. This
    bounds the number of distinct bucket shapes — and therefore per-mode jit
    traces — at ``~4 log2(num_clients)`` per mode, whatever sequence of mode
    mixes the policy produces, while wasting at most 25% of a bucket's work
    on masked padding (so total work stays O(num_clients) across modes, vs
    O(modes x num_clients) for the select lowering).
    """
    if count <= 4:
        return max(count, 1)
    granule = 1 << (count.bit_length() - 3)
    return -(-count // granule) * granule


@functools.lru_cache(maxsize=256)
def _cached_mode_batch_fn(cfg: TransportConfig, with_snr: bool,
                          donate: bool = False):
    """One jitted single-mode batch per (config, snr-arity) — jax caches per
    bucket shape underneath, so repeated rounds with the same mode mix reuse
    their traces. ``donate`` twins release the bucket payload buffer (always
    a fresh gather) into the launch."""
    kwargs = {"donate_argnums": (0,)} if donate else {}
    if with_snr:
        return jax.jit(lambda x, k, s, na: _batch_with_keys(
            x, k, cfg, s, num_active=na), **kwargs)
    return jax.jit(lambda x, k, na: _batch_with_keys(
        x, k, cfg, None, num_active=na), **kwargs)


def _mode_batch_fn(cfg: TransportConfig, with_snr: bool,
                   donate: bool = False):
    try:
        return _cached_mode_batch_fn(cfg, with_snr,
                                     donate and _donation_supported())
    except TypeError:
        # Unhashable config (e.g. an array-valued channel snr_db): fall back
        # to an unjitted call — correct, just not trace-cached.
        if with_snr:
            return lambda x, k, s, na: _batch_with_keys(
                x, k, cfg, s, num_active=na)
        return lambda x, k, na: _batch_with_keys(x, k, cfg, None, num_active=na)


@functools.lru_cache(maxsize=256)
def _cached_mode_aggregate_fn(cfg: TransportConfig, with_snr: bool,
                              donate: bool = False):
    """The :func:`_cached_mode_batch_fn` twin for the fused-aggregate path:
    one jitted single-mode batch+aggregate per (config, snr-arity). This jit
    is the *outermost* boundary of a bucket launch, so ``donate`` twins
    declare the payload donation here (inner jits inline)."""
    kwargs = {"donate_argnums": (0,)} if donate else {}
    if with_snr:
        return jax.jit(lambda x, k, s, w, na: _batch_aggregate_with_keys(
            x, k, cfg, s, w, num_active=na), **kwargs)
    return jax.jit(lambda x, k, w, na: _batch_aggregate_with_keys(
        x, k, cfg, None, w, num_active=na), **kwargs)


def _mode_aggregate_fn(cfg: TransportConfig, with_snr: bool,
                       donate: bool = False):
    try:
        return _cached_mode_aggregate_fn(cfg, with_snr,
                                         donate and _donation_supported())
    except TypeError:
        # Unhashable config: unjitted fallback, as in _mode_batch_fn.
        if with_snr:
            return lambda x, k, s, w, na: _batch_aggregate_with_keys(
                x, k, cfg, s, w, num_active=na)
        return lambda x, k, w, na: _batch_aggregate_with_keys(
            x, k, cfg, None, w, num_active=na)


def _scatter_stats(parts_st, order, num_clients):
    """Scatter per-bucket :class:`TxStats` back to client order.

    Concatenates the per-mode stat fields in sorted order and gathers them
    through the inverse of the stable ``order`` permutation. Returns
    ``(stats, inv)`` — ``stats`` without ``mode_idx`` (callers attach their
    own), and ``inv`` so callers can scatter extra per-bucket arrays the
    same way.
    """
    inv = np.empty(num_clients, np.int64)
    inv[order] = np.arange(num_clients)
    inv = jnp.asarray(inv)
    ds, tx, be, nb, boa = (
        jnp.take(jnp.concatenate([getattr(st, f) for st in parts_st]), inv)
        for f in ("data_symbols", "transmissions", "bit_errors", "n_bits",
                  "bits_on_air")
    )
    return TxStats(ds, tx, be, nb, bits_on_air=boa), inv


def _scatter_bucket_parts(parts_x, parts_st, order, num_clients):
    """Scatter per-bucket outputs back to client order.

    The shared tail of every bucketed dispatch (dense adaptive, sparse
    adaptive, the engine's compressed uplink): the payload rows ride the
    same inverse permutation as the :func:`_scatter_stats` stat fields.
    Returns ``(x_hat, stats, inv)``.
    """
    stats, inv = _scatter_stats(parts_st, order, num_clients)
    x_hat = jnp.take(jnp.concatenate(parts_x, axis=0), inv, axis=0)
    return x_hat, stats, inv


def _gather_bucket(x, keys, snr_vec, idx, count, n_payload):
    """Gather one mode bucket's rows and pad to its quarter-octave capacity.

    Payload pads with zero rows; keys/SNR broadcast row 0 (masked rows'
    outputs are discarded, the pads only keep shapes static). Returns
    ``(xb, kb, sb, cap)``.
    """
    xb = jnp.take(x, idx, axis=0)
    kb = jnp.take(keys, idx, axis=0)
    sb = None if snr_vec is None else jnp.take(snr_vec, idx)
    cap = _bucket_capacity(count)
    if cap > count:
        pad = cap - count
        xb = jnp.concatenate([xb, jnp.zeros((pad, n_payload), xb.dtype)])
        kb = jnp.concatenate(
            [kb, jnp.broadcast_to(kb[:1], (pad,) + kb.shape[1:])])
        if sb is not None:
            sb = jnp.concatenate([sb, jnp.broadcast_to(sb[:1], (pad,))])
    return xb, kb, sb, cap


def _slice_stats(st: "TxStats", count: int) -> "TxStats":
    """Drop a padded bucket's masked tail rows from every stat field."""
    return TxStats(st.data_symbols[:count], st.transmissions[:count],
                   st.bit_errors[:count], st.n_bits[:count],
                   bits_on_air=st.bits_on_air[:count])


def _bucketed_adaptive(x, keys, cfgs, mode_np, snr_vec, donate=False):
    """Sort/gather/scatter mixed-mode dispatch over concrete mode counts.

    Clients are stable-argsorted by mode so each mode's clients form one
    contiguous bucket; every bucket runs the fused single-mode engine once
    (kernel path included) on a quarter-octave capacity with the tail
    masked, and outputs scatter back through the inverse permutation. Keys/SNR are
    gathered by client index, so each row is bit-identical to the select
    path and to ``transmit_flat`` under the fold_in schedule.
    """
    num_clients, n_payload = x.shape
    if num_clients == 0:
        # Degenerate empty cohort (e.g. every client dropped): agree with
        # the select dispatch's empty vmap output instead of concatenating
        # zero buckets.
        empty = jnp.zeros((0,), jnp.float32)
        return x, TxStats(empty, empty, empty, empty, bits_on_air=empty)
    order = np.argsort(mode_np, kind="stable")
    counts = np.bincount(mode_np, minlength=len(cfgs))
    starts = np.concatenate([[0], np.cumsum(counts)])
    parts_x, parts_st = [], []
    for m, cfg in enumerate(cfgs):
        count = int(counts[m])
        if count == 0:
            continue
        idx = jnp.asarray(order[starts[m] : starts[m] + count])
        xb, kb, sb, _ = _gather_bucket(x, keys, snr_vec, idx, count,
                                       n_payload)
        fn = _mode_batch_fn(cfg, sb is not None, donate)
        na = jnp.int32(count)
        xh, st = fn(xb, kb, na) if sb is None else fn(xb, kb, sb, na)
        parts_x.append(xh[:count])
        parts_st.append(_slice_stats(st, count))
    x_hat, stats, _ = _scatter_bucket_parts(parts_x, parts_st, order,
                                            num_clients)
    return x_hat, stats


def _bucketed_adaptive_aggregate(x, keys, cfgs, mode_np, snr_vec, weights,
                                 donate=False):
    """Bucketed mixed-mode dispatch with per-bucket fused aggregation.

    Each mode bucket produces its own weighted partial sum (kernel
    accumulator or scan fallback, masked padding excluded via
    ``num_active``); the partials add in increasing mode-index order — the
    documented summation-order contract of the adaptive aggregate (NOT the
    raw client order: a mixed-mode cohort regroups the sum by bucket).
    Weights must be pre-normalized *globally*, before the bucket split.
    """
    num_clients, n_payload = x.shape
    if num_clients == 0:
        empty = jnp.zeros((0,), jnp.float32)
        return (jnp.zeros((n_payload,), jnp.float32),
                TxStats(empty, empty, empty, empty, bits_on_air=empty))
    order = np.argsort(mode_np, kind="stable")
    counts = np.bincount(mode_np, minlength=len(cfgs))
    starts = np.concatenate([[0], np.cumsum(counts)])
    total = None
    parts_st = []
    for m, cfg in enumerate(cfgs):
        count = int(counts[m])
        if count == 0:
            continue
        idx = jnp.asarray(order[starts[m] : starts[m] + count])
        xb, kb, sb, cap = _gather_bucket(x, keys, snr_vec, idx, count,
                                         n_payload)
        wb = jnp.take(jnp.asarray(weights, jnp.float32), idx)
        if cap > count:
            wb = jnp.concatenate(
                [wb, jnp.zeros((cap - count,), jnp.float32)])
        fn = _mode_aggregate_fn(cfg, sb is not None, donate)
        na = jnp.int32(count)
        agg, st = (fn(xb, kb, wb, na) if sb is None
                   else fn(xb, kb, sb, wb, na))
        total = agg if total is None else total + agg
        parts_st.append(_slice_stats(st, count))
    stats, _ = _scatter_stats(parts_st, order, num_clients)
    return total, stats


def _select_adaptive(x, keys, cfgs, mode_idx, snr_vec):
    """Per-client ``lax.switch`` over the table, vmapped over clients: one
    fused XLA program, but the switch lowers to a select over all branches
    (every client pays every mode's FLOPs)."""
    if snr_vec is None:
        branches = [
            lambda xc, kc, cfg=cfg: transmit_flat(xc, kc, cfg) for cfg in cfgs
        ]
        return jax.vmap(
            lambda xc, kc, m: jax.lax.switch(m, branches, xc, kc)
        )(x, keys, mode_idx)
    branches = [
        lambda xc, kc, s, cfg=cfg: transmit_flat(xc, kc, cfg, snr_db=s)
        for cfg in cfgs
    ]
    return jax.vmap(
        lambda xc, kc, s, m: jax.lax.switch(m, branches, xc, kc, s)
    )(x, keys, snr_vec, mode_idx)


def _adaptive_prologue(x, key, cfgs, mode_idx, snr_db, client_offset,
                       dispatch, caller):
    """Shared validation/normalization head of the adaptive dispatches.

    Validates the payload shape and the shared-channel invariant,
    canonicalizes array-valued snr_db configs to one hashable channel,
    resolves the dispatch strategy against mode concreteness, clamps the
    mode vector, and builds the fold_in key schedule. Returns
    ``(x, cfgs, mode_arr, snr_vec, keys, dispatch)``.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"{caller} wants (num_clients, N); got {x.shape}")
    cfgs = tuple(cfgs)
    if not cfgs:
        raise ValueError(f"{caller} needs a non-empty config table")
    for cfg in cfgs:
        if not _same_channel(cfg.channel, cfgs[0].channel):
            raise ValueError(
                "all adaptive mode configs must share one ChannelConfig; "
                f"got {cfg.channel} vs {cfgs[0].channel}"
            )
    # Normalize representation differences (scalar vs 0-d vs length-1
    # snr_db) so every row resolves SNR identically, and canonicalize an
    # array-valued snr_db to a hashable tuple — otherwise the per-mode jit
    # cache (keyed on the config) falls back to eager per-op dispatch for
    # every bucket of every round.
    ch0 = cfgs[0].channel
    try:
        hash(ch0)
    except TypeError:
        try:
            ch0 = dataclasses.replace(ch0, snr_db=tuple(
                float(v)
                for v in np.asarray(ch0.snr_db, np.float32).reshape(-1)))
        except (TypeError, ValueError):
            pass  # e.g. a traced snr_db: the unjitted fallback still works
    cfgs = tuple(
        cfg if cfg.channel is ch0
        else dataclasses.replace(cfg, channel=ch0)
        for cfg in cfgs
    )
    num_clients = x.shape[0]
    mode_concrete = not isinstance(mode_idx, jax.core.Tracer)
    if dispatch == "auto":
        dispatch = "bucketed" if mode_concrete else "select"
    if dispatch not in ("bucketed", "select"):
        raise ValueError(f"unknown dispatch {dispatch!r}; use bucketed|select")
    if dispatch == "bucketed" and not mode_concrete:
        raise ValueError(
            "bucketed dispatch needs a concrete mode_idx (bucket sizes are "
            "host-side); inside jit/shard_map with a traced mode vector use "
            "dispatch='select'"
        )
    if dispatch == "select" and any(cfg.use_kernel for cfg in cfgs):
        raise ValueError(
            "use_kernel configs cannot take the select dispatch; the Pallas "
            "grid does not lower inside a vmapped lax.switch — use the "
            "bucketed dispatch (concrete mode_idx)"
        )
    if dispatch == "bucketed":
        mode_arr = np.asarray(mode_idx, np.int32)
    else:
        mode_arr = jnp.asarray(mode_idx, jnp.int32)
    if mode_arr.shape != (num_clients,):
        raise ValueError(
            f"mode_idx must be ({num_clients},) to match the batch; got "
            f"{mode_arr.shape}"
        )
    # Clamp once, up front: the dispatch and the recorded stats.mode_idx
    # must agree on the mode each client actually used — a stray -1 would
    # otherwise transmit as cfgs[0] (lax.switch clamps) yet price as the
    # *last* row downstream (jnp indexing wraps negatives).
    mode_arr = (np.clip if dispatch == "bucketed" else jnp.clip)(
        mode_arr, 0, len(cfgs) - 1)
    snr_vec = _resolve_batch_snr(cfgs[0], num_clients, snr_db)
    keys = client_keys(key, num_clients, client_offset)
    return x, cfgs, mode_arr, snr_vec, keys, dispatch


def transmit_batch_adaptive(x: jax.Array, key: jax.Array,
                            cfgs, mode_idx, *, snr_db=None, client_offset=0,
                            dispatch: str = "auto", donate: bool = False):
    """Mixed-mode batched uplink: client ``i`` uses ``cfgs[mode_idx[i]]``.

    The link-adaptation dispatch (paper Sec. I: deliver gradients with errors
    "when the channel quality is satisfactory", protect otherwise): a policy
    upstream picks a transport config per client per round and the whole
    cohort runs through the fused batched engine. See the module docstring
    for the two dispatch strategies; the short version:

    * ``"bucketed"`` — sort/gather/scatter per-mode buckets, each mode runs
      once, O(num_clients) total work, Pallas-kernel rows allowed. Needs a
      *concrete* (non-traced) ``mode_idx``.
    * ``"select"`` — vmapped ``lax.switch``: one XLA program even with a
      traced ``mode_idx``, but ~``len(cfgs)``x the FLOPs and no kernel rows.
    * ``"auto"`` (default) — bucketed when ``mode_idx`` is concrete, select
      otherwise.

    Args:
      x: ``(num_clients, N)`` payload matrix.
      key: base PRNG key; the :func:`client_keys` fold_in schedule is shared
        with :func:`transmit_batch`, so row ``i`` is bit-identical to
        ``transmit_flat(x[i], fold_in(key, client_offset + i), cfgs[m_i])``
        under **either** dispatch (the bucketed key rides the client index,
        not the bucket slot).
      cfgs: sequence of :class:`TransportConfig` — the mode table. All
        entries must share one ``ChannelConfig`` (the physical link does not
        depend on the chosen transport); equal-valued configs of different
        shapes (scalar vs length-1 snr_db) are normalized to ``cfgs[0]``'s.
        ``use_kernel`` rows are accepted on the bucketed path and rejected
        on the select path (the Pallas grid cannot lower inside a vmapped
        switch).
      mode_idx: ``(num_clients,)`` integer vector of table indices.
        Out-of-range values clamp (matching ``lax.switch``), and the
        *clamped* vector is what ``stats.mode_idx`` records — so airtime
        pricing always sees the mode that actually transmitted.
      snr_db: optional per-client SNR override (scalar or ``(num_clients,)``),
        resolved against the shared channel config.
      client_offset: global index of row 0 (as in :func:`transmit_batch`).
      dispatch: ``"auto" | "bucketed" | "select"``.
      donate: release bucket payload buffers (fresh gathers) into their
        launches on the bucketed dispatch; a no-op on select and on
        backends without donation.

    Returns:
      ``(x_hat, stats)`` as :func:`transmit_batch`; ``stats.mode_idx`` holds
      the per-client mode vector.
    """
    x, cfgs, mode_arr, snr_vec, keys, dispatch = _adaptive_prologue(
        x, key, cfgs, mode_idx, snr_db, client_offset, dispatch,
        "transmit_batch_adaptive")
    if dispatch == "bucketed":
        x_hat, stats = _bucketed_adaptive(x, keys, cfgs, mode_arr, snr_vec,
                                          donate)
    else:
        x_hat, stats = _select_adaptive(x, keys, cfgs, mode_arr, snr_vec)
    stats.mode_idx = jnp.asarray(mode_arr, jnp.int32)
    return x_hat, stats


def transmit_batch_aggregate(x: jax.Array, key: jax.Array,
                             cfg: TransportConfig, weights, *, snr_db=None,
                             client_offset=0, donate: bool = False):
    """Fused uplink + aggregation: ``sum_c weights[c] * x_hat[c]`` in one pass.

    The hot-path twin of :func:`transmit_batch` followed by
    ``aggregation.fedsgd_aggregate_batch``: on the kernel path
    (``cfg.use_kernel``) the weighted sum accumulates *inside* the Pallas
    grid over the client axis and the per-client demapped payload never
    materializes in HBM — only the ``(N,)`` f32 aggregate and the per-client
    bit-error side-output come back. Bit-identical to the layered
    composition (same kernel rows, same scan-shaped accumulation; pinned by
    ``tests/test_fused_aggregate.py``).

    Args:
      x: ``(num_clients, N)`` payload matrix.
      key / cfg / snr_db / client_offset: as :func:`transmit_batch` — the
        fold_in key schedule is shared, so the per-client channel
        realizations are exactly ``transmit_batch``'s.
      weights: ``(num_clients,)`` aggregation weights, applied as given —
        pass them through :func:`repro.core.aggregation.normalize_weights`
        first (``fedsgd_aggregate_batch`` normalizes the same way).
      donate: release the ``x`` buffer into the launch on backends that
        honour donation (the uplink payload is dead after transmission).

    Returns:
      ``(agg, stats)``: the ``(N,)`` float32 weighted aggregate and
      per-client :class:`TxStats` (``(num_clients,)`` fields — BER reporting
      survives the fusion via the kernel's error side-output).
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(
            f"transmit_batch_aggregate wants (num_clients, N); got {x.shape}")
    num_clients = x.shape[0]
    snr_vec = _resolve_batch_snr(cfg, num_clients, snr_db)
    keys = client_keys(key, num_clients, client_offset)
    return _batch_aggregate_with_keys(x, keys, cfg, snr_vec, weights,
                                      donate=donate)


def transmit_batch_adaptive_aggregate(x: jax.Array, key: jax.Array, cfgs,
                                      mode_idx, weights, *, snr_db=None,
                                      client_offset=0, donate: bool = False):
    """Mixed-mode fused uplink + aggregation (bucketed dispatch only).

    :func:`transmit_batch_adaptive` with the aggregation folded into each
    mode bucket: bucket ``m`` reduces its clients to one weighted partial
    (kernel accumulator on ``use_kernel`` rows) and the partials add in
    increasing mode-index order. That bucket regrouping is the *documented*
    summation order — on a single-mode cohort it degenerates to the plain
    client-order scan and the result is bit-identical to
    :func:`transmit_batch_aggregate`. Needs a concrete ``mode_idx`` (the
    select lowering has no kernel rows and nothing to fuse); ``weights``
    must be pre-normalized globally (before the bucket split — per-bucket
    renormalization would change the estimator).

    Returns ``(agg (N,) float32, stats)``; ``stats.mode_idx`` holds the
    per-client mode vector, stats fields are in client order.
    """
    x, cfgs, mode_arr, snr_vec, keys, _ = _adaptive_prologue(
        x, key, cfgs, mode_idx, snr_db, client_offset, "bucketed",
        "transmit_batch_adaptive_aggregate")
    agg, stats = _bucketed_adaptive_aggregate(x, keys, cfgs, mode_arr,
                                              snr_vec, weights, donate)
    stats.mode_idx = jnp.asarray(mode_arr, jnp.int32)
    return agg, stats


def transmit_pytree(tree: Any, key: jax.Array, cfg: TransportConfig):
    """Transmit every leaf of a pytree as one flat uplink payload."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat_hat, stats = transmit_flat(flat, key, cfg)
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(flat_hat[off : off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out), stats


def _flatten_client_tree(tree: Any):
    """Stack a ``(num_clients, ...)``-leaved pytree into one (C, D) matrix."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    num_clients = leaves[0].shape[0]
    sizes = [l.size // num_clients for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(num_clients, -1).astype(jnp.float32) for l in leaves], axis=1
    )
    return flat, (leaves, treedef, sizes)


def _unflatten_client_tree(flat_hat: jax.Array, spec) -> Any:
    leaves, treedef, sizes = spec
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(
            flat_hat[:, off : off + size].reshape(leaf.shape).astype(leaf.dtype)
        )
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def transmit_pytree_batch(tree: Any, key: jax.Array, cfg: TransportConfig, *,
                          snr_db=None):
    """Batched :func:`transmit_pytree`: every leaf has a leading client dim.

    Args:
      tree: pytree whose leaves are ``(num_clients, ...)`` — e.g. the output
        of ``jax.vmap(client_grad)``. Each client's leaves are flattened into
        one ``(num_clients, D)`` payload matrix.
      key / cfg / snr_db: as in :func:`transmit_batch`.

    Returns:
      ``(tree_hat, stats)`` with the input structure/shapes/dtypes restored
      and per-client :class:`TxStats` (``(num_clients,)`` fields).
    """
    flat, spec = _flatten_client_tree(tree)
    flat_hat, stats = transmit_batch(flat, key, cfg, snr_db=snr_db)
    return _unflatten_client_tree(flat_hat, spec), stats


def transmit_pytree_batch_adaptive(tree: Any, key: jax.Array, cfgs, mode_idx,
                                   *, snr_db=None, dispatch: str = "auto"):
    """Pytree front-end of :func:`transmit_batch_adaptive`.

    Same flatten/transmit/unflatten contract as :func:`transmit_pytree_batch`
    with a per-client mode table dispatch — the entry point the
    scenario-driven FL loops feed each round's gradients through.
    """
    flat, spec = _flatten_client_tree(tree)
    flat_hat, stats = transmit_batch_adaptive(
        flat, key, cfgs, mode_idx, snr_db=snr_db, dispatch=dispatch)
    return _unflatten_client_tree(flat_hat, spec), stats


def _unflatten_aggregate_tree(flat_agg: jax.Array, spec) -> Any:
    """Restore an aggregated ``(D,)`` payload to the client-tree structure
    with the leading client axis reduced away (leaf ``(C, ...)`` -> ``(...)``).
    The aggregate stays float32 regardless of leaf dtype — it feeds the f32
    optimizer update, and a bf16 round-trip would throw away accumulator
    precision the fused kernel just paid for."""
    leaves, treedef, sizes = spec
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(flat_agg[off : off + size].reshape(leaf.shape[1:]))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def transmit_pytree_batch_aggregate(tree: Any, key: jax.Array,
                                    cfg: TransportConfig, weights, *,
                                    snr_db=None, donate: bool = False):
    """Pytree front-end of :func:`transmit_batch_aggregate`.

    Flattens the ``(num_clients, ...)``-leaved payload tree into one
    ``(C, D)`` matrix, runs the fused uplink+aggregation, and restores the
    aggregate to the tree structure with the client axis reduced away —
    the shape ``algo.apply`` expects from the layered
    ``fedsgd_aggregate_batch`` tail.
    """
    flat, spec = _flatten_client_tree(tree)
    agg, stats = transmit_batch_aggregate(
        flat, key, cfg, weights, snr_db=snr_db, donate=donate)
    return _unflatten_aggregate_tree(agg, spec), stats


def transmit_pytree_batch_adaptive_aggregate(tree: Any, key: jax.Array, cfgs,
                                             mode_idx, weights, *,
                                             snr_db=None,
                                             donate: bool = False):
    """Pytree front-end of :func:`transmit_batch_adaptive_aggregate` — the
    entry point the scenario-driven fused FL rounds feed each round's
    gradients through (bucketed dispatch, globally pre-normalized weights).
    """
    flat, spec = _flatten_client_tree(tree)
    agg, stats = transmit_batch_adaptive_aggregate(
        flat, key, cfgs, mode_idx, weights, snr_db=snr_db, donate=donate)
    return _unflatten_aggregate_tree(agg, spec), stats


def _broadcast_payload(x: jax.Array, num_clients: int) -> jax.Array:
    """Validate + tile one flat payload to a ``(num_clients, N)`` batch."""
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 1:
        raise ValueError(f"broadcast wants a flat (N,) payload; got {x.shape}")
    keylanes.check_cohort(DOWNLINK_KEY_LANE, num_clients)
    return jnp.broadcast_to(x, (num_clients, x.shape[0]))


def transmit_broadcast(x: jax.Array, key: jax.Array, cfg: TransportConfig,
                       num_clients: int, *, snr_db=None):
    """Broadcast one payload through ``num_clients`` independent downlinks.

    The downlink leg of an FL round: the PS transmits the global model once
    and every client hears it over its *own* fading channel — same bits in,
    per-client corrupted copies out. Runs the shared ``_batch_with_keys``
    engine on the tiled payload; client ``i``'s key is
    ``fold_in(key, DOWNLINK_KEY_LANE + i)`` (see :data:`DOWNLINK_KEY_LANE`),
    so the caller may reuse the round's uplink base key and the two legs
    stay decorrelated, with uplink draws unchanged vs a downlink-free run.

    Args:
      x: ``(N,)`` global payload (cast to float32).
      key: base PRNG key — typically the same key the round's uplink uses.
      cfg: downlink transport configuration.
      num_clients: number of receiving clients.
      snr_db: optional per-client downlink SNR (scalar or ``(num_clients,)``),
        overriding ``cfg.channel.snr_db``.

    Returns:
      ``(x_hat, stats)``: ``(num_clients, N)`` received copies and
      :class:`TxStats` with ``(num_clients,)`` fields. Note the broadcast is
      transmitted *once* — ``latency.broadcast_airtime`` prices the round
      from these per-client stats.
    """
    xb = _broadcast_payload(x, num_clients)
    snr_vec = _resolve_batch_snr(cfg, num_clients, snr_db)
    keys = client_keys(key, num_clients, DOWNLINK_KEY_LANE)
    return _batch_with_keys(xb, keys, cfg, snr_vec)


def transmit_broadcast_adaptive(x: jax.Array, key: jax.Array, cfgs, mode_idx,
                                *, snr_db=None, dispatch: str = "auto"):
    """Mixed-mode broadcast: client ``i`` *receives* via ``cfgs[mode_idx[i]]``.

    The downlink counterpart of :func:`transmit_batch_adaptive` — e.g. a
    policy table picks a protected transport for clients whose downlink CSI
    is poor. Same dispatch strategies and validation; keys ride the
    downlink lane (``client_offset=DOWNLINK_KEY_LANE``).
    """
    num_clients = int(np.shape(mode_idx)[0])
    xb = _broadcast_payload(x, num_clients)
    return transmit_batch_adaptive(
        xb, key, cfgs, mode_idx, snr_db=snr_db,
        client_offset=DOWNLINK_KEY_LANE, dispatch=dispatch)


def _flatten_global_tree(tree: Any):
    """Flatten a client-dim-free pytree into one ``(D,)`` payload vector."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (leaves, treedef, sizes)


def _unflatten_broadcast_tree(flat_hat: jax.Array, spec) -> Any:
    """Restore a broadcast ``(num_clients, D)`` matrix to a stacked pytree."""
    leaves, treedef, sizes = spec
    num_clients = flat_hat.shape[0]
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(flat_hat[:, off : off + size]
                   .reshape((num_clients,) + leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def transmit_pytree_broadcast(tree: Any, key: jax.Array, cfg: TransportConfig,
                              num_clients: int, *, snr_db=None):
    """Broadcast a whole pytree (e.g. the global model) to every client.

    Flattens the client-dim-free ``tree`` into one payload, broadcasts it via
    :func:`transmit_broadcast`, and returns a pytree whose leaves grew a
    leading ``(num_clients,)`` dimension — client ``i``'s received copy is
    ``tree_map(lambda l: l[i], out)``. ``stats`` fields are per-client.
    """
    flat, spec = _flatten_global_tree(tree)
    flat_hat, stats = transmit_broadcast(flat, key, cfg, num_clients,
                                         snr_db=snr_db)
    return _unflatten_broadcast_tree(flat_hat, spec), stats


def transmit_pytree_broadcast_adaptive(tree: Any, key: jax.Array, cfgs,
                                       mode_idx, *, snr_db=None,
                                       dispatch: str = "auto"):
    """Pytree front-end of :func:`transmit_broadcast_adaptive`."""
    flat, spec = _flatten_global_tree(tree)
    flat_hat, stats = transmit_broadcast_adaptive(
        flat, key, cfgs, mode_idx, snr_db=snr_db, dispatch=dispatch)
    return _unflatten_broadcast_tree(flat_hat, spec), stats


def transmit_sparse(values: jax.Array, indices: jax.Array, dim: int,
                    key: jax.Array, cfg: TransportConfig, compression=None, *,
                    snr_db=None):
    """Transmit one client's sparse ``(values, indices)`` payload.

    The compressed uplink (see the module docstring's "Sparse uplinks"):
    the ``(k,)`` value payload rides the configured transport under ``key``
    and the ``(k,)`` index header rides protected bits on the header key
    lane; the receiver scatters the values back to a dense ``(dim,)``
    vector. ``compression`` is a
    :class:`repro.compress.sparsify.CompressionConfig` choosing the header
    protection (default config if ``None``). Returns ``(x_hat_dense,
    stats)`` with combined header+payload :class:`TxStats` (including
    ``bits_on_air``). Delegates to :func:`repro.compress.framing.transmit_sparse`.
    """
    from repro.compress import framing as framing_lib

    return framing_lib.transmit_sparse(values, indices, dim, key, cfg,
                                       compression, snr_db=snr_db)


def transmit_sparse_batch(values: jax.Array, indices: jax.Array, dim: int,
                          key: jax.Array, cfg: TransportConfig,
                          compression=None, *, snr_db=None, client_offset=0):
    """Batched :func:`transmit_sparse` under the shared fold_in key schedule.

    Client ``i`` uses ``fold_in(key, client_offset + i)`` — bit-identical
    to a per-client loop of :func:`transmit_sparse`, exactly as
    :func:`transmit_batch` is to :func:`transmit_flat`. Delegates to
    :func:`repro.compress.framing.transmit_sparse_batch`.
    """
    from repro.compress import framing as framing_lib

    return framing_lib.transmit_sparse_batch(
        values, indices, dim, key, cfg, compression, snr_db=snr_db,
        client_offset=client_offset)
