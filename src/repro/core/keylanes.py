"""Central registry of reserved ``jax.random.fold_in`` key lanes.

Every bit-identity guarantee in this repo (batched ≡ per-client, bucketed ≡
select, async ≡ sync, sinks-on ≡ sinks-off) rests on disjoint ``fold_in``
lanes: the uplink folds the client index onto the round key, the downlink
broadcast folds ``DOWNLINK_KEY_LANE + i``, the event layer folds
``COMPUTE_KEY_LANE + i`` / ``EVENT_KEY_LANE + i``, and the sparse-framing
legs fold ``HEADER_KEY_LANE`` / ``SELECT_KEY_LANE`` onto the *client* key.
Historically each module declared its own integer constant and nothing
checked that the ranges stay disjoint — a new client-indexed lane that
overlaps an existing one would silently correlate two error processes the
uplink/downlink asymmetry study depends on (Qu et al., arXiv:2310.16652).

This module is now the single point of declaration. :func:`reserve` claims
an explicit ``[base, base + span)`` range inside a named key *space* and
raises at import time if two reservations overlap; the owning modules
(``core.transport``, ``compress.framing``, ``compress.sparsify``,
``link.dynamics``) re-export their historical symbols from here with the
exact same integer values (goldens pin this). Two spaces exist because
lanes are folded onto two different keys:

* ``"round"`` — lanes folded onto a **round/base key** (uplink client
  index, downlink broadcast, event-layer compute/churn/gap draws).
* ``"client"`` — lanes folded onto an already-derived **client key**
  (chunk indices, the sparse index header, rand-k selection).

A :class:`Lane` is an ``int`` subclass, so arithmetic like
``COMPUTE_KEY_LANE + i`` and ``jax.random.fold_in(key, LANE)`` behave
exactly as before; the attached ``span`` powers the runtime guards
(:func:`check_cohort`, :func:`check_range`) and the ``keylane`` rule of
``tools/lint``, which statically cross-checks every ``fold_in`` call site
against this table.
"""

from __future__ import annotations

__all__ = [
    "Lane",
    "Registry",
    "REGISTRY",
    "reserve",
    "registry",
    "lane_table",
    "check_cohort",
    "check_range",
    "UPLINK_KEY_LANE",
    "DOWNLINK_KEY_LANE",
    "COMPUTE_KEY_LANE",
    "EVENT_KEY_LANE",
    "EVENT_GAP_KEY_LANE",
    "OBS_KEY_LANE",
    "CHUNK_KEY_LANE",
    "HEADER_KEY_LANE",
    "SELECT_KEY_LANE",
]


class Lane(int):
    """A reserved fold_in lane: an ``int`` base with range metadata.

    Being an ``int`` subclass keeps every historical use site bit-identical
    (``fold_in(key, LANE)``, ``LANE + i``, dataclass defaults, jnp
    conversion); ``name``/``span``/``space`` carry the reservation so
    guards and the static checker can validate client-indexed uses.
    """

    name: str
    span: int
    space: str

    def __new__(cls, name: str, base: int, span: int, space: str) -> "Lane":
        """Build the lane; ``base`` is the integer value of the object."""
        if span < 1:
            raise ValueError(f"lane {name!r}: span must be >= 1, got {span}")
        if base < 0:
            raise ValueError(f"lane {name!r}: base must be >= 0, got {base}")
        self = super().__new__(cls, base)
        self.name = name
        self.span = span
        self.space = space
        return self

    @property
    def base(self) -> int:
        """The first index of the reserved range (== ``int(self)``)."""
        return int(self)

    @property
    def end(self) -> int:
        """One past the last reserved index (``base + span``)."""
        return int(self) + self.span

    def __repr__(self) -> str:
        """``Lane(name, base=…, span=…, space=…)`` — debugging aid."""
        return (f"Lane({self.name!r}, base={int(self)}, "
                f"span={self.span}, space={self.space!r})")


class Registry:
    """Overlap-rejecting collection of :class:`Lane` reservations.

    The module-level :data:`REGISTRY` instance holds the repo's canonical
    table; tests construct private instances to exercise the overlap
    rejection without disturbing it.
    """

    def __init__(self) -> None:
        """Start empty; lanes arrive via :meth:`reserve`."""
        self._lanes: dict[str, Lane] = {}

    def reserve(self, name: str, *, base: int, span: int,
                space: str = "round", owner: str = "") -> Lane:
        """Claim ``[base, base + span)`` in ``space``; raise on any overlap.

        ``owner`` names the module that historically declared (and still
        re-exports) the lane — documentation only, surfaced by
        :meth:`table`. Returns the :class:`Lane` (an ``int`` equal to
        ``base``).
        """
        lane = Lane(name, base, span, space)
        lane.owner = owner
        if name in self._lanes:
            raise ValueError(f"key lane {name!r} already reserved")
        for other in self._lanes.values():
            if other.space != space:
                continue
            if lane.base < other.end and other.base < lane.end:
                raise ValueError(
                    f"key lane {name!r} [{lane.base}, {lane.end}) overlaps "
                    f"{other.name!r} [{other.base}, {other.end}) in the "
                    f"{space!r} key space")
        self._lanes[name] = lane
        return lane

    def lanes(self) -> tuple[Lane, ...]:
        """All reservations, sorted by ``(space, base)``."""
        return tuple(sorted(self._lanes.values(),
                            key=lambda l: (l.space, l.base)))

    def table(self) -> list[dict]:
        """The lane table as plain dicts (docs / ``tools.lint`` output)."""
        return [{"name": l.name, "base": l.base, "span": l.span,
                 "space": l.space, "owner": getattr(l, "owner", "")}
                for l in self.lanes()]


REGISTRY = Registry()


def reserve(name: str, *, base: int, span: int, space: str = "round",
            owner: str = "") -> Lane:
    """Reserve a lane in the canonical :data:`REGISTRY` (see that class)."""
    return REGISTRY.reserve(name, base=base, span=span, space=space,
                            owner=owner)


def registry() -> tuple[Lane, ...]:
    """The canonical reservations, sorted by ``(space, base)``."""
    return REGISTRY.lanes()


def lane_table() -> list[dict]:
    """The canonical lane table as plain dicts."""
    return REGISTRY.table()


def check_cohort(lane: Lane, num_clients: int) -> None:
    """Validate a client-indexed use ``lane + i`` for ``i < num_clients``.

    Mirrors the broadcast leg's historical guard: ``num_clients`` must be
    in ``[1, lane.span]`` or the per-client draws would walk out of the
    reserved range into the next lane, silently correlating two error
    processes. Raises ``ValueError`` (message mentions ``num_clients``,
    which callers' tests match on).
    """
    if not 0 < num_clients <= lane.span:
        raise ValueError(
            f"num_clients must be in [1, {lane.span}] (the {lane.name!r} "
            f"key lane width); got {num_clients}")


def check_range(offset, count: int, space: str = "round") -> None:
    """Validate that ``[offset, offset + count)`` sits inside one lane.

    The guard for generic schedules like ``transport.client_keys`` where
    the caller passes a lane base as ``offset``: the whole folded range
    must fall within a single reservation of ``space``. ``offset`` may be
    a traced value (sharded dispatch passes per-shard offsets); validation
    is skipped when it is not a concrete Python int.
    """
    if not isinstance(offset, int):  # traced / array offsets: runtime-only
        return
    if count <= 0:
        return
    for lane in REGISTRY.lanes():
        if lane.space != space:
            continue
        if lane.base <= offset and offset + count <= lane.end:
            return
    raise ValueError(
        f"fold_in range [{offset}, {offset + count}) does not fit any "
        f"reserved {space!r} key lane; register it in "
        f"repro.core.keylanes or shrink the cohort")


# --------------------------------------------------------------------------
# The canonical table. Values are pinned by the golden bit-identity suites:
# do not renumber — reserve new, disjoint ranges instead. ``tools/lint``
# parses these declarations statically (keep them literal ``reserve()``
# calls with int-expression base/span).
# --------------------------------------------------------------------------

# round space: lanes folded onto a round/base key ---------------------------
# uplink client i draws fold_in(round_key, i)
UPLINK_KEY_LANE = reserve(
    "uplink", base=0, span=1 << 20, owner="repro.core.transport")
# downlink-broadcast client i draws fold_in(round_key, DOWNLINK + i)
DOWNLINK_KEY_LANE = reserve(
    "downlink", base=1 << 20, span=1 << 20, owner="repro.core.transport")
# async event layer: per-(wave, client) compute-time draw
COMPUTE_KEY_LANE = reserve(
    "compute", base=1 << 22, span=1 << 20, owner="repro.link.dynamics")
# async event layer: per-(attempt, client) churn uniform
EVENT_KEY_LANE = reserve(
    "event-churn", base=3 << 21, span=1 << 20, owner="repro.link.dynamics")
# async event layer: post-upload idle gap — historically written as
# EVENT_KEY_LANE + (1 << 20) + i; same integers, now a first-class lane
EVENT_GAP_KEY_LANE = reserve(
    "event-gap", base=(3 << 21) + (1 << 20), span=1 << 20,
    owner="repro.link.dynamics")
# observability reservoir exemplars: per-client tag fold_in(round_key,
# OBS + i). Disjoint from every training lane, so sketches-on stays
# bit-identical to sketches-off on model weights.
OBS_KEY_LANE = reserve(
    "obs-reservoir", base=1 << 23, span=1 << 20, owner="repro.obs.sketch")

# client space: lanes folded onto an already-derived client key -------------
# chunked uncoded transport folds the chunk index onto the client key
CHUNK_KEY_LANE = reserve(
    "chunk", base=0, span=1 << 21, space="client",
    owner="repro.core.transport")
# sparse index header channel realization
HEADER_KEY_LANE = reserve(
    "header", base=1 << 21, span=1, space="client",
    owner="repro.compress.framing")
# rand-k selection draw
SELECT_KEY_LANE = reserve(
    "select", base=(1 << 21) + 1, span=1, space="client",
    owner="repro.compress.sparsify")
