"""Forward-compatibility shims: the jax >= 0.7 sharding surface on jax 0.4.x.

The codebase is written against the modern API —

* ``jax.shard_map(f, mesh=..., axis_names=..., in_specs=..., out_specs=...,
  check_vma=...)`` (partial-manual by default, ambient mesh when ``mesh`` is
  omitted),
* ``jax.set_mesh(mesh)`` as a context manager,
* ``jax.sharding.get_abstract_mesh()`` with per-axis ``axis_types`` that mark
  axes ``Manual`` inside ``shard_map``,
* ``jax.lax.axis_size(name)``.

Older jax (the 0.4.x line pinned in some CI images) spells these
``jax.experimental.shard_map.shard_map(..., check_rep=..., auto=...)`` and has
no ambient-mesh notion at all. :func:`install` bridges the gap by *adding*
the missing attributes — it never overwrites an attribute the running jax
already provides, so on a modern jax it is a no-op and the native
implementations win.

Ambient state (the mesh set by ``set_mesh``, the manual axes of the
innermost ``shard_map``) is tracked in a thread-local here and consumed by
``get_abstract_mesh`` — which is exactly how ``models.layers.maybe_shard``
decides which sharding hints are applicable.

Imported for its side effect from ``repro/__init__.py``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax

_state = threading.local()  # .mesh: ambient Mesh | None; .manual: frozenset

# True when the running jax predates the modern sharding API (i.e. the shims
# below are live). Feature-gates code paths whose lowering the legacy XLA
# cannot handle (e.g. tiled all_to_all inside partial-manual shard_map, which
# hard-crashes spmd_partitioner.cc's IsManualSubgroup check).
LEGACY_JAX = not hasattr(jax, "shard_map")


def _ambient_mesh():
    return getattr(_state, "mesh", None)


def _manual_axes() -> frozenset:
    return getattr(_state, "manual", frozenset())


class _AbstractMeshView:
    """Duck-type of the modern AbstractMesh: axis_names / shape / axis_types.

    ``axis_types`` entries stringify to 'Manual' for axes collapsed by the
    innermost shard_map and 'Auto' otherwise — the only property callers
    inspect (``"Manual" in str(ty)``).
    """

    def __init__(self, mesh, manual: frozenset):
        self.axis_names = tuple(mesh.axis_names)
        self.shape = dict(mesh.shape)
        self.axis_types = tuple(
            "Manual" if a in manual else "Auto" for a in self.axis_names
        )


def _get_abstract_mesh():
    mesh = _ambient_mesh()
    if mesh is None:
        return None  # callers guard with `mesh is None or not mesh.axis_names`
    manual = _manual_axes()
    if manual:
        # Inside a shard_map body on legacy jax/XLA, a sharding constraint on
        # the remaining auto axes trips the SPMD partitioner's manual-subgroup
        # check (spmd_partitioner.cc "IsManualSubgroup" CHECK). Advertise every
        # axis as Manual so sharding *hints* (models.layers.maybe_shard) are
        # skipped wholesale — hints are optimizations, never semantics.
        manual = frozenset(mesh.axis_names)
    return _AbstractMeshView(mesh, manual)


@contextlib.contextmanager
def _set_mesh(mesh):
    """``with jax.set_mesh(mesh):`` — ambient mesh for shard_map/constraints."""
    prev = _ambient_mesh()
    _state.mesh = mesh
    try:
        with mesh:  # legacy Mesh context: axis-resource lookups inside pjit
            yield mesh
    finally:
        _state.mesh = prev


def _shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
               axis_names=None, check_vma: bool = True, **kw):
    """Modern ``jax.shard_map`` in terms of the legacy experimental one.

    ``axis_names`` are the *manual* axes (legacy ``auto`` is the complement);
    ``check_vma`` maps to legacy ``check_rep``. ``mesh=None`` uses the
    ambient mesh installed by :func:`_set_mesh`.

    Partial-manual bodies additionally get an ``axis_index`` workaround: the
    legacy SPMD partitioner rejects the PartitionId instruction that
    ``jax.lax.axis_index`` lowers to when auto axes remain, so each manual
    axis's coordinate is smuggled in as a hidden sharded-iota argument and
    served from a thread-local by the patched ``jax.lax.axis_index``.
    """
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    from jax.sharding import PartitionSpec as P

    if f is None:  # support functools.partial(jax.shard_map, ...) usage
        return lambda g: _shard_map(
            g, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma, **kw)

    use_mesh = mesh if mesh is not None else _ambient_mesh()
    if use_mesh is None:
        raise ValueError("jax.shard_map: no mesh given and no ambient "
                         "jax.set_mesh(...) is active")
    manual = (frozenset(axis_names) if axis_names is not None
              else frozenset(use_mesh.axis_names))
    auto = frozenset(use_mesh.axis_names) - manual
    # hidden per-axis coordinate inputs, only needed in partial-manual mode
    idx_axes = [a for a in use_mesh.axis_names if a in manual] if auto else []

    def call(*args):
        def traced(*inner):
            if idx_axes:
                args_in = inner[: -len(idx_axes)]
                coords = {a: v[0] for a, v in zip(idx_axes, inner[-len(idx_axes):])}
            else:
                args_in, coords = inner, {}
            prev = (_manual_axes(), _ambient_mesh(),
                    getattr(_state, "axis_coords", None))
            _state.manual = prev[0] | manual
            _state.mesh = use_mesh
            _state.axis_coords = coords or None
            try:
                return f(*args_in)
            finally:
                _state.manual, _state.mesh, _state.axis_coords = prev

        specs_in = in_specs
        extra = ()
        if idx_axes:
            # P is a tuple subclass: a bare P prefix means "same spec for
            # every argument" — expand it before appending the hidden inputs.
            if isinstance(specs_in, P) or not isinstance(specs_in, (tuple, list)):
                specs_in = (specs_in,) * len(args)
            specs_in = tuple(specs_in) + tuple(P(a) for a in idx_axes)
            extra = tuple(
                jnp.arange(use_mesh.shape[a], dtype=jnp.int32) for a in idx_axes)

        return legacy_shard_map(
            traced, use_mesh, in_specs=specs_in, out_specs=out_specs,
            check_rep=check_vma, auto=auto)(*args, *extra)

    return call


_orig_axis_index = jax.lax.axis_index


def _axis_index(name):
    """``jax.lax.axis_index`` that consults the compat shard_map's smuggled
    coordinates (partial-manual bodies), else defers to the real primitive."""
    coords = getattr(_state, "axis_coords", None)
    if coords is not None and name in coords:
        return coords[name]
    return _orig_axis_index(name)


def _axis_size(name) -> Any:
    """Static size from the ambient mesh when known, else a psum fallback."""
    mesh = _ambient_mesh()
    if mesh is not None and name in mesh.shape:
        return mesh.shape[name]
    return jax.lax.psum(1, name)


def install() -> None:
    """Add any missing modern-jax attributes (no-op where they exist)."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map
        jax.lax.axis_index = _axis_index  # PartitionId workaround, see above
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _get_abstract_mesh
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size


install()
