"""Paper Fig. 4(a): accuracy at the same SNR (10 dB) across modulations —
QPSK wins (fewest errors). Fig. 4(b): accuracy at the same BER ~4e-2
(QPSK@10dB, 16-QAM@16dB, 256-QAM@26dB) — 256-QAM wins thanks to Gray-coded
MSB protection concentrated on the float sign/exponent bits."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import modulation as M
from repro.core import transport as T
from repro.fl.loop import run_fl
import jax


def _fl(modulation, snr, cx, cy, ti, tl, rounds, lr):
    cfg = dataclasses.replace(cnn_config(), lr=lr)
    tcfg = T.TransportConfig(mode="approx", modulation=modulation,
                             channel=CH.ChannelConfig(snr_db=snr))
    return run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                  batch_per_round=32, eval_every=5)


def run(quick: bool = True):
    n_clients = 30 if quick else 100
    rounds = 100 if quick else 400
    lr = 0.05 if quick else 0.01
    cx, cy, ti, tl = fl_world(n_clients=n_clients)

    # Fig 4(a): same SNR
    accs_a = {}
    for mod in ("qpsk", "16qam", "256qam"):
        res = _fl(mod, 10.0, cx, cy, ti, tl, rounds, lr)
        accs_a[mod] = res.final_accuracy
        ber = float(M.measure_ber(jax.random.PRNGKey(0), M.MOD_SCHEMES[mod], 10.0))
        emit(f"fig4a/{mod}/snr10", res.wall_s * 1e6,
             f"acc={res.final_accuracy:.3f} ber={ber:.3g}")
    emit("fig4a/ordering", 0.0,
         f"qpsk>=16qam>=256qam: {accs_a['qpsk'] >= accs_a['16qam'] - 0.05} "
         f"{accs_a['16qam'] >= accs_a['256qam'] - 0.05} (paper: QPSK best)")

    # Fig 4(b): same BER ~ 4e-2
    pairs = {"qpsk": 10.0, "16qam": 16.0, "256qam": 26.0}
    accs_b = {}
    for mod, snr in pairs.items():
        res = _fl(mod, snr, cx, cy, ti, tl, rounds, lr)
        accs_b[mod] = res.final_accuracy
        ber = float(M.measure_ber(jax.random.PRNGKey(0), M.MOD_SCHEMES[mod], snr))
        emit(f"fig4b/{mod}/snr{int(snr)}", res.wall_s * 1e6,
             f"acc={res.final_accuracy:.3f} ber={ber:.3g}")
    emit("fig4b/ordering", 0.0,
         f"256qam_acc={accs_b['256qam']:.3f} vs qpsk_acc={accs_b['qpsk']:.3f} "
         f"(paper: 256-QAM significantly better at equal BER)")
    return accs_a, accs_b
