"""Link adaptation: accuracy vs airtime of the per-client mode policy.

The paper's mechanism is conditional — send uncoded gradients "when the
channel quality is satisfactory", protect otherwise. This suite runs that
decision layer end to end on time-varying scenarios (``repro.link``) and
compares three arms under *identical* channel trajectories (same seed, same
dynamics; the policy is the only difference):

* ``adaptive`` — threshold+hysteresis policy over ECRT / approx-QPSK /
  approx-16QAM / approx-256QAM, driven by noisy pilot CSI;
* ``approx``   — fixed uncoded QPSK (the paper's scheme, no adaptation);
* ``ecrt``     — fixed LDPC+retransmission (the protected baseline).

Headline check: on non-static scenarios the adaptive arm should Pareto-
dominate — accuracy at least fixed-approx's, airtime below fixed-ECRT's
(``pareto=True`` in the emitted line).

Dispatch arm (``link/dispatch/*``): times the mixed-mode uplink engine on a
vehicular-flavored 4-mode, 256-client round under both dispatch strategies —
``select`` (vmapped ``lax.switch``: every client pays every mode) vs
``bucketed`` (sort/gather/scatter: each mode runs once) — asserting the two
are **bit-identical** before reporting the speedup. Also verifies the fusion
claim for the select path: a mixed-mode 64-client round is ONE jitted XLA
program (a single trace), re-dispatching as the mode vector changes.

Results land on stdout (CSV) and in ``BENCH_link_adaptation.json`` (written
to the CWD; uploaded as a CI artifact) so the perf trajectory is tracked.

Standalone: ``python -m benchmarks.link_adaptation --dispatch both``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, fl_world, timeit
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.loop import run_fl
from repro.link import dynamics as dynamics_lib
from repro.link import policy as policy_lib
from repro.link import scenario as scenario_lib

ARMS = {
    "adaptive": policy_lib.PolicyConfig(),
    "approx": policy_lib.fixed_policy("approx", "qpsk"),
    "ecrt": policy_lib.fixed_policy("ecrt", "qpsk"),
}

JSON_PATH = "BENCH_link_adaptation.json"


def _check_single_trace(n_clients: int = 64, n_floats: int = 4096) -> int:
    """Trace-count the select-dispatch mixed-mode uplink at 64 clients."""
    ch = CH.ChannelConfig(snr_db=10.0)
    cfgs = policy_lib.build_mode_cfgs(
        T.TransportConfig(channel=ch), policy_lib.PolicyConfig(),
        ecrt_expected_tx=2.2)
    traces = [0]

    def uplink(x, key, mode_idx, snr_db):
        traces[0] += 1
        return T.transmit_batch_adaptive(x, key, cfgs, mode_idx, snr_db=snr_db)

    jitted = jax.jit(uplink)
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (n_clients, n_floats), minval=-0.9, maxval=0.9)
    snr = jnp.linspace(0.0, 30.0, n_clients)
    for seed in (1, 2, 3):  # three different mixed mode vectors, one trace
        mode = jax.random.randint(jax.random.PRNGKey(seed), (n_clients,), 0, 4)
        out, st = jitted(x, key, mode, snr)
        jax.block_until_ready(out)
    return traces[0]


def _vehicular_round(n_clients: int, seed: int = 7):
    """A realistic (snr, mode) draw: one vehicular dynamics step through the
    default threshold policy — the mode mix the adaptive FL loop sees."""
    dyn = dynamics_lib.DYNAMICS_PRESETS["vehicular"]
    snr = dynamics_lib.trajectory(
        jax.random.PRNGKey(seed), dyn, n_clients, 2)[-1]
    mode = np.asarray(policy_lib.initial_mode(snr, policy_lib.PolicyConfig()))
    return snr, mode


def dispatch_speedup(n_clients: int = 256, n_floats: int = 2048,
                     which: str = "both") -> dict:
    """Time select vs bucketed dispatch on a 4-mode vehicular round.

    Asserts the two dispatches are bit-identical (payloads and stats) before
    timing — ``make bench-link`` doubles as the equivalence smoke. Returns
    the record written into ``BENCH_link_adaptation.json``.
    """
    ch = CH.ChannelConfig(snr_db=10.0)
    cfgs = policy_lib.build_mode_cfgs(
        T.TransportConfig(channel=ch), policy_lib.PolicyConfig(),
        ecrt_expected_tx=2.2)
    snr, mode = _vehicular_round(n_clients)
    x = jax.random.uniform(jax.random.PRNGKey(1), (n_clients, n_floats),
                           minval=-0.99, maxval=0.99)
    key = jax.random.PRNGKey(2)
    mode_j = jnp.asarray(mode)

    select_fn = jax.jit(lambda x, k, m, s: T.transmit_batch_adaptive(
        x, k, cfgs, m, snr_db=s, dispatch="select"))

    def bucketed_fn():
        return T.transmit_batch_adaptive(
            x, key, cfgs, mode, snr_db=snr, dispatch="bucketed")

    a, sa = select_fn(x, key, mode_j, snr)
    b, sb = bucketed_fn()
    identical = bool(
        np.array_equal(np.asarray(a).view(np.uint32),
                       np.asarray(b).view(np.uint32))
        and all(
            np.array_equal(np.asarray(getattr(sa, f)),
                           np.asarray(getattr(sb, f)))
            for f in ("data_symbols", "transmissions", "bit_errors", "n_bits"))
    )
    if not identical:  # explicit raise: this gate must survive python -O
        raise AssertionError("bucketed dispatch diverged from the select path")
    emit("link/dispatch/bit_identical", 0.0,
         f"clients={n_clients} modes={len(cfgs)} identical={identical}")

    rec = {
        "clients": n_clients,
        "n_floats": n_floats,
        "modes": len(cfgs),
        "mode_mix": np.bincount(mode, minlength=len(cfgs)).tolist(),
        "bit_identical": identical,
    }
    if which in ("select", "both"):
        rec["select_us"] = timeit(lambda: select_fn(x, key, mode_j, snr))
        emit("link/dispatch/select", rec["select_us"],
             f"clients={n_clients} modes={len(cfgs)}")
    if which in ("bucketed", "both"):
        rec["bucketed_us"] = timeit(bucketed_fn)
        emit("link/dispatch/bucketed", rec["bucketed_us"],
             f"clients={n_clients} modes={len(cfgs)}")
    if which == "both":
        rec["speedup"] = rec["select_us"] / rec["bucketed_us"]
        emit("link/dispatch/speedup", 0.0,
             f"select/bucketed={rec['speedup']:.2f}x "
             f"mode_mix={rec['mode_mix']}")
    return rec


def run(quick: bool = True, dispatch: str = "both",
        dispatch_clients: int = 256, dispatch_floats: int = 2048):
    scenarios = ("vehicular",) if quick else (
        "vehicular", "bursty", "pedestrian", "shadowed-urban", "static")
    n_clients = 24 if quick else 64
    rounds = 60 if quick else 240
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))

    report = {
        "dispatch": dispatch_speedup(dispatch_clients, dispatch_floats,
                                     which=dispatch),
        "arms": {},
    }

    traces = _check_single_trace()
    emit("link/mixed_mode_single_trace", 0.0,
         f"traces={traces} clients=64 fused={traces == 1}")
    report["select_single_trace"] = traces == 1

    results = {}
    for scen_name in scenarios:
        base = scenario_lib.get_scenario(scen_name)
        for arm, pol in ARMS.items():
            scen = dataclasses.replace(base, policy=pol)
            res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                         batch_per_round=32, eval_every=5, scenario=scen)
            results[(scen_name, arm)] = res
            counts = [t["mode_counts"] for t in res.link]
            mix = [sum(c[i] for c in counts) for i in range(len(counts[0]))]
            emit(f"link/{scen_name}/{arm}", res.wall_s * 1e6,
                 f"final_acc={res.final_accuracy:.3f} "
                 f"airtime={res.airtime_s[-1]:.2f}s mode_mix={mix}")
            report["arms"][f"{scen_name}/{arm}"] = {
                "final_acc": float(res.final_accuracy),
                "airtime_s": float(res.airtime_s[-1]),
                "wall_s": float(res.wall_s),
                "mode_mix": mix,
            }

    for scen_name in scenarios:
        a = results[(scen_name, "adaptive")]
        fx = results[(scen_name, "approx")]
        ec = results[(scen_name, "ecrt")]
        pareto = (a.final_accuracy >= fx.final_accuracy
                  and a.airtime_s[-1] < ec.airtime_s[-1])
        emit(f"link/pareto/{scen_name}", 0.0,
             f"adaptive=({a.final_accuracy:.3f},{a.airtime_s[-1]:.2f}s) "
             f"approx=({fx.final_accuracy:.3f},{fx.airtime_s[-1]:.2f}s) "
             f"ecrt=({ec.final_accuracy:.3f},{ec.airtime_s[-1]:.2f}s) "
             f"pareto={pareto}")
        report["arms"][f"{scen_name}/pareto"] = bool(pareto)

    common.write_bench_json(JSON_PATH, report)
    emit("link/json", 0.0, f"wrote {JSON_PATH}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(
        description="link-adaptation benchmarks (standalone entry)")
    ap.add_argument("--dispatch", choices=("select", "bucketed", "both"),
                    default="both",
                    help="which uplink dispatch arm(s) to time")
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--floats", type=int, default=2048)
    ap.add_argument("--fl", action="store_true",
                    help="also run the full accuracy-vs-airtime FL arms")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale FL arms (implies --fl)")
    args = ap.parse_args()
    args.fl = args.fl or args.full
    print("name,us_per_call,derived")
    if args.fl:
        run(quick=not args.full, dispatch=args.dispatch,
            dispatch_clients=args.clients, dispatch_floats=args.floats)
    else:
        rec = dispatch_speedup(args.clients, args.floats, which=args.dispatch)
        common.write_bench_json(JSON_PATH, {"dispatch": rec})
        emit("link/json", 0.0, f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
