"""Link adaptation: accuracy vs airtime of the per-client mode policy.

The paper's mechanism is conditional — send uncoded gradients "when the
channel quality is satisfactory", protect otherwise. This suite runs that
decision layer end to end on time-varying scenarios (``repro.link``) and
compares three arms under *identical* channel trajectories (same seed, same
dynamics; the policy is the only difference):

* ``adaptive`` — threshold+hysteresis policy over ECRT / approx-QPSK /
  approx-16QAM / approx-256QAM, driven by noisy pilot CSI;
* ``approx``   — fixed uncoded QPSK (the paper's scheme, no adaptation);
* ``ecrt``     — fixed LDPC+retransmission (the protected baseline).

Headline check: on non-static scenarios the adaptive arm should Pareto-
dominate — accuracy at least fixed-approx's, airtime below fixed-ECRT's
(``pareto=True`` in the emitted line). Also verifies the fusion claim: a
mixed-mode 64-client round is ONE jitted XLA program (a single trace, no
per-client Python loop), re-dispatching as the mode vector changes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.loop import run_fl
from repro.link import policy as policy_lib
from repro.link import scenario as scenario_lib

ARMS = {
    "adaptive": policy_lib.PolicyConfig(),
    "approx": policy_lib.fixed_policy("approx", "qpsk"),
    "ecrt": policy_lib.fixed_policy("ecrt", "qpsk"),
}


def _check_single_trace(n_clients: int = 64, n_floats: int = 4096) -> int:
    """Trace-count the mixed-mode batched uplink at 64 clients."""
    ch = CH.ChannelConfig(snr_db=10.0)
    cfgs = policy_lib.build_mode_cfgs(
        T.TransportConfig(channel=ch), policy_lib.PolicyConfig(),
        ecrt_expected_tx=2.2)
    traces = [0]

    def uplink(x, key, mode_idx, snr_db):
        traces[0] += 1
        return T.transmit_batch_adaptive(x, key, cfgs, mode_idx, snr_db=snr_db)

    jitted = jax.jit(uplink)
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (n_clients, n_floats), minval=-0.9, maxval=0.9)
    snr = jnp.linspace(0.0, 30.0, n_clients)
    for seed in (1, 2, 3):  # three different mixed mode vectors, one trace
        mode = jax.random.randint(jax.random.PRNGKey(seed), (n_clients,), 0, 4)
        out, st = jitted(x, key, mode, snr)
        jax.block_until_ready(out)
    return traces[0]


def run(quick: bool = True):
    scenarios = ("vehicular",) if quick else (
        "vehicular", "bursty", "pedestrian", "shadowed-urban", "static")
    n_clients = 24 if quick else 64
    rounds = 60 if quick else 240
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))

    traces = _check_single_trace()
    emit("link/mixed_mode_single_trace", 0.0,
         f"traces={traces} clients=64 fused={traces == 1}")

    results = {}
    for scen_name in scenarios:
        base = scenario_lib.get_scenario(scen_name)
        for arm, pol in ARMS.items():
            scen = dataclasses.replace(base, policy=pol)
            res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                         batch_per_round=32, eval_every=5, scenario=scen)
            results[(scen_name, arm)] = res
            counts = [t["mode_counts"] for t in res.link]
            mix = [sum(c[i] for c in counts) for i in range(len(counts[0]))]
            emit(f"link/{scen_name}/{arm}", res.wall_s * 1e6,
                 f"final_acc={res.final_accuracy:.3f} "
                 f"airtime={res.airtime_s[-1]:.2f}s mode_mix={mix}")

    for scen_name in scenarios:
        a = results[(scen_name, "adaptive")]
        fx = results[(scen_name, "approx")]
        ec = results[(scen_name, "ecrt")]
        pareto = (a.final_accuracy >= fx.final_accuracy
                  and a.airtime_s[-1] < ec.airtime_s[-1])
        emit(f"link/pareto/{scen_name}", 0.0,
             f"adaptive=({a.final_accuracy:.3f},{a.airtime_s[-1]:.2f}s) "
             f"approx=({fx.final_accuracy:.3f},{fx.airtime_s[-1]:.2f}s) "
             f"ecrt=({ec.final_accuracy:.3f},{ec.airtime_s[-1]:.2f}s) "
             f"pareto={pareto}")
    return results
