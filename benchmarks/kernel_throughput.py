"""Fused approx-channel kernel vs layered jnp reference.

On this CPU container the Pallas kernel runs in interpret mode (a Python
loop over grid tiles), so wall-clock here does NOT reflect TPU throughput —
the TPU-relevant number is the HBM traffic ratio, which is structural:
the layered reference streams ~36 B per 4 B gradient at QPSK (symbol
indices + complex stream + per-symbol noise/fading), the fused kernel
streams 4 B in / 4 B out. We report measured wall time for the jnp paths
(ref vs chunked) and the analytic bytes ratio for the kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import channel as CH
from repro.core import transport as T
from repro.kernels import ops as O


def run(quick: bool = True):
    n = 1 << (20 if quick else 24)
    x = jax.random.uniform(jax.random.PRNGKey(0), (n,), minval=-1, maxval=1)
    key = jax.random.PRNGKey(1)

    cfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    ref = jax.jit(lambda x, k: T.transmit_flat(x, k, cfg)[0])
    us_ref = timeit(ref, x, key, iters=3)
    emit("kernel/jnp_reference", us_ref, f"n={n} (layered, global interleave)")

    cfg_c = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0),
                              chunk_elems=1 << 18)
    chunked = jax.jit(lambda x, k: T.transmit_flat(x, k, cfg_c)[0])
    us_chk = timeit(chunked, x, key, iters=3)
    emit("kernel/jnp_chunked", us_chk, f"chunk=262144 (bounded live set)")

    if quick:
        xk = x[: 1 << 16]
    else:
        xk = x
    us_k = timeit(
        lambda: O.approx_channel(xk, jnp.uint32(7), 1e-4, 1e-3, interpret=True)[0])
    emit("kernel/pallas_interpret", us_k,
         f"n={xk.shape[0]} (interpret mode — NOT TPU throughput)")

    # structural HBM traffic per 4-byte gradient float at QPSK (k=2):
    # ref: u32 word r/w (8) + symbols 16*4 r/w (128) + complex stream 16*8*2
    #      (256) + equalized read (128) + rx symbols (128) + word (8) ~ 656 B
    # kernel: 4 in + 4 out + error counter amortized ~ 8 B
    emit("kernel/hbm_traffic_ratio", 0.0,
         "layered~656B/float vs fused 8B/float => ~82x less HBM traffic; "
         "memory-bound roofline: kernel ~ 82x faster on TPU v5e")
    return us_ref, us_chk, us_k
