"""Fused approx-channel kernel vs layered jnp reference.

On this CPU container the Pallas kernels run in interpret mode, so their
wall-clock does NOT reflect TPU throughput — the TPU-relevant number is
the HBM traffic ratio, now computed from the *actual transport config*
via :func:`repro.launch.roofline.transport_traffic` (modulation order and
wire dtype read off the config, not a hard-coded QPSK/f32 assumption).
The layered jnp pipeline streams ~656 B per gradient float at QPSK f32;
the batch kernel 8 B + the aggregation pass; the fused-aggregate kernel
4 + 4/C B (the PS mean folded into the grid loop, aggregate written once
per tile). We report measured wall time for every arm, the analytic
roofline ratios, and two structural gates:

  * ``roofline_fused_5x``: the fused kernel moves >= 5x less HBM traffic
    than the layered jnp round (the ISSUE acceptance gate — it is ~100x).
  * ``bucketed_not_slower_on_single_mode``: adaptive ``bucketed`` dispatch
    is no slower than ``select`` when every client shares one mode (the
    degenerate cohort where select's one-program trick is strongest).

Wall times depend on the host env (allocator preload, XLA host flags);
the flag set in effect is stamped into ``meta.host_flags`` by
``write_bench_json`` so numbers are only compared like-for-like.
Writes ``BENCH_kernel_throughput.json``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_bench_json
from repro.core import aggregation as A
from repro.core import channel as CH
from repro.core import transport as T
from repro.kernels import ops as O
from repro.launch import roofline

JSON_PATH = "BENCH_kernel_throughput.json"


def run(quick: bool = True):
    n = 1 << (20 if quick else 24)
    x = jax.random.uniform(jax.random.PRNGKey(0), (n,), minval=-1, maxval=1)
    key = jax.random.PRNGKey(1)

    # --- historical single-client arms (unchanged lines) ----------------
    cfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    ref = jax.jit(lambda x, k: T.transmit_flat(x, k, cfg)[0])
    us_ref = timeit(ref, x, key, iters=3)
    emit("kernel/jnp_reference", us_ref, f"n={n} (layered, global interleave)")

    cfg_c = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0),
                              chunk_elems=1 << 18)
    chunked = jax.jit(lambda x, k: T.transmit_flat(x, k, cfg_c)[0])
    us_chk = timeit(chunked, x, key, iters=3)
    emit("kernel/jnp_chunked", us_chk, "chunk=262144 (bounded live set)")

    nk = 1 << (16 if quick else 20)
    xk = x[:nk]
    us_k = timeit(
        lambda: O.approx_channel(xk, jnp.uint32(7), 1e-4, 1e-3, interpret=True)[0])
    emit("kernel/pallas_interpret", us_k,
         f"n={nk} (interpret mode — NOT TPU throughput)")

    # --- multi-client round arms: layered vs batch-kernel vs fused ------
    clients = 8
    nb = 1 << (14 if quick else 18)
    xb = jax.random.uniform(jax.random.PRNGKey(2), (clients, nb),
                            minval=-1, maxval=1)
    weights = jnp.ones((clients,), jnp.float32)
    w_norm = A.normalize_weights(weights)

    cfg_b = T.TransportConfig(mode="approx",
                              channel=CH.ChannelConfig(snr_db=10.0))
    layered = jax.jit(lambda x, k: A.fedsgd_aggregate_batch(
        T.transmit_batch(x, k, cfg_b)[0], weights))
    us_lay = timeit(layered, xb, key, iters=3)
    emit("kernel/round_jnp_layered", us_lay,
         f"C={clients} n={nb} transmit_batch + fedsgd_aggregate_batch")

    cfg_k = T.TransportConfig(mode="approx",
                              channel=CH.ChannelConfig(snr_db=10.0),
                              use_kernel=True)
    kbatch = jax.jit(lambda x, k: A.fedsgd_aggregate_batch(
        T.transmit_batch(x, k, cfg_k)[0], weights))
    us_kb = timeit(kbatch, xb, key, iters=3)
    emit("kernel/round_kernel_batch", us_kb,
         f"C={clients} n={nb} batch kernel + scan aggregate (interpret)")

    fused = jax.jit(lambda x, k: T.transmit_batch_aggregate(
        x, k, cfg_k, w_norm)[0])
    us_fused = timeit(fused, xb, key, iters=3)
    emit("kernel/round_kernel_fused", us_fused,
         f"C={clients} n={nb} in-kernel aggregation (interpret)")

    # bit-identity of the paths we just timed (the golden suites pin this
    # exhaustively; this is a cheap self-check on the benchmarked shapes)
    agg_lay = np.asarray(kbatch(xb, key))
    agg_fus = np.asarray(fused(xb, key))
    fused_bit_identical = bool(
        (agg_lay.view(np.uint32) == agg_fus.view(np.uint32)).all())

    # --- analytic roofline from the real transport config ---------------
    traffic = roofline.transport_traffic(cfg_k, clients, n_floats=nb)
    ratio = traffic["ratio_vs_fused"]
    emit("kernel/hbm_traffic_ratio", ratio["jnp_layered"],
         f"{traffic['bytes_per_float']['jnp_layered']:.0f}B/float layered vs "
         f"{traffic['bytes_per_float']['kernel_fused']:.2f}B/float fused "
         f"(k={traffic['bits_per_symbol']}, {traffic['wire_dtype']}) => "
         f"memory-bound TPU v5e speedup")
    emit("kernel/hbm_traffic_ratio_batch", ratio["kernel_batch"],
         "batch kernel + separate aggregate pass vs fused")

    # --- adaptive dispatch on a single-mode cohort -----------------------
    cfgs = (cfg_b, T.TransportConfig(mode="naive",
                                     channel=CH.ChannelConfig(snr_db=10.0)))
    mode_idx = np.zeros((clients,), np.int32)  # everyone on mode 0
    buck = jax.jit(lambda x, k: T.transmit_batch_adaptive(
        x, k, cfgs, mode_idx, dispatch="bucketed")[0])
    sel = jax.jit(lambda x, k: T.transmit_batch_adaptive(
        x, k, cfgs, mode_idx, dispatch="select")[0])
    us_buck = timeit(buck, xb, key, iters=3)
    us_sel = timeit(sel, xb, key, iters=3)
    emit("kernel/adaptive_bucketed_single_mode", us_buck,
         f"C={clients} n={nb} single-mode cohort")
    emit("kernel/adaptive_select_single_mode", us_sel,
         f"C={clients} n={nb} single-mode cohort")

    gates = {
        "roofline_fused_5x": bool(ratio["jnp_layered"] >= 5.0),
        "fused_bit_identical_to_layered": fused_bit_identical,
        # wall-clock sanity, not a TPU claim: interpret-mode timings are
        # noisy, so allow 25% slack over select's one-program dispatch.
        "bucketed_not_slower_on_single_mode":
            bool(float(us_buck) <= 1.25 * float(us_sel)),
    }
    for name, ok in gates.items():
        emit(f"kernel/gate_{name}", 1.0 if ok else 0.0, "1=pass")

    write_bench_json(JSON_PATH, {
        "clients": clients,
        "n_floats": nb,
        "arms": {
            "jnp_reference_us": float(us_ref),
            "jnp_chunked_us": float(us_chk),
            "pallas_interpret_us": float(us_k),
            "round_jnp_layered_us": float(us_lay),
            "round_kernel_batch_us": float(us_kb),
            "round_kernel_fused_us": float(us_fused),
            "adaptive_bucketed_us": float(us_buck),
            "adaptive_select_us": float(us_sel),
        },
        "roofline": traffic,
        "gates": gates,
    })
    return us_ref, us_chk, us_k
