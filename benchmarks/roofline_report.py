"""Summarize the dry-run roofline artifacts into the benchmark CSV."""

from __future__ import annotations

import os

from benchmarks.common import emit


def run(quick: bool = True):
    art = "artifacts/dryrun"
    if not os.path.isdir(art):
        emit("roofline/missing", 0.0, "run scripts/run_dryruns.sh first")
        return
    from repro.configs import ARCH_IDS, INPUT_SHAPES
    from repro.launch import roofline as RL

    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            r = RL.analyze(art, arch, shape)
            if r is None:
                continue
            emit(f"roofline/{arch}/{shape}", 0.0,
                 f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                 f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']} "
                 f"useful={r['useful_ratio']*100:.1f}%")
