"""Shared benchmark utilities."""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def fl_world(n_clients: int = 40, per_client: int = 96, seed: int = 0):
    from repro.data import synth_mnist
    from repro.fl import partition

    (img, lab), (ti, tl) = synth_mnist.train_test(300, 60, seed=seed)
    parts = partition.non_iid_partition(img, lab, n_clients=n_clients, seed=seed)
    cx, cy = partition.stack_clients(parts, per_client=per_client, seed=seed)
    return cx, cy, ti, tl
