"""Shared benchmark utilities.

``timeit`` reports the steady-state median *and* the first (compile) call
separately — JAX wall times are bimodal and one number conflates tracing +
XLA compilation with execution. ``emit`` keeps the historical CSV line and
mirrors it as a machine-readable JSONL record; ``bench_meta`` /
``write_bench_json`` stamp every ``BENCH_*.json`` with the same provenance
block the FL run ledger carries (``tools/bench_schema.py`` validates it).
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

# JSONL sidecar for emit(): one record per CSV line. Overridable so the
# harness (benchmarks.run) can point every suite of one invocation at one
# file; empty value disables the sidecar.
RECORDS_ENV = "BENCH_RECORDS_PATH"
DEFAULT_RECORDS_PATH = "BENCH_records.jsonl"


class Timing(float):
    """``timeit``'s return value: *is* the steady-state median (µs), so
    every pre-existing caller keeps working, and carries the first-call
    (trace + compile) time as ``first_us``."""

    first_us: float

    def __new__(cls, steady_us: float, first_us: float):
        self = super().__new__(cls, steady_us)
        self.first_us = float(first_us)
        return self


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> Timing:
    """Steady-state median wall time per call in microseconds (blocking on
    results), with the first call — tracing + XLA compile included — kept
    separately on the returned :class:`Timing`'s ``first_us``."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first_us = (time.perf_counter() - t0) * 1e6
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return Timing(float(np.median(ts)), first_us)


def records_path() -> str | None:
    """Where ``emit`` mirrors its CSV lines (``None`` = sidecar disabled)."""
    path = os.environ.get(RECORDS_ENV, DEFAULT_RECORDS_PATH)
    return path or None


def emit(name: str, us_per_call: float, derived: str) -> None:
    """One benchmark result: the historical CSV line on stdout plus a
    machine-readable JSONL record (with the compile/steady split when
    ``us_per_call`` came from :func:`timeit`) in the sidecar file."""
    print(f"{name},{us_per_call:.1f},{derived}")
    path = records_path()
    if path is None:
        return
    rec = {"name": name, "us_per_call": float(us_per_call),
           "derived": derived}
    if isinstance(us_per_call, Timing):
        rec["first_us"] = us_per_call.first_us
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
        f.flush()


def reset_records(path: str | None = None) -> None:
    """Truncate the emit sidecar (the harness calls this once per
    invocation so records never accumulate across runs)."""
    path = records_path() if path is None else path
    if path is not None:
        open(path, "w").close()


# Host-level performance knobs that move benchmark wall times: the
# allocator preload and the XLA/TF host env. Wall numbers are only
# comparable across runs with the same flag set, so every BENCH_*.json
# records the values in effect (`make bench-*` exports the tuned set; a
# bare `python -m benchmarks.run` records the honest empty one).
TUNED_ENV = ("LD_PRELOAD", "TF_CPP_MIN_LOG_LEVEL", "XLA_FLAGS")


def host_flags() -> dict:
    """The host performance env in effect for this process, as recorded in
    every report's ``meta.host_flags``: the raw ``TUNED_ENV`` values plus a
    ``tcmalloc`` bool (whether the preloaded allocator is actually active —
    the Makefile only preloads it where the library exists)."""
    flags = {k: os.environ.get(k, "") for k in TUNED_ENV}
    flags["tcmalloc"] = "tcmalloc" in flags["LD_PRELOAD"]
    return flags


def bench_meta() -> dict:
    """The provenance block every ``BENCH_*.json`` carries — identical in
    shape to the FL run ledger's manifest ``provenance`` (jax/numpy/python
    versions, platform, backend, git sha, UTC timestamp), plus the
    ``host_flags`` benchmark env block above."""
    from repro.obs import ledger as obs_ledger

    meta = dict(obs_ledger.provenance())
    meta["host_flags"] = host_flags()
    return meta


def write_bench_json(path: str, payload: dict) -> None:
    """Write one suite's ``BENCH_*.json`` with the shared ``meta``
    provenance block stamped in (suites pass their report payload;
    ``tools/bench_schema.py`` validates the result)."""
    out = dict(payload)
    out["meta"] = bench_meta()
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=_scalar)
        f.write("\n")


def _scalar(obj):
    """JSON fallback for numpy scalars in suite reports."""
    if hasattr(obj, "item"):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def fl_world(n_clients: int = 40, per_client: int = 96, seed: int = 0):
    """Small synthetic FL world shared by the FL-level suites: non-IID
    client shards plus the held-out eval set."""
    from repro.data import synth_mnist
    from repro.fl import partition

    (img, lab), (ti, tl) = synth_mnist.train_test(300, 60, seed=seed)
    parts = partition.non_iid_partition(img, lab, n_clients=n_clients, seed=seed)
    cx, cy = partition.stack_clients(parts, per_client=per_client, seed=seed)
    return cx, cy, ti, tl
