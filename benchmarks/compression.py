"""Accuracy-vs-airtime Pareto study for the compression subsystem.

Four arms per scenario, same world/seed, all riding the scenario machinery
(fixed single-mode policies so the only axis is the transport):

  ``dense-approx``  the paper's uncoded uplink, every coordinate on the air
  ``topk10``        top-k + error feedback at ratio 0.1 (10x fewer slots)
  ``topk50``        top-k + error feedback at ratio 0.02 (50x fewer slots)
  ``dense-ecrt``    the protected baseline (rate-1/2 LDPC, E[tx] priced)

Sparse arms send the selected values through the same approx pipeline plus
a Gray-MSB-protected index header; cumulative airtime prices both legs
(``TxStats.data_symbols`` carries header + payload).

The comparison is **airtime-matched, not round-matched**: a sparse round
costs ~6-30x less air, so the sparse arms run 5x the dense arm's rounds
and each arm traces an accuracy-vs-cumulative-airtime curve. Headline (the
suite's gate, mirrored in ``BENCH_compression.json``): on at least one
scenario a top-k+EF arm's curve reaches the dense-approx arm's *final*
accuracy (within 0.02) at <= 1/5 of the dense arm's *total* cumulative
airtime — the bits-on-air lever composes with the approximate wire instead
of fighting it. Emits CSV lines + the JSON artifact (uploaded by the
``bench-compress`` CI job). Standalone:

    PYTHONPATH=src python -m benchmarks.compression [--full]
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common
from benchmarks.common import emit, fl_world
from repro.compress import CompressionConfig
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.loop import run_fl
from repro.link import policy as policy_lib
from repro.link import scenario as scenario_lib

JSON_PATH = "BENCH_compression.json"
ACC_TOL = 0.02  # "reaches dense accuracy" tolerance
AIRTIME_FACTOR = 5.0  # the gate's airtime bar: <= dense / 5


def _arms() -> dict:
    """(policy, compression) per arm; policies are fixed single-mode."""
    approx = policy_lib.fixed_policy("approx", "qpsk")
    ecrt = policy_lib.fixed_policy("ecrt", "qpsk")
    return {
        "dense-approx": (approx, None),
        "topk10": (approx, CompressionConfig(method="topk", ratio=0.10)),
        "topk50": (approx, CompressionConfig(method="topk", ratio=0.02)),
        "dense-ecrt": (ecrt, None),
    }


def _first_win(res, target_acc: float, air_budget: float):
    """Earliest eval point reaching ``target_acc`` within ``air_budget``.

    Scans the arm's accuracy-vs-cumulative-airtime curve; returns the
    ``(round, accuracy, airtime_s)`` of the first qualifying point, or
    ``None``.
    """
    for r, acc, air in zip(res.rounds, res.accuracy, res.airtime_s):
        if acc >= target_acc and air <= air_budget:
            return {"round": int(r), "accuracy": float(acc),
                    "airtime_s": float(air)}
    return None


def run(quick: bool = True, seed: int = 0) -> dict:
    """Run the Pareto arms on vehicular + iot-flaky and assert the gate."""
    n_clients = 12 if quick else 40
    rounds = 25 if quick else 60
    sparse_rounds = 5 * rounds  # a sparse round is ~6-30x cheaper on the air
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))

    report = {"clients": n_clients, "rounds": rounds,
              "sparse_rounds": sparse_rounds, "scenarios": {}}
    gate_ok = False
    for scen_name in ("vehicular", "iot-flaky"):
        base = dataclasses.replace(scenario_lib.get_scenario(scen_name),
                                   ecrt_expected_tx=2.0)
        scen_report = {}
        results = {}
        for arm, (pol, comp) in _arms().items():
            scen = dataclasses.replace(base, policy=pol)
            n_rounds = rounds if comp is None else sparse_rounds
            res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=n_rounds,
                         batch_per_round=32, eval_every=5, seed=seed,
                         scenario=scen, compression=comp)
            results[arm] = res
            boa = (sum(t.get("comp_bits_on_air", 0.0) for t in res.link)
                   if comp is not None else 0.0)
            emit(f"compression/{scen_name}/{arm}", res.wall_s * 1e6,
                 f"final_acc={res.final_accuracy:.3f} rounds={n_rounds} "
                 f"airtime={res.airtime_s[-1]:.2f}s bits_on_air={boa:.3g}")
            scen_report[arm] = {
                "final_acc": float(res.final_accuracy),
                "rounds": n_rounds,
                "airtime_s": float(res.airtime_s[-1]),
                "accuracy_curve": [float(a) for a in res.accuracy],
                "airtime_curve": [float(a) for a in res.airtime_s],
                "wall_s": float(res.wall_s),
                "bits_on_air": float(boa),
            }
        dense = scen_report["dense-approx"]
        target = dense["final_acc"] - ACC_TOL
        budget = dense["airtime_s"] / AIRTIME_FACTOR
        for arm in ("topk10", "topk50"):
            win = _first_win(results[arm], target, budget)
            scen_report[arm]["pareto_win_vs_dense"] = win
            gate_ok = gate_ok or win is not None
            emit(f"compression/{scen_name}/{arm}-vs-dense", 0.0,
                 f"target_acc={target:.3f} air_budget={budget:.2f}s "
                 + (f"win@round={win['round']} acc={win['accuracy']:.3f} "
                    f"air={win['airtime_s']:.2f}s" if win else "win=False"))
        report["scenarios"][scen_name] = scen_report
    report["topk_matches_dense_at_fifth_airtime"] = bool(gate_ok)

    common.write_bench_json(JSON_PATH, report)
    emit("compression/json", 0.0, f"wrote {JSON_PATH}")
    if not gate_ok:  # the suite doubles as a gate (see benchmarks/run.py)
        raise AssertionError(
            "expected a top-k+EF approx arm to reach dense-approx accuracy "
            f"(within {ACC_TOL}) at <= 1/{AIRTIME_FACTOR:.0f} the cumulative "
            "airtime on at least one scenario; see BENCH_compression.json")
    return report


def main() -> None:
    """Standalone entry: ``python -m benchmarks.compression``."""
    ap = argparse.ArgumentParser(
        description="compression accuracy-vs-airtime Pareto study")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale profile (40 clients, 80 rounds)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, seed=args.seed)


if __name__ == "__main__":
    main()
