"""Beyond-paper ablation: FedAvg (multi-step local training) over the
approximate uplink, with and without adaptive max-abs pre-scaling.

Findings recorded in EXPERIMENTS.md: FedAvg's weight deltas survive the
same clamp prior (they are bounded like gradients); adaptive scaling does
NOT reliably help — QAM bit errors hit exponent bits regardless of where
values sit in the representable range, so concentrating magnitudes near the
bound only helps with a smarter receiver prior than bit-30 clamping."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.fedavg import run_fedavg


def run(quick: bool = True):
    n_clients = 24 if quick else 100
    rounds = 40 if quick else 200
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)
    for mode, scale in (("perfect", "none"), ("approx", "none"),
                        ("approx", "max_abs"), ("naive", "none")):
        tcfg = T.TransportConfig(mode=mode, channel=CH.ChannelConfig(snr_db=10.0))
        res = run_fedavg(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                         local_steps=3, batch_per_step=24, scale_mode=scale,
                         eval_every=max(2, rounds // 8))
        emit(f"fedavg/{mode}/scale-{scale}", res.wall_s * 1e6,
             f"final_acc={res.final_accuracy:.3f} airtime={res.airtime_s[-1]:.2f}s")
    return None
