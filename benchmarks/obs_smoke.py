"""Observability smoke: ledger + Perfetto trace on a short buffered run.

Drives a 5-aggregation buffered FedSGD run on the ``metro-rush`` scenario
with every sink attached — the JSONL run ledger, the Chrome/Perfetto trace
recorder, and the phase timers — and gates on the acceptance axes of the
obs layer:

* the ledger schema-validates (``repro.obs.ledger.validate_ledger``) and
  its round records reproduce ``FLResult.link`` **bit-identically**;
* a twin run with no sinks attached produces the same accuracy / airtime /
  link numbers (observers must not perturb the run);
* the exported trace is loadable Chrome trace-event JSON with at least 4
  distinct track types (waves, client compute/uplink spans, aggregations,
  buffer fill);
* the phase timers saw every phase and split the first (compile) call out
  of the steady state.

Emits CSV lines + ``BENCH_obs.json`` (with the shared ``meta`` provenance
block) and leaves ``BENCH_obs_ledger.jsonl`` / ``BENCH_obs_trace.json`` on
disk for inspection (load the trace at ``https://ui.perfetto.dev``).
Standalone: ``PYTHONPATH=src python -m benchmarks.obs_smoke``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from benchmarks import common
from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.async_engine import run_fl_buffered
from repro.link import scenario as scenario_lib
from repro.obs import PhaseTimers, TraceRecorder
from repro.obs import ledger as obs_ledger

JSON_PATH = "BENCH_obs.json"
LEDGER_PATH = "BENCH_obs_ledger.jsonl"
TRACE_PATH = "BENCH_obs_trace.json"
MIN_TRACK_TYPES = 4  # waves + client spans + aggregations + buffer fill


def run(quick: bool = True, seed: int = 0) -> dict:
    """Run the instrumented + bare twin runs and assert the obs gates."""
    n_clients = 8 if quick else 24
    n_rounds = 5
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(scenario_lib.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(batch_per_round=32, eval_every=2, seed=seed, scenario=scen,
              n_rounds=n_rounds, buffer_k=max(2, n_clients // 4),
              staleness="polynomial")

    trace = TraceRecorder(TRACE_PATH)
    timers = PhaseTimers()
    res = run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **kw,
                          ledger=LEDGER_PATH, trace=trace,
                          phase_timers=timers)
    emit("obs/run", res.wall_s * 1e6,
         f"rounds={n_rounds} final_acc={res.final_accuracy:.3f} "
         f"waves={len(res.records)} events={len(trace.events)}")

    problems = obs_ledger.validate_ledger(LEDGER_PATH)
    if problems:
        raise AssertionError(f"ledger schema problems: {problems}")
    data = obs_ledger.read_ledger(LEDGER_PATH)
    if data.link != res.link:
        raise AssertionError(
            "ledger round-trip does not reproduce FLResult.link")
    emit("obs/ledger", 0.0,
         f"wrote {LEDGER_PATH} rounds={len(data.rounds)} "
         f"events={len(data.events)} (schema-valid, link exact)")

    with open(TRACE_PATH) as f:
        chrome = json.load(f)
    tracks = sorted(trace.track_types())
    if len(tracks) < MIN_TRACK_TYPES:
        raise AssertionError(
            f"trace has track types {tracks}, need >= {MIN_TRACK_TYPES}")
    if not chrome.get("traceEvents"):
        raise AssertionError("exported trace has no traceEvents")
    emit("obs/trace", 0.0,
         f"wrote {TRACE_PATH} events={len(chrome['traceEvents'])} "
         f"tracks={'+'.join(tracks)}")

    phases = timers.summary()
    for phase in ("sample", "wave", "telemetry", "eval"):
        if phase not in phases or phases[phase]["calls"] < 1:
            raise AssertionError(f"phase timers missed phase {phase!r}")
    wave = phases["wave"]
    emit("obs/timers", wave["steady_median_s"] * 1e6,
         f"wave_first={wave['first_s'] * 1e3:.0f}ms "
         f"calls={wave['calls']}")

    # Observer-neutrality gate: the bare twin must match bit-for-bit.
    bare = run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **kw)
    same = (bare.accuracy == res.accuracy
            and bare.airtime_s == res.airtime_s
            and bare.event_s == res.event_s and bare.link == res.link)
    if not same:
        raise AssertionError(
            "attaching obs sinks changed the run's numeric results")
    emit("obs/neutrality", 0.0, "sinks-on == sinks-off (bit-identical)")

    report = {
        "clients": n_clients, "rounds": n_rounds, "scenario": scen.name,
        "ledger": LEDGER_PATH, "trace": TRACE_PATH,
        "ledger_rounds": len(data.rounds), "ledger_events": len(data.events),
        "track_types": tracks, "phases": phases,
        "sinks_are_neutral": same,
    }
    common.write_bench_json(JSON_PATH, report)
    emit("obs/json", 0.0, f"wrote {JSON_PATH}")
    return report


def main() -> None:
    """Standalone entry: ``python -m benchmarks.obs_smoke``."""
    ap = argparse.ArgumentParser(
        description="ledger + trace + timers smoke on a buffered run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="larger cohort (24 clients)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, seed=args.seed)


if __name__ == "__main__":
    main()
