"""Observability smoke: ledger + trace + sketches on a short buffered run.

Drives a 5-aggregation buffered FedSGD run on the ``metro-rush`` scenario
with every sink attached — the JSONL run ledger, the Chrome/Perfetto trace
recorder, the phase timers, and the per-round distribution sketches — and
gates on the acceptance axes of the obs layer:

* the ledger schema-validates (``repro.obs.ledger.validate_ledger``) and
  its round records reproduce ``FLResult.link`` **bit-identically**;
* every round record carries a ``sketches`` group (schema v2);
* a twin run with no sinks attached produces the same accuracy / airtime /
  link numbers (observers must not perturb the run);
* **overhead**: the sinks-on arm's wall clock is within 5% of the
  sinks-off arm (plus a 0.5 s absolute slack absorbing the sketch
  kernel's one-time jit compile) — both arms run after a shared compile
  warmup so neither pays the training jit tax;
* the exported trace is loadable Chrome trace-event JSON with at least 4
  distinct track types (waves, client compute/uplink spans, aggregations,
  buffer fill);
* the phase timers saw every phase and split the first (compile) call out
  of the steady state;
* **scale**: driving the link engine alone at 64 and 1024 clients with a
  ``detail="sketch"`` ledger yields round lines whose structure (and size,
  within formatting noise) is cohort-independent, while the run-level
  sketch p50/p95/p99 of per-client BER and SNR match the exact values
  within each bucket layout's documented error bound.

Emits CSV lines + ``BENCH_obs.json`` (with the shared ``meta`` provenance
block) and leaves ``BENCH_obs_ledger.jsonl`` / ``BENCH_obs_trace.json`` /
``BENCH_obs_sketch_{64,1024}c.jsonl`` on disk for inspection (load the
trace at ``https://ui.perfetto.dev``).
Standalone: ``PYTHONPATH=src python -m benchmarks.obs_smoke``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import keylanes
from repro.core import transport as T
from repro.fl.async_engine import run_fl_buffered
from repro.link import scenario as scenario_lib
from repro.obs import PhaseTimers, RoundSketcher, Sketch, TraceRecorder
from repro.obs import ledger as obs_ledger
from repro.obs import records as obs_records

JSON_PATH = "BENCH_obs.json"
LEDGER_PATH = "BENCH_obs_ledger.jsonl"
TRACE_PATH = "BENCH_obs_trace.json"
MIN_TRACK_TYPES = 4  # waves + client spans + aggregations + buffer fill
OVERHEAD_REL = 0.05  # sinks-on wall clock budget: 5% over sinks-off ...
OVERHEAD_ABS_S = 0.5  # ... plus the sketch kernel's one-time compile
SCALE_COHORTS = (64, 1024)
SCALE_ROUNDS = 3
SCALE_LEDGER_FMT = "BENCH_obs_sketch_{n}c.jsonl"


def _sketch_scale_check(tcfg, scen, seed: int) -> dict:
    """The constant-size-at-scale gate (link engine only, no training).

    Drives ``ScenarioDriver`` rounds at each cohort size in
    ``SCALE_COHORTS``, sketching synthetic-but-exactly-known per-client
    uplink outcomes into a ``detail="sketch"`` ledger. Asserts: the ledger
    validates; every round line has the same per-metric bucket-count
    structure regardless of cohort size (and its byte size stays within
    formatting noise); and the run-level sketch p50/p95/p99 of BER and SNR
    agree with ``np.quantile(..., method="lower")`` of the exact values
    within ``BucketLayout.error_bound()``.
    """
    driver = scenario_lib.ScenarioDriver(scen, tcfg)
    structures, line_bytes, quantiles = {}, {}, {}
    ber_bound = snr_bound = 0.0
    for n in SCALE_COHORTS:
        sk = RoundSketcher(n)
        ber_lay, snr_lay = sk.layouts["ber"], sk.layouts["snr_db"]
        ber_bound, snr_bound = ber_lay.error_bound(), snr_lay.error_bound()
        path = SCALE_LEDGER_FMT.format(n=n)
        exact_ber, exact_snr = [], []
        with obs_ledger.RunLedger(path, detail="sketch") as led:
            led.write_manifest({
                "engine": "sketch-scale-check", "algorithm": "none",
                "scenario": scen.name, "num_clients": n,
                "n_rounds": SCALE_ROUNDS, "seed": seed,
                "fingerprint": obs_ledger.config_fingerprint(
                    scen, n, SCALE_ROUNDS, seed),
                "provenance": obs_ledger.provenance()})
            key = jax.random.PRNGKey(seed)
            # Init + round keys ride indices [0, SCALE_ROUNDS] of the
            # standalone root key; the guard pins the folded range inside
            # one reserved lane of the round key space.
            keylanes.check_range(0, SCALE_ROUNDS + 1)
            keys = [jax.random.fold_in(key, i)
                    for i in range(SCALE_ROUNDS + 1)]
            state, mode, est = driver.init(keys[0], n)
            for r in range(SCALE_ROUNDS):
                rk = keys[r + 1]
                state, rnd = driver.round(state, mode, est, rk)
                mode, est = rnd.mode, rnd.est_db
                # Synthetic but exactly-known uplink outcomes driven by
                # the scenario's real SNR draw, clipped inside the BER
                # bucket range so the exact-quantile comparison is well
                # defined (no underflow-bucket saturation).
                ber = jnp.clip(10.0 ** (-(rnd.snr_db + 25.0) / 10.0),
                               1e-6, 1.0)
                air = 0.01 * (1.0 + jnp.maximum(0.0, 30.0 - rnd.snr_db))
                led.write_round(obs_records.RoundRecord(
                    round=r, sketches=sk.round_group(
                        rk, snr_db=rnd.snr_db, est_db=rnd.est_db, ber=ber,
                        airtime_s=air, mode=rnd.mode, active=rnd.active)))
                act = np.asarray(rnd.active) > 0
                exact_ber.append(np.asarray(ber)[act])
                exact_snr.append(np.asarray(rnd.snr_db))
        problems = obs_ledger.validate_ledger(path)
        if problems:
            raise AssertionError(f"scale ledger {path}: {problems}")
        sizes, struct = [], None
        with open(path) as f:
            for line in f:
                obj = json.loads(line)
                if obj.get("kind") != "round":
                    continue
                sizes.append(len(line))
                shape = {m: len(g["counts"])
                         for m, g in obj["sketches"].items()
                         if m != "exemplars"}
                if struct is None:
                    struct = shape
                elif shape != struct:
                    raise AssertionError(
                        f"{path}: sketch line structure varies per round")
        structures[n] = struct
        line_bytes[n] = max(sizes)
        # Quantile accuracy vs the exact per-client values (BER is masked
        # by activity like the sketch's eff mask; SNR is clipped to the
        # layout range, matching the under/overflow -> lo/hi convention).
        ber_sk = Sketch.from_dict(sk.summary()["ber"])
        snr_sk = Sketch.from_dict(sk.summary()["snr_db"])
        eb = np.concatenate(exact_ber)
        es = np.clip(np.concatenate(exact_snr), snr_lay.lo, snr_lay.hi)
        q = {}
        for p in (0.5, 0.95, 0.99):
            ber_exact = float(np.quantile(eb, p, method="lower"))
            rel = abs(ber_sk.quantile(p) - ber_exact) / ber_exact
            snr_exact = float(np.quantile(es, p, method="lower"))
            ab = abs(snr_sk.quantile(p) - snr_exact)
            q[f"p{int(p * 100)}"] = {"ber_rel_err": rel,
                                     "snr_abs_err_db": ab}
            # 1e-5 epsilon: a ranked value sitting exactly on a bucket
            # edge can overshoot the analytic bound by the float32
            # edge-rounding error (~1e-7 relative).
            if rel > ber_bound + 1e-5:
                raise AssertionError(
                    f"{n} clients: BER p{int(p * 100)} rel err {rel:.4f} "
                    f"exceeds layout bound {ber_bound:.4f}")
            if ab > snr_bound + 1e-5:
                raise AssertionError(
                    f"{n} clients: SNR p{int(p * 100)} abs err {ab:.3f} dB "
                    f"exceeds layout bound {snr_bound:.3f} dB")
        quantiles[n] = q
    lo_n, hi_n = SCALE_COHORTS[0], SCALE_COHORTS[-1]
    if structures[lo_n] != structures[hi_n]:
        raise AssertionError(
            f"sketch line structure depends on cohort size: "
            f"{structures[lo_n]} vs {structures[hi_n]}")
    if line_bytes[hi_n] > line_bytes[lo_n] * 1.5:
        raise AssertionError(
            f"sketch line size grew with the cohort: {line_bytes[lo_n]}B "
            f"at {lo_n} clients vs {line_bytes[hi_n]}B at {hi_n}")
    return {
        "cohorts": list(SCALE_COHORTS), "rounds": SCALE_ROUNDS,
        "structure_constant": True,
        "max_line_bytes": {str(n): line_bytes[n] for n in SCALE_COHORTS},
        "quantile_err": {str(n): quantiles[n] for n in SCALE_COHORTS},
        "ber_rel_bound": ber_bound, "snr_abs_bound_db": snr_bound,
    }


def run(quick: bool = True, seed: int = 0) -> dict:
    """Run the instrumented + bare twin runs and assert the obs gates."""
    n_clients = 8 if quick else 24
    n_rounds = 5
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(scenario_lib.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(batch_per_round=32, eval_every=2, seed=seed, scenario=scen,
              n_rounds=n_rounds, buffer_k=max(2, n_clients // 4),
              staleness="polynomial")

    # Shared compile warmup (result discarded): the overhead gate below
    # compares steady-state wall clocks, so neither arm may pay the jit
    # tax. ``sketches=True`` here also compiles the (instance-shared)
    # sketch reduction the instrumented arm will hit warm.
    run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **kw, sketches=True)

    trace = TraceRecorder(TRACE_PATH)
    timers = PhaseTimers()
    res = run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **kw,
                          ledger=LEDGER_PATH, trace=trace,
                          phase_timers=timers, sketches=True)
    emit("obs/run", res.wall_s * 1e6,
         f"rounds={n_rounds} final_acc={res.final_accuracy:.3f} "
         f"waves={len(res.records)} events={len(trace.events)}")

    problems = obs_ledger.validate_ledger(LEDGER_PATH)
    if problems:
        raise AssertionError(f"ledger schema problems: {problems}")
    data = obs_ledger.read_ledger(LEDGER_PATH)
    if data.link != res.link:
        raise AssertionError(
            "ledger round-trip does not reproduce FLResult.link")
    emit("obs/ledger", 0.0,
         f"wrote {LEDGER_PATH} rounds={len(data.rounds)} "
         f"events={len(data.events)} (schema-valid, link exact)")

    sketch_rounds = sum(1 for r in data.rounds if r.sketches is not None)
    if sketch_rounds != len(data.rounds):
        raise AssertionError(
            f"only {sketch_rounds}/{len(data.rounds)} round records carry "
            f"a sketches group")
    emit("obs/sketches", 0.0,
         f"all {sketch_rounds} round records carry schema-v2 sketches")

    with open(TRACE_PATH) as f:
        chrome = json.load(f)
    tracks = sorted(trace.track_types())
    if len(tracks) < MIN_TRACK_TYPES:
        raise AssertionError(
            f"trace has track types {tracks}, need >= {MIN_TRACK_TYPES}")
    if not chrome.get("traceEvents"):
        raise AssertionError("exported trace has no traceEvents")
    emit("obs/trace", 0.0,
         f"wrote {TRACE_PATH} events={len(chrome['traceEvents'])} "
         f"tracks={'+'.join(tracks)}")

    phases = timers.summary()
    for phase in ("sample", "wave", "telemetry", "eval"):
        if phase not in phases or phases[phase]["calls"] < 1:
            raise AssertionError(f"phase timers missed phase {phase!r}")
    wave = phases["wave"]
    emit("obs/timers", wave["steady_median_s"] * 1e6,
         f"wave_first={wave['first_s'] * 1e3:.0f}ms "
         f"calls={wave['calls']}")

    # Observer-neutrality gate: the bare twin must match bit-for-bit.
    bare = run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **kw)
    same = (bare.accuracy == res.accuracy
            and bare.airtime_s == res.airtime_s
            and bare.event_s == res.event_s and bare.link == res.link)
    if not same:
        raise AssertionError(
            "attaching obs sinks changed the run's numeric results")
    emit("obs/neutrality", 0.0, "sinks-on == sinks-off (bit-identical)")

    # Overhead gate: all four sinks together must cost <= 5% wall clock
    # (+ OVERHEAD_ABS_S absorbing the sketch kernel's one-time compile,
    # which only the instrumented arm pays).
    wall_on, wall_off = res.wall_s, bare.wall_s
    budget_s = wall_off * (1.0 + OVERHEAD_REL) + OVERHEAD_ABS_S
    overhead_ok = wall_on <= budget_s
    if not overhead_ok:
        raise AssertionError(
            f"obs overhead: sinks-on {wall_on:.2f}s exceeds budget "
            f"{budget_s:.2f}s (sinks-off {wall_off:.2f}s)")
    emit("obs/overhead", (wall_on - wall_off) * 1e6,
         f"on={wall_on:.2f}s off={wall_off:.2f}s "
         f"ratio={wall_on / max(wall_off, 1e-9):.3f} (budget 5% + "
         f"{OVERHEAD_ABS_S:.1f}s compile slack)")

    scale = _sketch_scale_check(tcfg, scen, seed)
    emit("obs/scale", 0.0,
         f"cohorts={'x'.join(str(n) for n in SCALE_COHORTS)} "
         f"line_bytes={scale['max_line_bytes']} "
         f"ber_bound={scale['ber_rel_bound']:.4f}")

    report = {
        "clients": n_clients, "rounds": n_rounds, "scenario": scen.name,
        "ledger": LEDGER_PATH, "trace": TRACE_PATH,
        "ledger_rounds": len(data.rounds), "ledger_events": len(data.events),
        "sketch_rounds": sketch_rounds,
        "track_types": tracks, "phases": phases,
        "sinks_are_neutral": same,
        "overhead": {"wall_on_s": wall_on, "wall_off_s": wall_off,
                     "ratio": wall_on / max(wall_off, 1e-9),
                     "budget_s": budget_s, "ok": overhead_ok},
        "sketch_scale": scale,
    }
    common.write_bench_json(JSON_PATH, report)
    emit("obs/json", 0.0, f"wrote {JSON_PATH}")
    return report


def main() -> None:
    """Standalone entry: ``python -m benchmarks.obs_smoke``."""
    ap = argparse.ArgumentParser(
        description="ledger + trace + timers + sketches smoke on a "
                    "buffered run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="larger cohort (24 clients)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, seed=args.seed)


if __name__ == "__main__":
    main()
