"""Multi-client uplink scaling: batched engine vs per-client Python loop.

The production question behind ``transport.transmit_batch``: serving M
clients per round, does one fused (vmapped / 2-D-grid) computation beat M
sequential single-client pipelines? We sweep the cohort size 1 -> 1024 and
report floats/sec through the approx mode.

Two regimes, both reported:

* **dispatch-bound** (small per-client payloads, the serving sweet spot —
  e.g. per-layer or quantized updates): the loop pays per-call dispatch +
  key-fold + stack overhead M times; the batch pays it once. This is where
  the headline >= 5x at batch 64 comes from.
* **compute-bound** (64k-float payloads): on CPU both spend their time in
  the channel RNG, so the ratio approaches 1x; on TPU this regime belongs
  to the fused batched Pallas kernel (one launch, full VPU occupancy — see
  ``benchmarks/kernel_throughput.py`` for the structural HBM argument).

Also verifies the engine contract at scale: 64 clients x 64k floats in ONE
jitted call, received bits identical to a 64-iteration ``transmit_flat``
loop under the same fold_in key schedule (so mean BER matches exactly, well
within any statistical tolerance).

The loop baseline is the *best possible* loop: the single-client transmit is
jitted once and replayed, so the gap is overhead + lost cross-client
parallelism, not tracing time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import channel as CH
from repro.core import transport as T

HEADLINE_BATCH = 64
N_SMALL = 64  # dispatch-bound per-client payload (floats)


def _cfg():
    return T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))


def _loop_fn(single, key, m):
    def loop_all(xb):
        outs = []
        for i in range(m):
            # mirrors client_keys' uplink schedule for the batched-vs-loop
            # equivalence check: lint: ignore[keylane]
            outs.append(single(xb[i], jax.random.fold_in(key, i))[0])
        return jnp.stack(outs)

    return loop_all


def run(quick: bool = True):
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    single = jax.jit(lambda xc, kc: T.transmit_flat(xc, kc, cfg))
    batched = jax.jit(lambda xb, k: T.transmit_batch(xb, k, cfg))

    # --- cohort-size sweep, dispatch-bound regime -------------------------
    cohorts = (1, 4, 16, 64) if quick else (1, 4, 16, 64, 256, 1024)
    ratio64 = None
    for m in cohorts:
        xb = jax.random.uniform(
            jax.random.PRNGKey(1), (m, N_SMALL), minval=-0.99, maxval=0.99)
        us_batch = timeit(batched, xb, key, iters=3)
        emit(f"scaling/batch_{m}", us_batch,
             f"{m * N_SMALL / (us_batch / 1e6):.3e} floats/s "
             f"({m} clients x {N_SMALL} floats fused)")
        if m == HEADLINE_BATCH:
            us_loop = timeit(_loop_fn(single, key, m), xb, iters=3)
            ratio64 = us_loop / us_batch
            emit(f"scaling/loop_{m}", us_loop,
                 f"{m * N_SMALL / (us_loop / 1e6):.3e} floats/s "
                 f"({m} jitted single-client calls)")
            emit(f"scaling/speedup_{m}", 0.0,
                 f"batched {ratio64:.1f}x faster than looped at {m} clients "
                 f"x {N_SMALL} floats (dispatch-bound)")

    # --- heterogeneous links cost nothing extra ---------------------------
    m = HEADLINE_BATCH
    xb = jax.random.uniform(
        jax.random.PRNGKey(1), (m, N_SMALL), minval=-0.99, maxval=0.99)
    snr = jnp.linspace(0.0, 30.0, m)
    het = jax.jit(lambda xb, k: T.transmit_batch(xb, k, cfg, snr_db=snr))
    us_het = timeit(het, xb, key, iters=3)
    emit(f"scaling/heterogeneous_{m}", us_het,
         f"per-client SNR 0..30 dB, {m * N_SMALL / (us_het / 1e6):.3e} floats/s")

    # --- contract at scale: 64 x 64k in one jitted call == 64-iter loop ---
    # (each side runs twice total: one compile/warm pass, one timed pass
    # whose outputs are reused for the equivalence check)
    import time

    n_big = 1 << 16
    xb = jax.random.uniform(
        jax.random.PRNGKey(2), (m, n_big), minval=-0.99, maxval=0.99)
    jax.block_until_ready(batched(xb, key))  # compile
    t0 = time.perf_counter()
    out_b, st_b = jax.block_until_ready(batched(xb, key))
    us_big = (time.perf_counter() - t0) * 1e6
    emit(f"scaling/batch_{m}x{n_big}", us_big,
         f"{m * n_big / (us_big / 1e6):.3e} floats/s (compute-bound, one jit call)")
    loop_all = _loop_fn(single, key, m)
    jax.block_until_ready(loop_all(xb))  # compile
    t0 = time.perf_counter()
    loop_out = jax.block_until_ready(loop_all(xb))
    us_loop_big = (time.perf_counter() - t0) * 1e6
    emit(f"scaling/loop_{m}x{n_big}", us_loop_big,
         f"{m * n_big / (us_loop_big / 1e6):.3e} floats/s "
         f"(compute-bound: CPU channel-RNG limited; TPU kernel regime)")
    ber_b = float(jnp.mean(st_b.ber))
    identical = bool((np.asarray(out_b) == np.asarray(loop_out)).all())
    emit(f"scaling/equivalence_{m}x{n_big}", 0.0,
         f"mean BER {ber_b:.5f}; batch == loop bit-for-bit: {identical}")
    assert identical, "batched uplink diverged from the per-client loop"
    return ratio64
