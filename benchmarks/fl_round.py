"""Uplink-vs-downlink error-budget study (per Qu et al., arXiv:2310.16652).

The paper models bit errors only on the uplink; Qu et al. show FL is
markedly *less* tolerant of errors on the downlink broadcast of the global
model than of errors on the uplink gradients. With the round engine's
downlink leg both directions ride the same transport, so the comparison is
apples-to-apples: four arms on the same world/seed, one noisy leg at a time,
the noisy leg always uncoded (``approx``) at the **same matched SNR**:

  ``clean``     perfect uplink + error-free downlink (reference)
  ``uplink``    approx uplink @ SNR dB, error-free downlink (paper setting)
  ``downlink``  perfect uplink, approx downlink @ the same SNR dB
  ``both``      approx on both legs

Headline (the ``fl_round/asymmetry`` line): the downlink arm's final
accuracy falls below the uplink arm's at the same SNR. Mechanism: an uplink
bit error corrupts one client's gradient and is averaged down ~1/M by the
aggregate; a downlink bit error corrupts the weights a client computes its
*entire* local step from, every round, so the same BER buys far more damage.

Emits CSV lines + ``BENCH_fl_round.json`` (uploaded as a CI artifact by the
``bench-fl`` job). Standalone:

    PYTHONPATH=src python -m benchmarks.fl_round [--snr 10] [--full]
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common
from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.fl.loop import run_fl
from repro.link.scenario import DownlinkConfig

JSON_PATH = "BENCH_fl_round.json"


def _arms(snr_db: float) -> dict:
    """The four (uplink transport, downlink config) arms at one SNR."""
    perfect = T.TransportConfig(mode="perfect",
                                channel=CH.ChannelConfig(snr_db=snr_db))
    approx = T.TransportConfig(mode="approx",
                               channel=CH.ChannelConfig(snr_db=snr_db))
    noisy_dl = DownlinkConfig(mode="approx", snr_offset_db=0.0)
    return {
        "clean": (perfect, None),
        "uplink": (approx, None),
        "downlink": (perfect, noisy_dl),
        "both": (approx, noisy_dl),
    }


def run(quick: bool = True, snr_db: float = 10.0, seed: int = 0) -> dict:
    """Run the four arms and assert/report the error-budget asymmetry."""
    n_clients = 16 if quick else 40
    rounds = 30 if quick else 100
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)

    report = {"snr_db": snr_db, "clients": n_clients, "rounds": rounds,
              "arms": {}}
    results = {}
    for arm, (tcfg, dl) in _arms(snr_db).items():
        res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                     batch_per_round=32, eval_every=5, seed=seed,
                     downlink=dl)
        results[arm] = res
        dl_ber = (sum(t["downlink_ber"] for t in res.link) / len(res.link)
                  if res.link else 0.0)
        emit(f"fl_round/{arm}", res.wall_s * 1e6,
             f"final_acc={res.final_accuracy:.3f} "
             f"airtime={res.airtime_s[-1]:.2f}s dl_ber={dl_ber:.2e}")
        report["arms"][arm] = {
            "final_acc": float(res.final_accuracy),
            "airtime_s": float(res.airtime_s[-1]),
            "wall_s": float(res.wall_s),
            "downlink_ber": float(dl_ber),
        }

    # Qu et al.'s qualitative claim at matched SNR: the noisy downlink hurts
    # accuracy more than the equally-noisy uplink.
    up, dn = results["uplink"], results["downlink"]
    asymmetric = dn.final_accuracy < up.final_accuracy
    emit("fl_round/asymmetry", 0.0,
         f"uplink_acc={up.final_accuracy:.3f} "
         f"downlink_acc={dn.final_accuracy:.3f} "
         f"downlink_worse={asymmetric}")
    report["downlink_worse_than_uplink"] = bool(asymmetric)

    common.write_bench_json(JSON_PATH, report)
    emit("fl_round/json", 0.0, f"wrote {JSON_PATH}")
    if not asymmetric:  # the suite doubles as a gate (see benchmarks/run.py)
        raise AssertionError(
            f"expected the noisy downlink to degrade accuracy more than the "
            f"equally-noisy uplink at {snr_db} dB; got uplink "
            f"{up.final_accuracy:.3f} vs downlink {dn.final_accuracy:.3f}")
    return report


def main() -> None:
    """Standalone entry: ``python -m benchmarks.fl_round``."""
    ap = argparse.ArgumentParser(
        description="uplink-vs-downlink FL error-budget study")
    ap.add_argument("--snr", type=float, default=10.0,
                    help="matched SNR (dB) for whichever leg is noisy")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale profile (40 clients, 100 rounds)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, snr_db=args.snr, seed=args.seed)


if __name__ == "__main__":
    main()
