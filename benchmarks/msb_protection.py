"""Paper Table I: per-position bit error counts in Gray-coded 16-QAM.

For each transmitted symbol we count, over a noisy channel, how often each
of the 4 bit positions flips. The Gray constellation protects the first
(MSB) bit of each axis: its error rate is about half the LSB's."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import modulation as M


def run(quick: bool = True):
    scheme = M.MOD_SCHEMES["16qam"]
    n = 1 << (16 if quick else 19)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    sym = jax.random.randint(k1, (n,), 0, scheme.points).astype(jnp.uint32)
    tx = M.modulate(sym, scheme)
    noise = 0.22 * (jax.random.normal(k2, (n,)) + 1j * jax.random.normal(k3, (n,)))
    rx = M.demod_hard(tx + noise.astype(jnp.complex64), scheme)
    diff = sym ^ rx
    k = scheme.bits_per_symbol
    rates = []
    for j in range(k):
        r = float(jnp.mean((diff >> (k - 1 - j)) & 1))
        rates.append(r)
        emit(f"table1/bit{j}", 0.0,
             f"err_rate={r:.4f} ({'MSB' if j == 0 else 'LSB' if j == k-1 else 'mid'})")
    emit("table1/msb_vs_lsb", 0.0,
         f"msb={rates[0]:.4f} lsb={rates[-1]:.4f} ratio={rates[-1]/max(rates[0],1e-9):.2f} "
         "(paper: MSB better protected)")

    # neighbour analysis mirroring Table I's construction for s0, s1, s4, s5
    pts = M.constellation(scheme)
    import numpy as np

    P = np.asarray(pts)
    step = 2 * scheme.amp_norm * 1.01
    for s in (0, 1, 4, 5):
        nbrs = [j for j in range(16) if j != s and abs(P[j] - P[s]) <= step * 1.45]
        msb = sum(((s ^ j) >> 3) & 1 for j in nbrs)
        lsb = sum((s ^ j) & 1 for j in nbrs)
        emit(f"table1/s{s}", 0.0,
             f"neighbours={len(nbrs)} msb_err_count={msb} lsb_err_count={lsb}")
    return rates
