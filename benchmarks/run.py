"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Default is the quick
single-core profile; ``--full`` runs paper-scale (100 clients, eta=0.01).

  PYTHONPATH=src python -m benchmarks.run [--only fig3,ber] [--full]
"""

from __future__ import annotations

import argparse
import sys
import time

SUITES = {
    "ber": ("benchmarks.ber_vs_snr", "BER vs SNR (paper Sec. V)"),
    "table1": ("benchmarks.msb_protection", "Gray 16-QAM MSB protection (Table I)"),
    "ecrt": ("benchmarks.ecrt_overhead", "LDPC E[tx] + airtime model"),
    "kernel": ("benchmarks.kernel_throughput", "fused kernel vs jnp reference"),
    "scaling": ("benchmarks.clients_scaling", "batched multi-client uplink scaling"),
    "fig3": ("benchmarks.accuracy_vs_time", "accuracy vs comm-time (Fig. 3)"),
    "fig4": ("benchmarks.same_snr_same_ber", "same-SNR / same-BER (Fig. 4)"),
    "fedavg": ("benchmarks.fedavg_ablation", "FedAvg + adaptive scaling ablation"),
    "roofline": ("benchmarks.roofline_report", "dry-run roofline summary"),
    "link": ("benchmarks.link_adaptation",
             "adaptive mode policy vs fixed transports across scenarios"),
    "fl_round": ("benchmarks.fl_round",
                 "uplink-vs-downlink error budget (Qu et al. asymmetry)"),
    "compression": ("benchmarks.compression",
                    "sparse top-k+EF uplink accuracy-vs-airtime Pareto"),
    "async_fl": ("benchmarks.async_fl",
                 "buffered-async vs sync FL under straggling (FedBuff)"),
    "obs": ("benchmarks.obs_smoke",
            "run ledger + Perfetto trace + phase timers smoke"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    picks = [s.strip() for s in args.only.split(",") if s.strip()] or list(SUITES)
    unknown = [p for p in picks if p not in SUITES]
    if unknown:
        print(f"unknown suite(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"valid suites: {', '.join(SUITES)}", file=sys.stderr)
        raise SystemExit(2)

    from benchmarks import common

    # One emit-record sidecar per invocation (benchmarks/common.emit
    # appends; without the reset, records would accumulate across runs).
    common.reset_records()
    print("name,us_per_call,derived")
    failed = []
    for name in picks:
        mod_name, desc = SUITES[name]
        print(f"# === {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{e!r}", file=sys.stdout)
            failed.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s")
    if failed:
        # Remaining suites still ran (the ERROR lines above are per-suite),
        # but the invocation as a whole must fail: suites double as gates —
        # e.g. the link suite asserts bucketed ≡ select bit-equivalence.
        print(f"# FAILED suites: {', '.join(failed)}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
