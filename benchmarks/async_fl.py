"""Buffered-async vs synchronous FL under heavy straggling (FedBuff study).

Two arms on the ``metro-rush`` scenario (vehicular fading + 15% compute
stragglers at 20x slowdown + idle gaps), same world/seed, both driven by
the buffered engine so they share one event-clock model:

  ``sync``      ``buffer_k = M`` — every aggregation waits for the whole
                cohort, i.e. the synchronous barrier priced on the event
                clock (each round costs the *slowest* client's compute +
                arrival).
  ``buffered``  ``buffer_k = M // 4`` with polynomial staleness weights —
                the server folds the buffer every K arrivals; stragglers
                land late and staleness-damped, and fresh waves dispatch at
                every aggregation, so 4x the model versions in the same
                event time.

The comparison is **event-time-matched, not round-matched**: the buffered
arm runs 4x the versions and traces accuracy vs the event clock. Headline
(the suite's gate, mirrored in ``BENCH_async_fl.json``): the buffered
arm's curve reaches the sync arm's *final* accuracy (within 0.02) in at
most ``0.6x`` the sync arm's total event-clock time — buffering converts
straggler stalls into extra model versions. Emits CSV lines + the JSON
artifact (uploaded by the ``bench-async`` CI job). Standalone:

    PYTHONPATH=src python -m benchmarks.async_fl [--full]
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import latency as latency_lib
from repro.core import transport as T
from repro.fl.async_engine import run_fl_buffered
from repro.link import dynamics as dynamics_lib
from repro.link import scenario as scenario_lib

JSON_PATH = "BENCH_async_fl.json"
LEDGER_PATH = "BENCH_async_fl_ledger.jsonl"  # CI artifact (bench-async job)
ACC_TOL = 0.02  # "reaches sync accuracy" tolerance
TIME_FACTOR = 0.6  # the gate's bar: buffered event time <= 0.6x sync's


def _first_win(res, target_acc: float, time_budget: float):
    """Earliest eval point reaching ``target_acc`` within the event-clock
    ``time_budget``; ``(round, accuracy, event_s)`` dict or ``None``."""
    for r, acc, t in zip(res.rounds, res.accuracy, res.event_s):
        if acc >= target_acc and t <= time_budget:
            return {"round": int(r), "accuracy": float(acc),
                    "event_s": float(t)}
    return None


def run(quick: bool = True, seed: int = 0) -> dict:
    """Run both arms on metro-rush and assert the 0.6x event-time gate."""
    n_clients = 12 if quick else 40
    sync_rounds = 16 if quick else 40
    buffered_rounds = 4 * sync_rounds
    buffer_k = max(2, n_clients // 4)
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(scenario_lib.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(batch_per_round=32, eval_every=4, seed=seed, scenario=scen)

    report = {"clients": n_clients, "scenario": scen.name,
              "buffer_k": buffer_k, "arms": {}}
    # The buffered arm carries the run ledger (repro.obs): the JSONL file
    # is schema-validated below and uploaded as a CI artifact.
    arms = {
        "sync": dict(n_rounds=sync_rounds, buffer_k=None),
        "buffered": dict(n_rounds=buffered_rounds, buffer_k=buffer_k,
                         staleness="polynomial", ledger=LEDGER_PATH),
    }
    results = {}
    for arm, akw in arms.items():
        res = run_fl_buffered(cfg, tcfg, cx, cy, ti, tl, **akw, **kw)
        results[arm] = res
        akw.pop("ledger", None)  # not a report field
        emit(f"async_fl/{arm}", res.wall_s * 1e6,
             f"final_acc={res.final_accuracy:.3f} rounds={akw['n_rounds']} "
             f"event_clock={res.event_s[-1]:.1f}s "
             f"airtime={res.airtime_s[-1]:.2f}s")
        report["arms"][arm] = {
            "final_acc": float(res.final_accuracy),
            "rounds": akw["n_rounds"],
            "buffer_k": akw["buffer_k"] or n_clients,
            "event_clock_s": float(res.event_s[-1]),
            "airtime_s": float(res.airtime_s[-1]),
            "accuracy_curve": [float(a) for a in res.accuracy],
            "event_curve": [float(t) for t in res.event_s],
            "wall_s": float(res.wall_s),
        }

    # Reference figure: what one *fully synchronous* TDMA barrier costs on
    # this compute model (max compute + summed airtime), vs the event
    # clock's contention-free arrival model.
    key = jax.random.fold_in(jax.random.PRNGKey(seed),
                             dynamics_lib.COMPUTE_KEY_LANE)
    speed = dynamics_lib.client_speed_factors(key, n_clients, scen.compute)
    comp_s = dynamics_lib.compute_times(jax.random.PRNGKey(seed + 1),
                                        scen.compute, n_clients, speed)
    mean_air = results["sync"].link[0]["airtime_s"] / n_clients
    barrier = latency_lib.sync_round_duration(
        np.asarray(comp_s), np.full(n_clients, mean_air))
    emit("async_fl/tdma_barrier", 0.0,
         f"one_sync_round={barrier:.2f}s (max_compute + sum_airtime)")
    report["tdma_barrier_s"] = float(barrier)

    sync = report["arms"]["sync"]
    target = sync["final_acc"] - ACC_TOL
    budget = sync["event_clock_s"] * TIME_FACTOR
    win = _first_win(results["buffered"], target, budget)
    report["arms"]["buffered"]["win_vs_sync"] = win
    report["buffered_matches_sync_in_0p6x_time"] = win is not None
    emit("async_fl/buffered-vs-sync", 0.0,
         f"target_acc={target:.3f} time_budget={budget:.1f}s "
         + (f"win@round={win['round']} acc={win['accuracy']:.3f} "
            f"t={win['event_s']:.1f}s" if win else "win=False"))

    # Ledger gate: the buffered arm's JSONL must validate against the obs
    # schema and reproduce the run's link telemetry bit-identically.
    from repro.obs import ledger as obs_ledger

    problems = obs_ledger.validate_ledger(LEDGER_PATH)
    if problems:
        raise AssertionError(
            f"run ledger failed schema validation: {problems}")
    if obs_ledger.read_ledger(LEDGER_PATH).link != results["buffered"].link:
        raise AssertionError(
            "run ledger round-trip does not reproduce FLResult.link")
    report["ledger"] = LEDGER_PATH
    emit("async_fl/ledger", 0.0,
         f"wrote {LEDGER_PATH} (schema-valid, link round-trip exact)")

    common.write_bench_json(JSON_PATH, report)
    emit("async_fl/json", 0.0, f"wrote {JSON_PATH}")
    if win is None:  # the suite doubles as a gate (see benchmarks/run.py)
        raise AssertionError(
            "expected the buffered arm to reach sync final accuracy "
            f"(within {ACC_TOL}) in <= {TIME_FACTOR}x the sync arm's "
            "event-clock time on metro-rush; see BENCH_async_fl.json")
    return report


def main() -> None:
    """Standalone entry: ``python -m benchmarks.async_fl``."""
    ap = argparse.ArgumentParser(
        description="buffered-async vs sync FL under straggling")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale profile (40 clients, 40 sync rounds)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, seed=args.seed)


if __name__ == "__main__":
    main()
