"""ECRT cost quantification: E[transmissions] of the rate-1/2 LDPC chain
under per-codeword block fading, via (a) the real min-sum decoder and
(b) the paper's bounded-distance (7-error) abstraction; plus the resulting
per-round airtime model vs the approximate scheme."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import latency as LAT
from repro.core import transport as T


def run(quick: bool = True):
    n_cw = 48 if quick else 256
    n_params = 21_840  # the paper CNN's parameter count
    timings = LAT.PhyTimings()
    for snr in (10.0, 16.0, 20.0, 26.0):
        e_soft = LAT.calibrate_ecrt(snr, n_codewords=n_cw, max_tx=6)
        e_hard = LAT.calibrate_ecrt(snr, n_codewords=n_cw, max_tx=6,
                                    decoder="bounded")
        emit(f"ecrt/etx/snr{int(snr)}", 0.0,
             f"minsum={e_soft:.2f} bounded7={e_hard:.2f}")
        n_bits = n_params * 32
        approx = T.TxStats(*map(jnp.float32, (n_bits / 2, 1, 0, n_bits)))
        ecrt = T.TxStats(*map(jnp.float32,
                              (2 * n_bits / 2 * e_soft, e_soft, 0, n_bits)))
        ta = float(LAT.round_airtime(approx, timings, "approx"))
        te = float(LAT.round_airtime(ecrt, timings, "ecrt"))
        emit(f"ecrt/airtime_ratio/snr{int(snr)}", 0.0,
             f"approx={ta*1e3:.2f}ms ecrt={te*1e3:.2f}ms ratio={te/ta:.2f}")
    return None
