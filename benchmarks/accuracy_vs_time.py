"""Paper Fig. 3: test accuracy vs communication time for ECRT / naive /
proposed, at SNR 10 and 20 dB. Headline: ECRT needs >= 2x (20 dB) and >= 3x
(10 dB) the airtime of the proposed scheme to reach the same accuracy.

Scale deviations from the paper, recorded in EXPERIMENTS.md: procedural
digits instead of MNIST (offline container), 40 clients instead of 100 and
eta=0.05 instead of 0.01 (single-core budget; orderings and time *ratios*
are preserved — run with quick=False for 100 clients).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, fl_world
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import latency as LAT
from repro.core import transport as T
from repro.fl.loop import run_fl


def time_to_accuracy(res, target: float) -> float:
    for acc, air in zip(res.accuracy, res.airtime_s):
        if acc >= target:
            return air
    return float("inf")


def run(quick: bool = True):
    n_clients = 40 if quick else 100
    rounds = 120 if quick else 400
    cx, cy, ti, tl = fl_world(n_clients=n_clients)
    cfg = dataclasses.replace(cnn_config(), lr=0.05 if quick else 0.01)

    results = {}
    for snr in (10.0, 20.0):
        for mode in ("approx", "naive", "ecrt"):
            e_tx = 1.0
            if mode == "ecrt":
                # calibrate with the real soft decoder (block fading);
                # the paper's bounded-distance model is reported alongside
                e_tx = LAT.calibrate_ecrt(snr, n_codewords=64, max_tx=6)
            tcfg = T.TransportConfig(
                mode=mode, channel=CH.ChannelConfig(snr_db=snr),
                simulate_fec=False, ecrt_expected_tx=float(e_tx))
            res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                         batch_per_round=32, eval_every=5)
            results[(mode, snr)] = res
            emit(f"fig3/{mode}/snr{int(snr)}", res.wall_s * 1e6,
                 f"final_acc={res.final_accuracy:.3f} airtime={res.airtime_s[-1]:.2f}s"
                 + (f" E[tx]={e_tx:.2f}" if mode == "ecrt" else ""))

    # headline ratios: airtime to reach the best-common accuracy
    for snr in (10.0, 20.0):
        a = results[("approx", snr)]
        e = results[("ecrt", snr)]
        target = 0.8 * min(a.final_accuracy, e.final_accuracy)
        ta, te = time_to_accuracy(a, target), time_to_accuracy(e, target)
        ratio = te / ta if np.isfinite(ta) and ta > 0 else float("nan")
        emit(f"fig3/ecrt_over_approx_time/snr{int(snr)}", 0.0,
             f"target_acc={target:.2f} approx={ta:.2f}s ecrt={te:.2f}s ratio={ratio:.2f}"
             f" (paper: >={3 if snr == 10 else 2}x)")
        n = results[("naive", snr)]
        emit(f"fig3/naive_collapse/snr{int(snr)}", 0.0,
             f"naive_final={n.final_accuracy:.3f} (paper: ~0.10)")
    return results
