"""Paper Sec. V BER-vs-SNR claims: QPSK ~4e-2 @10 dB, ~5e-3 @20 dB over the
Rayleigh uplink; QPSK < 16-QAM < 256-QAM at equal SNR."""

from __future__ import annotations

import jax

from benchmarks.common import emit, timeit
from repro.core import modulation as M


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    n = 1 << 15 if quick else 1 << 18
    rows = []
    for name in ("qpsk", "16qam", "256qam"):
        scheme = M.MOD_SCHEMES[name]
        for snr in (0.0, 5.0, 10.0, 15.0, 20.0, 25.0, 30.0):
            ber = float(M.measure_ber(key, scheme, snr, n_symbols=n))
            rows.append((name, snr, ber))
            emit(f"ber/{name}/snr{int(snr)}", 0.0, f"ber={ber:.4g}")
    # headline checks vs the paper
    qpsk10 = next(b for m, s, b in rows if m == "qpsk" and s == 10.0)
    qpsk20 = next(b for m, s, b in rows if m == "qpsk" and s == 20.0)
    th10, th20 = M.rayleigh_qpsk_ber(10), M.rayleigh_qpsk_ber(20)
    emit("ber/qpsk10_vs_paper", 0.0,
         f"measured={qpsk10:.3g} paper~4e-2 theory={th10:.3g}")
    emit("ber/qpsk20_vs_paper", 0.0,
         f"measured={qpsk20:.3g} paper~5e-3 theory={th20:.3g}")
    us = timeit(lambda: M.measure_ber(key, M.MOD_SCHEMES["qpsk"], 10.0, n_symbols=n))
    emit("ber/measure_call", us, f"n_symbols={n}")
    return rows
