"""MoE dispatch vs a dense per-expert reference; capacity semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as MOE


def _tiny_cfg(**kw):
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced(
        d_model=32, moe_d_ff=16, n_experts=4, top_k=2)
    return dataclasses.replace(cfg, **kw)


def _reference_moe(x, p, cfg):
    """Dense O(T*E) reference: every token through every expert, masked."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, K)
    topv = topv / topv.sum(-1, keepdims=True)
    out = jnp.zeros((T, D), jnp.float32)
    for e in range(E):
        hi = x @ p["wi"][e]
        hg = x @ p["wg"][e]
        y = (jax.nn.silu(hg.astype(jnp.float32)).astype(hi.dtype) * hi) @ p["wo"][e]
        w = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)
        out = out + w[:, None] * y.astype(jnp.float32)
    if cfg.n_shared_experts:
        from repro.models import layers as L

        s = p["shared"]
        out = out + L.swiglu(x, s["wi"], s["wg"], s["wo"]).astype(jnp.float32)
    return out


@pytest.mark.parametrize("seed", [0, 1])
def test_dispatch_matches_dense_reference(seed):
    cfg = _tiny_cfg(capacity_factor=4.0)  # capacity high: no drops
    key = jax.random.PRNGKey(seed)
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 10), (64, cfg.d_model), jnp.float32)
    got, aux = MOE.moe_ffn(x, p, cfg)
    want = _reference_moe(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_capacity_drops_are_graceful():
    """With capacity_factor ~ 0, (almost) everything drops: output ~ shared
    experts only (or ~0), never NaN."""
    cfg = _tiny_cfg(capacity_factor=0.01, n_shared_experts=0)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, cfg.d_model), jnp.float32)
    out, _ = MOE.moe_ffn(x, p, cfg)
    assert bool(jnp.isfinite(out).all())
    # mostly dropped -> much smaller norm than a full dispatch
    full, _ = MOE.moe_ffn(x, p, dataclasses.replace(cfg, capacity_factor=4.0))
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(full))


def test_routing_weights_normalized():
    cfg = _tiny_cfg()
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.d_model))
    logits = x.astype(jnp.float32) @ p["router"]
    topv, _ = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(topv.sum(-1)), 1.0, rtol=1e-5)
