"""Fused uplink+aggregation (the in-kernel accumulator hot path).

Pins the tentpole invariant: ``transmit_batch_aggregate`` (and its
adaptive / pytree / engine wrappers) is **bit-identical** to the layered
``fedsgd_aggregate_batch``-over-``transmit_batch`` composition — same
per-client key schedule, same weight normalization (applied exactly once
on either path), same accumulation order (a client-order scan; the Pallas
grid loop and ``lax.scan`` contract identically). Covered here:

  * all five scenario presets x both wire dtypes, heterogeneous SNR
  * masked partial batches (``num_active < C`` zero-pads, does not alias)
  * adaptive mixed-mode cohorts vs the documented per-bucket order
    (increasing mode index, client-order within a bucket)
  * the scan fallback for non-kernel configs (perfect / ecrt / jnp paths)
  * naive mode's NaN contract: bitwise on finite lanes, identical NaN
    positions (the kernel preserves noisy NaN payloads, XLA canonicalizes)
  * donation safety on backends that ignore donation (CPU: same result,
    input stays live)
  * engine-level goldens: sync driverless, scenario bucketed, and the
    degenerate buffered-async config all reproduce their layered twins,
    and the fused/incompatible-feature guards raise.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import config as cnn_config
from repro.core import aggregation as A
from repro.core import channel as CH
from repro.core import transport as T
from repro.kernels import ops as O

M, N = 8, 2048

PRESETS = ["static", "pedestrian", "vehicular", "shadowed-urban", "bursty"]


def _cfg(**kw):
    ch = kw.pop("channel", CH.ChannelConfig(snr_db=10.0))
    return T.TransportConfig(channel=ch, **kw)


@pytest.fixture(scope="module")
def payloads():
    return jax.random.uniform(
        jax.random.PRNGKey(1), (M, N), minval=-0.99, maxval=0.99)


@pytest.fixture(scope="module")
def weights():
    return jax.random.uniform(
        jax.random.PRNGKey(7), (M,), minval=0.2, maxval=2.0)


def _preset_snr(preset: str, num_clients: int):
    """A heterogeneous per-client SNR vector drawn from the preset's
    dynamics (stable across processes)."""
    import zlib

    from repro.link import dynamics as D

    seed = zlib.crc32(preset.encode()) % 2**31
    return D.trajectory(
        jax.random.PRNGKey(seed), D.DYNAMICS_PRESETS[preset], num_clients, 2)[-1]


def _layered(x, key, cfg, weights, snr_db=None):
    """The reference composition: batched transport, then the PS scan."""
    x_hat, stats = T.transmit_batch(x, key, cfg, snr_db=snr_db)
    return A.fedsgd_aggregate_batch(x_hat, weights), stats


def assert_bits_equal(a, b):
    np.testing.assert_array_equal(
        np.asarray(a).view(np.uint32), np.asarray(b).view(np.uint32))


def assert_stats_equal(sa, sb):
    for f in ("data_symbols", "transmissions", "bit_errors", "n_bits",
              "bits_on_air"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sa, f)), np.asarray(getattr(sb, f)))


@pytest.mark.parametrize("preset", PRESETS)
@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
def test_fused_equals_layered_across_presets(payloads, weights, preset,
                                             wire_dtype):
    """Kernel fused round == layered round, bit for bit, on heterogeneous
    SNR vectors drawn from every scenario preset, for both wire dtypes
    (approx mode with the paper's clamp prior is NaN-free, so full bitwise
    identity holds)."""
    cfg = _cfg(mode="approx", use_kernel=True, wire_dtype=wire_dtype)
    key = jax.random.PRNGKey(11)
    snr = _preset_snr(preset, M)
    agg_f, st_f = T.transmit_batch_aggregate(
        payloads, key, cfg, A.normalize_weights(weights), snr_db=snr)
    agg_l, st_l = _layered(payloads, key, cfg, weights, snr_db=snr)
    assert_bits_equal(agg_f, agg_l)
    assert_stats_equal(st_f, st_l)


def test_fused_masked_partial_batch(payloads, weights):
    """``num_active < C`` at the ops layer: padded clients contribute
    nothing and the active prefix reproduces the layered truncated round
    (weights pre-normalized over the active slice, zero-padded)."""
    cfg = _cfg(mode="approx", use_kernel=True)
    key = jax.random.PRNGKey(12)
    keys = T.client_keys(key, M)
    na = 5
    w_act = A.normalize_weights(weights[:na])
    w_pad = jnp.concatenate([w_act, jnp.zeros((M - na,), jnp.float32)])
    agg_m, _ = O.approx_channel_transmit_batch_aggregate(
        payloads, keys, cfg, None, w_pad, num_active=na)
    # layered truncated reference: same per-client keys for the prefix
    x_hat, _ = T.transmit_batch(payloads[:na], key, cfg)
    agg_l = A.fedsgd_aggregate_batch(x_hat, weights[:na])
    assert_bits_equal(agg_m, agg_l)


@pytest.mark.parametrize("preset", ["pedestrian", "vehicular", "bursty"])
def test_adaptive_fused_equals_bucketed_layered(payloads, weights, preset):
    """Mixed-mode fused aggregation matches the documented order: globally
    normalized weights, per-bucket client-order partial sums added in
    increasing mode index."""
    from repro.link import policy as P

    snr = _preset_snr(preset, M)
    mode = np.asarray(P.initial_mode(snr, P.PolicyConfig()))
    cfgs = P.build_mode_cfgs(_cfg(use_kernel=True), P.PolicyConfig(),
                             ecrt_expected_tx=2.0)
    key = jax.random.PRNGKey(13)
    w_norm = A.normalize_weights(weights)
    agg_f, st_f = T.transmit_batch_adaptive_aggregate(
        payloads, key, cfgs, mode, w_norm, snr_db=snr)
    x_hat, st_l = T.transmit_batch_adaptive(
        payloads, key, cfgs, mode, snr_db=snr, dispatch="bucketed")
    total = jnp.zeros((N,), jnp.float32)
    for m in sorted(set(mode.tolist())):
        idx = np.flatnonzero(mode == m)
        part, _ = jax.lax.scan(
            lambda acc, wx: (acc + wx[0] * wx[1], None),
            jnp.zeros((N,), jnp.float32),
            (w_norm[idx], x_hat[idx].astype(jnp.float32)))
        total = total + part
    assert_bits_equal(agg_f, total)
    assert_stats_equal(st_f, st_l)
    np.testing.assert_array_equal(np.asarray(st_f.mode_idx), mode)


def test_adaptive_fused_single_mode_equals_plain(payloads, weights):
    """A single-mode cohort degenerates to the plain fused batch (one
    client-order scan — no bucket reordering)."""
    cfg = _cfg(mode="approx", use_kernel=True)
    cfgs = (cfg, _cfg(mode="naive", use_kernel=True))
    key = jax.random.PRNGKey(14)
    w_norm = A.normalize_weights(weights)
    agg_a, _ = T.transmit_batch_adaptive_aggregate(
        payloads, key, cfgs, np.zeros((M,), np.int32), w_norm)
    agg_p, _ = T.transmit_batch_aggregate(payloads, key, cfg, w_norm)
    assert_bits_equal(agg_a, agg_p)


@pytest.mark.parametrize(
    "kw",
    [
        {"mode": "approx"},                # layered jnp pipeline
        {"mode": "approx", "chunk_elems": 512},
        {"mode": "perfect"},
        {"mode": "ecrt", "simulate_fec": False, "ecrt_expected_tx": 1.25},
    ],
    ids=lambda kw: "-".join(f"{k}={v}" for k, v in kw.items()),
)
def test_scan_fallback_equals_layered(payloads, weights, kw):
    """Non-kernel configs take the jnp scan fallback — still bit-identical
    to transmit_batch + fedsgd_aggregate_batch."""
    cfg = _cfg(**kw)
    key = jax.random.PRNGKey(15)
    agg_f, st_f = T.transmit_batch_aggregate(
        payloads, key, cfg, A.normalize_weights(weights))
    agg_l, st_l = _layered(payloads, key, cfg, weights)
    assert_bits_equal(agg_f, agg_l)
    assert_stats_equal(st_f, st_l)


def test_naive_nan_contract(payloads, weights):
    """Naive mode decodes NaNs; the kernel keeps noisy NaN payload bits
    while XLA's scan canonicalizes them. Contract: identical NaN positions,
    bitwise identity on every finite lane."""
    cfg = _cfg(mode="naive", use_kernel=True,
               channel=CH.ChannelConfig(snr_db=0.0))
    key = jax.random.PRNGKey(16)
    agg_f, _ = T.transmit_batch_aggregate(
        payloads, key, cfg, A.normalize_weights(weights))
    agg_l, _ = _layered(payloads, key, cfg, weights)
    f, l = np.asarray(agg_f), np.asarray(agg_l)
    np.testing.assert_array_equal(np.isnan(f), np.isnan(l))
    ok = ~np.isnan(l)
    np.testing.assert_array_equal(f[ok].view(np.uint32),
                                  l[ok].view(np.uint32))


def test_pytree_fused_equals_flat(weights):
    """The pytree wrapper flattens, fuses, and unflattens without touching
    the numerics (leaves come back float32, shaped like the leaf suffix)."""
    tree = {
        "w": jax.random.uniform(jax.random.PRNGKey(20), (M, 32, 8),
                                minval=-0.9, maxval=0.9),
        "b": jax.random.uniform(jax.random.PRNGKey(21), (M, 8),
                                minval=-0.9, maxval=0.9),
    }
    cfg = _cfg(mode="approx", use_kernel=True)
    key = jax.random.PRNGKey(22)
    w_norm = A.normalize_weights(weights)
    agg_tree, st_t = T.transmit_pytree_batch_aggregate(tree, key, cfg, w_norm)
    flat = jnp.concatenate(
        [tree["b"].reshape(M, -1), tree["w"].reshape(M, -1)], axis=1)
    agg_flat, st_f = T.transmit_batch_aggregate(flat, key, cfg, w_norm)
    assert agg_tree["w"].shape == (32, 8) and agg_tree["b"].shape == (8,)
    got = jnp.concatenate(
        [agg_tree["b"].ravel(), agg_tree["w"].ravel()])
    assert_bits_equal(got, agg_flat)
    assert_stats_equal(st_t, st_f)


def test_donation_noop_on_cpu(payloads, weights):
    """``donate=True`` must not change results, and on backends that ignore
    donation (CPU) the donated input stays readable afterwards."""
    cfg = _cfg(mode="approx", use_kernel=True)
    key = jax.random.PRNGKey(17)
    w_norm = A.normalize_weights(weights)
    x = payloads + 0.0  # fresh buffer we could legally donate
    agg_d, _ = T.transmit_batch_aggregate(x, key, cfg, w_norm, donate=True)
    agg_p, _ = T.transmit_batch_aggregate(payloads, key, cfg, w_norm)
    assert_bits_equal(agg_d, agg_p)
    if not O.donation_supported():  # CPU: buffer must still be live
        np.testing.assert_array_equal(np.asarray(x), np.asarray(payloads))


def test_ops_bit_errors_match_batch_kernel(payloads):
    """The fused kernel's in-kernel error side-output equals the batch
    kernel's per-client error counts (pad words transmit as exact zeros,
    masked in-kernel)."""
    cfg = _cfg(mode="approx", use_kernel=True)
    keys = T.client_keys(jax.random.PRNGKey(18), M)
    w = jnp.full((M,), 1.0 / M, jnp.float32)
    _, st_f = O.approx_channel_transmit_batch_aggregate(
        payloads, keys, cfg, None, w)
    _, st_b = O.approx_channel_transmit_batch(payloads, keys, cfg)
    np.testing.assert_array_equal(np.asarray(st_f.bit_errors),
                                  np.asarray(st_b.bit_errors))


# ---------------------------------------------------------------- engine


@pytest.fixture(scope="module")
def world():
    from repro.data import synth_mnist
    from repro.fl import partition

    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.fixture(scope="module")
def mcfg():
    return dataclasses.replace(cnn_config(), lr=0.1)


def _assert_same_run(a, b):
    assert a.rounds == b.rounds
    assert a.accuracy == b.accuracy  # float lists: exact equality intended
    assert a.final_accuracy == b.final_accuracy
    assert a.link == b.link


def test_engine_sync_fused_golden(mcfg, world):
    """Driverless FedSGD with ``fused_aggregate=True`` reproduces the
    layered engine exactly (same key schedule, same normalized-uniform
    weights, same accumulation order)."""
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = world
    tc = _cfg(mode="approx", use_kernel=True)
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=1, seed=3)
    _assert_same_run(run_fl(mcfg, tc, cx, cy, ti, tl, **kw),
                     run_fl(mcfg, tc, cx, cy, ti, tl,
                            fused_aggregate=True, **kw))


@pytest.mark.slow
def test_engine_scenario_fused_golden(mcfg, world):
    """Scenario-driven bucketed rounds (dropout included — dropped clients
    transmit with weight zero on both paths)."""
    from repro.fl.loop import run_fl
    from repro.link import scenario as S

    cx, cy, ti, tl = world
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0, dropout_prob=0.1)
    tc = _cfg(mode="approx", use_kernel=True)
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=1, seed=5,
              scenario=scen)
    _assert_same_run(run_fl(mcfg, tc, cx, cy, ti, tl, **kw),
                     run_fl(mcfg, tc, cx, cy, ti, tl,
                            fused_aggregate=True, **kw))


@pytest.mark.slow
def test_engine_async_degenerate_fused_golden(mcfg, world):
    """Buffered-async with ``buffer_k == M`` (one wave in flight, staleness
    zero) is the only async config the fused path accepts — and there it
    reproduces the layered buffered engine exactly."""
    from repro.fl.async_engine import run_fl_buffered

    cx, cy, ti, tl = world
    tc = _cfg(mode="approx", use_kernel=True)
    kw = dict(n_rounds=3, eval_every=1, seed=6, buffer_k=4)
    a = run_fl_buffered(mcfg, tc, cx, cy, ti, tl, **kw)
    b = run_fl_buffered(mcfg, tc, cx, cy, ti, tl, fused_aggregate=True, **kw)
    _assert_same_run(a, b)
    assert a.event_s == b.event_s


def test_engine_fused_guards(mcfg, world):
    """Configurations the fused path cannot reproduce bit-identically are
    rejected up front, not silently degraded."""
    from repro.compress import CompressionConfig
    from repro.fl.async_engine import run_fl_buffered
    from repro.fl.fedavg import run_fedavg
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = world
    tc = _cfg(mode="approx", use_kernel=True)
    with pytest.raises(ValueError, match="compressed"):
        run_fl(mcfg, tc, cx, cy, ti, tl, n_rounds=1, fused_aggregate=True,
               compression=CompressionConfig(method="topk", ratio=0.1))
    with pytest.raises(ValueError, match="max_abs"):
        run_fedavg(mcfg, tc, cx, cy, ti, tl, n_rounds=1,
                   fused_aggregate=True, scale_mode="max_abs")
    with pytest.raises(ValueError, match="bucketed"):
        run_fl(mcfg, tc, cx, cy, ti, tl, n_rounds=1, fused_aggregate=True,
               scenario="pedestrian", adaptive_dispatch="select")
    with pytest.raises(ValueError, match="buffer_k"):
        run_fl_buffered(mcfg, tc, cx, cy, ti, tl, n_rounds=1,
                        fused_aggregate=True, buffer_k=2)


def test_engine_fused_manifest_fingerprint(mcfg, world, tmp_path):
    """A fused run declares itself in the ledger manifest and re-derives
    its config fingerprint, so layered runs keep their historical ones."""
    import json

    from repro.fl.loop import run_fl

    cx, cy, ti, tl = world
    tc = _cfg(mode="approx", use_kernel=True)
    kw = dict(n_rounds=1, batch_per_round=8, eval_every=1, seed=3)
    p_lay, p_fus = tmp_path / "lay.jsonl", tmp_path / "fus.jsonl"
    run_fl(mcfg, tc, cx, cy, ti, tl, ledger=str(p_lay), **kw)
    run_fl(mcfg, tc, cx, cy, ti, tl, ledger=str(p_fus),
           fused_aggregate=True, **kw)
    man_l = json.loads(p_lay.read_text().splitlines()[0])
    man_f = json.loads(p_fus.read_text().splitlines()[0])
    assert "fused_aggregate" not in man_l
    assert man_f["fused_aggregate"] is True
    assert man_f["fingerprint"] != man_l["fingerprint"]
