"""Aggregation (eq. 5), the FL loop, data partition and checkpointing."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as AGG
from repro.core import channel as CH
from repro.core import transport as T
from repro.configs.mnist_cnn import config as cnn_config
from repro.data import synth_mnist
from repro.fl import cnn, partition
from repro.fl.loop import run_fl


def test_fedsgd_weighted_aggregate():
    g1 = {"w": jnp.ones((3,))}
    g2 = {"w": jnp.full((3,), 4.0)}
    out = AGG.fedsgd_aggregate([g1, g2], weights=[1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.25 * 1 + 0.75 * 4)


def test_partition_non_iid():
    (img, lab), _ = synth_mnist.train_test(60, 10, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=10, digits_per_client=2)
    assert len(parts) == 10
    for x, y in parts:
        assert len(np.unique(y)) <= 2  # the paper's 2-digits-per-client split
        assert len(y) > 0


def test_synth_digits_are_separable():
    """A linear probe gets well above chance on the procedural digits."""
    (img, lab), (ti, tl) = synth_mnist.train_test(100, 30, seed=0)
    X = img.reshape(len(lab), -1)
    Xt = ti.reshape(len(tl), -1)
    # one ridge-regression step per class (closed form)
    Y = np.eye(10)[lab]
    W = np.linalg.solve(X.T @ X + 10.0 * np.eye(X.shape[1]), X.T @ Y)
    acc = (Xt @ W).argmax(-1) == tl
    assert acc.mean() > 0.5


@pytest.fixture(scope="module")
def fl_setup():
    (img, lab), (ti, tl) = synth_mnist.train_test(80, 20, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=8)
    cx, cy = partition.stack_clients(parts, per_client=64)
    return cx, cy, ti, tl


def _run(mode, fl_setup, snr=10.0, rounds=8):
    cx, cy, ti, tl = fl_setup
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tcfg = T.TransportConfig(mode=mode, channel=CH.ChannelConfig(snr_db=snr),
                             simulate_fec=False, ecrt_expected_tx=1.2)
    return run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=rounds,
                  batch_per_round=24, eval_every=rounds - 1)


def test_fl_perfect_learns(fl_setup):
    res = _run("perfect", fl_setup, rounds=10)
    assert res.accuracy[-1] > res.accuracy[0]


def test_fl_naive_collapses_approx_does_not(fl_setup):
    """The paper's core claim at small scale: naive error transmission stays
    at chance; the proposed scheme learns."""
    naive = _run("naive", fl_setup, rounds=8)
    approx = _run("approx", fl_setup, rounds=8)
    assert naive.accuracy[-1] < 0.2  # ~ random guessing
    assert np.isfinite(approx.accuracy[-1])
    assert approx.accuracy[-1] > naive.accuracy[-1]


def test_fl_ecrt_airtime_exceeds_approx(fl_setup):
    ecrt = _run("ecrt", fl_setup, rounds=4)
    approx = _run("approx", fl_setup, rounds=4)
    assert ecrt.airtime_s[-1] > 1.9 * approx.airtime_s[-1]


def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt

    cfg = cnn_config()
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, step=7)
    like = cnn.init_params(jax.random.PRNGKey(1), cfg)
    restored, step = ckpt.restore(path, like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_fedavg_learns_over_approx_uplink(fl_setup):
    """FedAvg weight deltas survive the clamp prior (beyond-paper)."""
    from repro.fl.fedavg import run_fedavg

    cx, cy, ti, tl = fl_setup
    cfg = dataclasses.replace(cnn_config(), lr=0.08)
    tcfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=12.0))
    res = run_fedavg(cfg, tcfg, cx, cy, ti, tl, n_rounds=16, local_steps=3,
                     batch_per_step=24, eval_every=15)
    assert res.accuracy[-1] > res.accuracy[0]
    assert np.isfinite(res.accuracy[-1])
