"""Golden-equivalence regression for the round-engine refactor.

``repro.fl.engine`` replaced the hand-written round loops of
``fl/loop.py``/``fl/fedavg.py``; these tests pin the refactor to a frozen
snapshot of the pre-engine implementations (``tests/golden_pre_engine.py``):
the same seed/config must produce a **bit-identical** ``FLResult`` —
accuracy trajectory, cumulative airtime, and per-round link telemetry —
for FedSGD and FedAvg, driver-less and scenario-driven, under both adaptive
dispatches. Any engine change that alters the key schedule, the jit
boundaries, or the op order of a round shows up here as a float mismatch.
"""

import dataclasses

import pytest

import golden_pre_engine as golden
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.fedavg import run_fedavg
from repro.fl.loop import run_fl
from repro.link import scenario as S


@pytest.fixture(scope="module")
def world():
    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(cnn_config(), lr=0.1)


def _scenario():
    # Explicit ecrt_expected_tx skips LDPC calibration; dropout exercises the
    # weighted aggregate.
    return dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0, dropout_prob=0.1)


def assert_identical(a, b):
    """Bit-exact FLResult comparison (everything but wall-clock time)."""
    assert a.rounds == b.rounds
    assert a.accuracy == b.accuracy  # float lists: exact equality intended
    assert a.airtime_s == b.airtime_s
    assert a.final_accuracy == b.final_accuracy
    assert a.link == b.link  # per-round telemetry dicts, exact


def test_fedsgd_driverless_matches_golden(cfg, world):
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=3)
    assert_identical(run_fl(cfg, tc, cx, cy, ti, tl, **kw),
                     golden.golden_run_fl(cfg, tc, cx, cy, ti, tl, **kw))


def test_compression_none_is_bit_identical(cfg, world):
    """The compression subsystem must be invisible when off: an explicit
    ``compression=None`` reproduces the golden pre-compression engine bit
    for bit (same draws, same airtime, same telemetry)."""
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=3)
    assert_identical(
        run_fl(cfg, tc, cx, cy, ti, tl, compression=None, **kw),
        golden.golden_run_fl(cfg, tc, cx, cy, ti, tl, **kw))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_compression_none_scenario_is_bit_identical(cfg, world, dispatch):
    """Scenario-driven rounds with ``compression=None`` stay pinned to the
    golden engine under both dispatches."""
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=2, batch_per_round=8, eval_every=1, seed=7,
              scenario=_scenario(), adaptive_dispatch=dispatch)
    assert_identical(
        run_fl(cfg, tc, cx, cy, ti, tl, compression=None, **kw),
        golden.golden_run_fl(cfg, tc, cx, cy, ti, tl, **kw))


def test_fedavg_driverless_matches_golden(cfg, world):
    """Covers the analytic-ECRT pricing path + max_abs scaling driver-less."""
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="ecrt", channel=CH.ChannelConfig(snr_db=10.0),
                           simulate_fec=False, ecrt_expected_tx=1.3)
    kw = dict(n_rounds=3, local_steps=2, batch_per_step=6, eval_every=2,
              seed=5, scale_mode="max_abs")
    assert_identical(run_fedavg(cfg, tc, cx, cy, ti, tl, **kw),
                     golden.golden_run_fedavg(cfg, tc, cx, cy, ti, tl, **kw))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_fedsgd_scenario_matches_golden(cfg, world, dispatch):
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=7,
              scenario=_scenario(), adaptive_dispatch=dispatch)
    assert_identical(run_fl(cfg, tc, cx, cy, ti, tl, **kw),
                     golden.golden_run_fl(cfg, tc, cx, cy, ti, tl, **kw))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_fedavg_scenario_matches_golden(cfg, world, dispatch):
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=2, local_steps=2, batch_per_step=6, eval_every=1,
              seed=9, scale_mode="max_abs", scenario=_scenario(),
              adaptive_dispatch=dispatch)
    assert_identical(run_fedavg(cfg, tc, cx, cy, ti, tl, **kw),
                     golden.golden_run_fedavg(cfg, tc, cx, cy, ti, tl, **kw))
