"""QC-LDPC(648, 324) construction and min-sum decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ecrt as E


@pytest.fixture(scope="module")
def code():
    return E.LdpcCode()


def test_construction(code):
    H, P = code.H, code.P
    assert H.shape == (324, 648) and P.shape == (324, 324)
    # dual-diagonal parity part is invertible: every codeword checks out
    rng = np.random.default_rng(0)
    m = rng.integers(0, 2, (8, code.k)).astype(np.uint8)
    cw = np.concatenate([m, (m @ P.T) % 2], axis=1)
    assert not ((cw @ H.T) % 2).any()


def test_encode_syndrome_ok(code):
    msg = jax.random.randint(jax.random.PRNGKey(0), (4, code.k), 0, 2).astype(jnp.uint32)
    cw = E.encode(msg, code)
    assert bool(E.syndrome_ok(cw, code).all())
    # flipping any single bit breaks the syndrome
    flipped = cw.at[0, 17].set(1 - cw[0, 17])
    assert not bool(E.syndrome_ok(flipped, code)[0])


@pytest.mark.parametrize("n_flips", [0, 4, 8, 12])
def test_minsum_corrects_hard_flips(code, n_flips):
    """min-sum corrects well beyond the 7-bit bounded-distance guarantee."""
    msg = jax.random.randint(jax.random.PRNGKey(1), (4, code.k), 0, 2).astype(jnp.uint32)
    cw = E.encode(msg, code)
    llr = (1.0 - 2.0 * cw.astype(jnp.float32)) * 6.0
    rng = np.random.default_rng(2)
    llr = np.array(llr)  # writable copy
    for i in range(4):
        idx = rng.choice(code.n, n_flips, replace=False)
        llr[i, idx] *= -1
    hard, ok = E.decode(jnp.asarray(llr), code)
    assert bool(ok.all())
    np.testing.assert_array_equal(np.asarray(hard), np.asarray(cw))
