"""End-to-end behaviour tests for the paper's system.

The paper's three headline behaviours, at test scale:
  1. naive erroneous transmission collapses FL to chance accuracy;
  2. the proposed approximate scheme learns (close to error-free);
  3. ECRT reaches the same accuracy but pays >= 2x airtime.
Plus: the e2e train/serve drivers run.
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.loop import run_fl


@pytest.fixture(scope="module")
def fl_world():
    (img, lab), (ti, tl) = synth_mnist.train_test(120, 25, seed=1)
    parts = partition.non_iid_partition(img, lab, n_clients=10)
    cx, cy = partition.stack_clients(parts, per_client=96)
    return cx, cy, ti, tl


@pytest.mark.slow
def test_paper_headline_ordering(fl_world):
    cx, cy, ti, tl = fl_world
    cfg = dataclasses.replace(cnn_config(), lr=0.1)

    def run(mode, snr=10.0):
        tcfg = T.TransportConfig(mode=mode, channel=CH.ChannelConfig(snr_db=snr),
                                 simulate_fec=False, ecrt_expected_tx=1.1)
        return run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=60,
                      batch_per_round=32, eval_every=59)

    perfect = run("perfect")
    naive = run("naive")
    approx = run("approx")
    ecrt = run("ecrt")

    assert perfect.final_accuracy > 0.45
    assert naive.final_accuracy < 0.25  # collapse (paper Fig. 3)
    assert approx.final_accuracy > naive.final_accuracy + 0.2
    assert approx.final_accuracy > 0.5 * perfect.final_accuracy
    # same rounds, ECRT bits exact but slower air
    assert ecrt.final_accuracy >= approx.final_accuracy - 0.15
    assert ecrt.airtime_s[-1] > 2.0 * approx.airtime_s[-1]


@pytest.mark.slow
def test_train_driver_e2e():
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-1.5b",
         "--reduced", "--mesh-shape", "2,2", "--steps", "8", "--batch", "4",
         "--seq", "64", "--mode", "approx", "--snr-db", "20"],
        capture_output=True, text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    lines = [l for l in out.stdout.splitlines() if l.startswith("step")]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first, out.stdout


@pytest.mark.slow
def test_serve_driver_e2e():
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "falcon-mamba-7b",
         "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "tok/s" in out.stdout
