"""Gray-QAM properties: unit energy, Gray adjacency, closed-form == ML
(paper eq. (8)), BER vs theory (paper Sec. V numbers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import modulation as M

SCHEMES = list(M.MOD_SCHEMES.values())


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_unit_average_energy(scheme):
    pts = M.constellation(scheme)
    assert float(jnp.mean(jnp.abs(pts) ** 2)) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_gray_adjacency(scheme):
    """Nearest horizontal/vertical constellation neighbours differ in exactly
    one bit — the Gray property behind Table I's MSB protection."""
    pts = np.asarray(M.constellation(scheme))
    L = scheme.levels
    step = 2 * scheme.amp_norm
    for i in range(scheme.points):
        for j in range(scheme.points):
            d = abs(pts[i] - pts[j])
            if 0 < d <= step * 1.01:
                diff = bin(i ^ j).count("1")
                assert diff == 1, (scheme.name, i, j, diff)


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_mod_demod_roundtrip_noiseless(scheme):
    sym = jnp.arange(scheme.points, dtype=jnp.uint32)
    assert (M.demod_hard(M.modulate(sym, scheme), scheme) == sym).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([s.name for s in SCHEMES]))
def test_closed_form_equals_ml(seed, name):
    """demod_hard (per-axis clamp+round+gray) == brute-force argmin (eq. 8)."""
    scheme = M.MOD_SCHEMES[name]
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    y = (jax.random.normal(k1, (512,)) + 1j * jax.random.normal(k2, (512,))).astype(jnp.complex64)
    np.testing.assert_array_equal(
        np.asarray(M.demod_hard(y, scheme)), np.asarray(M.demod_ml(y, scheme)))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**16 - 1))
def test_gray_roundtrip_property(n):
    """gray_decode(gray_encode(n)) == n for arbitrary level indices."""
    enc = M.gray_encode(jnp.uint32(n))
    assert int(M.gray_decode(enc)) == n


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**16 - 2))
def test_gray_adjacent_hamming_distance_one(n):
    """Consecutive level indices map to Gray codes differing in exactly one
    bit — the property that makes near-neighbour symbol errors single-bit."""
    a = int(M.gray_encode(jnp.uint32(n)))
    b = int(M.gray_encode(jnp.uint32(n + 1)))
    assert bin(a ^ b).count("1") == 1


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_gray_axis_levels_adjacent_hamming1(scheme):
    """Per-axis PAM levels (up to 64-QAM's 8 levels and beyond): the whole
    Gray sequence round-trips and every adjacent pair is Hamming-distance 1."""
    levels = jnp.arange(scheme.levels, dtype=jnp.uint32)
    enc = M.gray_encode(levels)
    np.testing.assert_array_equal(
        np.asarray(M.gray_decode(enc)), np.asarray(levels))
    diffs = np.asarray(enc[:-1] ^ enc[1:])
    assert all(bin(int(d)).count("1") == 1 for d in diffs)


def test_qpsk_rayleigh_ber_matches_paper():
    """Paper Sec. V: BER ~ 4e-2 @ 10 dB and ~ 5e-3 @ 20 dB."""
    assert M.rayleigh_qpsk_ber(10.0) == pytest.approx(4e-2, rel=0.15)
    assert M.rayleigh_qpsk_ber(20.0) == pytest.approx(5e-3, rel=0.15)
    for snr in (10.0, 20.0):
        emp = float(M.measure_ber(jax.random.PRNGKey(1), M.MOD_SCHEMES["qpsk"], snr))
        assert emp == pytest.approx(M.rayleigh_qpsk_ber(snr), rel=0.1)


def test_ber_ordering_at_same_snr():
    """Fig. 4(a): QPSK < 16-QAM < 256-QAM BER at the same SNR."""
    key = jax.random.PRNGKey(2)
    bers = [float(M.measure_ber(key, M.MOD_SCHEMES[n], 10.0, n_symbols=1 << 15))
            for n in ("qpsk", "16qam", "256qam")]
    assert bers[0] < bers[1] < bers[2]


def test_ber_monotonic_in_snr():
    key = jax.random.PRNGKey(3)
    bers = [float(M.measure_ber(key, M.MOD_SCHEMES["qpsk"], s, n_symbols=1 << 15))
            for s in (0.0, 10.0, 20.0, 30.0)]
    assert all(a > b for a, b in zip(bers, bers[1:]))


def test_msb_better_protected_than_lsb():
    """Table I: within a Gray 16-QAM symbol, the first (MSB) bit has a lower
    error rate than the last (LSB) bit."""
    scheme = M.MOD_SCHEMES["16qam"]
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    sym = jax.random.randint(k1, (1 << 16,), 0, scheme.points).astype(jnp.uint32)
    noise = 0.25 * (jax.random.normal(k2, sym.shape) +
                    1j * jax.random.normal(jax.random.PRNGKey(5), sym.shape))
    rx = M.demod_hard(M.modulate(sym, scheme) + noise.astype(jnp.complex64), scheme)
    diff = sym ^ rx
    k = scheme.bits_per_symbol
    msb_err = float(jnp.mean((diff >> (k - 1)) & 1))
    lsb_err = float(jnp.mean(diff & 1))
    assert msb_err < lsb_err
