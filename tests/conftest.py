import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
