"""Shared fixtures + a deterministic fallback for ``hypothesis``.

The property tests (``test_float_codec``, ``test_modulation``,
``test_kernels``) are written against the real `hypothesis` API. When the
package is unavailable (hermetic CI images pin only jax + pytest), we install
a minimal deterministic stand-in *before collection*: same decorator surface
(`given`, `settings`,
`strategies.lists/floats/integers/sampled_from/booleans/tuples`), but
examples are drawn from a fixed per-test PRNG seeded by the test name, with
boundary values injected first. No shrinking — a failing example prints its
arguments via the assertion itself.
"""

import importlib.util
import random
import sys
import types
import zlib

import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# hypothesis fallback (only installed when the real package is missing)
# --------------------------------------------------------------------------


class _Strategy:
    """Base: ``example(rng, i)`` returns the i-th example for this test run."""

    def example(self, rng: random.Random, i: int):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, min_value=-1e9, max_value=1e9, width=64, **_kw):
        self.lo, self.hi = float(min_value), float(max_value)
        self.width = width

    def example(self, rng, i):
        if i == 0:
            v = self.lo
        elif i == 1:
            v = self.hi
        elif i == 2 and self.lo <= 0.0 <= self.hi:
            v = 0.0
        else:
            v = rng.uniform(self.lo, self.hi)
        if self.width == 32:
            # hypothesis(width=32) only emits exactly-representable float32s
            import numpy as np

            v = float(np.float32(v))
            v = min(max(v, self.lo), self.hi)
        return v


class _SampledFrom(_Strategy):
    def __init__(self, items):
        self.items = list(items)

    def example(self, rng, i):
        # Guarantee full coverage of small domains before going random.
        if i < len(self.items):
            return self.items[i]
        return rng.choice(self.items)


class _Booleans(_Strategy):
    def example(self, rng, i):
        # Both values first, then random.
        if i < 2:
            return bool(i)
        return rng.random() < 0.5


class _Tuples(_Strategy):
    def __init__(self, *elems):
        self.elems = elems

    def example(self, rng, i):
        # Boundary-first elementwise on the first examples, then random.
        return tuple(e.example(rng, i if i < 2 else 3 + rng.randint(0, 7))
                     for e in self.elems)


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=10):
        self.elem, self.lo, self.hi = elem, int(min_size), int(max_size)

    def example(self, rng, i):
        size = self.lo if i == 0 else rng.randint(self.lo, self.hi)
        return [self.elem.example(rng, 3 + rng.randint(0, 7)) for _ in range(size)]


def _stub_given(*strategies):
    def deco(fn):
        # Deliberately *not* functools.wraps: the wrapper must expose a
        # zero-arg signature so pytest doesn't treat the strategy parameters
        # as fixtures.
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 20)
            prng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                fn(*[s.example(prng, i) for s in strategies])

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def _stub_settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def _install_hypothesis_stub() -> None:
    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda min_value=0, max_value=0, **kw: _Integers(min_value, max_value)
    st.floats = lambda **kw: _Floats(
        min_value=kw.get("min_value", -1e9),
        max_value=kw.get("max_value", 1e9),
        width=kw.get("width", 64),
    )
    st.sampled_from = _SampledFrom
    st.lists = lambda elem, min_size=0, max_size=10, **kw: _Lists(elem, min_size, max_size)
    st.booleans = lambda **kw: _Booleans()
    st.tuples = _Tuples

    hyp = types.ModuleType("hypothesis")
    hyp.given = _stub_given
    hyp.settings = _stub_settings
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_stub()
