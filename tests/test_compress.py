"""Compression subsystem contracts: EF identity, deterministic selection,
sparse framing, batched ≡ per-client equivalence, policy/scenario plumbing,
and the FL engine's compressed rounds."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import framing as FR
from repro.compress import sparsify as SP
from repro.compress.sparsify import CompressionConfig
from repro.core import channel as CH
from repro.core import transport as T
from repro.link import policy as P
from repro.link import scenario as S

KEY = jax.random.PRNGKey(0)
DIM = 300


def _acc_pair(dim=DIM, seed=1):
    res = jax.random.normal(jax.random.fold_in(KEY, seed), (dim,)) * 0.1
    grad = jax.random.normal(jax.random.fold_in(KEY, seed + 1), (dim,))
    return res, grad


# -------------------------------------------------------------- sparsifiers


def test_topk_tie_break_is_lower_index():
    x = jnp.array([1.0, -1.0, 0.5, 1.0, -1.0, 0.25])
    vals, idx = SP.select_topk(x, 3)
    np.testing.assert_array_equal(np.asarray(idx), [0, 1, 3])
    np.testing.assert_array_equal(np.asarray(vals), [1.0, -1.0, 1.0])


@pytest.mark.parametrize("method", ["topk", "randk", "threshold"])
def test_selection_deterministic_across_jit(method):
    """Selection must resolve identically inside and outside jit — the
    bucketed (host) and select (traced) dispatches share one selection."""
    cfg = CompressionConfig(method=method, threshold=0.5)
    _, x = _acc_pair()
    # duplicated magnitudes force the tie-break to matter
    x = jnp.concatenate([x[:DIM // 2], x[:DIM // 2]])
    key = jax.random.fold_in(KEY, 9)
    eager = SP.select(x, 17, cfg, key)
    jitted = jax.jit(lambda a, kk: SP.select(a, 17, cfg, kk))(x, key)
    for a, b in zip(eager, jitted):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("method", ["topk", "randk", "threshold"])
def test_ef_identity_bit_exact(method):
    """transmitted + residual ≡ accumulated gradient, bit for bit."""
    cfg = CompressionConfig(method=method, threshold=0.3)
    res, grad = _acc_pair()
    key = jax.random.fold_in(KEY, 3)
    vals, idx, new_res = SP.ef_select(res, grad, 23, cfg, key)
    acc = res + grad
    recon = SP.scatter_dense(vals, idx, DIM) + new_res
    np.testing.assert_array_equal(
        np.asarray(recon).view(np.uint32), np.asarray(acc).view(np.uint32))


def test_ef_identity_batch_matches_loop():
    cfg = CompressionConfig()
    M, k = 5, 12
    res = jax.random.normal(jax.random.fold_in(KEY, 4), (M, DIM)) * 0.1
    grads = jax.random.normal(jax.random.fold_in(KEY, 5), (M, DIM))
    vb, ib, rb = SP.ef_select_batch(res, grads, k, cfg)
    for i in range(M):
        v, ix, r = SP.ef_select(res[i], grads[i], k, cfg)
        np.testing.assert_array_equal(np.asarray(vb[i]), np.asarray(v))
        np.testing.assert_array_equal(np.asarray(ib[i]), np.asarray(ix))
        np.testing.assert_array_equal(np.asarray(rb[i]), np.asarray(r))


def test_ef_dropped_client_keeps_accumulation():
    """active=0 means the client never transmitted: its residual must hold
    the whole accumulated gradient, not lose the selected mass."""
    cfg = CompressionConfig()
    res, grad = _acc_pair(seed=7)
    _, _, r_active = SP.ef_select(res, grad, 16, cfg, active=jnp.float32(1.0))
    _, _, r_dropped = SP.ef_select(res, grad, 16, cfg, active=jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(r_dropped), np.asarray(res + grad))
    assert not np.array_equal(np.asarray(r_active), np.asarray(r_dropped))


def test_ef_identity_accumulates_across_participation_gaps():
    """transmitted + residual ≡ accumulated identity over a *history* with
    gaps: a client that participates intermittently must end with
    ``sum(sent) + residual == sum(participated grads)`` — no gradient mass
    is created or lost while it sits out (buffered-engine semantics: a
    non-participating wave leaves the residual untouched)."""
    cfg = CompressionConfig()
    M, k, n_waves = 3, 12, 6
    participation = np.array([[1, 0, 1, 0, 0, 1],
                              [1, 1, 1, 1, 1, 1],
                              [0, 0, 0, 1, 0, 1]], np.float32)
    res = jnp.zeros((M, DIM), jnp.float32)
    total_sent = np.zeros((M, DIM), np.float32)
    total_grad = np.zeros((M, DIM), np.float32)
    for w in range(n_waves):
        grads = jax.random.normal(jax.random.fold_in(KEY, 100 + w), (M, DIM))
        member = jnp.asarray(participation[:, w])
        vals, idx, new_res = SP.ef_select_batch(res, grads, k, cfg,
                                                active=member)
        new_res = jnp.where(member[:, None] > 0, new_res, res)
        sent = np.asarray(SP.scatter_dense_batch(vals, idx, DIM))
        total_sent += sent * participation[:, w][:, None]
        total_grad += np.asarray(grads) * participation[:, w][:, None]
        res = new_res
    np.testing.assert_allclose(total_sent + np.asarray(res), total_grad,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_ef_residual_bit_exact_across_buffered_gaps():
    """Engine-level gap contract: drive the buffered engine's compressed
    wave function directly with member masks. A client absent for R waves
    re-enters with its accumulated residual **bit-exact** — the masked
    wave computation (its rows are mask fodder) must not perturb it."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.async_engine import AsyncRoundEngine
    from repro.fl.engine import FedSGD

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(mode="approx",
                           channel=CH.ChannelConfig(snr_db=10.0))
    eng = AsyncRoundEngine(FedSGD(cfg, batch_per_round=8), tc, cx, cy, ti,
                           tl, n_rounds=1, seed=3,
                           compression=CompressionConfig(ratio=0.25))
    rng = np.random.default_rng(0)
    params, residual = eng.params, eng._ef_residual
    absent = 2
    member = np.ones(eng.num_clients, np.float32)
    member[absent] = 0.0
    frozen = np.asarray(residual[absent]).copy()
    key = jax.random.PRNGKey(42)
    for w in range(3):  # R = 3 waves with client 2 out
        key, rk = jax.random.split(key)
        xb, yb = eng.algo.sample(rng, cx, cy)
        _, _, _, residual = eng._wave_plain_comp(
            params, xb, yb, rk, residual, jnp.asarray(member))
        np.testing.assert_array_equal(
            np.asarray(residual[absent]).view(np.uint32),
            frozen.view(np.uint32))
    # Members actually accumulated state meanwhile.
    assert not np.array_equal(np.asarray(residual[0]),
                              np.zeros_like(frozen))
    # Re-entry wave: the absent client transmits from its (intact)
    # accumulated residual and its row finally moves.
    key, rk = jax.random.split(key)
    xb, yb = eng.algo.sample(rng, cx, cy)
    _, _, _, res_back = eng._wave_plain_comp(
        params, xb, yb, rk, residual, jnp.ones(eng.num_clients, jnp.float32))
    assert not np.array_equal(np.asarray(res_back[absent]), frozen)


def test_threshold_zeroes_small_slots_and_keeps_them_in_residual():
    cfg = CompressionConfig(method="threshold", threshold=10.0)
    res, grad = _acc_pair(seed=11)
    vals, idx, new_res = SP.ef_select(res, grad, 16, cfg)
    assert np.all(np.asarray(vals) == 0.0)  # nothing clears a 10.0 floor
    np.testing.assert_array_equal(np.asarray(new_res), np.asarray(res + grad))


def test_no_error_feedback_discards_remainder():
    cfg = CompressionConfig(error_feedback=False)
    res, grad = _acc_pair(seed=13)
    vals, idx, new_res = SP.ef_select(res, grad, 16, cfg)
    assert np.all(np.asarray(new_res) == 0.0)
    # selection ignores the residual entirely
    v2, i2 = SP.select_topk(grad, 16)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i2))


def test_compression_config_validation():
    with pytest.raises(ValueError, match="method"):
        CompressionConfig(method="magic")
    with pytest.raises(ValueError, match="header"):
        CompressionConfig(header="hope")
    with pytest.raises(ValueError, match="ratio"):
        CompressionConfig(ratio=0.0)
    with pytest.raises(ValueError, match="k must be"):
        CompressionConfig(k=0)
    assert SP.resolve_k(CompressionConfig(ratio=0.02), 1000) == 20
    assert SP.resolve_k(CompressionConfig(k=7), 1000) == 7
    assert SP.resolve_k(CompressionConfig(ratio=1e-9), 1000) == 1


# ------------------------------------------------------------------ framing


def test_index_pack_roundtrip():
    idx = jnp.array([0, 1, 5, 17, DIM - 1], jnp.int32)
    words = FR.pack_index_bits(idx, DIM)
    back = FR.unpack_index_bits(words, idx.shape[0], DIM)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))
    assert FR.index_bits(1) == 1 and FR.index_bits(2) == 1
    assert FR.index_bits(3) == 2 and FR.index_bits(1 << 15) == 15


@pytest.mark.parametrize("header", ["gray", "ecrt", "perfect"])
def test_header_exact_at_high_snr(header):
    cfg = T.TransportConfig(mode="approx",
                            channel=CH.ChannelConfig(snr_db=60.0))
    ccfg = CompressionConfig(header=header)
    idx = jnp.sort(jax.random.permutation(KEY, DIM)[:24]).astype(jnp.int32)
    idx_rx, (sym, xtx, errs, nbits, boa) = FR.transmit_header(
        idx, DIM, jax.random.fold_in(KEY, 21), cfg, ccfg)
    np.testing.assert_array_equal(np.asarray(idx_rx), np.asarray(idx))
    assert float(errs) == 0.0
    assert float(sym) > 0 and float(boa) >= float(nbits) > 0


def test_gray_header_uses_most_protected_positions():
    """At moderate SNR on 256-QAM the Gray-MSB header BER must sit well
    below the raw payload BER of the same constellation: header bits ride
    b0/b1 only."""
    cfg = T.TransportConfig(mode="naive", modulation="256qam",
                            channel=CH.ChannelConfig(snr_db=18.0))
    ccfg = CompressionConfig(header="gray")
    k = 512
    idx = jnp.sort(jax.random.permutation(KEY, 1 << 15)[:k]).astype(jnp.int32)
    _, (sym, _, errs, nbits, _) = FR.transmit_header(
        idx, 1 << 15, jax.random.fold_in(KEY, 22), cfg, ccfg)
    header_ber = float(errs) / float(nbits)
    vals = jax.random.uniform(KEY, (k,), minval=-0.9, maxval=0.9)
    _, st = T.transmit_flat(vals, jax.random.fold_in(KEY, 23), cfg)
    payload_ber = float(st.ber)
    assert header_ber < payload_ber / 2


def test_scatter_received_drops_out_of_range():
    vals = jnp.array([1.0, 2.0, 3.0])
    idx = jnp.array([2, 99, 4], jnp.int32)
    out = np.asarray(FR.scatter_received(vals, idx, 10))
    assert out[2] == 1.0 and out[4] == 3.0 and out.sum() == 4.0


def test_sparse_stats_units_and_bits_on_air():
    """Combined stats: symbols/bits sum both legs; bits_on_air of the dense
    uplink equals offered bits, the sparse uplink's is far smaller."""
    cfg = T.TransportConfig(mode="approx",
                            channel=CH.ChannelConfig(snr_db=12.0))
    dense = jax.random.uniform(KEY, (DIM,), minval=-0.9, maxval=0.9)
    _, dstat = T.transmit_flat(dense, KEY, cfg)
    assert float(dstat.bits_on_air) == float(dstat.n_bits) == DIM * 32
    k = 15
    vals, idx = SP.select_topk(dense, k)
    _, sstat = T.transmit_sparse(vals, idx, DIM, KEY, cfg)
    b = FR.index_bits(DIM)
    # value leg: k words * 16 sym (qpsk); header: ceil(k*b/2) symbols
    assert float(sstat.data_symbols) == k * 16 + -(-k * b // 2)
    assert float(sstat.n_bits) == k * 32 + k * b
    assert float(sstat.bits_on_air) < 0.1 * float(dstat.bits_on_air)


def test_transmit_sparse_batch_equals_per_client_loop():
    """The batched sparse uplink under the fold_in schedule is bit-identical
    to a per-client transmit_sparse loop — values, stats, everything."""
    cfg = T.TransportConfig(mode="approx",
                            channel=CH.ChannelConfig(snr_db=10.0))
    ccfg = CompressionConfig(header="gray")
    M, k = 6, 11
    acc = jax.random.normal(KEY, (M, DIM))
    vals, idx = SP.select_batch(acc, k, ccfg)
    snr = jnp.linspace(6.0, 18.0, M)
    xb, sb = T.transmit_sparse_batch(vals, idx, DIM, KEY, cfg, ccfg,
                                     snr_db=snr)
    for i in range(M):
        xi, si = T.transmit_sparse(vals[i], idx[i], DIM,
                                   jax.random.fold_in(KEY, i), cfg, ccfg,
                                   snr_db=snr[i])
        np.testing.assert_array_equal(
            np.asarray(xb[i]).view(np.uint32),
            np.asarray(xi).view(np.uint32))
        for f in ("data_symbols", "transmissions", "bit_errors", "n_bits",
                  "bits_on_air"):
            np.testing.assert_array_equal(np.asarray(getattr(sb, f)[i]),
                                          np.asarray(getattr(si, f)))


def test_sparse_adaptive_bucketed_equals_select():
    """Mixed-mode sparse dispatch: bucketed ≡ select ≡ per-client, sharing
    the dense engine's fold_in contract."""
    cfgs = P.build_mode_cfgs(
        T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0)),
        P.PolicyConfig(), ecrt_expected_tx=2.0)
    ccfg = CompressionConfig()
    M, k = 8, 9
    acc = jax.random.normal(KEY, (M, DIM))
    vals, idx = SP.select_batch(acc, k, ccfg)
    mode = np.array([0, 1, 2, 3, 3, 1, 0, 2], np.int32)
    snr = jnp.linspace(4.0, 30.0, M)
    a, sa = FR.transmit_sparse_batch_adaptive(
        vals, idx, DIM, KEY, cfgs, mode, ccfg, snr_db=snr, dispatch="select")
    b, sb2 = FR.transmit_sparse_batch_adaptive(
        vals, idx, DIM, KEY, cfgs, mode, ccfg, snr_db=snr,
        dispatch="bucketed")
    np.testing.assert_array_equal(
        np.asarray(a).view(np.uint32), np.asarray(b).view(np.uint32))
    for f in ("data_symbols", "transmissions", "bit_errors", "n_bits",
              "bits_on_air", "mode_idx"):
        np.testing.assert_array_equal(np.asarray(getattr(sa, f)),
                                      np.asarray(getattr(sb2, f)))
    xi, _ = T.transmit_sparse(vals[2], idx[2], DIM,
                              jax.random.fold_in(KEY, 2), cfgs[2], ccfg,
                              snr_db=snr[2])
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(xi))


def test_perfect_mode_sparse_reconstruction_exact():
    cfg = T.TransportConfig(mode="perfect")
    ccfg = CompressionConfig(header="perfect")
    vals = jnp.array([0.5, -0.25, 0.125])
    idx = jnp.array([3, 7, 250], jnp.int32)
    dense, st = T.transmit_sparse(vals, idx, DIM, KEY, cfg, ccfg)
    np.testing.assert_array_equal(np.asarray(dense),
                                  np.asarray(SP.scatter_dense(vals, idx, DIM)))
    assert float(st.bit_errors) == 0.0


# ---------------------------------------------------------- policy/scenario


def test_policy_compress_ratios_validation():
    with pytest.raises(ValueError, match="one entry per mode"):
        P.PolicyConfig(compress_ratios=(0.1, 0.2))
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        P.PolicyConfig(compress_ratios=(0.1, 0.2, 0.5, 1.5))
    pc = P.PolicyConfig(compress_ratios=(0.01, 0.02, 0.05, 0.1))
    assert P.compress_k_table(pc, 1000, 0.5) == (10, 20, 50, 100)
    flat = P.PolicyConfig()
    assert P.compress_k_table(flat, 1000, 0.05) == (50,) * 4


def test_iot_lowrate_preset_has_compression_defaults():
    scen = S.get_scenario("iot-lowrate")
    assert scen.compression is not None
    assert scen.compression.method == "topk"
    assert scen.policy.compress_ratios is not None
    assert len(scen.policy.compress_ratios) == len(scen.policy.modes)
    # deeper compression in the protected low-SNR modes
    assert scen.policy.compress_ratios[0] < scen.policy.compress_ratios[-1]


# ------------------------------------------------------------ FL engine


def _world():
    from repro.data import synth_mnist
    from repro.fl import partition

    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.mark.slow
def test_run_fl_compressed_smoke_and_telemetry():
    """Driver-less compressed FedSGD: telemetry fields present, airtime far
    below the dense run's, accuracy finite."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(mode="approx",
                           channel=CH.ChannelConfig(snr_db=10.0))
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=3)
    dense = run_fl(cfg, tc, cx, cy, ti, tl, **kw)
    comp = run_fl(cfg, tc, cx, cy, ti, tl,
                  compression=CompressionConfig(ratio=0.05), **kw)
    assert np.isfinite(comp.final_accuracy)
    assert comp.airtime_s[-1] < dense.airtime_s[-1] / 5
    assert len(comp.link) == 3
    for rec in comp.link:
        assert rec["comp_ratio"] == pytest.approx(0.05, abs=1e-3)
        assert rec["comp_bits_on_air"] > 0
        assert rec["comp_residual_norm"] > 0  # EF holds untransmitted mass


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_run_fl_scenario_compressed(dispatch):
    """Scenario-driven compressed rounds under both dispatches; the
    bucketed arm exercises the CSI-adaptive per-mode slot budgets."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    if dispatch == "bucketed":
        scen = dataclasses.replace(S.get_scenario("iot-lowrate"),
                                   ecrt_expected_tx=2.0)
        comp = None  # scenario default compression kicks in
    else:
        scen = dataclasses.replace(S.get_scenario("vehicular"),
                                   ecrt_expected_tx=2.0)
        comp = CompressionConfig(ratio=0.05)
    res = run_fl(cfg, tc, cx, cy, ti, tl, n_rounds=3, batch_per_round=8,
                 eval_every=2, seed=7, scenario=scen,
                 adaptive_dispatch=dispatch, compression=comp)
    assert np.isfinite(res.final_accuracy)
    assert len(res.link) == 3
    for rec in res.link:
        assert "comp_ratio" in rec and "comp_bits_on_air" in rec
        assert sum(rec["mode_counts"]) == 4


@pytest.mark.slow
def test_explicit_k_agrees_across_dispatches():
    """An explicit CompressionConfig.k is an absolute budget everywhere:
    the bucketed (default) dispatch must not fall back to the ratio-derived
    per-mode table — bits on air agree with the select dispatch."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0)
    comp = CompressionConfig(method="topk", k=5)
    kw = dict(n_rounds=2, batch_per_round=8, eval_every=1, seed=11,
              scenario=scen, compression=comp)
    rb = run_fl(cfg, tc, cx, cy, ti, tl, adaptive_dispatch="bucketed", **kw)
    rs = run_fl(cfg, tc, cx, cy, ti, tl, adaptive_dispatch="select", **kw)
    for tb, ts in zip(rb.link, rs.link):
        assert tb["comp_bits_on_air"] == ts["comp_bits_on_air"]
    assert rb.accuracy == rs.accuracy


@pytest.mark.slow
def test_compress_ratios_need_bucketed_dispatch():
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.loop import run_fl

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("iot-lowrate"),
                               ecrt_expected_tx=2.0)
    with pytest.raises(ValueError, match="bucketed"):
        run_fl(cfg, tc, cx, cy, ti, tl, n_rounds=1, batch_per_round=8,
               seed=7, scenario=scen, adaptive_dispatch="select")


@pytest.mark.slow
def test_run_fedavg_compressed_with_max_abs():
    """max_abs scaling composes with the sparse uplink: the per-client
    scale is computed over the *selected* values."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.fl.fedavg import run_fedavg

    cx, cy, ti, tl = _world()
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(mode="approx",
                           channel=CH.ChannelConfig(snr_db=10.0))
    res = run_fedavg(cfg, tc, cx, cy, ti, tl, n_rounds=2, local_steps=2,
                     batch_per_step=6, eval_every=1, seed=5,
                     scale_mode="max_abs",
                     compression=CompressionConfig(ratio=0.05))
    assert np.isfinite(res.final_accuracy)
    assert all("comp_bits_on_air" in rec for rec in res.link)
