"""Link-adaptation subsystem: channel dynamics, noisy CSI, mode policy,
scenario registry/driver, mode-priced airtime, and the scenario-driven FL
loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as CH
from repro.core import latency as LAT
from repro.core import transport as T
from repro.link import dynamics as D
from repro.link import estimator as E
from repro.link import policy as P
from repro.link import scenario as S

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- dynamics


def test_trajectory_shape_and_determinism():
    cfg = D.DYNAMICS_PRESETS["vehicular"]
    a = D.trajectory(KEY, cfg, 16, 25)
    b = D.trajectory(KEY, cfg, 16, 25)
    assert a.shape == (25, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_static_preset_is_constant_per_client():
    tr = D.trajectory(KEY, D.DYNAMICS_PRESETS["static"], 8, 12)
    assert float(jnp.std(tr, axis=0).max()) == 0.0


def test_trajectory_respects_floor_and_ceiling():
    cfg = D.DYNAMICS_PRESETS["vehicular"]
    tr = np.asarray(D.trajectory(KEY, cfg, 32, 60))
    assert tr.min() >= cfg.snr_floor_db and tr.max() <= cfg.snr_ceil_db


def test_faster_mobility_means_bigger_round_to_round_swings():
    """Vehicular (rho=0.35) must decorrelate faster than pedestrian
    (rho=0.9): mean |SNR_t - SNR_{t-1}| strictly larger."""
    ped = np.asarray(D.trajectory(KEY, D.DYNAMICS_PRESETS["pedestrian"], 32, 50))
    veh = np.asarray(D.trajectory(KEY, D.DYNAMICS_PRESETS["vehicular"], 32, 50))
    assert np.abs(np.diff(veh, axis=0)).mean() > np.abs(np.diff(ped, axis=0)).mean()


def test_blockage_pulls_snr_down():
    """p_block=1, p_recover=0: every client is blocked from round 1 on and
    sits off_penalty_db below the unblocked process."""
    base = dataclasses.replace(
        D.DYNAMICS_PRESETS["static"], mean_snr_db=20.0)
    blocked = dataclasses.replace(
        base, onoff=True, p_block=1.0, p_recover=0.0, off_penalty_db=15.0)
    tr_base = np.asarray(D.trajectory(KEY, base, 8, 10))
    tr_blk = np.asarray(D.trajectory(KEY, blocked, 8, 10))
    np.testing.assert_allclose(tr_blk[1:], tr_base[1:] - 15.0, atol=1e-5)


def test_jakes_rho_limits_and_monotonicity():
    assert D.jakes_rho(0.0, 1.0) == 1.0
    small = [D.jakes_rho(f, 0.01) for f in (1.0, 5.0, 15.0, 30.0)]
    assert all(1.0 >= a > b >= 0.0 for a, b in zip(small, small[1:]))
    assert 0.0 <= D.jakes_rho(100.0, 1.0) <= 1.0


# ---------------------------------------------------------------- estimator


def test_oracle_csi_passthrough():
    snr = jnp.linspace(0.0, 30.0, 7)
    est = E.estimate_snr_db(snr, KEY, E.EstimatorConfig(n_pilots=0))
    np.testing.assert_array_equal(np.asarray(est), np.asarray(snr))


def test_more_pilots_tighter_estimates():
    snr = jnp.full((4096,), 12.0)
    stds = []
    for n in (4, 32, 256):
        est = E.estimate_snr_db(snr, KEY, E.EstimatorConfig(n_pilots=n))
        stds.append(float(jnp.std(est)))
    assert stds[0] > stds[1] > stds[2]
    assert stds[2] < 1.0  # 256 pilots: well under 1 dB


def test_estimator_bias_applied():
    snr = jnp.full((5,), 10.0)
    est = E.estimate_snr_db(snr, KEY, E.EstimatorConfig(n_pilots=0, bias_db=3.0))
    np.testing.assert_allclose(np.asarray(est), 13.0)


def test_stale_csi_reuses_previous_estimate():
    cfg = E.EstimatorConfig(n_pilots=8, stale_prob=1.0)
    prev = jnp.linspace(-3.0, 3.0, 6)
    est = E.step_estimate(jnp.full((6,), 25.0), prev, KEY, cfg)
    np.testing.assert_array_equal(np.asarray(est), np.asarray(prev))


# ------------------------------------------------------------------- policy


def test_initial_mode_threshold_mapping():
    pc = P.PolicyConfig()  # thresholds (6, 16, 26)
    m = P.initial_mode(jnp.array([0.0, 6.0, 15.9, 16.0, 25.9, 26.0, 40.0]), pc)
    np.testing.assert_array_equal(np.asarray(m), [0, 1, 1, 2, 2, 3, 3])


def test_hysteresis_holds_mode_inside_window():
    """CSI jitter of +-h/2 around a threshold must not flap the mode."""
    pc = P.PolicyConfig(hysteresis_db=2.0)  # window 6 +- 1
    prev_hi = jnp.array([1], dtype=jnp.int32)
    prev_lo = jnp.array([0], dtype=jnp.int32)
    for snr in (5.1, 5.9, 6.5, 6.9):
        s = jnp.array([snr])
        assert int(P.choose_mode(s, prev_hi, pc)[0]) == 1
        assert int(P.choose_mode(s, prev_lo, pc)[0]) == 0
    # decisive margins do switch
    assert int(P.choose_mode(jnp.array([7.1]), prev_lo, pc)[0]) == 1
    assert int(P.choose_mode(jnp.array([4.9]), prev_hi, pc)[0]) == 0


def test_choose_mode_observed_mask_holds_absent_clients():
    """Regression: a client that sat out a wave reported no CSI, so its
    hysteresis state must freeze — ``observed=0`` returns ``prev_mode``
    verbatim even when the (stale or garbage) estimate would demand a
    switch. Without the mask, one crashed CSI reading while absent would
    flap the mode the client re-enters with."""
    pc = P.PolicyConfig(hysteresis_db=2.0)
    prev = jnp.array([3, 0, 2], dtype=jnp.int32)
    crashed = jnp.array([-40.0, 60.0, 14.0])  # would move every client
    observed = jnp.array([0.0, 0.0, 1.0])
    m = P.choose_mode(crashed, prev, pc, observed=observed)
    np.testing.assert_array_equal(np.asarray(m[:2]), np.asarray(prev[:2]))
    assert int(m[2]) == 1  # the observed client still adapts (14 dB -> m1)
    # observed=None keeps the historical unmasked behavior bit-for-bit.
    np.testing.assert_array_equal(
        np.asarray(P.choose_mode(crashed, prev, pc)),
        np.asarray(P.choose_mode(crashed, prev, pc,
                                 observed=jnp.ones(3))))


def test_choose_mode_observed_no_flap_after_gap():
    """In-band CSI across a participation gap: holding the mode while
    absent, then re-entering at the same SNR, must land back on the mode
    the client left with (no transient flap from the gap itself)."""
    pc = P.PolicyConfig(hysteresis_db=2.0)
    mode = jnp.array([1], dtype=jnp.int32)
    snr = jnp.array([6.5])  # inside the 6 +- 1 hysteresis window
    for _ in range(4):  # absent waves: whatever CSI says, mode holds
        mode = P.choose_mode(jnp.array([-30.0]), mode, pc,
                             observed=jnp.zeros(1))
    back = P.choose_mode(snr, mode, pc, observed=jnp.ones(1))
    assert int(back[0]) == 1


def test_policy_can_jump_multiple_modes():
    pc = P.PolicyConfig()
    m = P.choose_mode(jnp.array([35.0]), jnp.array([0], jnp.int32), pc)
    assert int(m[0]) == 3
    m = P.choose_mode(jnp.array([0.0]), jnp.array([3], jnp.int32), pc)
    assert int(m[0]) == 0


def test_fixed_policy_is_degenerate():
    pc = P.fixed_policy("approx", "qpsk")
    m = P.choose_mode(jnp.linspace(0, 40, 9), jnp.zeros((9,), jnp.int32), pc)
    np.testing.assert_array_equal(np.asarray(m), np.zeros(9))


def test_policy_config_validation():
    with pytest.raises(ValueError, match="thresholds"):
        P.PolicyConfig(modes=(("ecrt", "qpsk"), ("approx", "qpsk")),
                       thresholds_db=(1.0, 2.0))
    with pytest.raises(ValueError, match="ascend"):
        P.PolicyConfig(thresholds_db=(16.0, 6.0, 26.0))


def test_build_mode_cfgs_rejects_non_dividing_modulation():
    with pytest.raises(ValueError, match="64qam"):
        P.build_mode_cfgs(
            T.TransportConfig(),
            P.PolicyConfig(modes=(("approx", "64qam"),), thresholds_db=()))


def test_build_mode_cfgs_rows():
    base = T.TransportConfig(channel=CH.ChannelConfig(snr_db=9.0),
                             use_kernel=True)
    cfgs = P.build_mode_cfgs(base, P.PolicyConfig(), ecrt_expected_tx=2.5)
    assert [c.mode for c in cfgs] == ["ecrt", "approx", "approx", "approx"]
    assert [c.modulation for c in cfgs] == ["qpsk", "qpsk", "16qam", "256qam"]
    # Kernel flag threads through to the uncoded rows (legal under the
    # bucketed adaptive dispatch); the ECRT row clears it (no coded kernel).
    assert not cfgs[0].use_kernel
    assert all(c.use_kernel for c in cfgs[1:])
    assert cfgs[0].ecrt_expected_tx == 2.5 and not cfgs[0].simulate_fec
    assert all(c.channel == base.channel for c in cfgs)
    # Without use_kernel on the base, no row gets it.
    plain = P.build_mode_cfgs(
        dataclasses.replace(base, use_kernel=False), P.PolicyConfig(),
        ecrt_expected_tx=2.5)
    assert all(not c.use_kernel for c in plain)


def test_ecrt_anchor_snr_db_rule():
    assert P.ecrt_anchor_snr_db(P.PolicyConfig(), 99.0) == 6.0
    assert P.ecrt_anchor_snr_db(P.fixed_policy("ecrt", "qpsk"), 12.5) == 12.5


def test_build_mode_cfgs_calibrates_per_ecrt_modulation(monkeypatch):
    """A table with two ECRT rows of different modulations prices each with
    its own calibrated E[tx] — 16-QAM fails more codewords than QPSK at the
    same anchor, so sharing QPSK's constant would undercount airtime."""
    from repro.core import latency as LATmod

    def fake_calibrate(snr_db, modulation="qpsk", **kw):
        return {"qpsk": 1.5, "16qam": 3.0}[modulation]

    monkeypatch.setattr(LATmod, "calibrate_ecrt", fake_calibrate)
    pc = P.PolicyConfig(
        modes=(("ecrt", "qpsk"), ("ecrt", "16qam"), ("approx", "16qam")),
        thresholds_db=(6.0, 16.0))
    cfgs = P.build_mode_cfgs(T.TransportConfig(), pc)
    assert cfgs[0].ecrt_expected_tx == 1.5
    assert cfgs[1].ecrt_expected_tx == 3.0
    assert cfgs[2].ecrt_expected_tx == 1.0  # non-ECRT rows untouched


def test_ecrt_expected_tx_single_source(monkeypatch):
    """The two ECRT-pricing entry points (``build_mode_cfgs`` default and
    ``ScenarioDriver`` with ``ecrt_expected_tx=None``) must resolve E[tx]
    through the same calibration, at the same anchor SNR."""
    from repro.core import latency as LATmod

    calls = []

    def fake_calibrate(snr_db, modulation="qpsk", fading="block_rayleigh",
                       n_codewords=256, max_tx=8, seed=0, decoder="minsum"):
        calls.append((float(snr_db), n_codewords, max_tx))
        return 1.0 + 0.1 * float(snr_db)

    monkeypatch.setattr(LATmod, "calibrate_ecrt", fake_calibrate)
    base = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    via_policy = P.build_mode_cfgs(base, P.PolicyConfig())
    scen = dataclasses.replace(S.get_scenario("static"),
                               ecrt_expected_tx=None)
    via_driver = S.ScenarioDriver(scen, base).mode_cfgs
    # Same anchor (first threshold = 6 dB) AND the same calibration sample
    # budget — two Monte-Carlo runs with different n_codewords would price
    # the same table differently even at one anchor.
    assert set(calls) == {
        (6.0, P.DEFAULT_CALIB_CODEWORDS, P.DEFAULT_CALIB_MAX_TX)}
    assert via_policy[0].ecrt_expected_tx == via_driver[0].ecrt_expected_tx
    assert via_policy[0].ecrt_expected_tx == pytest.approx(1.6)

    # Fixed-ECRT (threshold-less) tables: the driver's fleet operating point
    # flows through the same anchor_fallback_db hook, so the two entry
    # points still agree — even when base channel SNR != dynamics mean.
    calls.clear()
    fixed = P.fixed_policy("ecrt", "qpsk")
    base20 = T.TransportConfig(channel=CH.ChannelConfig(snr_db=20.0))
    scen_fixed = dataclasses.replace(S.get_scenario("static"), policy=fixed,
                                     ecrt_expected_tx=None)
    drv_cfgs = S.ScenarioDriver(scen_fixed, base20).mode_cfgs
    pol_cfgs = P.build_mode_cfgs(
        base20, fixed, anchor_fallback_db=scen_fixed.dynamics.mean_snr_db)
    assert set(calls) == {(scen_fixed.dynamics.mean_snr_db,
                           P.DEFAULT_CALIB_CODEWORDS, P.DEFAULT_CALIB_MAX_TX)}
    assert drv_cfgs[0].ecrt_expected_tx == pol_cfgs[0].ecrt_expected_tx


# ----------------------------------------------------------------- scenario


def test_scenario_registry():
    names = S.list_scenarios()
    for expected in ("static", "pedestrian", "vehicular", "shadowed-urban",
                     "bursty", "iot-flaky", "iot-lowrate"):
        assert expected in names
        assert S.get_scenario(expected).name == expected
    with pytest.raises(KeyError, match="registered"):
        S.get_scenario("warp-drive")
    custom = S.register_scenario(dataclasses.replace(
        S.get_scenario("static"), name="test-custom"))
    assert S.get_scenario("test-custom") is custom
    del S.SCENARIOS["test-custom"]


def _driver(scen_name="vehicular", **scen_kw):
    scen = dataclasses.replace(S.get_scenario(scen_name),
                               ecrt_expected_tx=2.0, **scen_kw)
    base = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    return S.ScenarioDriver(scen, base)


def test_driver_round_inside_jit():
    drv = _driver(dropout_prob=0.25, straggler_prob=0.25)
    M = 16
    state, mode0, prev_est = drv.init(KEY, M)
    assert mode0.shape == prev_est.shape == (M,)

    @jax.jit
    def one(state, mode, est, key):
        return drv.round(state, mode, est, key)

    state, rnd = one(state, mode0, prev_est, jax.random.fold_in(KEY, 1))
    for field in (rnd.snr_db, rnd.est_db, rnd.mode, rnd.active, rnd.straggler):
        assert field.shape == (M,)
    assert rnd.mode.dtype == jnp.int32
    assert set(np.unique(np.asarray(rnd.active))) <= {0.0, 1.0}


def test_driver_airtime_prices_modes_and_availability():
    drv = _driver(dropout_prob=0.0, straggler_prob=0.0)
    M, N = 8, 512
    x = jax.random.uniform(KEY, (M, N), minval=-0.9, maxval=0.9)
    mode = jnp.array([0, 0, 1, 1, 2, 2, 3, 3])
    _, stats = T.transmit_batch_adaptive(
        x, KEY, drv.mode_cfgs, mode, snr_db=jnp.full((M,), 12.0))
    rnd = S.LinkRound(
        snr_db=jnp.full((M,), 12.0), est_db=jnp.full((M,), 12.0), mode=mode,
        active=jnp.array([1, 1, 1, 1, 1, 1, 1, 0], jnp.float32),
        straggler=jnp.array([0, 0, 0, 1, 0, 0, 0, 0], jnp.float32))
    air = np.asarray(drv.airtime(stats, rnd, LAT.PhyTimings()))
    # ECRT (2x coded symbols x E[tx]=2) slowest, higher QAM faster
    assert air[0] > air[2] > air[4] > air[6]
    # straggler pays slowdown x its mode's airtime
    assert air[3] == pytest.approx(air[2] * drv.scenario.straggler_slowdown)
    # dropped client transmits nothing
    assert air[7] == 0.0


def test_driver_calibrates_ecrt_when_unset():
    scen = dataclasses.replace(S.get_scenario("static"),
                               ecrt_expected_tx=None)
    drv = S.ScenarioDriver(
        scen, T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0)),
        calib_codewords=16, calib_max_tx=4)
    assert drv.mode_cfgs[0].mode == "ecrt"
    assert drv.mode_cfgs[0].ecrt_expected_tx >= 1.0


def test_calibrate_ecrt_canonicalizes_cache_key(monkeypatch):
    """Keyword vs positional call forms and float64-vs-float32 SNR
    representations of the same calibration must resolve to one cache
    entry — the anchor/curve-point consistency the airtime interpolation
    relies on."""
    from repro.core import latency as LATmod

    calls = []

    def fake_inner(snr, mod, fading, ncw, mtx, seed, dec):
        calls.append((snr, mod, fading, ncw, mtx))
        return 2.0

    monkeypatch.setattr(LATmod, "_calibrate_ecrt", fake_inner)
    a = LATmod.calibrate_ecrt(6.1, "qpsk", n_codewords=48, max_tx=6)
    b = LATmod.calibrate_ecrt(float(np.float32(6.1)), "qpsk",
                              "block_rayleigh", 48, 6)
    assert a == b == 2.0
    assert len(set(calls)) == 1  # identical canonical arguments


def test_driver_airtime_interpolates_ecrt_per_client(monkeypatch):
    """Regression for the constant-E[tx] airtime bug: under calibrated ECRT
    (``ecrt_expected_tx=None``) two ECRT clients at different SNRs the same
    round must pay different airtime — E[tx] interpolated from the
    calibration curve at each client's SNR — while non-ECRT clients are
    untouched; an explicit float keeps the flat constant."""
    from repro.core import latency as LATmod

    # Steep fake curve: E[tx] = 4 at the floor, 1 above the anchor.
    def fake_calibrate(snr_db, modulation="qpsk", fading="block_rayleigh",
                       n_codewords=256, max_tx=8, seed=0, decoder="minsum"):
        return float(np.clip(4.0 - 0.5 * (float(snr_db) + 5.0), 1.0, 4.0))

    monkeypatch.setattr(LATmod, "calibrate_ecrt", fake_calibrate)
    base = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=None)
    drv = S.ScenarioDriver(scen, base)
    M = 4
    x = jax.random.uniform(KEY, (M, 256), minval=-0.9, maxval=0.9)
    mode = jnp.array([0, 0, 1, 1], jnp.int32)  # two ECRT, two approx clients
    snr = jnp.array([-3.0, 4.0, -3.0, 4.0], jnp.float32)
    _, stats = T.transmit_batch_adaptive(x, KEY, drv.mode_cfgs, mode,
                                         snr_db=snr)
    rnd = S.LinkRound(snr_db=snr, est_db=snr, mode=mode,
                      active=jnp.ones((M,), jnp.float32),
                      straggler=jnp.zeros((M,), jnp.float32))
    air = np.asarray(drv.airtime(stats, rnd, LAT.PhyTimings()))
    # ECRT client in the fade pays more than the ECRT client in the clear...
    assert air[0] > air[1] * 1.5
    # ...approx clients price identically regardless of SNR (same symbols).
    assert air[2] == pytest.approx(air[3])

    # The anchor SNR is on the grid, so a client sitting exactly at the
    # transport constant's calibration point reprices with ratio 1.
    grid, vals = drv._ecrt_tx_curve()
    anchor = P.ecrt_anchor_snr_db(scen.policy, scen.dynamics.mean_snr_db)
    assert anchor in np.asarray(grid)
    at_anchor = float(LAT.interp_expected_tx(anchor, grid, vals))
    assert at_anchor == pytest.approx(drv.mode_cfgs[0].ecrt_expected_tx)

    # An explicit constant disables the interpolation: equal ECRT airtimes.
    drv_const = S.ScenarioDriver(
        dataclasses.replace(scen, ecrt_expected_tx=2.0), base)
    _, stats_c = T.transmit_batch_adaptive(x, KEY, drv_const.mode_cfgs, mode,
                                           snr_db=snr)
    air_c = np.asarray(drv_const.airtime(stats_c, rnd, LAT.PhyTimings()))
    assert air_c[0] == pytest.approx(air_c[1])


# ------------------------------------------------------- FL loop integration


@pytest.mark.slow
def test_run_fl_scenario_smoke():
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.data import synth_mnist
    from repro.fl import partition
    from repro.fl.loop import run_fl

    (img, lab), (ti, tl) = synth_mnist.train_test(120, 30)
    parts = partition.non_iid_partition(img, lab, n_clients=6)
    cx, cy = partition.stack_clients(parts, per_client=32)
    cfg = dataclasses.replace(cnn_config(), lr=0.05)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0, dropout_prob=0.1)
    res = run_fl(cfg, tcfg, cx, cy, ti, tl, n_rounds=4, batch_per_round=8,
                 eval_every=2, scenario=scen)
    assert len(res.link) == 4
    n_modes = len(scen.policy.modes)
    for t in res.link:
        assert len(t["mode_counts"]) == n_modes
        assert sum(t["mode_counts"]) == 6
        assert 0 <= t["n_active"] <= 6
        assert t["airtime_s"] >= 0.0
    assert res.airtime_s[-1] > 0.0
    assert np.isfinite(res.final_accuracy)


@pytest.mark.slow
def test_run_fedavg_scenario_smoke():
    """The FedAvg link path (scaled_uplink over the adaptive transport +
    dropout-weighted delta aggregation) mirrors run_fl's coverage."""
    from repro.configs.mnist_cnn import config as cnn_config
    from repro.data import synth_mnist
    from repro.fl import partition
    from repro.fl.fedavg import run_fedavg

    (img, lab), (ti, tl) = synth_mnist.train_test(120, 30)
    parts = partition.non_iid_partition(img, lab, n_clients=6)
    cx, cy = partition.stack_clients(parts, per_client=32)
    cfg = dataclasses.replace(cnn_config(), lr=0.05)
    tcfg = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("iot-flaky"),
                               ecrt_expected_tx=2.0)
    res = run_fedavg(cfg, tcfg, cx, cy, ti, tl, n_rounds=3, local_steps=2,
                     batch_per_step=8, scale_mode="max_abs", eval_every=2,
                     scenario=scen)
    assert len(res.link) == 3
    for t in res.link:
        assert sum(t["mode_counts"]) == 6
        assert t["airtime_s"] >= 0.0
    assert np.isfinite(res.final_accuracy)


# ------------------------------------------- event-layer lane-span guards


def test_event_layer_rejects_cohort_beyond_lane_span():
    """Every event-layer draw is client-indexed inside a reserved fold_in
    lane; a cohort wider than the lane span would walk into the next lane
    (mirroring transmit_broadcast's historical num_clients guard)."""
    too_many = D.COMPUTE_KEY_LANE.span + 1
    ccfg = D.ComputeTimeConfig()
    acfg = D.ArrivalConfig()
    with pytest.raises(ValueError, match="num_clients"):
        D.client_speed_factors(KEY, too_many, ccfg)
    with pytest.raises(ValueError, match="num_clients"):
        D.compute_times(KEY, ccfg, too_many)
    with pytest.raises(ValueError, match="num_clients"):
        D.churn_step(KEY, jnp.ones(D.EVENT_KEY_LANE.span + 1,
                                   dtype=jnp.float32), acfg)
    with pytest.raises(ValueError, match="num_clients"):
        D.idle_gaps(KEY, D.EVENT_GAP_KEY_LANE.span + 1, acfg)


def test_event_layer_lane_spans_admit_full_width_cohorts():
    """The guard itself accepts cohorts up to exactly the lane span (checked
    on the guard, not the draw, to avoid allocating 1M-element arrays) and
    small cohorts draw normally."""
    from repro.core import keylanes

    for lane in (D.COMPUTE_KEY_LANE, D.EVENT_KEY_LANE,
                 D.EVENT_GAP_KEY_LANE):
        keylanes.check_cohort(lane, lane.span)
        with pytest.raises(ValueError, match="num_clients"):
            keylanes.check_cohort(lane, lane.span + 1)
    assert D.compute_times(KEY, D.ComputeTimeConfig(), 4).shape == (4,)
    assert D.idle_gaps(KEY, 4, D.ArrivalConfig()).shape == (4,)
