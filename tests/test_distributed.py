"""Distributed-runtime tests: run in subprocesses with fake host devices so
the main pytest process keeps the 1-device view (per the brief)."""

import subprocess
import sys
import textwrap

import pytest


def _run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env_code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        "import sys; sys.path.insert(0, 'src')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", env_code + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_approx_allreduce_matches_mean_at_high_snr():
    """At very high SNR the approximate all-reduce equals the exact mean."""
    _run_py("""
        import jax, jax.numpy as jnp, functools
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import aggregation as AGG, transport as T, channel as CH

        mesh = jax.make_mesh((4,), ("data",))
        cfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=60.0, fading="awgn"))
        g = jnp.linspace(-0.9, 0.9, 4 * 64).reshape(4, 64)

        @functools.partial(jax.shard_map, mesh=mesh, axis_names={"data"},
                           in_specs=P("data", None), out_specs=P())
        def agg(gl):
            out, stats = AGG.approx_allreduce(gl[0], jax.random.PRNGKey(0), cfg, ("data",))
            return out

        with jax.set_mesh(mesh):
            got = jax.jit(agg)(g)
        want = g.mean(0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
        print("OK")
    """)


@pytest.mark.slow
def test_train_step_approx_runs_and_descends():
    """Paper-faithful per-client uplink step on a 4x2 mesh: loss decreases
    over steps at moderate SNR."""
    out = _run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import transport as T, channel as CH
        from repro.launch import steps as S
        from repro.models import registry as R
        from repro.optim.sgd import sgd as make_sgd

        cfg = get_config("qwen2-1.5b").reduced(n_layers=2, d_model=64, d_ff=128, vocab_size=128)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        tcfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=20.0))
        opt = make_sgd(0.2)
        key = jax.random.PRNGKey(0)
        params = R.init_params(key, cfg)
        opt_state = opt.init(params)
        tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size, jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        with jax.set_mesh(mesh):
            step = jax.jit(S.make_train_step_approx(cfg, opt, tcfg, mesh))
            losses = []
            for i in range(6):
                key, sk = jax.random.split(key)
                params, opt_state, loss, stats = step(params, opt_state, batch, sk)
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        assert all(l == l for l in losses)  # no NaN
        print("LOSSES", losses)
    """)
    assert "LOSSES" in out


@pytest.mark.slow
def test_per_shard_corruption_step():
    """Fully-manual elementwise uplink corruption (kimi-k2 path)."""
    _run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import transport as T, channel as CH
        from repro.launch import steps as S
        from repro.models import registry as R
        from repro.optim.sgd import sgd as make_sgd

        cfg = get_config("qwen2-1.5b").reduced(n_layers=2, d_model=64, d_ff=128, vocab_size=128)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        tcfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=25.0))
        opt = make_sgd(0.2)
        key = jax.random.PRNGKey(0)
        params = R.init_params(key, cfg)
        tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size, jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        with jax.set_mesh(mesh):
            step = jax.jit(S.make_train_step(cfg, opt, transport_cfg=tcfg, mesh=mesh))
            p2, o2, loss = step(params, opt.init(params), batch, key)
        assert jnp.isfinite(loss), loss
        print("OK", float(loss))
    """)


@pytest.mark.slow
def test_dryrun_single_combo_small_mesh():
    """The dry-run driver itself (reduced arch, production-mesh code path)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "decode_32k", "--mesh", "single", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK qwen2-1.5b" in out.stdout


@pytest.mark.slow
def test_expert_parallel_moe_matches_dense():
    """shard_map + tiled all_to_all expert parallelism == dense dispatch."""
    _run_py("""
        import jax, jax.numpy as jnp, dataclasses
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models import moe as MOE

        cfg = get_config("kimi-k2-1t-a32b").reduced(
            d_model=64, moe_d_ff=32, n_experts=8, top_k=2)
        cfg = dataclasses.replace(cfg, capacity_factor=4.0, n_shared_experts=1)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)
        with jax.set_mesh(mesh):
            xd = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            pd = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, NamedSharding(mesh, P())), p)
            pe = dict(pd)
            for k2 in ("wi", "wg", "wo"):
                pe[k2] = jax.device_put(p[k2], NamedSharding(mesh, P("data", None, None)))
            d_out, d_aux = jax.jit(lambda x, p: MOE.moe_ffn(x, p, cfg))(xd, pd)
            e_out, e_aux = jax.jit(lambda x, p: MOE.moe_ffn_shardmap(x, p, cfg))(xd, pe)
        np.testing.assert_allclose(np.asarray(d_out), np.asarray(e_out),
                                   rtol=2e-4, atol=2e-4)
        # gradients flow through the all_to_all pair
        g = jax.jit(jax.grad(lambda p: jnp.sum(
            MOE.moe_ffn_shardmap(xd, p, cfg)[0].astype(jnp.float32) ** 2)))(pe)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(g))
        print("OK")
    """)


@pytest.mark.slow
def test_bf16_wire_train_step():
    """Per-client uplink with the bf16 wire format descends and halves
    the reported airtime symbols."""
    out = _run_py("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.core import transport as T, channel as CH
        from repro.launch import steps as S
        from repro.models import registry as R
        from repro.optim.sgd import sgd as make_sgd

        cfg = get_config("qwen2-1.5b").reduced(n_layers=2, d_model=64, d_ff=128, vocab_size=128)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = make_sgd(0.2)
        key = jax.random.PRNGKey(0)
        params = R.init_params(key, cfg)
        tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size, jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        syms = {}
        with jax.set_mesh(mesh):
            for wd in ("float32", "bfloat16"):
                tcfg = T.TransportConfig(mode="approx", wire_dtype=wd,
                                         channel=CH.ChannelConfig(snr_db=25.0))
                step = jax.jit(S.make_train_step_approx(cfg, opt, tcfg, mesh))
                p, o, loss, stats = step(params, opt.init(params), batch, key)
                assert jnp.isfinite(loss)
                syms[wd] = float(stats.data_symbols)
        assert abs(syms["bfloat16"] - syms["float32"] / 2) < 1e-3 * syms["float32"]
        print("SYMS", syms)
    """)
    assert "SYMS" in out
