"""Frozen pre-engine FL loops: the golden reference for the round-engine
refactor (PR 4).

This is a verbatim snapshot of ``repro.fl.loop`` / ``repro.fl.fedavg`` as of
commit c0bf671 (PR 3), taken immediately before both were collapsed into the
unified ``repro.fl.engine``. ``tests/test_engine_golden.py`` runs these
side by side with the engine-backed ``run_fl``/``run_fedavg`` and asserts
bit-identical ``FLResult``s (accuracy, airtime, link telemetry) for FedSGD
and FedAvg, driver-less and scenario-driven, under both adaptive dispatches.

Only mechanical edits vs the snapshot: the two modules are merged into one
file (the fedavg half imports the loop half's helpers from here), public
names gained a ``golden_`` prefix, and nothing else — do NOT "improve" this
file; its value is being frozen.
"""



from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency as latency_lib
from repro.core import transport as transport_lib
from repro.fl import cnn
from repro.optim.sgd import sgd as make_sgd


@dataclasses.dataclass
class FLResult:
    rounds: list
    accuracy: list
    airtime_s: list  # cumulative uplink airtime (TDMA sum over clients)
    wall_s: float
    final_accuracy: float
    # Per-round link telemetry (scenario-driven runs only; [] otherwise).
    # Each entry: {round, mean_snr_db, mean_est_db, mode_counts, n_active,
    # n_stragglers, airtime_s} — mode_counts indexes the driver's mode table.
    link: list = dataclasses.field(default_factory=list)


def resolve_scenario(scenario, transport_cfg):
    """``scenario=`` argument -> a bound ``ScenarioDriver`` (or ``None``).

    Accepts a registered scenario name, a ``Scenario``, or an already-built
    ``ScenarioDriver``; shared by ``run_fl`` and ``fedavg.run_fedavg``.
    """
    if scenario is None:
        return None
    from repro.link import scenario as scenario_lib

    if isinstance(scenario, scenario_lib.ScenarioDriver):
        return scenario
    if isinstance(scenario, str):
        scenario = scenario_lib.get_scenario(scenario)
    return scenario_lib.ScenarioDriver(scenario, transport_cfg)


def dropout_weighted_mean(tree, active):
    """Mean of ``(M, ...)`` leaves over active clients only.

    ``active`` is the 0/1 ``(M,)`` availability vector; an all-dropped round
    yields zeros (the global model simply does not move). Jit-safe — the
    shared aggregation rule of both scenario-driven FL loops.
    """
    denom = jnp.maximum(jnp.sum(active), 1.0)
    return jax.tree_util.tree_map(
        lambda g: jnp.tensordot(active, g, axes=(0, 0)) / denom, tree)


def record_link_round(res: "FLResult", r: int, driver, stats, rnd,
                      timings) -> jax.Array:
    """Per-round scenario bookkeeping shared by the FL loops: price the
    round's per-client airtime and append the telemetry record. Returns the
    ``(M,)`` airtime vector."""
    air = driver.airtime(stats, rnd, timings)
    res.link.append(link_telemetry(r, rnd, air, len(driver.mode_cfgs)))
    return air


def link_telemetry(r: int, rnd, per_client_air, n_modes: int) -> dict:
    """One ``FLResult.link`` record from a round's ``LinkRound`` + airtime."""
    mode = np.asarray(rnd.mode)
    return {
        "round": r,
        "mean_snr_db": float(np.mean(np.asarray(rnd.snr_db))),
        "mean_est_db": float(np.mean(np.asarray(rnd.est_db))),
        "mode_counts": np.bincount(mode, minlength=n_modes).tolist(),
        "n_active": int(np.asarray(rnd.active).sum()),
        "n_stragglers": int(np.asarray(rnd.straggler).sum()),
        "airtime_s": float(np.asarray(per_client_air).sum()),
    }


def select_mode_cfgs(driver):
    """The driver's mode table, legal for the select dispatch.

    Delegates to ``transport.clear_kernel_rows`` (the one clearing rule):
    the fused select round cannot lower the Pallas grid. A select round is
    therefore *not* bit-comparable to a bucketed round of a kernel-enabled
    table — the jnp rows draw their own, equally valid, channel
    realization; within the select dispatch everything stays deterministic
    as usual.
    """
    return transport_lib.clear_kernel_rows(driver.mode_cfgs)


def resolve_ecrt_analytic(transport_cfg, num_clients: int):
    """Swap real-FEC ECRT for the calibrated analytic model in an FL loop.

    The real decoder inside a vmapped per-round loop would only re-measure a
    constant; calibrate instead — with the shared pricing sample budget
    (``latency.DEFAULT_CALIB_CODEWORDS``), so every entry point resolves
    the same channel to the same E[tx]. Heterogeneous cohorts get E[tx]
    interpolated per client over an SNR grid (``ecrt_expected_tx_profile``),
    with the cohort mean driving the transport constant and the per-client
    ratio returned as a ``(num_clients,)`` airtime scale (the analytic model
    is linear in E[tx]). Returns ``(transport_cfg, air_scale_or_None)``.
    """
    if not (transport_cfg.mode == "ecrt" and transport_cfg.simulate_fec):
        return transport_cfg, None
    snr_vec = np.asarray(transport_cfg.channel.snr_db, np.float32).reshape(-1)
    e_tx = latency_lib.ecrt_expected_tx_profile(
        snr_vec, transport_cfg.modulation,
        n_codewords=latency_lib.DEFAULT_CALIB_CODEWORDS,
        max_tx=latency_lib.DEFAULT_CALIB_MAX_TX)
    e_mean = float(e_tx.mean())
    transport_cfg = dataclasses.replace(
        transport_cfg, simulate_fec=False, ecrt_expected_tx=e_mean)
    air_scale = None
    if e_tx.size == num_clients and e_tx.size > 1:
        air_scale = jnp.asarray(e_tx / e_mean)
    return transport_cfg, air_scale


def golden_run_fl(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,  # (M, n, 28, 28)
    client_y: np.ndarray,  # (M, n)
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 40,
    batch_per_round: int = 32,
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
    adaptive_dispatch: str = "bucketed",
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    opt = make_sgd(cfg.lr)
    opt_state = opt.init(params)
    driver = resolve_scenario(scenario, transport_cfg)
    if adaptive_dispatch not in ("bucketed", "select"):
        raise ValueError(
            f"adaptive_dispatch must be bucketed|select, got {adaptive_dispatch!r}")

    ecrt_air_scale = None
    if driver is None:
        transport_cfg, ecrt_air_scale = resolve_ecrt_analytic(transport_cfg, M)

    grad_fn = jax.grad(cnn.loss_fn)

    @jax.jit
    def round_step(params, opt_state, xb, yb, key):
        def client_grad(x, y):
            return grad_fn(params, x, y)

        grads = jax.vmap(client_grad)(xb, yb)  # pytree leaves (M, ...)
        # Batched uplink: M independent channels, fold_in key schedule,
        # per-client TxStats — one fused computation instead of M pipelines.
        grads_hat, stats = transport_lib.transmit_pytree_batch(
            grads, key, transport_cfg)
        agg = jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads_hat)
        new_params, new_state = opt.update(agg, opt_state, params)
        return new_params, new_state, stats

    @jax.jit
    def round_step_link(params, opt_state, xb, yb, key, lstate, prev_mode,
                        prev_est):
        # Select dispatch: one fused program — dynamics -> noisy CSI -> mode
        # policy -> vmapped-switch uplink -> dropout-weighted aggregation.
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link)

        def client_grad(x, y):
            return grad_fn(params, x, y)

        grads = jax.vmap(client_grad)(xb, yb)
        grads_hat, stats = transport_lib.transmit_pytree_batch_adaptive(
            grads, k_tx, select_mode_cfgs(driver), rnd.mode,
            snr_db=rnd.snr_db, dispatch="select")
        agg = dropout_weighted_mean(grads_hat, rnd.active)
        new_params, new_state = opt.update(agg, opt_state, params)
        return new_params, new_state, stats, lstate, rnd

    @jax.jit
    def link_round(lstate, prev_mode, prev_est, key):
        return driver.round(lstate, prev_mode, prev_est, key)

    @jax.jit
    def client_grads(params, xb, yb):
        return jax.vmap(lambda x, y: grad_fn(params, x, y))(xb, yb)

    @jax.jit
    def apply_update(params, opt_state, grads_hat, active):
        agg = dropout_weighted_mean(grads_hat, active)
        return opt.update(agg, opt_state, params)

    def round_step_link_bucketed(params, opt_state, xb, yb, key, lstate,
                                 prev_mode, prev_est):
        # Bucketed dispatch: the link step runs first and the mode vector
        # syncs to the host, so the uplink can sort clients into per-mode
        # buckets and run each mode once (O(M) work, kernel rows allowed)
        # instead of paying every mode for every client.
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
        mode_np = np.asarray(rnd.mode)
        grads = client_grads(params, xb, yb)
        grads_hat, stats = transport_lib.transmit_pytree_batch_adaptive(
            grads, k_tx, driver.mode_cfgs, mode_np, snr_db=rnd.snr_db,
            dispatch="bucketed")
        params, opt_state = apply_update(params, opt_state, grads_hat,
                                         rnd.active)
        return params, opt_state, stats, lstate, rnd

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    if driver is not None:
        key, lk = jax.random.split(key)
        lstate, prev_mode, prev_est = driver.init(lk, M)

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, batch_per_round))
        xb = jnp.asarray(np.take_along_axis(client_x, take[:, :, None, None], axis=1))
        yb = jnp.asarray(np.take_along_axis(client_y, take, axis=1))
        if driver is None:
            params, opt_state, stats = round_step(params, opt_state, xb, yb, rk)
            # TDMA uplink: total airtime is the sum over clients ((M,) stats)
            per_client_air = latency_lib.round_airtime(
                stats, timings, transport_cfg.mode)
            if ecrt_air_scale is not None:
                # Heterogeneous analytic ECRT: rescale each client's airtime
                # from the cohort-mean E[tx] to its own interpolated value.
                per_client_air = per_client_air * ecrt_air_scale
        else:
            step = (round_step_link_bucketed
                    if adaptive_dispatch == "bucketed" else round_step_link)
            params, opt_state, stats, lstate, rnd = step(
                params, opt_state, xb, yb, rk, lstate, prev_mode, prev_est)
            prev_mode, prev_est = rnd.mode, rnd.est_db
            per_client_air = record_link_round(
                res, r, driver, stats, rnd, timings)
        cum_air += float(jnp.sum(per_client_air))
        if r % eval_every == 0 or r == n_rounds - 1:
            acc = float(eval_acc(params))
            res.rounds.append(r)
            res.accuracy.append(acc)
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res









def golden_run_fedavg(
    cfg,
    transport_cfg: transport_lib.TransportConfig,
    client_x: np.ndarray,
    client_y: np.ndarray,
    test_x: np.ndarray,
    test_y: np.ndarray,
    n_rounds: int = 30,
    local_steps: int = 4,
    batch_per_step: int = 32,
    scale_mode: str = "none",  # "none" | "max_abs"
    seed: int = 0,
    eval_every: int = 2,
    timings: latency_lib.PhyTimings | None = None,
    scenario=None,
    adaptive_dispatch: str = "bucketed",
) -> FLResult:
    timings = timings or latency_lib.PhyTimings()
    M = client_x.shape[0]
    key = jax.random.PRNGKey(seed)
    key, pk = jax.random.split(key)
    params = cnn.init_params(pk, cfg)
    grad_fn = jax.grad(cnn.loss_fn)
    driver = resolve_scenario(scenario, transport_cfg)
    if adaptive_dispatch not in ("bucketed", "select"):
        raise ValueError(
            f"adaptive_dispatch must be bucketed|select, got {adaptive_dispatch!r}")

    ecrt_air_scale = None
    if driver is None:
        # Per-client analytic E[tx] for heterogeneous cohorts (see loop.py).
        transport_cfg, ecrt_air_scale = resolve_ecrt_analytic(transport_cfg, M)

    def client_deltas(params, xb, yb):
        # xb: (M, local_steps, batch, 28, 28) -> weight deltas, leaves (M, ...)
        def client_update(x, y):
            def body(p, inp):
                xi, yi = inp
                g = grad_fn(p, xi, yi)
                p = jax.tree_util.tree_map(lambda a, b: a - cfg.lr * b, p, g)
                return p, None

            local, _ = jax.lax.scan(body, params, (x, y))
            return jax.tree_util.tree_map(lambda a, b: a - b, local, params)

        return jax.vmap(client_update)(xb, yb)

    def expand(s, like):
        return s.reshape((M,) + (1,) * (like.ndim - 1))

    # jitted so the host-driven bucketed round doesn't run the scale math
    # op-by-op; inside round_step_link's trace they simply inline.
    @jax.jit
    def compute_scale(deltas):
        flat = jnp.concatenate(
            [l.reshape(M, -1) for l in jax.tree_util.tree_leaves(deltas)],
            axis=1)
        return jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-8) / 0.9

    @jax.jit
    def div_scale(deltas, scale):
        return jax.tree_util.tree_map(lambda l: l / expand(scale, l), deltas)

    @jax.jit
    def mul_scale(deltas, scale):
        return jax.tree_util.tree_map(lambda l: l * expand(scale, l), deltas)

    def scaled_uplink(deltas, transmit):
        # Per-client adaptive scale (scale_mode == "max_abs"): one scalar per
        # client travels on the (error-free) control channel; the cohort then
        # rides the batched uplink in a single fused computation.
        if scale_mode != "max_abs":
            return transmit(deltas)
        scale = compute_scale(deltas)
        out, stats = transmit(div_scale(deltas, scale))
        return mul_scale(out, scale), stats

    @jax.jit
    def round_step(params, xb, yb, key):
        deltas = client_deltas(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch(t, key, transport_cfg))
        agg = jax.tree_util.tree_map(lambda d: jnp.mean(d, axis=0), deltas_hat)
        new_params = jax.tree_util.tree_map(lambda p, d: p + d, params, agg)
        return new_params, stats

    @jax.jit
    def round_step_link(params, xb, yb, key, lstate, prev_mode, prev_est):
        # Select dispatch, scenario-driven round: link pipeline + vmapped-
        # switch uplink + dropout-weighted FedAvg aggregate (see loop.run_fl).
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = driver.round(lstate, prev_mode, prev_est, k_link)
        deltas = client_deltas(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch_adaptive(
                t, k_tx, select_mode_cfgs(driver), rnd.mode,
                snr_db=rnd.snr_db, dispatch="select"))
        agg = dropout_weighted_mean(deltas_hat, rnd.active)
        new_params = jax.tree_util.tree_map(lambda p, d: p + d, params, agg)
        return new_params, stats, lstate, rnd

    @jax.jit
    def link_round(lstate, prev_mode, prev_est, key):
        return driver.round(lstate, prev_mode, prev_est, key)

    @jax.jit
    def deltas_fn(params, xb, yb):
        return client_deltas(params, xb, yb)

    @jax.jit
    def apply_deltas(params, deltas_hat, active):
        agg = dropout_weighted_mean(deltas_hat, active)
        return jax.tree_util.tree_map(lambda p, d: p + d, params, agg)

    def round_step_link_bucketed(params, xb, yb, key, lstate, prev_mode,
                                 prev_est):
        # Bucketed dispatch: the mode vector syncs to the host after the
        # jitted link step, the uplink runs each mode once on its own client
        # bucket, and the (jitted) aggregate applies the deltas (see
        # loop.run_fl for the trade-off).
        k_link, k_tx = jax.random.split(key)
        lstate, rnd = link_round(lstate, prev_mode, prev_est, k_link)
        mode_np = np.asarray(rnd.mode)
        deltas = deltas_fn(params, xb, yb)
        deltas_hat, stats = scaled_uplink(
            deltas,
            lambda t: transport_lib.transmit_pytree_batch_adaptive(
                t, k_tx, driver.mode_cfgs, mode_np, snr_db=rnd.snr_db,
                dispatch="bucketed"))
        params = apply_deltas(params, deltas_hat, rnd.active)
        return params, stats, lstate, rnd

    @jax.jit
    def eval_acc(params):
        return cnn.accuracy(params, jnp.asarray(test_x), jnp.asarray(test_y))

    if driver is not None:
        key, lk = jax.random.split(key)
        lstate, prev_mode, prev_est = driver.init(lk, M)

    rng = np.random.default_rng(seed)
    res = FLResult([], [], [], 0.0, 0.0)
    t0 = time.time()
    cum_air = 0.0
    for r in range(n_rounds):
        key, rk = jax.random.split(key)
        take = rng.integers(0, client_x.shape[1], (M, local_steps, batch_per_step))
        xb = jnp.asarray(np.take_along_axis(
            client_x, take.reshape(M, -1)[:, :, None, None], axis=1
        ).reshape(M, local_steps, batch_per_step, 28, 28))
        yb = jnp.asarray(np.take_along_axis(
            client_y, take.reshape(M, -1), axis=1
        ).reshape(M, local_steps, batch_per_step))
        if driver is None:
            params, stats = round_step(params, xb, yb, rk)
            air = latency_lib.round_airtime(stats, timings, transport_cfg.mode)
            if ecrt_air_scale is not None:
                air = air * ecrt_air_scale
        else:
            step = (round_step_link_bucketed
                    if adaptive_dispatch == "bucketed" else round_step_link)
            params, stats, lstate, rnd = step(
                params, xb, yb, rk, lstate, prev_mode, prev_est)
            prev_mode, prev_est = rnd.mode, rnd.est_db
            air = record_link_round(res, r, driver, stats, rnd, timings)
        cum_air += float(jnp.sum(air))
        if r % eval_every == 0 or r == n_rounds - 1:
            res.rounds.append(r)
            res.accuracy.append(float(eval_acc(params)))
            res.airtime_s.append(cum_air)
    res.wall_s = time.time() - t0
    res.final_accuracy = res.accuracy[-1]
    return res
