"""Downlink broadcast leg: transport primitives, key-lane schedule, airtime
pricing, policy mapping, and the FL integration (driver-less + scenario,
both dispatches) — plus the FedAvg ``max_abs`` x scenario x bucketed
coverage the pre-engine loops never exercised."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import latency as LAT
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import cnn, partition
from repro.fl.fedavg import run_fedavg
from repro.fl.loop import run_fl
from repro.link import policy as P
from repro.link import scenario as S

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------ transport primitives


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=-1.9, max_value=1.9, width=32),
                min_size=1, max_size=64))
def test_perfect_downlink_is_exact_identity(values):
    """Property: a perfect downlink channel is the identity on the broadcast
    payload — every client's received copy equals the transmitted bits."""
    x = jnp.asarray(values, jnp.float32)
    x_hat, stats = T.transmit_broadcast(x, KEY, T.TransportConfig(mode="perfect"),
                                        num_clients=3)
    assert x_hat.shape == (3, x.shape[0])
    np.testing.assert_array_equal(
        np.asarray(x_hat).view(np.uint32),
        np.tile(np.asarray(x).view(np.uint32), (3, 1)))
    assert np.all(np.asarray(stats.bit_errors) == 0)


def test_perfect_pytree_broadcast_identity_on_model():
    """The pytree front-end: a CNN params tree survives a perfect broadcast
    bit-exactly, with every leaf growing a leading client dim."""
    params = cnn.init_params(KEY, cnn_config())
    out, stats = T.transmit_pytree_broadcast(
        params, KEY, T.TransportConfig(mode="perfect"), num_clients=4)
    for name, leaf in params.items():
        got = out[name]
        assert got.shape == (4,) + leaf.shape and got.dtype == leaf.dtype
        for i in range(4):
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(leaf))
    assert stats.data_symbols.shape == (4,)


def test_broadcast_rides_the_downlink_key_lane():
    """Client ``i``'s broadcast draw is ``fold_in(key, LANE + i)`` — so the
    downlink is reproducible per client AND decorrelated from the uplink's
    ``fold_in(key, i)`` schedule under the same base key."""
    cfg = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=8.0))
    x = jax.random.normal(jax.random.PRNGKey(3), (300,)) * 0.5
    x_hat, _ = T.transmit_broadcast(x, KEY, cfg, num_clients=4)
    for i in range(4):
        ref, _ = T.transmit_flat(
            x, jax.random.fold_in(KEY, T.DOWNLINK_KEY_LANE + i), cfg)
        np.testing.assert_array_equal(np.asarray(x_hat[i]), np.asarray(ref))
    # Same base key on the uplink lane draws a different realization.
    up_hat, _ = T.transmit_batch(jnp.tile(x, (4, 1)), KEY, cfg)
    assert not np.array_equal(np.asarray(x_hat), np.asarray(up_hat))


def test_broadcast_validation():
    cfg = T.TransportConfig(mode="perfect")
    with pytest.raises(ValueError, match="flat"):
        T.transmit_broadcast(jnp.zeros((2, 8)), KEY, cfg, num_clients=2)
    with pytest.raises(ValueError, match="num_clients"):
        T.transmit_broadcast(jnp.zeros((8,)), KEY, cfg, num_clients=0)
    with pytest.raises(ValueError, match="num_clients"):
        T.transmit_broadcast(jnp.zeros((8,)), KEY, cfg,
                             num_clients=T.DOWNLINK_KEY_LANE + 1)


def test_broadcast_adaptive_bucketed_equals_select():
    """The mixed-mode broadcast inherits the uplink engine's dispatch
    equivalence: bucketed == select bit-for-bit on a kernel-free table."""
    base = T.TransportConfig(channel=CH.ChannelConfig(snr_db=10.0))
    cfgs = P.build_mode_cfgs(base, P.PolicyConfig(), ecrt_expected_tx=2.0)
    x = jax.random.normal(jax.random.PRNGKey(5), (512,)) * 0.5
    mode = np.array([0, 1, 2, 3, 1, 1, 2, 0], np.int32)
    snr = jnp.linspace(2.0, 28.0, 8)
    a, sa = T.transmit_broadcast_adaptive(x, KEY, cfgs, mode, snr_db=snr,
                                          dispatch="bucketed")
    b, sb = T.transmit_broadcast_adaptive(x, KEY, cfgs, jnp.asarray(mode),
                                          snr_db=snr, dispatch="select")
    np.testing.assert_array_equal(np.asarray(a).view(np.uint32),
                                  np.asarray(b).view(np.uint32))
    for f in ("data_symbols", "transmissions", "bit_errors", "n_bits",
              "mode_idx"):
        np.testing.assert_array_equal(np.asarray(getattr(sa, f)),
                                      np.asarray(getattr(sb, f)))


# --------------------------------------------------------------- airtime


def test_broadcast_airtime_prices_one_transmission_per_mode():
    air = np.array([3.0, 1.0, 2.0, 2.5], np.float32)
    # Single-mode broadcast: the PS transmits once -> max, not sum.
    assert LAT.broadcast_airtime(air) == pytest.approx(3.0)
    # Mixed modes: one transmission per distinct mode (per-mode max).
    modes = np.array([0, 1, 1, 0])
    assert LAT.broadcast_airtime(air, modes) == pytest.approx(3.0 + 2.0)
    assert LAT.broadcast_airtime(np.zeros((0,))) == 0.0


# ----------------------------------------------------------------- policy


def test_downlink_mode_uses_policy_table_at_shifted_csi():
    pc = P.PolicyConfig()  # thresholds (6, 16, 26)
    est = jnp.array([0.0, 5.0, 15.0, 25.0])
    np.testing.assert_array_equal(
        np.asarray(P.downlink_mode(est, pc)), [0, 0, 1, 2])
    # +3 dB downlink offset pushes each client over its next threshold.
    np.testing.assert_array_equal(
        np.asarray(P.downlink_mode(est, pc, snr_offset_db=3.0)), [0, 1, 2, 3])


# ----------------------------------------------------------- FL integration


@pytest.fixture(scope="module")
def fl_world():
    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


CFG = dataclasses.replace(cnn_config(), lr=0.1)
TCFG = T.TransportConfig(mode="approx", channel=CH.ChannelConfig(snr_db=10.0))


def test_run_fl_driverless_downlink_smoke(fl_world):
    """Driver-less noisy downlink: telemetry records appear, airtime grows
    by the broadcast leg, and the run stays finite."""
    cx, cy, ti, tl = fl_world
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=1)
    clean = run_fl(CFG, TCFG, cx, cy, ti, tl, **kw)
    noisy = run_fl(CFG, TCFG, cx, cy, ti, tl,
                   downlink=S.DownlinkConfig(mode="approx"), **kw)
    assert clean.link == []
    assert len(noisy.link) == 3
    for rec in noisy.link:
        assert rec["downlink_airtime_s"] > 0.0
        assert 0.0 <= rec["downlink_ber"] < 0.5
    assert noisy.airtime_s[-1] > clean.airtime_s[-1]
    assert np.isfinite(noisy.final_accuracy)


def test_run_fl_perfect_downlink_is_bitwise_noop(fl_world):
    """An explicitly error-free downlink leg must reproduce downlink=None
    exactly: the broadcast is the identity and the uplink keys are on a
    disjoint fold_in lane."""
    cx, cy, ti, tl = fl_world
    kw = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=2)
    a = run_fl(CFG, TCFG, cx, cy, ti, tl, **kw)
    b = run_fl(CFG, TCFG, cx, cy, ti, tl,
               downlink=S.DownlinkConfig(mode="perfect"), **kw)
    assert a.accuracy == b.accuracy
    # perfect broadcast still costs airtime (the PS transmits the model)
    assert b.airtime_s[-1] > a.airtime_s[-1]


def test_ecrt_downlink_prices_analytically_at_shifted_snr(fl_world,
                                                          monkeypatch):
    """Regression: an ECRT downlink must never trace the real LDPC decoder
    inside the round, and its analytic E[tx] must be calibrated at the
    *downlink's* operating point (uplink SNR + offset), not the uplink's."""
    from repro.core import latency as LATmod
    from repro.fl import engine as engine_lib

    cx, cy, ti, tl = fl_world
    profile_snrs, calib_anchors = [], []

    def fake_profile(snr_vec, modulation, **kw):
        snr = np.asarray(snr_vec, np.float32).reshape(-1)
        profile_snrs.append(snr.copy())
        return np.full(snr.shape, 1.7, np.float32)

    def fake_calibrate(snr_db, modulation="qpsk", **kw):
        calib_anchors.append(float(snr_db))
        return 1.7

    monkeypatch.setattr(LATmod, "ecrt_expected_tx_profile", fake_profile)
    monkeypatch.setattr(LATmod, "calibrate_ecrt", fake_calibrate)

    # Driver-less: approx uplink at 10 dB + ECRT downlink at +5 dB.
    dl = S.DownlinkConfig(mode="ecrt", snr_offset_db=5.0)
    eng = engine_lib.RoundEngine(
        engine_lib.FedSGD(CFG, batch_per_round=8), TCFG, cx, cy, ti, tl,
        n_rounds=1, eval_every=1, downlink=dl)
    assert not eng.dl_cfg.simulate_fec  # no LDPC decode inside the round
    assert eng.dl_cfg.ecrt_expected_tx == pytest.approx(1.7)
    assert profile_snrs and np.allclose(profile_snrs[-1], 15.0)  # 10 + 5

    # Scenario: the anchor is the fleet operating point + offset.
    scen = S.get_scenario("vehicular")
    eng2 = engine_lib.RoundEngine(
        engine_lib.FedSGD(CFG, batch_per_round=8), TCFG, cx, cy, ti, tl,
        n_rounds=1, eval_every=1,
        scenario=dataclasses.replace(scen, ecrt_expected_tx=2.0),
        downlink=dl)
    assert not eng2.dl_cfg.simulate_fec
    assert calib_anchors[-1] == pytest.approx(
        scen.dynamics.mean_snr_db + 5.0)

    # And the leg stays exact: ECRT delivers bits error-free.
    res = eng.run()
    assert res.link[0]["downlink_ber"] == 0.0
    assert res.link[0]["downlink_airtime_s"] > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("preset", ["vehicular-noisy-dl", "static-noisy-dl"])
def test_scenario_downlink_presets_both_dispatches(fl_world, preset):
    """The downlink leg works across both dispatches on the registered
    noisy-downlink presets — and kernel-free tables stay bit-identical
    between bucketed and select, broadcast included."""
    cx, cy, ti, tl = fl_world
    scen = dataclasses.replace(S.get_scenario(preset), ecrt_expected_tx=2.0)
    assert scen.downlink is not None
    results = {}
    for disp in ("bucketed", "select"):
        res = run_fl(CFG, TCFG, cx, cy, ti, tl, n_rounds=3, batch_per_round=8,
                     eval_every=2, seed=4, scenario=scen,
                     adaptive_dispatch=disp)
        assert len(res.link) == 3
        for rec in res.link:
            assert rec["downlink_airtime_s"] > 0.0
            assert "downlink_ber" in rec
            if scen.downlink.adaptive:
                assert sum(rec["downlink_mode_counts"]) == 4
        results[disp] = res
    assert results["bucketed"].accuracy == results["select"].accuracy
    assert results["bucketed"].link == results["select"].link


@pytest.mark.slow
def test_fedavg_max_abs_scenario_bucketed_equals_select(fl_world):
    """FedAvg ``scale_mode="max_abs"`` under a scenario-driven *bucketed*
    dispatch (previously only exercised driver-less): the bucketed round
    must agree bit-for-bit with the fused select round on a kernel-free
    table — scaling, mixed-mode uplink, dropout-weighted aggregate and all."""
    cx, cy, ti, tl = fl_world
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0, dropout_prob=0.1)
    kw = dict(n_rounds=3, local_steps=2, batch_per_step=6, eval_every=1,
              seed=6, scale_mode="max_abs", scenario=scen)
    a = run_fedavg(CFG, TCFG, cx, cy, ti, tl, adaptive_dispatch="bucketed",
                   **kw)
    b = run_fedavg(CFG, TCFG, cx, cy, ti, tl, adaptive_dispatch="select",
                   **kw)
    assert a.accuracy == b.accuracy
    assert a.airtime_s == b.airtime_s
    assert a.link == b.link
    assert np.isfinite(a.final_accuracy)
