"""repro-lint: rule fixtures, suppressions, registry, and mutation checks.

Each rule gets a minimal fixture where it fires exactly once (and a clean
twin where it stays silent); the suppression comment grammar, the key-lane
registry's overlap rejection, and a mutation check — a seeded violation
injected into a copy of ``transport.py`` must be caught — pin the
framework's contract. The linter itself never imports jax, so these tests
run on the plain AST layer.
"""

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:  # tools/ is imported from the repo root
    sys.path.insert(0, str(REPO_ROOT))

from tools.lint.core import Module, gather_files, run_rules  # noqa: E402
from tools.lint.rules.benchschema import BenchSchemaRule  # noqa: E402
from tools.lint.rules.determinism import DeterminismRule  # noqa: E402
from tools.lint.rules.docstrings import DocstringRule  # noqa: E402
from tools.lint.rules.dtype import DtypeDisciplineRule  # noqa: E402
from tools.lint.rules.jitpurity import JitPurityRule  # noqa: E402
from tools.lint.rules.keylane import KeyLaneRule  # noqa: E402

from repro.core import keylanes  # noqa: E402

TRANSPORT = REPO_ROOT / "src" / "repro" / "core" / "transport.py"


def _mod(source, relpath="src/repro/core/fixture.py"):
    return Module(relpath, textwrap.dedent(source))


def _names(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------ rule: keylane


def test_keylane_fires_on_bare_integer():
    m = _mod("""\
        import jax

        def f(key):
            return jax.random.fold_in(key, 12345)
        """)
    fs = KeyLaneRule().check_module(m)
    assert _names(fs) == ["keylane"]
    assert "12345" in fs[0].message


def test_keylane_clean_on_registered_symbol():
    m = _mod("""\
        import jax
        from repro.core.keylanes import DOWNLINK_KEY_LANE, check_cohort

        def f(key, num_clients):
            check_cohort(DOWNLINK_KEY_LANE, num_clients)
            return [jax.random.fold_in(key, DOWNLINK_KEY_LANE + i)
                    for i in range(num_clients)]
        """)
    assert KeyLaneRule().check_module(m) == []


def test_keylane_unguarded_index_fires():
    m = _mod("""\
        import jax

        def f(key, i):
            return jax.random.fold_in(key, i)
        """)
    fs = KeyLaneRule().check_module(m)
    assert _names(fs) == ["keylane"]
    assert "guard" in fs[0].message


def test_keylane_constant_offset_outside_span_fires():
    m = _mod("""\
        import jax
        from repro.core.keylanes import HEADER_KEY_LANE

        def f(key):
            return jax.random.fold_in(key, HEADER_KEY_LANE + 1)
        """)
    fs = KeyLaneRule().check_module(m)
    assert _names(fs) == ["keylane"]
    assert "span" in fs[0].message


def test_keylane_two_symbols_fires():
    m = _mod("""\
        import jax
        from repro.core.keylanes import DOWNLINK_KEY_LANE, UPLINK_KEY_LANE

        def f(key):
            return jax.random.fold_in(
                key, DOWNLINK_KEY_LANE + UPLINK_KEY_LANE)
        """)
    fs = KeyLaneRule().check_module(m)
    assert _names(fs) == ["keylane"]


# -------------------------------------------------- rule: determinism


def test_determinism_fires_on_wall_clock():
    src = """\
        import time

        def f():
            return time.time()
        """
    fs = DeterminismRule().check_module(_mod(src, "src/repro/fl/x.py"))
    assert _names(fs) == ["determinism"]
    # the obs/ subtree is a whitelisted wall-clock consumer
    assert DeterminismRule().check_module(
        _mod(src, "src/repro/obs/x.py")) == []
    # out-of-scope paths are never checked
    assert DeterminismRule().check_module(_mod(src, "tools/x.py")) == []


def test_determinism_fires_on_stdlib_random():
    m = _mod("""\
        import random

        def f():
            return random.random()
        """, "src/repro/core/x.py")
    fs = DeterminismRule().check_module(m)
    assert _names(fs) == ["determinism"]


def test_determinism_seeded_rng_is_clean():
    m = _mod("""\
        import numpy as np

        def f():
            return np.random.default_rng(0).normal()
        """, "src/repro/core/x.py")
    assert DeterminismRule().check_module(m) == []


def test_determinism_unseeded_default_rng_fires():
    m = _mod("""\
        import numpy as np

        def f():
            return np.random.default_rng().normal()
        """, "src/repro/core/x.py")
    assert _names(DeterminismRule().check_module(m)) == ["determinism"]


# --------------------------------------------------- rule: jit-purity


def test_jitpurity_fires_on_print_in_decorated_fn():
    m = _mod("""\
        import jax

        @jax.jit
        def f(x):
            print(x)
            return x
        """)
    fs = JitPurityRule().check_module(m)
    assert _names(fs) == ["jit-purity"]


def test_jitpurity_resolves_wrapped_function():
    m = _mod("""\
        import jax

        def f(x):
            return float(x)

        g = jax.jit(f)
        """)
    fs = JitPurityRule().check_module(m)
    assert _names(fs) == ["jit-purity"]
    # the same body un-jitted is fine
    m2 = _mod("""\
        def f(x):
            return float(x)
        """)
    assert JitPurityRule().check_module(m2) == []


def test_jitpurity_fires_on_closure_mutation():
    m = _mod("""\
        import jax

        acc = []

        @jax.jit
        def f(x):
            acc.append(x)
            return x
        """)
    assert _names(JitPurityRule().check_module(m)) == ["jit-purity"]


# --------------------------------------------- rule: dtype-discipline


def test_dtype_fires_on_float64_in_wire_module():
    src = """\
        import numpy as np

        def f(x):
            return np.float64(x)
        """
    fs = DtypeDisciplineRule().check_module(
        _mod(src, "src/repro/core/modulation.py"))
    assert _names(fs) == ["dtype-discipline"]
    # the same source outside the wire-module list is not checked
    assert DtypeDisciplineRule().check_module(
        _mod(src, "src/repro/fl/engine.py")) == []


def test_dtype_fires_on_implied_float64_creation():
    m = _mod("""\
        import numpy as np

        def f():
            return np.zeros(4)
        """, "src/repro/core/modulation.py")
    fs = DtypeDisciplineRule().check_module(m)
    assert _names(fs) == ["dtype-discipline"]
    # with an explicit declared dtype it is clean
    m2 = _mod("""\
        import numpy as np

        def f():
            return np.zeros(4, dtype=np.float32)
        """, "src/repro/core/modulation.py")
    assert DtypeDisciplineRule().check_module(m2) == []


# -------------------------------------------------- rule: docstrings


def test_docstrings_fires_once_on_missing_function_docstring():
    m = _mod('''\
        """Module docstring present."""

        def documented():
            """Has one."""

        def naked():
            return 1
        ''', "src/repro/core/x.py")
    fs = DocstringRule().check_module(m)
    assert _names(fs) == ["docstrings"]
    assert "naked" in fs[0].message
    # private modules and ungated paths are skipped
    assert DocstringRule().check_module(
        _mod("x = 1", "src/repro/core/_private.py")) == []
    assert DocstringRule().check_module(
        _mod("x = 1", "src/repro/models/x.py")) == []


# ------------------------------------------------- rule: bench-schema


def test_bench_schema_fires_once_on_missing_meta_key(tmp_path):
    obj = {"snr_db": [], "clients": 1, "rounds": 1, "arms": {},
           "downlink_worse_than_uplink": True,
           "meta": {"schema": 1, "jax": "x", "numpy": "x", "python": "x",
                    "platform": "x", "backend": "cpu", "git_sha": "x"}}
    p = tmp_path / "BENCH_fl_round.json"
    p.write_text(json.dumps(obj))
    fs = BenchSchemaRule().check_paths([p])
    assert _names(fs) == ["bench-schema"]
    assert "timestamp" in fs[0].message
    # completing the meta block silences it
    obj["meta"]["timestamp"] = "now"
    p.write_text(json.dumps(obj))
    assert BenchSchemaRule().check_paths([p]) == []


# ------------------------------------------------------- suppressions


def test_trailing_suppression_comment(tmp_path):
    f = tmp_path / "src.py"
    f.write_text(textwrap.dedent("""\
        '''Doc.'''
        import jax


        def f(key):
            '''Doc.'''
            return jax.random.fold_in(key, 7)  # lint: ignore[keylane]
        """))
    findings, n_suppressed = run_rules([KeyLaneRule()], [f])
    assert findings == []
    assert n_suppressed == 1


def test_comment_only_line_suppresses_next_line(tmp_path):
    f = tmp_path / "src.py"
    f.write_text(textwrap.dedent("""\
        '''Doc.'''
        import jax


        def f(key):
            '''Doc.'''
            # a dedicated keyspace, not the lane table: lint: ignore[keylane]
            return jax.random.fold_in(key, 7)
        """))
    findings, n_suppressed = run_rules([KeyLaneRule()], [f])
    assert findings == []
    assert n_suppressed == 1


def test_suppression_is_rule_specific(tmp_path):
    f = tmp_path / "src.py"
    f.write_text(textwrap.dedent("""\
        '''Doc.'''
        import jax


        def f(key):
            '''Doc.'''
            return jax.random.fold_in(key, 7)  # lint: ignore[determinism]
        """))
    findings, n_suppressed = run_rules([KeyLaneRule()], [f])
    assert _names(findings) == ["keylane"]
    assert n_suppressed == 0


def test_syntax_error_becomes_parse_error_finding(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("def f(:\n")
    findings, _ = run_rules([KeyLaneRule()], [f])
    assert _names(findings) == ["parse-error"]


# ---------------------------------------------------- lane registry


def test_registry_rejects_overlap():
    r = keylanes.Registry()
    r.reserve("a", base=0, span=16)
    with pytest.raises(ValueError, match="overlaps"):
        r.reserve("b", base=15, span=4)
    # adjacent is fine; same range in another space is fine
    r.reserve("c", base=16, span=4)
    r.reserve("d", base=0, span=16, space="client")


def test_registry_rejects_duplicate_name_and_bad_lane():
    r = keylanes.Registry()
    r.reserve("a", base=0, span=1)
    with pytest.raises(ValueError, match="already reserved"):
        r.reserve("a", base=100, span=1)
    with pytest.raises(ValueError, match="span"):
        r.reserve("b", base=0, span=0)


def test_canonical_lane_values_are_pinned():
    # the goldens pin these integers: renumbering is a breaking change
    assert int(keylanes.UPLINK_KEY_LANE) == 0
    assert int(keylanes.DOWNLINK_KEY_LANE) == 1 << 20
    assert int(keylanes.COMPUTE_KEY_LANE) == 1 << 22
    assert int(keylanes.EVENT_KEY_LANE) == 3 << 21
    assert int(keylanes.EVENT_GAP_KEY_LANE) == (3 << 21) + (1 << 20)
    assert int(keylanes.CHUNK_KEY_LANE) == 0
    assert int(keylanes.HEADER_KEY_LANE) == 1 << 21
    assert int(keylanes.SELECT_KEY_LANE) == (1 << 21) + 1


def test_owner_modules_reexport_the_same_objects():
    from repro.compress import framing, sparsify
    from repro.core import transport
    from repro.link import dynamics

    assert transport.DOWNLINK_KEY_LANE is keylanes.DOWNLINK_KEY_LANE
    assert framing.HEADER_KEY_LANE is keylanes.HEADER_KEY_LANE
    assert sparsify.SELECT_KEY_LANE is keylanes.SELECT_KEY_LANE
    assert dynamics.COMPUTE_KEY_LANE is keylanes.COMPUTE_KEY_LANE
    assert dynamics.EVENT_KEY_LANE is keylanes.EVENT_KEY_LANE
    assert dynamics.EVENT_GAP_KEY_LANE is keylanes.EVENT_GAP_KEY_LANE


def test_check_cohort_boundaries():
    lane = keylanes.DOWNLINK_KEY_LANE
    keylanes.check_cohort(lane, 1)
    keylanes.check_cohort(lane, lane.span)  # exactly the lane width: OK
    with pytest.raises(ValueError, match="num_clients"):
        keylanes.check_cohort(lane, lane.span + 1)
    with pytest.raises(ValueError, match="num_clients"):
        keylanes.check_cohort(lane, 0)


def test_check_range_boundaries():
    keylanes.check_range(0, 1 << 20)  # the whole uplink lane
    keylanes.check_range(1 << 20, 1 << 20)  # the whole downlink lane
    with pytest.raises(ValueError, match="lane"):
        keylanes.check_range(0, (1 << 20) + 1)  # crosses uplink->downlink
    with pytest.raises(ValueError, match="lane"):
        keylanes.check_range(17, 1, space="nonexistent")
    keylanes.check_range(object(), 10)  # traced/opaque offsets skip


# ---------------------------------------------------- mutation check


def test_mutated_transport_is_caught():
    source = TRANSPORT.read_text()
    rel = "src/repro/core/transport.py"
    baseline = KeyLaneRule().check_module(Module(rel, source))
    assert baseline == [], [f.format() for f in baseline]
    mutated = source + textwrap.dedent("""\


        def _mutant(key):
            return jax.random.fold_in(key, 12345)
        """)
    fs = KeyLaneRule().check_module(Module(rel, mutated))
    assert _names(fs) == ["keylane"]
    assert "12345" in fs[0].message


def test_mutated_unguarded_index_is_caught():
    source = TRANSPORT.read_text()
    rel = "src/repro/core/transport.py"
    mutated = source + textwrap.dedent("""\


        def _mutant(key, i):
            return jax.random.fold_in(key, DOWNLINK_KEY_LANE + i)
        """)
    fs = KeyLaneRule().check_module(Module(rel, mutated))
    assert _names(fs) == ["keylane"]
    assert "guard" in fs[0].message


# ------------------------------------------------------------- CLI


def test_cli_clean_and_dirty_exit_codes(tmp_path):
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "ok.py").write_text('"""Doc."""\nX = 1\n')
    dirty = tmp_path / "dirty"
    dirty.mkdir()
    (dirty / "bad.py").write_text(
        '"""Doc."""\nimport jax\nK = jax.random.fold_in(0, 99)\n')

    def lint(*args):
        return subprocess.run(
            [sys.executable, "-m", "tools.lint", *args],
            cwd=REPO_ROOT, capture_output=True, text=True)

    r = lint(str(clean))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
    r = lint(str(dirty))
    assert r.returncode == 1
    assert "[keylane]" in r.stdout
    r = lint("--format", "json", str(dirty))
    obj = json.loads(r.stdout)
    assert obj["ok"] is False
    assert obj["findings"][0]["rule"] == "keylane"
    r = lint("--rules", "nope", str(clean))
    assert r.returncode == 2


def test_gather_files_skips_pycache_and_hidden(tmp_path):
    (tmp_path / "a.py").write_text("")
    (tmp_path / "__pycache__").mkdir()
    (tmp_path / "__pycache__" / "a.cpython-311.pyc").write_text("")
    (tmp_path / ".hidden").mkdir()
    (tmp_path / ".hidden" / "b.py").write_text("")
    (tmp_path / "BENCH_x.json").write_text("{}")
    files = gather_files([tmp_path])
    names = {f.name for f in files}
    assert names == {"a.py", "BENCH_x.json"}
