"""Gates for the observability layer (``repro.obs``) across both engines.

Three invariants, in order of load-bearing-ness:

1. **Sinks are neutral.** Attaching a ledger / trace recorder / phase
   timers must not change a single numeric result — same accuracy
   trajectory, same airtime, same per-round telemetry dicts, bit for bit.
   The engines compute nothing extra for the sinks except the ``uplink_*``
   aggregates, which are derived (device->host reads) after the round's
   arithmetic is already fixed.

2. **Records ARE the telemetry.** ``FLResult.link`` is now a dict *view*
   of the typed ``RoundRecord`` list (``to_link_dict`` with the exact
   historical key order), and the pre-engine golden loop still matches the
   instrumented engine — the record refactor changed representation, not
   values.

3. **The ledger round-trips.** ``read_ledger`` on the JSONL file
   reproduces ``FLResult.link`` exactly (JSON float serialization is
   shortest-round-trip, so equality is bit-level), ``validate_ledger``
   passes on real ledgers and fails on broken ones, and the Chrome trace
   is loadable JSON with the required track types.

Runs are kept tiny (4 clients x 24 samples, 3-4 rounds) but cover the
arms the ISSUE names: scenario, compression, downlink, and buffered.
"""

import dataclasses
import json

import golden_pre_engine as golden
import pytest

from repro.compress.sparsify import CompressionConfig
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.async_engine import run_fl_buffered
from repro.fl.loop import run_fl
from repro.link import scenario as S
from repro.obs import PhaseTimers, TraceRecorder
from repro.obs import ledger as L
from repro.obs import records as R
from repro.obs import timers as timers_lib


@pytest.fixture(scope="module")
def world():
    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(cnn_config(), lr=0.1)


def _tc():
    return T.TransportConfig(mode="approx",
                             channel=CH.ChannelConfig(snr_db=10.0))


def _scenario(**over):
    # Explicit ecrt_expected_tx skips LDPC calibration (fast); downlink and
    # compression arms layer onto the same vehicular dynamics.
    base = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0)
    return dataclasses.replace(base, **over) if over else base


_KW = dict(n_rounds=4, batch_per_round=8, eval_every=2, seed=3)


def _full_arm_kw():
    """The all-subsystems sync arm: scenario + noisy downlink + top-k."""
    scen = _scenario(downlink=S.DownlinkConfig(mode="approx",
                                               snr_offset_db=-3.0,
                                               adaptive=True))
    return dict(_KW, scenario=scen,
                compression=CompressionConfig(method="topk", ratio=0.1))


@pytest.fixture(scope="module")
def sync_pair(cfg, world, tmp_path_factory):
    """(instrumented run, bare twin, ledger path, timers) for the full
    scenario+downlink+compression sync arm."""
    cx, cy, ti, tl = world
    path = str(tmp_path_factory.mktemp("obs") / "sync.jsonl")
    timers = PhaseTimers()
    kw = _full_arm_kw()
    res = run_fl(cfg, _tc(), cx, cy, ti, tl, ledger=path,
                 phase_timers=timers, **kw)
    bare = run_fl(cfg, _tc(), cx, cy, ti, tl, **kw)
    return res, bare, path, timers


@pytest.fixture(scope="module")
def async_pair(cfg, world, tmp_path_factory):
    """(instrumented run, bare twin, ledger path, trace, timers) for the
    buffered metro-rush arm (compute-time skew => real event traffic)."""
    cx, cy, ti, tl = world
    tmp = tmp_path_factory.mktemp("obs_async")
    path = str(tmp / "async.jsonl")
    trace = TraceRecorder(str(tmp / "trace.json"))
    timers = PhaseTimers()
    scen = dataclasses.replace(S.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(_KW, scenario=scen, buffer_k=2, staleness="polynomial")
    res = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, ledger=path,
                          trace=trace, phase_timers=timers, **kw)
    bare = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **kw)
    return res, bare, path, trace, timers


# -------------------------------------------------------------------------
# 1. Observer neutrality
# -------------------------------------------------------------------------


def test_sync_sinks_are_neutral(sync_pair):
    res, bare, _, _ = sync_pair
    assert res.rounds == bare.rounds
    assert res.accuracy == bare.accuracy  # exact float equality intended
    assert res.airtime_s == bare.airtime_s
    assert res.final_accuracy == bare.final_accuracy
    assert res.link == bare.link


def test_async_sinks_are_neutral(async_pair):
    res, bare, _, _, _ = async_pair
    assert res.accuracy == bare.accuracy
    assert res.airtime_s == bare.airtime_s
    assert res.event_s == bare.event_s
    assert res.link == bare.link


# -------------------------------------------------------------------------
# 2. Records are the telemetry (golden link-view equivalence)
# -------------------------------------------------------------------------


def test_link_is_record_view(sync_pair, async_pair):
    """``FLResult.link`` must be exactly the ``to_link_dict`` view of the
    typed records, in order, across the full sync arm and the buffered
    arm (compression + downlink keys included)."""
    for res in (sync_pair[0], async_pair[0]):
        assert len(res.records) == len(res.link)
        assert [r.to_link_dict() for r in res.records] == res.link
    # The full sync arm carries all three optional field families.
    top = sync_pair[0].link[0]
    for key in ("comp_ratio", "downlink_airtime_s", "mode_counts"):
        assert key in top


def test_scenario_link_matches_pre_engine_golden(cfg, world, tmp_path):
    """Instrumented engine vs the frozen pre-engine loop: the record
    refactor (and an attached ledger) must not move the telemetry."""
    cx, cy, ti, tl = world
    kw = dict(_KW, scenario=_scenario())
    res = run_fl(cfg, _tc(), cx, cy, ti, tl,
                 ledger=str(tmp_path / "g.jsonl"), **kw)
    ref = golden.golden_run_fl(cfg, _tc(), cx, cy, ti, tl, **kw)
    assert res.accuracy == ref.accuracy
    assert res.airtime_s == ref.airtime_s
    assert res.link == ref.link


def test_driverless_run_has_records_but_no_link(cfg, world, tmp_path):
    """Driver-less runs never emitted link dicts; the record list still
    exists (one per round) but carries no link fields."""
    cx, cy, ti, tl = world
    res = run_fl(cfg, _tc(), cx, cy, ti, tl,
                 ledger=str(tmp_path / "d.jsonl"), **_KW)
    assert res.link == []
    assert len(res.records) == _KW["n_rounds"]
    assert not any(r.has_link_fields() for r in res.records)


def test_record_dict_roundtrip(sync_pair, async_pair):
    for res in (sync_pair[0], async_pair[0]):
        for rec in res.records:
            assert R.RoundRecord.from_dict(rec.to_dict()) == rec
    ev = R.EventRecord(t=1.5, kind="compute", wave=2, client=7, dur=0.25)
    assert R.EventRecord.from_dict(ev.to_dict()) == ev
    with pytest.raises(ValueError):
        R.EventRecord(t=0.0, kind="not-a-kind")


# -------------------------------------------------------------------------
# 3. Ledger round-trip + schema
# -------------------------------------------------------------------------


def test_ledger_roundtrips_link(sync_pair, async_pair):
    for res, path in ((sync_pair[0], sync_pair[2]),
                      (async_pair[0], async_pair[2])):
        assert L.validate_ledger(path) == []
        data = L.read_ledger(path)
        assert data.link == res.link  # bit-exact through JSON
        assert len(data.rounds) == len(res.records)
        assert [ev["accuracy"] for ev in data.evals] == res.accuracy


def test_manifest_contents(sync_pair, async_pair):
    sync = L.read_ledger(sync_pair[2]).manifest
    asy = L.read_ledger(async_pair[2]).manifest
    for man in (sync, asy):
        for key in L.MANIFEST_KEYS:
            if key != "kind":  # read_ledger strips the line discriminator
                assert key in man
        for key in L.PROVENANCE_KEYS:
            assert key in man["provenance"]
        assert man["seed"] == _KW["seed"]
    assert sync["engine"] == "sync"
    assert asy["engine"] == "async"
    assert asy["buffer_k"] == 2
    # Different engine configs must not collide on the join key.
    assert sync["fingerprint"] != asy["fingerprint"]


def test_async_ledger_has_events(async_pair):
    data = L.read_ledger(async_pair[2])
    kinds = {ev.kind for ev in data.events}
    for kind in ("wave", "compute", "uplink", "arrival", "aggregate",
                 "buffer"):
        assert kind in kinds
    # Summary carries the run outcome + the phase table.
    assert data.summary["final_accuracy"] == async_pair[0].final_accuracy
    assert "phases" in data.summary


def test_config_fingerprint_is_stable():
    a = L.config_fingerprint(_tc(), _scenario(), 4, "seed", 3)
    b = L.config_fingerprint(_tc(), _scenario(), 4, "seed", 3)
    c = L.config_fingerprint(_tc(), _scenario(), 4, "seed", 4)
    assert a == b
    assert a != c
    assert len(a) == 12


def test_validate_ledger_failure_modes(tmp_path):
    # Missing manifest keys.
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"kind": "manifest", "schema": 1}) + "\n")
    assert any("manifest" in msg for msg in L.validate_ledger(str(p)))
    # First line is not a manifest at all.
    p.write_text(json.dumps({"kind": "round", "round": 0}) + "\n")
    assert L.validate_ledger(str(p)) != []
    # Torn final line (crashed run) must not break reading: every complete
    # record before the tear is preserved.
    good = tmp_path / "torn.jsonl"
    lines = [json.dumps({"kind": "manifest", "schema": 1,
                         "fingerprint": "x", "engine": "sync",
                         "algorithm": "a", "n_rounds": 1,
                         "num_clients": 1, "seed": 0,
                         "provenance": {k: None
                                        for k in L.PROVENANCE_KEYS}}),
             json.dumps({"kind": "round", "round": 0}),
             '{"kind": "round", "rou']
    good.write_text("\n".join(lines))
    data = L.read_ledger(str(good))
    assert len(data.rounds) == 1


# -------------------------------------------------------------------------
# Trace + timers
# -------------------------------------------------------------------------


def test_trace_is_loadable_chrome_json(async_pair):
    trace = async_pair[3]
    tracks = trace.track_types()
    assert len(tracks) >= 4, f"only {sorted(tracks)}"
    with open(trace.path) as f:
        chrome = json.load(f)
    evs = chrome["traceEvents"]
    assert evs
    # Metadata names the process tracks; spans are complete ('X') events
    # with microsecond timestamps.
    phases = {e["ph"] for e in evs}
    assert "M" in phases and "X" in phases
    assert all(e["ts"] >= 0 for e in evs if e["ph"] != "M")


def test_phase_timers_split_first_call(async_pair):
    timers = async_pair[4]
    summary = timers.summary()
    for phase in ("sample", "wave", "telemetry", "eval"):
        assert phase in summary
        assert summary[phase]["calls"] >= 1
    wave = summary["wave"]
    # First call includes jit compilation; it must be excluded from the
    # steady-state median (calls counts every scope entry).
    assert wave["first_s"] >= wave["steady_median_s"]
    assert wave["total_s"] >= wave["first_s"]


def test_phase_timers_unit():
    tm = PhaseTimers()
    with tm.scope("p"):
        pass
    assert tm.summary()["p"]["calls"] == 1
    assert "p" in tm.report()
    # Deterministic durations straight through the accumulator.
    stat = timers_lib.PhaseStat("q")
    for dt in (5.0, 1.0, 2.0, 3.0):
        stat.record(dt)
    assert stat.calls == 4
    assert stat.first_s == 5.0
    assert stat.steady_median_s() == 2.0
    assert stat.total_s == 11.0
    # The null sink records nothing and resolve_timers passes real ones
    # through untouched.
    with timers_lib.NULL_TIMERS.scope("x"):
        pass
    assert timers_lib.NULL_TIMERS.summary() == {}
    assert timers_lib.resolve_timers(tm) is tm
    assert timers_lib.resolve_timers(None) is timers_lib.NULL_TIMERS


# -------------------------------------------------------------------------
# Tooling satellites: bench schema validator + report CLI + timeit split
# -------------------------------------------------------------------------


def test_bench_schema_validator(tmp_path):
    from tools import bench_schema

    meta = {k: "x" for k in bench_schema.META_KEYS}
    good = {"snr_db": 10, "clients": 4, "rounds": 3, "arms": {},
            "downlink_worse_than_uplink": True, "meta": meta}
    p = tmp_path / "BENCH_fl_round.json"
    p.write_text(json.dumps(good))
    assert bench_schema.validate_file(p) == []
    # Missing + unexpected keys are both named.
    bad = dict(good)
    del bad["arms"]
    bad["extra"] = 1
    p.write_text(json.dumps(bad))
    msgs = "\n".join(bench_schema.validate_file(p))
    assert "'arms'" in msgs and "'extra'" in msgs
    # Incomplete meta provenance.
    weak = dict(good, meta={"jax": "x"})
    p.write_text(json.dumps(weak))
    assert bench_schema.validate_file(p) != []
    # Unknown artifacts are an error (schema drift must be registered).
    q = tmp_path / "BENCH_mystery.json"
    q.write_text("{}")
    assert bench_schema.validate_file(q) != []


def test_report_cli_smoke(sync_pair, async_pair, capsys):
    from tools import report

    report.summarize(sync_pair[2])
    out = capsys.readouterr().out
    assert "fingerprint" in out and "mode histogram" in out
    assert "final accuracy" in out
    report.diff(sync_pair[2], async_pair[2])
    out = capsys.readouterr().out
    assert "DIFFER" in out and "final_accuracy" in out


def test_timeit_splits_first_call():
    from benchmarks import common

    calls = []
    t = common.timeit(lambda: calls.append(0), warmup=1, iters=3)
    assert isinstance(t, common.Timing)
    assert isinstance(t, float)  # drop-in for the old steady median
    assert t.first_us >= 0.0
    assert len(calls) == 1 + 3  # first+warmup share one call, then iters
