"""Degenerate-equivalence gate for the buffered asynchronous engine.

The load-bearing invariant of ``repro.fl.async_engine``: with simultaneous
arrivals (the default degenerate ``ComputeTimeConfig`` — every client's
compute time is exactly ``mean_s``, no churn), ``buffer_k`` equal to the
cohort size (the ``buffer_k=None`` default), and constant staleness
weights, every wave is one full synchronous round and the buffered engine
must be **bit-identical** to the synchronous ``RoundEngine`` — same
accuracy trajectory, same cumulative airtime, same per-round link/
compression/downlink telemetry — for FedSGD and FedAvg, driver-less and
scenario-driven, under both adaptive dispatches, with and without the
compressed uplink and the noisy downlink leg. Any change to the wave key
schedule, the member-mask plumbing, or the aggregation arithmetic shows up
here as a float mismatch.
"""

import dataclasses

import pytest

from repro.compress.sparsify import CompressionConfig
from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.async_engine import run_fedavg_buffered, run_fl_buffered
from repro.fl.fedavg import run_fedavg
from repro.fl.loop import run_fl
from repro.link import scenario as S


@pytest.fixture(scope="module")
def world():
    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(cnn_config(), lr=0.1)


def _tc():
    return T.TransportConfig(mode="approx",
                             channel=CH.ChannelConfig(snr_db=10.0))


def _scenario():
    # Explicit ecrt_expected_tx skips LDPC calibration; dropout exercises
    # the buffer's drain-flush path (dropped clients never arrive, so the
    # wave aggregates short of buffer_k — exactly the weighted sync round).
    return dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0, dropout_prob=0.1)


def assert_identical(a, b):
    """Bit-exact FLResult comparison (everything but wall-clock time)."""
    assert a.rounds == b.rounds
    assert a.accuracy == b.accuracy  # float lists: exact equality intended
    assert a.airtime_s == b.airtime_s
    assert a.final_accuracy == b.final_accuracy
    assert a.link == b.link  # per-round telemetry dicts, exact
    # The sync engine has no event clock; the async one must have one
    # timestamp per eval point.
    assert a.event_s == []
    assert len(b.event_s) == len(b.rounds)


KW = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=3)
AKW = dict(n_rounds=3, local_steps=2, batch_per_step=6,
           scale_mode="max_abs", eval_every=2, seed=5)


def test_fedsgd_driverless_degenerate_is_sync(cfg, world):
    cx, cy, ti, tl = world
    assert_identical(run_fl(cfg, _tc(), cx, cy, ti, tl, **KW),
                     run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **KW))


def test_fedavg_driverless_degenerate_is_sync(cfg, world):
    cx, cy, ti, tl = world
    tc = T.TransportConfig(mode="ecrt", channel=CH.ChannelConfig(snr_db=6.0),
                           simulate_fec=False, ecrt_expected_tx=1.3)
    assert_identical(run_fedavg(cfg, tc, cx, cy, ti, tl, **AKW),
                     run_fedavg_buffered(cfg, tc, cx, cy, ti, tl, **AKW))


@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_fedsgd_scenario_degenerate_is_sync(cfg, world, dispatch):
    cx, cy, ti, tl = world
    kw = dict(scenario=_scenario(), adaptive_dispatch=dispatch, **KW)
    assert_identical(run_fl(cfg, _tc(), cx, cy, ti, tl, **kw),
                     run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **kw))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_fedavg_scenario_degenerate_is_sync(cfg, world, dispatch):
    cx, cy, ti, tl = world
    kw = dict(scenario=_scenario(), adaptive_dispatch=dispatch, **AKW)
    assert_identical(run_fedavg(cfg, _tc(), cx, cy, ti, tl, **kw),
                     run_fedavg_buffered(cfg, _tc(), cx, cy, ti, tl, **kw))


def test_compressed_driverless_degenerate_is_sync(cfg, world):
    """EF residual state must thread through the wave functions without
    perturbing the degenerate schedule."""
    cx, cy, ti, tl = world
    comp = CompressionConfig(method="topk", ratio=0.25)
    assert_identical(
        run_fl(cfg, _tc(), cx, cy, ti, tl, compression=comp, **KW),
        run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, compression=comp, **KW))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_compressed_scenario_degenerate_is_sync(cfg, world, dispatch):
    """Member-masked EF (``active = member * rnd.active``) must reduce to
    the synchronous dropout-masked EF when every client is a member."""
    cx, cy, ti, tl = world
    comp = CompressionConfig(method="randk", ratio=0.25)
    kw = dict(scenario=_scenario(), adaptive_dispatch=dispatch,
              compression=comp, **KW)
    assert_identical(run_fl(cfg, _tc(), cx, cy, ti, tl, **kw),
                     run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **kw))


def test_downlink_driverless_degenerate_is_sync(cfg, world):
    cx, cy, ti, tl = world
    dl = S.DownlinkConfig(mode="approx", snr_offset_db=6.0)
    assert_identical(
        run_fl(cfg, _tc(), cx, cy, ti, tl, downlink=dl, **KW),
        run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, downlink=dl, **KW))


@pytest.mark.slow
@pytest.mark.parametrize("dispatch", ["bucketed", "select"])
def test_downlink_scenario_degenerate_is_sync(cfg, world, dispatch):
    """The adaptive broadcast leg (CSI-picked downlink modes) rides the
    same wave key and must not disturb the degenerate schedule."""
    cx, cy, ti, tl = world
    dl = S.DownlinkConfig(mode="approx", snr_offset_db=6.0, adaptive=True)
    kw = dict(scenario=_scenario(), adaptive_dispatch=dispatch,
              downlink=dl, **KW)
    assert_identical(run_fl(cfg, _tc(), cx, cy, ti, tl, **kw),
                     run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **kw))


def test_explicit_buffer_k_equal_cohort_matches_default(cfg, world):
    """``buffer_k=M`` spelled explicitly is the same engine as the
    ``None`` default."""
    cx, cy, ti, tl = world
    a = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, buffer_k=4, **KW)
    b = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **KW)
    assert a.accuracy == b.accuracy
    assert a.airtime_s == b.airtime_s
    assert a.event_s == b.event_s


def test_small_buffer_diverges_from_sync(cfg, world):
    """Sanity check that the gate can fail: K < cohort under per-client
    airtime spread actually changes the trajectory (otherwise the
    equivalence assertions above would be vacuous)."""
    cx, cy, ti, tl = world
    s = run_fl(cfg, _tc(), cx, cy, ti, tl, **KW)
    b = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, buffer_k=1, **KW)
    assert b.rounds == s.rounds
    assert b.accuracy != s.accuracy
